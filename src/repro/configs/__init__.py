"""Architecture registry: one module per assigned architecture.

``get_config(name)`` returns the full-size :class:`ModelConfig`;
``get_config(name, reduced=True)`` the CPU smoke-test variant.
"""

from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.config import ModelConfig

ARCH_IDS: List[str] = [
    "llama4_maverick_400b_a17b",
    "zamba2_1p2b",
    "chatglm3_6b",
    "whisper_tiny",
    "qwen2_moe_a2p7b",
    "minitron_8b",
    "qwen2_vl_2b",
    "gemma_2b",
    "mamba2_2p7b",
    "starcoder2_15b",
]

# the hyphenated public ids map to module names
ALIASES: Dict[str, str] = {
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "zamba2-1.2b": "zamba2_1p2b",
    "chatglm3-6b": "chatglm3_6b",
    "whisper-tiny": "whisper_tiny",
    "qwen2-moe-a2.7b": "qwen2_moe_a2p7b",
    "minitron-8b": "minitron_8b",
    "qwen2-vl-2b": "qwen2_vl_2b",
    "gemma-2b": "gemma_2b",
    "mamba2-2.7b": "mamba2_2p7b",
    "starcoder2-15b": "starcoder2_15b",
}

PUBLIC_IDS = list(ALIASES)


def get_config(name: str, *, reduced: bool = False) -> ModelConfig:
    mod_name = ALIASES.get(name, name.replace("-", "_").replace(".", "p"))
    if mod_name not in ARCH_IDS:
        raise KeyError(f"unknown architecture {name!r}; known: {PUBLIC_IDS}")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    cfg: ModelConfig = mod.CONFIG
    return cfg.reduced() if reduced else cfg


def all_configs(*, reduced: bool = False) -> Dict[str, ModelConfig]:
    return {pub: get_config(pub, reduced=reduced) for pub in PUBLIC_IDS}
