"""zamba2-1.2b — hybrid Mamba2 + shared (weight-tied) attention blocks.

[arXiv:2411.15242 — 38 Mamba2 layers, d_model=2048, a single SHARED
attention+MLP block invoked periodically (weight-tied), ssm_state=64,
32 heads (kv=32 — full MHA in the shared block), d_ff=8192, vocab=32000.]

Stack: 6 x (6 mamba + 1 shared_attn) + 2 trailing mamba = 38 mamba
layers with 6 tied-attention invocations.
"""

from repro.models.config import BlockGroup, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    d_model=2048,
    num_layers=38,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=32000,
    head_dim=64,
    groups=(
        BlockGroup(("mamba",) * 6 + ("shared_attn",), 6),
        BlockGroup(("mamba",), 2),
    ),
    rope="standard",
    mlp_act="gelu",
    ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, conv_width=4, chunk_len=64),
    citation="arXiv:2411.15242",
)
