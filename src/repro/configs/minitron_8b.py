"""minitron-8b — width/depth-pruned Nemotron-4.

[arXiv:2407.14679 — 32L, d_model=4096, 48->32 heads GQA kv=8,
d_ff=16384, vocab=256000.]
"""

from repro.models.config import BlockGroup, ModelConfig

CONFIG = ModelConfig(
    name="minitron-8b",
    family="dense",
    d_model=4096,
    num_layers=32,
    num_heads=32,
    num_kv_heads=8,
    d_ff=16384,
    vocab_size=256000,
    groups=(BlockGroup(("dense",), 32),),
    rope="standard",
    mlp_act="silu",
    citation="arXiv:2407.14679",
)
