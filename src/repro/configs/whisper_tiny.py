"""whisper-tiny — encoder-decoder audio model (backbone only).

[arXiv:2212.04356 — 4 encoder + 4 decoder layers, d_model=384,
6 heads (MHA), d_ff=1536 (plain GELU MLP), vocab=51865, learned
absolute positions, 1500 mel frames after the conv frontend.]

The mel-spectrogram + conv feature extractor is a STUB (the allowed
carve-out): ``input_specs`` provides pre-computed (B, 1500, 384) frame
embeddings. long_500k is SKIPPED for this arch (DESIGN.md §Skips).
"""

from repro.models.config import BlockGroup, ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    d_model=384,
    num_layers=4,  # decoder layers; encoder declared separately
    num_heads=6,
    num_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,
    head_dim=64,
    groups=(BlockGroup(("encdec",), 4),),
    rope="none",  # whisper uses learned absolute positions
    mlp_act="gelu",
    encoder_layers=4,
    encoder_seq_len=1500,
    max_seq_len=32768,  # backbone carve-out: decode_32k needs 32k positions
    citation="arXiv:2212.04356",
)
