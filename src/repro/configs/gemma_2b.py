"""gemma-2b — GeGLU, head_dim=256, MQA (kv=1), tied embeddings.

[arXiv:2403.08295 — 18L, d_model=2048, 8 heads x head_dim 256,
d_ff=16384 (GeGLU), vocab=256000, embeddings scaled by sqrt(d_model).]
"""

from repro.models.config import BlockGroup, ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b",
    family="dense",
    d_model=2048,
    num_layers=18,
    num_heads=8,
    num_kv_heads=1,
    d_ff=16384,
    vocab_size=256000,
    head_dim=256,
    groups=(BlockGroup(("dense",), 18),),
    rope="standard",
    mlp_act="geglu",
    tie_embeddings=True,
    citation="arXiv:2403.08295",
)
