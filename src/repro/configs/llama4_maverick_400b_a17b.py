"""llama4-maverick-400b-a17b — MoE, early fusion.

[hf:meta-llama/Llama-4-Scout-17B-16E (family model card); Maverick:
128 routed experts, top-1 routing + 1 shared expert, MoE every other
layer (interleave=2), 48L, d_model=5120, 40 heads GQA kv=8,
dense d_ff=8192, vocab=202048 -> ~400B total / ~17B active params.]
"""

from repro.models.config import BlockGroup, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    d_model=5120,
    num_layers=48,
    num_heads=40,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    groups=(BlockGroup(("dense", "moe"), 24),),
    rope="standard",
    rope_theta=500000.0,
    mlp_act="silu",
    moe=MoEConfig(
        num_experts=128,
        top_k=1,
        expert_d_ff=8192,
        num_shared_experts=1,
        shared_d_ff=8192,
    ),
    citation="hf:meta-llama/Llama-4-Scout-17B-16E",
)
