"""starcoder2-15b — dense GQA code model.

[arXiv:2402.19173 — 40L, d_model=6144, 48 heads GQA kv=4, d_ff=24576,
vocab=49152, RoPE.]
"""

from repro.models.config import BlockGroup, ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b",
    family="dense",
    d_model=6144,
    num_layers=40,
    num_heads=48,
    num_kv_heads=4,
    d_ff=24576,
    vocab_size=49152,
    groups=(BlockGroup(("dense",), 40),),
    rope="standard",
    mlp_act="gelu",
    citation="arXiv:2402.19173",
)
