"""mamba2-2.7b — attention-free SSD (state-space duality).

[arXiv:2405.21060 — 64L, d_model=2560, d_inner=5120 (expand=2),
head_dim P=64 (80 heads), ssm_state N=128, conv_width=4, vocab=50280,
no MLP blocks (d_ff=0).]

long_500k runs natively (linear-time scan, O(1) decode state).
"""

from repro.models.config import BlockGroup, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    d_model=2560,
    num_layers=64,
    num_heads=1,  # attention-free; unused
    num_kv_heads=1,
    d_ff=0,
    vocab_size=50280,
    groups=(BlockGroup(("mamba",), 64),),
    rope="none",
    mlp_act="gelu",
    ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, conv_width=4, chunk_len=64),
    citation="arXiv:2405.21060",
)
