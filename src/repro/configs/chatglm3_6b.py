"""chatglm3-6b — dense, 2d-RoPE (rotary on half the head dim), GQA kv=2.

[arXiv:2406.12793 — 28L, d_model=4096, 32 heads / 2 kv heads,
d_ff=13696 (SwiGLU), vocab=65024.]
"""

from repro.models.config import BlockGroup, ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b",
    family="dense",
    d_model=4096,
    num_layers=28,
    num_heads=32,
    num_kv_heads=2,
    d_ff=13696,
    vocab_size=65024,
    groups=(BlockGroup(("dense",), 28),),
    rope="2d",
    mlp_act="silu",
    citation="arXiv:2406.12793",
)
