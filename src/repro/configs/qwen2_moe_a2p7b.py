"""qwen2-moe-a2.7b — 60 routed experts top-4 + 4 shared experts.

[hf:Qwen/Qwen1.5-MoE-A2.7B — 24L, d_model=2048, 16 heads (MHA kv=16),
expert d_ff=1408, shared-expert intermediate 5632, vocab=151936.]
"""

from repro.models.config import BlockGroup, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    d_model=2048,
    num_layers=24,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    vocab_size=151936,
    groups=(BlockGroup(("moe",), 24),),
    rope="standard",
    mlp_act="silu",
    moe=MoEConfig(
        num_experts=60,
        top_k=4,
        expert_d_ff=1408,
        num_shared_experts=4,
        shared_d_ff=1408,  # 4 shared experts fused -> 5632 total intermediate
    ),
    citation="hf:Qwen/Qwen1.5-MoE-A2.7B",
)
