"""qwen2-vl-2b — VLM backbone with M-RoPE (3-section rotary).

[arXiv:2409.12191 — 28L, d_model=1536, 12 heads GQA kv=2, d_ff=8960,
vocab=151936, multimodal rotary (temporal/height/width sections),
dynamic-resolution ViT.]

The vision tower is a STUB (the allowed carve-out): ``input_specs``
provides pre-computed patch embeddings spliced over the first
``vision_tokens`` positions; M-RoPE position ids arrive as (3, B, S).
"""

from repro.models.config import BlockGroup, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    d_model=1536,
    num_layers=28,
    num_heads=12,
    num_kv_heads=2,
    d_ff=8960,
    vocab_size=151936,
    head_dim=128,
    groups=(BlockGroup(("dense",), 28),),
    rope="mrope",
    mlp_act="silu",
    vision_tokens=1024,  # stubbed dynamic-resolution patch budget
    citation="arXiv:2409.12191",
)
