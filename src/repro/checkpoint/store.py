"""Flat-npz pytree checkpointing.

Keys are '/'-joined tree paths; restore requires a template tree with
the same structure (shape/dtype checked).  Atomic via rename.  Suitable
for the CPU reproduction scale; a real multi-pod deployment would swap
in per-shard array serialization behind the same two functions.
"""

from __future__ import annotations

import os
import re
import tempfile
from typing import Any, Optional

import jax
import numpy as np

PyTree = Any


def _key_of_path(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def save_pytree(tree: PyTree, directory: str, step: int) -> str:
    os.makedirs(directory, exist_ok=True)
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        flat[_key_of_path(path)] = np.asarray(leaf)
    final = os.path.join(directory, f"step_{step:08d}.npz")
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".tmp")
    with os.fdopen(fd, "wb") as f:
        np.savez(f, **flat)
    os.rename(tmp, final)
    return final


def load_pytree(template: PyTree, directory: str, step: Optional[int] = None) -> PyTree:
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    path = os.path.join(directory, f"step_{step:08d}.npz")
    with np.load(path) as data:
        paths, treedef = jax.tree_util.tree_flatten_with_path(template)
        leaves = []
        for p, tmpl in paths:
            key = _key_of_path(p)
            if key not in data:
                raise KeyError(f"checkpoint missing {key}")
            arr = data[key]
            if tuple(arr.shape) != tuple(tmpl.shape):
                raise ValueError(f"{key}: shape {arr.shape} != template {tmpl.shape}")
            leaves.append(arr.astype(tmpl.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def load_flat(directory: str, step: Optional[int] = None) -> dict:
    """Template-free restore: the flat '/'-keyed mapping as saved.

    For consumers whose tree structure is data-dependent (e.g. a head
    registry whose retained versions are part of the state) and so
    cannot supply :func:`load_pytree`'s template up front.
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    path = os.path.join(directory, f"step_{step:08d}.npz")
    with np.load(path) as data:
        return {k: data[k] for k in data.files}


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(m.group(1))
        for f in os.listdir(directory)
        if (m := re.fullmatch(r"step_(\d{8})\.npz", f))
    ]
    return max(steps) if steps else None
