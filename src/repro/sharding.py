"""Logical-axis sharding rule engine (MaxText-style, DESIGN.md §5).

Every parameter/activation dimension carries a *logical* name; a rule
table maps logical names to an ordered list of mesh-axis candidates.
Resolution walks the candidates and picks the first whose mesh extent
divides the dimension — so ONE code path serves architectures whose
dims don't all divide the mesh (e.g. qwen2-moe's 60 experts on a
16-way model axis fall back to replication while its 1408 expert_mlp
shards instead).

Candidates may be joint tuples: ``("pod", "data")`` shards a dim over
the product of both axes (used for the global batch).  Axes already
consumed by an earlier dim of the same tensor are skipped.

A process-global context (set by :func:`use_mesh`) lets model code call
:func:`constrain` unconditionally; outside a mesh context it's a no-op,
so single-device smoke tests need zero ceremony.
"""

from __future__ import annotations

import contextlib
import dataclasses
import math
import threading
from typing import Any, Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any
Candidate = Union[str, Tuple[str, ...]]


# ---------------------------------------------------------------------------
# shard_map compatibility
# ---------------------------------------------------------------------------


def shard_map(fn, *, mesh: Mesh, in_specs, out_specs, axis_names=None,
              check_rep: Optional[bool] = None):
    """``jax.shard_map`` with a fallback to the pre-0.5 experimental API.

    On older jax the ``axis_names`` subset (manual axes) maps onto the
    experimental ``auto=`` complement, which forces replication checking
    off (auto axes and check_rep don't compose there).  ``check_rep=False``
    is also needed whenever the body contains primitives without a
    replication rule (e.g. ``pallas_call`` on 0.4.x).
    """
    native = getattr(jax, "shard_map", None)
    if native is not None:
        kwargs = {} if axis_names is None else {"axis_names": set(axis_names)}
        if check_rep is not None:
            import inspect

            params = inspect.signature(native).parameters
            for name in ("check_rep", "check_vma"):
                if name in params:
                    kwargs[name] = check_rep
                    break
        return native(
            fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    kwargs = {}
    if axis_names is not None:
        # size-1 axes need no auto treatment: manual over a trivial axis
        # is identical to auto, and the experimental auto= path is far
        # more restricted (raises NotImplementedError outside jit).
        auto = frozenset(
            a for a in mesh.axis_names
            if a not in frozenset(axis_names) and mesh.shape[a] > 1
        )
        if auto:
            kwargs = {"auto": auto, "check_rep": False}
    if check_rep is not None:
        kwargs["check_rep"] = kwargs.get("check_rep", True) and check_rep
    return _shard_map(
        fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs
    )


# ---------------------------------------------------------------------------
# rule tables
# ---------------------------------------------------------------------------

# fmt: off
DEFAULT_RULES: Dict[str, Tuple[Candidate, ...]] = {
    # ---- parameters ----
    "vocab":      ("model",),            # embedding / unembedding vocab dim
    "embed":      ("data",),             # FSDP: weight d_model dim over data
    "heads":      ("model",),            # fused Hq*head_dim projection dim
    "kv_heads":   ("model",),
    "mlp":        ("model",),
    "expert":     ("model", "data"),     # falls back when E % axis != 0
    "expert_mlp": ("data", "model"),
    "inner":      ("model",),            # mamba d_inner-derived dims
    "layers":     (),                    # stacked-scan dim: never sharded
    "state":      (),
    # ---- activations ----
    "act_batch":  (("pod", "data"),),
    "act_seq":    (),
    "act_embed":  (),
    "act_heads":  ("model",),
    "act_mlp":    ("model",),
    "act_vocab":  ("model",),
    "act_expert": ("model", "data"),
    "act_inner":  ("model",),
    "act_classes": ("model",),           # FedCGS statistics: A's class dim
    "act_feature": (),                   # FedCGS statistics: feature dim
    "act_dispatch": (("pod", "data"),),  # MoE per-shard dispatch dim (§Perf)
}
# fmt: on


# Serving layout (§Perf): FSDP's data-sharded weights are right for
# training (grads reduce where they live) but force a full weight
# all-gather EVERY DECODED TOKEN. For decode, weights replicate over
# data/pod and shard only over "model" — per-chip weight memory rises
# (params/model_axis instead of params/all_chips) but the per-token
# collective drops to the TP partial-sum all-reduces only.
SERVE_RULES: Dict[str, Tuple[Candidate, ...]] = {
    **DEFAULT_RULES,
    "embed": (),  # weight d_model dim: replicated (no FSDP)
}


def merge_rules(
    base: Dict[str, Tuple[Candidate, ...]], **overrides: Tuple[Candidate, ...]
) -> Dict[str, Tuple[Candidate, ...]]:
    out = dict(base)
    out.update(overrides)
    return out


# ---------------------------------------------------------------------------
# resolution
# ---------------------------------------------------------------------------


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name]


def resolve_spec(
    axes: Sequence[Optional[str]],
    shape: Sequence[int],
    mesh: Mesh,
    rules: Optional[Dict[str, Tuple[Candidate, ...]]] = None,
) -> P:
    """Logical axis names + concrete shape -> PartitionSpec."""
    rules = rules if rules is not None else DEFAULT_RULES
    used: set = set()
    entries = []
    for name, size in zip(axes, shape):
        if name is None:
            entries.append(None)
            continue
        cands = rules.get(name)
        if cands is None:
            raise KeyError(f"no sharding rule for logical axis {name!r}")
        chosen: Optional[Tuple[str, ...]] = None
        for cand in cands:
            cand_axes = (cand,) if isinstance(cand, str) else tuple(cand)
            cand_axes = tuple(
                a for a in cand_axes if a in mesh.axis_names and a not in used
            )
            if not cand_axes:
                continue
            total = math.prod(_axis_size(mesh, a) for a in cand_axes)
            if total > 1 and size % total == 0:
                chosen = cand_axes
                break
        if chosen is None:
            entries.append(None)
        else:
            used.update(chosen)
            entries.append(chosen if len(chosen) > 1 else chosen[0])
    return P(*entries)


def named_sharding(
    axes: Sequence[Optional[str]],
    shape: Sequence[int],
    mesh: Mesh,
    rules=None,
) -> NamedSharding:
    return NamedSharding(mesh, resolve_spec(axes, shape, mesh, rules))


def tree_shardings(spec_tree: PyTree, mesh: Mesh, rules=None) -> PyTree:
    """ParamSpec tree -> NamedSharding tree (for jit in_shardings)."""
    from repro.models.common import ParamSpec  # local import, avoids cycle

    return jax.tree_util.tree_map(
        lambda s: named_sharding(s.axes, s.shape, mesh, rules),
        spec_tree,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


# ---------------------------------------------------------------------------
# global context + constrain()
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _ShardingCtx:
    mesh: Optional[Mesh] = None
    rules: Optional[Dict[str, Tuple[Candidate, ...]]] = None


_TLS = threading.local()


def _ctx() -> _ShardingCtx:
    if not hasattr(_TLS, "ctx"):
        _TLS.ctx = _ShardingCtx()
    return _TLS.ctx


@contextlib.contextmanager
def use_mesh(mesh: Mesh, rules: Optional[Dict[str, Tuple[Candidate, ...]]] = None):
    """Activate (mesh, rules) for all :func:`constrain` calls in scope."""
    ctx = _ctx()
    prev = (ctx.mesh, ctx.rules)
    ctx.mesh, ctx.rules = mesh, rules if rules is not None else DEFAULT_RULES
    try:
        yield
    finally:
        ctx.mesh, ctx.rules = prev


def active_mesh() -> Optional[Mesh]:
    return _ctx().mesh


def constrain(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """with_sharding_constraint via logical names; no-op without a mesh."""
    ctx = _ctx()
    if ctx.mesh is None:
        return x
    spec = resolve_spec(axes, x.shape, ctx.mesh, ctx.rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, spec))


def batch_sharding(mesh: Mesh, ndim: int, rules=None) -> NamedSharding:
    """Sharding for a (global_batch, ...) input: batch over (pod, data)."""
    axes = ["act_batch"] + [None] * (ndim - 1)
    # shape values don't matter for None dims; batch divisibility is the
    # caller's responsibility (use resolve for exactness when known).
    rules = rules if rules is not None else DEFAULT_RULES
    cand = rules["act_batch"][0]
    cand_axes = (cand,) if isinstance(cand, str) else tuple(
        a for a in cand if a in mesh.axis_names
    )
    return NamedSharding(mesh, P(cand_axes if len(cand_axes) > 1 else cand_axes[0], *([None] * (ndim - 1))))
