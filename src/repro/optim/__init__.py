from repro.optim.optimizers import Optimizer, adamw, sgd, apply_updates, global_norm

__all__ = ["Optimizer", "adamw", "sgd", "apply_updates", "global_norm"]
