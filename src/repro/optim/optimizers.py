"""Minimal functional optimizers (optax-style, no external deps).

An :class:`Optimizer` is an (init, update) pair over pytrees.  The
launcher shards optimizer state with the same logical-axis rules as the
parameters (plus optional ZeRO-1 extra sharding — see
``repro.launch.train``); here the math is mesh-agnostic.

The paper's experiments use SGD(momentum=0.9, lr=0.01) for local
training and Adam(1e-3) for the DENSE generator — both provided.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


class Optimizer(NamedTuple):
    init: Callable[[PyTree], PyTree]
    update: Callable[[PyTree, PyTree, PyTree], Tuple[PyTree, PyTree]]
    # update(grads, state, params) -> (updates, new_state); apply with
    # apply_updates(params, updates).


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    return jax.tree_util.tree_map(lambda p, u: p + u.astype(p.dtype), params, updates)


def global_norm(tree: PyTree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def _clip_by_global_norm(grads: PyTree, max_norm: float) -> PyTree:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, grads)


import functools


@functools.lru_cache(maxsize=64)
def sgd(
    lr: float,
    *,
    momentum: float = 0.0,
    weight_decay: float = 0.0,
    nesterov: bool = False,
) -> Optimizer:
    """Memoized: identical hyperparameters return the SAME Optimizer
    object, so downstream jit caches keyed on it never retrace."""
    def init(params):
        if momentum == 0.0:
            return ()
        return jax.tree_util.tree_map(lambda p: jnp.zeros_like(p, jnp.float32), params)

    def update(grads, state, params):
        if weight_decay:
            grads = jax.tree_util.tree_map(
                lambda g, p: g + weight_decay * p.astype(g.dtype), grads, params
            )
        if momentum == 0.0:
            return jax.tree_util.tree_map(lambda g: -lr * g, grads), ()
        new_m = jax.tree_util.tree_map(
            lambda m, g: momentum * m + g.astype(jnp.float32), state, grads
        )
        if nesterov:
            upd = jax.tree_util.tree_map(
                lambda m, g: -lr * (momentum * m + g.astype(jnp.float32)), new_m, grads
            )
        else:
            upd = jax.tree_util.tree_map(lambda m: -lr * m, new_m)
        return upd, new_m

    return Optimizer(init, update)


@dataclasses.dataclass
class AdamWState:
    mu: PyTree
    nu: PyTree
    count: jax.Array


jax.tree_util.register_dataclass(
    AdamWState, data_fields=["mu", "nu", "count"], meta_fields=[]
)


@functools.lru_cache(maxsize=64)
def adamw(
    lr: float,
    *,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
    grad_clip: Optional[float] = None,
) -> Optimizer:
    def init(params):
        z = lambda p: jnp.zeros_like(p, jnp.float32)
        return AdamWState(
            mu=jax.tree_util.tree_map(z, params),
            nu=jax.tree_util.tree_map(z, params),
            count=jnp.zeros((), jnp.int32),
        )

    def update(grads, state, params):
        if grad_clip is not None:
            grads = _clip_by_global_norm(grads, grad_clip)
        count = state.count + 1
        mu = jax.tree_util.tree_map(
            lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32), state.mu, grads
        )
        nu = jax.tree_util.tree_map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state.nu,
            grads,
        )
        c1 = 1 - b1**count.astype(jnp.float32)
        c2 = 1 - b2**count.astype(jnp.float32)

        def upd(m, v, p):
            step = (m / c1) / (jnp.sqrt(v / c2) + eps)
            if weight_decay:
                step = step + weight_decay * p.astype(jnp.float32)
            return -lr * step

        return (
            jax.tree_util.tree_map(upd, mu, nu, params),
            AdamWState(mu=mu, nu=nu, count=count),
        )

    return Optimizer(init, update)
