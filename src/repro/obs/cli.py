"""fedcgs-obs — dump live observability off a running ``fedcgs-front``.

    fedcgs-obs dump --port 7011                    # Prometheus text
    fedcgs-obs dump --port 7011 --what trace       # recent spans, JSONL
    fedcgs-obs dump --port 7011 --what json        # metrics as JSON

Speaks the front's newline-delimited JSON admin ops (``{"op":
"metrics"}`` / ``{"op": "trace"}``) over one TCP connection — no
dependency beyond the stdlib, so it works from any box that can reach
the socket.
"""

from __future__ import annotations

import argparse
import asyncio
import json
from typing import List, Optional


async def _admin_request(host: str, port: int, op: dict) -> dict:
    # one JSON-lines message per response: a full trace dump easily
    # exceeds asyncio's default 64 KiB line limit
    reader, writer = await asyncio.open_connection(host, port, limit=1 << 26)
    try:
        writer.write((json.dumps(op) + "\n").encode())
        await writer.drain()
        line = await reader.readline()
    finally:
        writer.close()
    if not line:
        raise ConnectionError(f"{host}:{port} closed without responding")
    return json.loads(line)


def fetch_metrics(host: str, port: int) -> dict:
    """One ``{"op": "metrics"}`` round trip (text + JSON renderings)."""
    return asyncio.run(_admin_request(host, port, {"op": "metrics"}))


def fetch_trace(host: str, port: int, limit: Optional[int] = None) -> dict:
    """One ``{"op": "trace"}`` round trip (recent spans)."""
    op: dict = {"op": "trace"}
    if limit is not None:
        op["limit"] = limit
    return asyncio.run(_admin_request(host, port, op))


def main(argv: Optional[List[str]] = None) -> int:
    p = argparse.ArgumentParser(prog="fedcgs-obs", description=__doc__)
    sub = p.add_subparsers(dest="cmd", required=True)
    dump = sub.add_parser(
        "dump", help="scrape a running fedcgs-front's metrics or trace"
    )
    dump.add_argument("--host", default="127.0.0.1")
    dump.add_argument("--port", type=int, required=True)
    dump.add_argument(
        "--what", choices=("metrics", "json", "trace"), default="metrics",
        help="metrics = Prometheus text, json = structured metrics, "
             "trace = recent spans as JSON lines",
    )
    dump.add_argument("--limit", type=int, default=None,
                      help="newest-N span cap for --what trace")
    args = p.parse_args(argv)

    if args.what == "trace":
        resp = fetch_trace(args.host, args.port, args.limit)
        if "error" in resp:
            print(json.dumps(resp))
            return 1
        for span in resp.get("spans", []):
            print(json.dumps(span))
        return 0
    resp = fetch_metrics(args.host, args.port)
    if "error" in resp:
        print(json.dumps(resp))
        return 1
    if args.what == "json":
        print(json.dumps(resp.get("json", {}), indent=2))
    else:
        print(resp.get("metrics", ""), end="")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
