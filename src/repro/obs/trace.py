"""Span-based structured tracing with propagated trace IDs.

``repro.timing.timed`` answers "how long did this call take"; a span
answers "what happened to THIS request" — a named, attributed interval
tied to a trace ID that travels with the request through every layer it
crosses (``submit → enqueue → batch-form → score → complete`` in the
serving tier, fold/finalize/recover/hot-swap in the round lifecycle).

Design constraints, in order:

1. **The disabled path is a near-zero-cost no-op.**  Tracing ships in
   the serving hot path, so ``span()`` with tracing off must cost one
   module-bool check and return a shared stateless context manager —
   no allocation beyond the caller's kwargs, no lock, no clock read.
   The serve-bench obs-overhead point holds this to <2% throughput.
2. **Thread-safe bounded memory.**  Finished spans land in one
   process-wide ring buffer (``collections.deque(maxlen=...)`` under a
   lock); a runaway workload overwrites the oldest spans instead of
   growing without bound.
3. **Explicit propagation across threads.**  Within a thread, nested
   spans inherit the active trace ID from a thread-local stack; across
   threads (a request's future completes on the worker), the trace ID
   is carried explicitly (``trace_id=`` on ``span()``; the serve tier
   stows it on the queued request).

Activation: :func:`enable` / :func:`disable`, or ``FEDCGS_TRACE=1`` in
the environment at import time.  ``FEDCGS_TRACE_DEVICE=1`` (or
``enable(device=True)``) additionally wraps the audited jit call sites
in ``jax.profiler`` annotations (:func:`annotate`), so a device
profile collected with ``jax.profiler.trace`` lines up with the host
spans by name.

Export: :func:`spans` (list of dicts), :func:`export_jsonl` (one JSON
object per line), both draining nothing — :func:`reset` clears.
"""

from __future__ import annotations

import collections
import itertools
import json
import os
import threading
import time
from typing import Dict, List, Optional

__all__ = [
    "Span",
    "annotate",
    "current_trace_id",
    "disable",
    "enable",
    "enabled",
    "export_jsonl",
    "new_trace_id",
    "reset",
    "span",
    "spans",
]

DEFAULT_CAPACITY = 65536

# process-wide tracer state; `_enabled` is the hot-path gate (read
# un-locked: a stale read worth one span either way is harmless)
_enabled = False
_device = False
_lock = threading.Lock()
_buffer: collections.deque = collections.deque(maxlen=DEFAULT_CAPACITY)
_ids = itertools.count(1)
_pid = os.getpid()
_tls = threading.local()


def enable(*, capacity: Optional[int] = None, device: bool = False) -> None:
    """Switch tracing on process-wide (idempotent).

    ``capacity`` bounds the ring buffer (finished spans retained);
    ``device`` additionally turns :func:`annotate` into real
    ``jax.profiler`` annotations.
    """
    global _enabled, _device, _buffer
    with _lock:
        if capacity is not None and capacity != _buffer.maxlen:
            _buffer = collections.deque(_buffer, maxlen=capacity)
        _device = device or _device
        _enabled = True


def disable() -> None:
    """Switch tracing off (the buffer keeps what it holds)."""
    global _enabled, _device
    with _lock:
        _enabled = False
        _device = False


def enabled() -> bool:
    return _enabled


def new_trace_id() -> str:
    """A process-unique trace ID (pid-prefixed counter — deterministic
    within a run, collision-free across forked smoke workers)."""
    return f"{_pid:x}-{next(_ids):x}"


def _stack() -> List["Span"]:
    stack = getattr(_tls, "stack", None)
    if stack is None:
        stack = _tls.stack = []
    return stack


def current_trace_id() -> Optional[str]:
    """The active span's trace ID on this thread (None outside spans)."""
    stack = getattr(_tls, "stack", None)
    return stack[-1].trace_id if stack else None


class Span:
    """One named interval.  Context manager; records itself on exit.

    ``set(**attrs)`` merges attributes mid-span; ``fail(error)`` stamps
    an error string (an exception escaping the ``with`` block stamps
    its repr automatically).
    """

    __slots__ = (
        "trace_id", "span_id", "parent_id", "name", "attrs", "error",
        "start_s", "end_s",
    )

    def __init__(self, name: str, trace_id: Optional[str], attrs: Dict):
        self.name = name
        self.trace_id = trace_id
        self.span_id = f"{_pid:x}-s{next(_ids):x}"
        self.parent_id: Optional[str] = None
        self.attrs = attrs
        self.error: Optional[str] = None
        self.start_s = 0.0
        self.end_s = 0.0

    def set(self, **attrs) -> None:
        self.attrs.update(attrs)

    def fail(self, error: str) -> None:
        self.error = str(error)

    def __enter__(self) -> "Span":
        stack = _stack()
        if stack:
            parent = stack[-1]
            self.parent_id = parent.span_id
            if self.trace_id is None:
                self.trace_id = parent.trace_id
        if self.trace_id is None:
            self.trace_id = new_trace_id()
        self.start_s = time.perf_counter()
        stack.append(self)
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        self.end_s = time.perf_counter()
        stack = _stack()
        if stack and stack[-1] is self:
            stack.pop()
        if exc is not None and self.error is None:
            self.error = repr(exc)
        if _enabled:  # a span straddling disable() is dropped, not lost-locked
            with _lock:
                _buffer.append(self)
        return False

    def as_dict(self) -> Dict:
        out = {
            "trace_id": self.trace_id,
            "span_id": self.span_id,
            "parent_id": self.parent_id,
            "name": self.name,
            "start_s": self.start_s,
            "end_s": self.end_s,
            "duration_s": self.end_s - self.start_s,
            "attrs": self.attrs,
        }
        if self.error is not None:
            out["error"] = self.error
        return out


class _NoopSpan:
    """The shared disabled-path context manager: stateless, reentrant."""

    __slots__ = ()
    trace_id = None

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        return False

    def set(self, **attrs) -> None:
        pass

    def fail(self, error: str) -> None:
        pass


_NOOP = _NoopSpan()


def span(name: str, *, trace_id: Optional[str] = None, **attrs):
    """Open a span (context manager).

    Disabled → the shared no-op (one bool check).  Enabled → a
    :class:`Span` inheriting the thread's active trace ID unless
    ``trace_id=`` pins one explicitly (cross-thread propagation).
    """
    if not _enabled:
        return _NOOP
    return Span(name, trace_id, attrs)


def event(name: str, *, trace_id: Optional[str] = None, **attrs) -> None:
    """A zero-duration span (a point-in-time marker)."""
    if not _enabled:
        return
    with span(name, trace_id=trace_id, **attrs):
        pass


def annotate(name: str):
    """A device-profile annotation around an audited jit call site.

    With device tracing on, returns ``jax.profiler.TraceAnnotation`` so
    the host span and the device trace carry the same name; otherwise
    the shared no-op.  Host-only tracing deliberately skips this — a
    TraceAnnotation costs a TraceMe even when no profiler session runs.
    """
    if not (_enabled and _device):
        return _NOOP
    import jax

    return jax.profiler.TraceAnnotation(name)


# -- export ------------------------------------------------------------------


def spans(*, name: Optional[str] = None, limit: Optional[int] = None) -> List[Dict]:
    """Finished spans (oldest first), optionally filtered by name /
    truncated to the newest ``limit``."""
    with _lock:
        out = [s.as_dict() for s in _buffer]
    if name is not None:
        out = [s for s in out if s["name"] == name]
    if limit is not None:
        out = out[-limit:]
    return out


def export_jsonl(path: str) -> int:
    """Write every buffered span as JSON lines; returns the span count."""
    all_spans = spans()
    with open(path, "w") as fh:
        for s in all_spans:
            fh.write(json.dumps(s) + "\n")
    return len(all_spans)


def reset() -> None:
    """Drop every buffered span (tests, between bench points)."""
    with _lock:
        _buffer.clear()


if os.environ.get("FEDCGS_TRACE", "").strip() not in ("", "0"):
    enable(device=os.environ.get("FEDCGS_TRACE_DEVICE", "").strip()
           not in ("", "0"))
