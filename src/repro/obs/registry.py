"""The unified metrics registry: Counter / Gauge / Histogram in one
named, labeled, process-wide namespace.

Before this module every serve-layer component grew its own private
counters behind its own lock and its own ``snapshot()`` dict; nothing
could scrape the process as a whole, and the latency "histogram" was a
65536-entry deque sorted under the lock on EVERY snapshot.  The
registry inverts that: components create named instruments here
(get-or-create, so N workers share one family distinguished by
labels), their ``snapshot()`` dicts become views over the shared
instruments, and :mod:`repro.obs.expo` renders the whole registry as
Prometheus text or JSON in one pass.

Instruments:

- :class:`Counter` — monotone ``inc()`` (floats allowed: summed
  seconds are counters too);
- :class:`Gauge` — ``set()`` to the current value;
- :class:`Histogram` — fixed log-spaced buckets (default: 8 per
  decade over 10µs…100s, built for latencies) **plus** an exact
  nearest-rank small-window path: while the observation count fits the
  bounded sample window, ``percentile(q)`` is the exact nearest-rank
  statistic (bit-identical to ``serve.metrics.percentile``); past it,
  the rank is located in the bucket counts and interpolated inside the
  bucket — O(#buckets), never a sort over the raw samples.

Every instrument is individually thread-safe; the registry lock only
guards the name table.  Labels are fixed per family at creation;
``labels(**values)`` returns the per-labelset child (created on first
use).  ``collect()`` walks everything for the exposition layer.
"""

from __future__ import annotations

import bisect
import collections
import math
import threading
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "default_registry",
    "latency_buckets",
]

# exact nearest-rank percentiles while a histogram holds at most this
# many observations; beyond it the bucket path takes over (no sort)
EXACT_WINDOW = 1024


def latency_buckets(
    lo: float = 1e-5, hi: float = 1e2, per_decade: int = 8
) -> Tuple[float, ...]:
    """Fixed log-spaced bucket upper bounds, ``lo``…``hi`` seconds."""
    decades = math.log10(hi / lo)
    n = int(round(decades * per_decade))
    return tuple(lo * 10 ** (i / per_decade) for i in range(n + 1))


_DEFAULT_BUCKETS = latency_buckets()


def _check_label_values(names: Tuple[str, ...], values: Dict[str, str]) -> Tuple[str, ...]:
    if set(values) != set(names):
        raise ValueError(
            f"label values {sorted(values)} != declared labels {sorted(names)}"
        )
    return tuple(str(values[n]) for n in names)


class _Instrument:
    """Shared family machinery: label table + per-child creation."""

    kind = "untyped"

    def __init__(self, name: str, help: str, label_names: Sequence[str] = ()):
        self.name = name
        self.help = help
        self.label_names = tuple(label_names)
        self._lock = threading.Lock()
        self._children: Dict[Tuple[str, ...], "_Instrument"] = {}
        self._init_state()

    def _init_state(self) -> None:  # pragma: no cover - overridden
        pass

    def _new_child(self) -> "_Instrument":
        child = type(self)(self.name, self.help)
        return child

    def labels(self, **values) -> "_Instrument":
        """The per-labelset child (get-or-create)."""
        if not self.label_names:
            raise ValueError(f"{self.name} declares no labels")
        key = _check_label_values(self.label_names, values)
        with self._lock:
            child = self._children.get(key)
            if child is None:
                child = self._children[key] = self._new_child()
            return child

    def children(self) -> Iterator[Tuple[Tuple[str, ...], "_Instrument"]]:
        """(label values, child) pairs — the unlabeled family yields
        itself under the empty tuple."""
        if not self.label_names:
            yield (), self
            return
        with self._lock:
            items = list(self._children.items())
        yield from items


class Counter(_Instrument):
    """Monotonically increasing value (thread-safe)."""

    kind = "counter"

    def _init_state(self) -> None:
        with self._lock:  # init-time, but guarded writes stay guarded
            self._value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError(f"counters only go up, got {amount}")
        with self._lock:
            self._value += amount

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


class Gauge(_Instrument):
    """Set-to-current value (thread-safe)."""

    kind = "gauge"

    def _init_state(self) -> None:
        with self._lock:  # init-time, but guarded writes stay guarded
            self._value = 0.0

    def set(self, value: float) -> None:
        with self._lock:
            self._value = float(value)

    def inc(self, amount: float = 1.0) -> None:
        with self._lock:
            self._value += amount

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


class Histogram(_Instrument):
    """Log-spaced bucket counts + an exact small-window percentile path.

    ``observe(v)`` is O(log #buckets); ``percentile(q)`` is exact
    nearest-rank while ``count <= window`` (the bounded raw-sample
    window still holds everything), and a bucket-rank interpolation —
    O(#buckets), no sort — once the window has been outgrown.
    """

    kind = "histogram"

    def __init__(
        self,
        name: str,
        help: str,
        label_names: Sequence[str] = (),
        *,
        buckets: Optional[Sequence[float]] = None,
        window: int = EXACT_WINDOW,
    ):
        self.buckets = tuple(buckets) if buckets is not None else _DEFAULT_BUCKETS
        if list(self.buckets) != sorted(self.buckets):
            raise ValueError("bucket bounds must be sorted ascending")
        self.window = window
        super().__init__(name, help, label_names)

    def _init_state(self) -> None:
        with self._lock:  # init-time, but guarded writes stay guarded
            self._counts = [0] * (len(self.buckets) + 1)  # +1: +Inf bucket
            self._count = 0
            self._sum = 0.0
            self._samples: collections.deque = collections.deque(
                maxlen=self.window
            )

    def _new_child(self) -> "Histogram":
        return Histogram(
            self.name, self.help, buckets=self.buckets, window=self.window
        )

    def observe(self, value: float) -> None:
        value = float(value)
        idx = bisect.bisect_left(self.buckets, value)
        with self._lock:
            self._counts[idx] += 1
            self._count += 1
            self._sum += value
            self._samples.append(value)

    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    @property
    def sum(self) -> float:
        with self._lock:
            return self._sum

    def bucket_counts(self) -> List[Tuple[float, int]]:
        """Cumulative (upper_bound, count) pairs, ending at +Inf."""
        with self._lock:
            counts = list(self._counts)
        out, running = [], 0
        for bound, c in zip(self.buckets, counts):
            running += c
            out.append((bound, running))
        out.append((math.inf, running + counts[-1]))
        return out

    def percentile(self, q: float) -> float:
        """Nearest-rank percentile, exact while the window holds all
        observations, bucket-interpolated beyond it (never a sort of
        more than ``window`` samples)."""
        with self._lock:
            n = self._count
            if n == 0:
                return float("nan")
            if n <= self.window:
                ordered = sorted(self._samples)
                rank = min(n, max(1, math.ceil(q * n)))
                return float(ordered[rank - 1])
            counts = list(self._counts)
        rank = min(n, max(1, math.ceil(q * n)))
        running = 0
        for idx, c in enumerate(counts):
            if running + c >= rank:
                if idx >= len(self.buckets):
                    # +Inf bucket has no upper edge: the highest finite
                    # bound is the best monotone floor we can report
                    return float(self.buckets[-1])
                hi = self.buckets[idx]
                lo = self.buckets[idx - 1] if idx > 0 else 0.0
                frac = (rank - running) / c
                return lo + (hi - lo) * frac
            running += c
        return float("nan")  # pragma: no cover — rank <= n by construction


class MetricsRegistry:
    """The named instrument table (get-or-create, type-checked)."""

    def __init__(self):
        self._lock = threading.Lock()
        self._instruments: Dict[str, _Instrument] = {}

    def _get_or_create(self, cls, name, help, label_names, **kwargs):
        with self._lock:
            existing = self._instruments.get(name)
            if existing is not None:
                if type(existing) is not cls:
                    raise ValueError(
                        f"{name} already registered as {existing.kind}, "
                        f"not {cls.kind}"
                    )
                if existing.label_names != tuple(label_names):
                    raise ValueError(
                        f"{name} already registered with labels "
                        f"{existing.label_names}, not {tuple(label_names)}"
                    )
                return existing
            inst = cls(name, help, label_names, **kwargs)
            self._instruments[name] = inst
            return inst

    def counter(self, name: str, help: str = "",
                label_names: Sequence[str] = ()) -> Counter:
        return self._get_or_create(Counter, name, help, label_names)

    def gauge(self, name: str, help: str = "",
              label_names: Sequence[str] = ()) -> Gauge:
        return self._get_or_create(Gauge, name, help, label_names)

    def histogram(
        self, name: str, help: str = "", label_names: Sequence[str] = (),
        *, buckets: Optional[Sequence[float]] = None,
        window: int = EXACT_WINDOW,
    ) -> Histogram:
        return self._get_or_create(
            Histogram, name, help, label_names, buckets=buckets, window=window
        )

    def get(self, name: str) -> Optional[_Instrument]:
        with self._lock:
            return self._instruments.get(name)

    def collect(self) -> List[_Instrument]:
        """Every registered family, name-sorted (the exposition walk)."""
        with self._lock:
            return [self._instruments[k] for k in sorted(self._instruments)]


_REGISTRY = MetricsRegistry()


def default_registry() -> MetricsRegistry:
    """The process-wide registry every component shares by default."""
    return _REGISTRY
