"""Exposition: render a :class:`MetricsRegistry` as Prometheus text or JSON.

``render_prometheus`` emits the text exposition format (version 0.0.4)
— ``# HELP`` / ``# TYPE`` headers per family, one sample line per
labeled child, histograms as cumulative ``_bucket{le=...}`` series plus
``_sum`` / ``_count`` — so any scrape-compatible collector can ingest
the serving tier live.  ``render_json`` is the structured twin for the
socket admin path and the dump CLI.

This module renders; it never mutates.  The live wiring (the
``{"op": "metrics"}`` socket op on ``fedcgs-front``, the ``fedcgs-obs``
dump CLI) lives with the servers it exposes.
"""

from __future__ import annotations

import json
import math
from typing import Dict, List, Optional, Tuple

from repro.obs.registry import Histogram, MetricsRegistry, default_registry

__all__ = [
    "metrics_payload",
    "parse_prometheus",
    "render_json",
    "render_prometheus",
]


def _escape(value: str) -> str:
    return (
        value.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")
    )


def _label_str(names: Tuple[str, ...], values: Tuple[str, ...],
               extra: Optional[Tuple[str, str]] = None) -> str:
    pairs = [f'{n}="{_escape(v)}"' for n, v in zip(names, values)]
    if extra is not None:
        pairs.append(f'{extra[0]}="{_escape(extra[1])}"')
    return "{" + ",".join(pairs) + "}" if pairs else ""


def _fmt(value: float) -> str:
    if isinstance(value, float) and math.isinf(value):
        return "+Inf" if value > 0 else "-Inf"
    if isinstance(value, float) and math.isnan(value):
        return "NaN"
    # integral floats print as integers (counter semantics)
    if float(value) == int(value):
        return str(int(value))
    return repr(float(value))


def render_prometheus(registry: Optional[MetricsRegistry] = None) -> str:
    """The whole registry in Prometheus text exposition format."""
    registry = registry if registry is not None else default_registry()
    lines: List[str] = []
    for family in registry.collect():
        lines.append(f"# HELP {family.name} {_escape(family.help)}")
        lines.append(f"# TYPE {family.name} {family.kind}")
        for values, child in family.children():
            labels = _label_str(family.label_names, values)
            if isinstance(child, Histogram):
                for bound, cumulative in child.bucket_counts():
                    le = _label_str(
                        family.label_names, values, extra=("le", _fmt(bound))
                    )
                    lines.append(
                        f"{family.name}_bucket{le} {cumulative}"
                    )
                lines.append(f"{family.name}_sum{labels} {_fmt(child.sum)}")
                lines.append(f"{family.name}_count{labels} {child.count}")
            else:
                lines.append(f"{family.name}{labels} {_fmt(child.value)}")
    return "\n".join(lines) + "\n"


def render_json(registry: Optional[MetricsRegistry] = None) -> Dict:
    """The whole registry as one JSON-ready dict."""
    registry = registry if registry is not None else default_registry()
    families = []
    for family in registry.collect():
        children = []
        for values, child in family.children():
            labels = dict(zip(family.label_names, values))
            if isinstance(child, Histogram):
                children.append({
                    "labels": labels,
                    "count": child.count,
                    "sum": child.sum,
                    "buckets": [
                        {"le": ("+Inf" if math.isinf(b) else b), "count": c}
                        for b, c in child.bucket_counts()
                    ],
                })
            else:
                children.append({"labels": labels, "value": child.value})
        families.append({
            "name": family.name,
            "kind": family.kind,
            "help": family.help,
            "series": children,
        })
    return {"families": families}


def parse_prometheus(text: str) -> Dict[str, Dict[str, float]]:
    """A minimal parser of the text format: name → {label_str: value}.

    Strict enough to catch malformed output (tests and the smoke
    self-check use it); not a general scraper.  Raises ``ValueError``
    on a line that is neither a comment nor a well-formed sample.
    """
    out: Dict[str, Dict[str, float]] = {}
    for line in text.splitlines():
        if not line.strip() or line.startswith("#"):
            continue
        rest = line
        if "{" in line:
            name = line[: line.index("{")]
            closing = line.rindex("}")
            labels = line[line.index("{"): closing + 1]
            rest = line[closing + 1:]
        else:
            name, labels = line.split(None, 1)[0], ""
            rest = line[len(name):]
        value_str = rest.strip().split()[0]
        if value_str == "+Inf":
            value = math.inf
        elif value_str == "NaN":
            value = math.nan
        else:
            value = float(value_str)
        if not name or not name[0].isalpha():
            raise ValueError(f"malformed sample line: {line!r}")
        out.setdefault(name, {})[labels] = value
    return out


def metrics_payload(registry: Optional[MetricsRegistry] = None) -> Dict:
    """The socket ``{"op": "metrics"}`` response body: both renderings."""
    return {
        "metrics": render_prometheus(registry),
        "json": render_json(registry),
    }


if __name__ == "__main__":  # pragma: no cover — debugging aid
    print(json.dumps(render_json(), indent=2))
