"""repro.obs — observability for the FedCGS stack.

Three pieces, one funnel (the ``metric-funnel`` lint rule holds the
serving and launch layers to it):

- :mod:`repro.obs.trace`    — span-based structured tracing: trace IDs
  propagated through the full request lifecycle (``submit → enqueue →
  batch-form → score → complete``) and the round lifecycle (fold /
  finalize, dropout recovery, hot-swap, replica sync), a thread-safe
  bounded ring buffer, JSONL export, and a process-wide switch whose
  disabled path is a near-zero-cost no-op;
- :mod:`repro.obs.registry` — the unified metrics registry: named,
  labeled Counter / Gauge / Histogram instruments (log-spaced latency
  buckets + exact nearest-rank small-window percentiles) that
  ``ServeMetrics`` / ``FrontMetrics`` snapshots are views over;
- :mod:`repro.obs.expo`     — Prometheus text + JSON rendering, served
  live via the ``fedcgs-front`` socket's ``{"op": "metrics"}`` /
  ``{"op": "trace"}`` admin ops and the ``fedcgs-obs`` dump CLI.
"""

from repro.obs import trace
from repro.obs.expo import parse_prometheus, render_json, render_prometheus
from repro.obs.registry import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    default_registry,
)

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "default_registry",
    "parse_prometheus",
    "render_json",
    "render_prometheus",
    "trace",
]
