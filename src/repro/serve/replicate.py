"""serve.replicate — drive replica registries off shared head snapshots.

The multi-worker front scales scoring on one host; replication scales
it across hosts.  The write side stays exactly what it was — an FL
round publishes into a :class:`~repro.serve.registry.HeadRegistry`,
then :func:`publish_snapshot` persists the registry through
:mod:`repro.checkpoint.store` (flat npz, atomic rename).  Each replica
host runs a :class:`RegistryReplicator` against the same directory: a
poll loop that watches ``store.latest_step`` and calls
``HeadRegistry.restore()`` whenever a NEWER step lands.  Restore is an
atomic all-state swap that fires the registry's subscribers on a live
version change, so the replica's servers hot-swap mid-traffic exactly
as if the publish had happened locally — same metrics, same
per-batch version stamping.

Polling (not inotify) is deliberate: the snapshot directory is
typically network storage where file events don't propagate, and the
store's atomic-rename discipline makes "newest ``step_*.npz``" a safe
thing to poll.
"""

from __future__ import annotations

import threading
from typing import Optional

from repro.checkpoint import store
from repro.obs import trace
from repro.serve.registry import HeadRegistry


def publish_snapshot(
    registry: HeadRegistry,
    directory: str,
    head=None,
    *,
    step: Optional[int] = None,
) -> str:
    """Publish ``head`` (optional) and snapshot the registry for replicas.

    The one-call write side of replication: an FL round that just
    refit a head publishes + persists in one step, and every
    :class:`RegistryReplicator` watching ``directory`` picks it up on
    its next poll.  Returns the snapshot path.
    """
    if head is not None:
        registry.publish(head)
    return registry.snapshot(directory, step=step)


class RegistryReplicator:
    """Poll a snapshot directory and restore newer steps into a replica.

    ``sync_once()`` is the unit of work (poll → maybe restore); the
    ``start()``/``stop()`` thread just repeats it on an interval.  Steps
    are tracked monotonically — an already-applied or older snapshot is
    never re-restored, so a replica under traffic only ever swaps
    forward.
    """

    def __init__(
        self,
        registry: HeadRegistry,
        directory: str,
        *,
        poll_interval_s: float = 0.05,
    ):
        self.registry = registry
        self.directory = directory
        self.poll_interval_s = poll_interval_s
        self._lock = threading.Lock()
        self._last_step: Optional[int] = None
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    @property
    def last_step(self) -> Optional[int]:
        """The snapshot step most recently restored (None before any)."""
        with self._lock:
            return self._last_step

    def sync_once(self) -> Optional[int]:
        """Restore the directory's latest snapshot if it is new.

        Returns the restored live head version, or None when there was
        nothing newer (or the new snapshot carries no live head).
        """
        step = store.latest_step(self.directory)
        if step is None:
            return None
        with self._lock:
            if self._last_step is not None and step <= self._last_step:
                return None
        with trace.span("replicate.sync_once", step=step) as sp:
            version = self.registry.restore(self.directory, step=step)
            sp.set(version=version)
        with self._lock:
            self._last_step = step
        return version

    # -- watch thread --------------------------------------------------------

    def start(self) -> "RegistryReplicator":
        if self._thread is not None:
            raise RuntimeError("replicator already started")
        self._stop.clear()
        self._thread = threading.Thread(
            target=self._run_loop, name="gnb-replicate", daemon=True
        )
        self._thread.start()
        return self

    def __enter__(self) -> "RegistryReplicator":
        return self.start()

    def __exit__(self, exc_type, exc, tb) -> None:
        self.stop()

    def stop(self, timeout: Optional[float] = None) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout)
            self._thread = None

    @property
    def running(self) -> bool:
        return self._thread is not None and self._thread.is_alive()

    def _run_loop(self) -> None:
        while not self._stop.is_set():
            try:
                self.sync_once()
            except FileNotFoundError:
                pass  # directory not created yet — keep watching
            self._stop.wait(self.poll_interval_s)
