"""Request queue + dynamic batcher for the GNB serving loop.

Requests are ragged (any row count ≥ 1); the batcher coalesces whatever
is in flight each tick into one feature matrix, pads the row count up
to the scoring path's row multiple — ``repro.tune.serve_row_multiple``:
the tuned ``gnb_logits`` block, or a small lane-aligned quantum when
the tuner picked the jnp matmul (the same zero-row pad
discipline as ``stats_pipeline._pad_batch`` — padded rows are pure
garbage lanes that get sliced off, they never reach a caller), scores
the padded batch ONCE, and slices each request's rows back out.  Row
counts are always one of ``row_multiple · k`` for small k, so the whole
workload costs one jit trace per padded shape instead of one per ragged
request size.

Admission policy: a batch is formed as soon as the queue holds
``max_batch_rows`` rows OR the oldest request has waited
``max_delay_s`` — the classic dynamic-batching latency/throughput
dial.  Backpressure: when the queued rows would exceed
``max_queue_rows``, ``submit`` raises :class:`QueueFull` instead of
letting the queue grow without bound.

The batcher owns NO thread and NO kernel call — it is a pure data
structure (lock-protected deque) the server's run loop drives via
``ready()`` / ``form_batch()`` / ``complete()``, which keeps every
policy decision unit-testable without a running server.
"""

from __future__ import annotations

import collections
import dataclasses
import threading
import time
from concurrent.futures import Future
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro import tune

Array = np.ndarray


class QueueFull(RuntimeError):
    """Raised by ``submit`` when the queue bound would be exceeded."""


@dataclasses.dataclass(frozen=True)
class ServeResult:
    """What a request's future resolves to."""

    logits: Array  # (n_i, C)
    predictions: Array  # (n_i,)
    head_version: int  # the registry version that scored these rows
    latency_s: float  # enqueue → result
    batch_rows: int  # real rows of the batch this request rode in


@dataclasses.dataclass
class _Pending:
    features: Array
    rows: int
    future: Future
    enqueued_at: float


def pad_rows_to(features: Array, multiple: int) -> Array:
    """Zero-pad rows up to the next ``multiple`` (no-op when aligned)."""
    pad = (-features.shape[0]) % multiple
    if pad == 0:
        return features
    return np.pad(features, ((0, pad), (0, 0)))


class DynamicBatcher:
    """Coalesce ragged requests into block-padded kernel batches."""

    def __init__(
        self,
        feature_dim: int,
        *,
        num_classes: Optional[int] = None,
        max_batch_rows: Optional[int] = None,
        max_delay_s: float = 2e-3,
        max_queue_rows: Optional[int] = None,
        row_multiple: Optional[int] = None,
    ):
        # the pad-to multiple is COUPLED to the scoring dispatch: the
        # tuned kernel's block_n (or the jnp quantum) via the one shared
        # accessor, so tuning can't desync batcher padding from what the
        # kernel pads to internally.  Explicit row_multiple= overrides.
        if row_multiple is None:
            row_multiple = tune.serve_row_multiple(feature_dim, num_classes)
        if max_batch_rows is None:
            max_batch_rows = 4 * row_multiple
        if max_queue_rows is None:
            max_queue_rows = 64 * row_multiple
        if max_batch_rows < 1 or max_queue_rows < max_batch_rows:
            raise ValueError(
                "need max_queue_rows >= max_batch_rows >= 1, got "
                f"{max_queue_rows} / {max_batch_rows}"
            )
        if row_multiple < 1:
            raise ValueError(f"row_multiple must be >= 1, got {row_multiple}")
        self.feature_dim = feature_dim
        self.max_batch_rows = max_batch_rows
        self.max_delay_s = max_delay_s
        self.max_queue_rows = max_queue_rows
        self.row_multiple = row_multiple
        self._lock = threading.Lock()
        self._queue: collections.deque[_Pending] = collections.deque()
        self._queued_rows = 0

    # -- producer side ------------------------------------------------------

    def submit(self, features) -> Future:
        """Enqueue one request; returns a Future of :class:`ServeResult`.

        A request larger than ``max_batch_rows`` is admitted whole (it
        forms its own oversized batch) as long as it fits the queue
        bound; anything that would push the queue past
        ``max_queue_rows`` raises :class:`QueueFull` — callers see the
        backpressure instead of unbounded latency.
        """
        f = np.asarray(features, dtype=np.float32)
        if f.ndim != 2 or f.shape[1] != self.feature_dim:
            raise ValueError(
                f"expected (n, {self.feature_dim}) features, got {f.shape}"
            )
        if f.shape[0] < 1:
            raise ValueError("empty request (0 rows)")
        pending = _Pending(
            features=f, rows=f.shape[0], future=Future(),
            enqueued_at=time.perf_counter(),
        )
        with self._lock:
            if self._queued_rows + pending.rows > self.max_queue_rows:
                raise QueueFull(
                    f"queue holds {self._queued_rows} rows; "
                    f"+{pending.rows} exceeds the {self.max_queue_rows} bound"
                )
            self._queue.append(pending)
            self._queued_rows += pending.rows
        return pending.future

    # -- consumer side (the server's run loop) ------------------------------

    @property
    def queued_rows(self) -> int:
        with self._lock:
            return self._queued_rows

    @property
    def pending_requests(self) -> int:
        with self._lock:
            return len(self._queue)

    def ready(self, now: Optional[float] = None) -> bool:
        """Admission policy: enough rows, or the oldest waited too long."""
        now = time.perf_counter() if now is None else now
        with self._lock:
            if not self._queue:
                return False
            if self._queued_rows >= self.max_batch_rows:
                return True
            return (now - self._queue[0].enqueued_at) >= self.max_delay_s

    def form_batch(self) -> Tuple[List[_Pending], Array, int]:
        """Pop FIFO requests up to ``max_batch_rows`` and coalesce them.

        Returns ``(pendings, padded_features, real_rows)``; the padded
        row count is the least ``row_multiple`` multiple covering the
        real rows.  The first request is always admitted even if it
        alone exceeds ``max_batch_rows``.
        """
        taken: List[_Pending] = []
        rows = 0
        with self._lock:
            while self._queue:
                nxt = self._queue[0]
                if taken and rows + nxt.rows > self.max_batch_rows:
                    break
                self._queue.popleft()
                self._queued_rows -= nxt.rows
                taken.append(nxt)
                rows += nxt.rows
        if not taken:
            return [], np.zeros((0, self.feature_dim), np.float32), 0
        feats = (
            taken[0].features
            if len(taken) == 1
            else np.concatenate([p.features for p in taken], axis=0)
        )
        return taken, pad_rows_to(feats, self.row_multiple), rows

    def complete(
        self,
        pendings: Sequence[_Pending],
        logits,
        head_version: int,
        *,
        batch_rows: int,
    ) -> List[ServeResult]:
        """Slice per-request rows out of the batch logits, resolve futures."""
        logits = np.asarray(logits)
        now = time.perf_counter()
        offset = 0
        results: List[ServeResult] = []
        for p in pendings:
            sl = logits[offset : offset + p.rows]
            offset += p.rows
            result = ServeResult(
                logits=sl,
                predictions=np.argmax(sl, axis=-1),
                head_version=head_version,
                latency_s=now - p.enqueued_at,
                batch_rows=batch_rows,
            )
            results.append(result)
            p.future.set_result(result)
        return results

    def fail(self, pendings: Sequence[_Pending], exc: BaseException) -> None:
        for p in pendings:
            if not p.future.done():
                p.future.set_exception(exc)

    def drain_pending(self) -> List[_Pending]:
        """Pop EVERYTHING (shutdown without scoring — callers fail them)."""
        with self._lock:
            taken = list(self._queue)
            self._queue.clear()
            self._queued_rows = 0
        return taken
