"""Request queue + shape-bucketed dynamic batcher for the GNB serving loop.

Requests are ragged (any row count ≥ 1).  The batcher keeps one FIFO
queue per power-of-two row bucket (``repro.tune.bucket``) and each tick
coalesces ONE bucket's requests into a feature matrix padded to that
batch's bucket target — ``repro.tune.serve_pad_target``: the pow2 row
bucket covering the real rows, rounded up to the bucket backend's
quantum (the tuned fused ``block_n``, or the sublane quantum on a jnp
verdict).  Padded rows are pure garbage lanes that get sliced off; they
never reach a caller.  Because targets are pow2 buckets, the whole
traffic mix costs O(log max_rows) jit traces — and because a 5-row
request no longer pads to one global block shape, pad waste collapses
under mixed request sizes.

Two policies turn the buckets into batches:

- **primary pick**: the bucket whose head request has waited longest
  (global FIFO fairness — no bucket starves);
- **top-up**: after the primary bucket is drained up to
  ``max_batch_rows``, the gap between the real rows and the pad target
  is filled with requests from OTHER buckets that fit — a padding lane
  converted into a real row is a free occupancy win (same kernel shape,
  same trace).

Admission policy: a batch is formed as soon as the queues hold
``max_batch_rows`` rows OR the oldest request has waited
``max_delay_s`` — the classic dynamic-batching latency/throughput
dial.  Backpressure: when the queued rows would exceed
``max_queue_rows``, ``submit`` raises :class:`QueueFull` instead of
letting the queue grow without bound.

The batcher owns NO thread and NO kernel call — it is a pure data
structure (lock-protected deques) the server's run loop drives via
``ready()`` / ``form_batch()`` / ``complete()``, which keeps every
policy decision unit-testable without a running server.
"""

from __future__ import annotations

import collections
import dataclasses
import threading
import time
from concurrent.futures import Future
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro import tune
from repro.obs import trace

Array = np.ndarray


class QueueFull(RuntimeError):
    """Raised by ``submit`` when the queue bound would be exceeded."""


@dataclasses.dataclass(frozen=True)
class ServeResult:
    """What a request's future resolves to."""

    logits: Array  # (n_i, C)
    predictions: Array  # (n_i,)
    head_version: int  # the registry version that scored these rows
    latency_s: float  # enqueue → result
    batch_rows: int  # real rows of the batch this request rode in


@dataclasses.dataclass
class _Pending:
    features: Array
    rows: int
    future: Future
    enqueued_at: float
    trace_id: Optional[str] = None  # propagated submit → complete
    topup: bool = False  # rode another bucket's batch as a top-up


def pad_rows_to(features: Array, multiple: int) -> Array:
    """Zero-pad rows up to the next ``multiple`` (no-op when aligned)."""
    pad = (-features.shape[0]) % multiple
    if pad == 0:
        return features
    return np.pad(features, ((0, pad), (0, 0)))


def _pad_to_rows(features: Array, target: int) -> Array:
    """Zero-pad rows up to exactly ``target`` (no-op when already there)."""
    if features.shape[0] >= target:
        return features
    return np.pad(features, ((0, target - features.shape[0]), (0, 0)))


class DynamicBatcher:
    """Coalesce ragged requests into bucket-padded kernel batches."""

    def __init__(
        self,
        feature_dim: int,
        *,
        num_classes: Optional[int] = None,
        max_batch_rows: Optional[int] = None,
        max_delay_s: float = 2e-3,
        max_queue_rows: Optional[int] = None,
        row_multiple: Optional[int] = None,
    ):
        # ``row_multiple`` is the ALIGNMENT every pad target must divide
        # by (the mesh shard lcm when serving sharded) — the per-batch
        # pad target itself comes from ``tune.serve_pad_target``, so the
        # tuner's per-bucket verdicts pick each batch's padded shape.
        if row_multiple is None:
            row_multiple = tune.SERVE_ROW_ALIGN
        if max_batch_rows is None:
            max_batch_rows = 4 * tune.serve_row_multiple(feature_dim, num_classes)
        if max_queue_rows is None:
            max_queue_rows = 16 * max_batch_rows
        if max_batch_rows < 1 or max_queue_rows < max_batch_rows:
            raise ValueError(
                "need max_queue_rows >= max_batch_rows >= 1, got "
                f"{max_queue_rows} / {max_batch_rows}"
            )
        if row_multiple < 1:
            raise ValueError(f"row_multiple must be >= 1, got {row_multiple}")
        self.feature_dim = feature_dim
        self.num_classes = num_classes
        self.max_batch_rows = max_batch_rows
        self.max_delay_s = max_delay_s
        self.max_queue_rows = max_queue_rows
        self.row_multiple = row_multiple
        self._lock = threading.Lock()
        self._buckets: Dict[int, collections.deque[_Pending]] = {}
        self._queued_rows = 0

    # -- producer side ------------------------------------------------------

    def submit(self, features, *, trace_id: Optional[str] = None) -> Future:
        """Enqueue one request; returns a Future of :class:`ServeResult`.

        A request larger than ``max_batch_rows`` is admitted whole (it
        forms its own oversized batch) as long as it fits the queue
        bound; anything that would push the queue past
        ``max_queue_rows`` raises :class:`QueueFull` — callers see the
        backpressure instead of unbounded latency.

        ``trace_id`` pins the request's trace (the front mints one per
        request); omitted, the thread's active trace — or a fresh ID —
        is used.  The ID rides the queued request so the worker-thread
        spans (score, complete) join the same trace.
        """
        f = np.asarray(features, dtype=np.float32)
        if f.ndim != 2 or f.shape[1] != self.feature_dim:
            raise ValueError(
                f"expected (n, {self.feature_dim}) features, got {f.shape}"
            )
        if f.shape[0] < 1:
            raise ValueError("empty request (0 rows)")
        pending = _Pending(
            features=f, rows=f.shape[0], future=Future(),
            enqueued_at=time.perf_counter(),
        )
        with trace.span("serve.enqueue", trace_id=trace_id,
                        rows=pending.rows) as sp:
            pending.trace_id = sp.trace_id
            key = tune.bucket(pending.rows)
            sp.set(bucket=key)
            with self._lock:
                if self._queued_rows + pending.rows > self.max_queue_rows:
                    sp.fail("queue_full")
                    raise QueueFull(
                        f"queue holds {self._queued_rows} rows; "
                        f"+{pending.rows} exceeds the "
                        f"{self.max_queue_rows} bound"
                    )
                queue = self._buckets.get(key)
                if queue is None:
                    queue = self._buckets[key] = collections.deque()
                queue.append(pending)
                self._queued_rows += pending.rows
        return pending.future

    # -- consumer side (the server's run loop) ------------------------------

    @property
    def queued_rows(self) -> int:
        with self._lock:
            return self._queued_rows

    @property
    def pending_requests(self) -> int:
        with self._lock:
            return sum(len(q) for q in self._buckets.values())

    def queued_buckets(self) -> Dict[int, int]:
        """bucket → queued request count (introspection/tests)."""
        with self._lock:
            return {k: len(q) for k, q in self._buckets.items() if q}

    def pad_targets(self) -> List[int]:
        """The distinct padded shapes normal traffic can produce — the
        trace-warming set (oversized single requests may add more)."""
        return tune.serve_pad_targets(
            self.max_batch_rows, self.feature_dim, self.num_classes,
            align=self.row_multiple,
        )

    def ready(self, now: Optional[float] = None) -> bool:
        """Admission policy: enough rows, or the oldest waited too long."""
        now = time.perf_counter() if now is None else now
        with self._lock:
            oldest = self._oldest_locked()
            if oldest is None:
                return False
            if self._queued_rows >= self.max_batch_rows:
                return True
            return (now - oldest.enqueued_at) >= self.max_delay_s

    def _oldest_locked(self) -> Optional[_Pending]:
        oldest = None
        for queue in self._buckets.values():
            if queue and (oldest is None
                          or queue[0].enqueued_at < oldest.enqueued_at):
                oldest = queue[0]
        return oldest

    def _pad_target(self, rows: int) -> int:
        return tune.serve_pad_target(
            rows, self.feature_dim, self.num_classes, align=self.row_multiple
        )

    def form_batch(self) -> Tuple[List[_Pending], Array, int]:
        """Pop one bucket's FIFO (plus top-ups) and coalesce them.

        Returns ``(pendings, padded_features, real_rows)``.  The primary
        bucket is the one whose head request is oldest; its queue drains
        FIFO up to ``max_batch_rows`` (the first request is always
        admitted even if it alone exceeds the bound), the pad target is
        the batch's bucket shape, and the remaining padding lanes are
        topped up with fitting requests from other buckets — real rows
        in lanes the kernel would otherwise burn on zeros.
        """
        taken: List[_Pending] = []
        rows = 0
        with self._lock:
            oldest = self._oldest_locked()
            if oldest is None:
                return [], np.zeros((0, self.feature_dim), np.float32), 0
            primary_bucket = tune.bucket(oldest.rows)
            primary = self._buckets[primary_bucket]
            while primary:
                nxt = primary[0]
                if taken and rows + nxt.rows > self.max_batch_rows:
                    break
                primary.popleft()
                self._queued_rows -= nxt.rows
                taken.append(nxt)
                rows += nxt.rows
            target = self._pad_target(rows)
            # top-up: convert padding lanes into real rows, largest
            # fitting requests first; per-bucket FIFO order is kept (only
            # queue heads pop), so no request is overtaken within its
            # own bucket
            for key in sorted(self._buckets, reverse=True):
                queue = self._buckets[key]
                while queue and queue[0].rows <= target - rows:
                    nxt = queue.popleft()
                    self._queued_rows -= nxt.rows
                    nxt.topup = True
                    taken.append(nxt)
                    rows += nxt.rows
        if trace.enabled():
            with trace.span(
                "serve.batch_form", trace_id=taken[0].trace_id,
                bucket=primary_bucket, pad_target=target, rows=rows,
                trace_ids=[p.trace_id for p in taken],
                topup_trace_ids=[p.trace_id for p in taken if p.topup],
            ):
                pass
        feats = (
            taken[0].features
            if len(taken) == 1
            else np.concatenate([p.features for p in taken], axis=0)
        )
        return taken, _pad_to_rows(feats, target), rows

    def complete(
        self,
        pendings: Sequence[_Pending],
        logits,
        head_version: int,
        *,
        batch_rows: int,
    ) -> List[ServeResult]:
        """Slice per-request rows out of the batch logits, resolve futures."""
        logits = np.asarray(logits)
        now = time.perf_counter()
        offset = 0
        results: List[ServeResult] = []
        for p in pendings:
            sl = logits[offset : offset + p.rows]
            offset += p.rows
            result = ServeResult(
                logits=sl,
                predictions=np.argmax(sl, axis=-1),
                head_version=head_version,
                latency_s=now - p.enqueued_at,
                batch_rows=batch_rows,
            )
            with trace.span("serve.complete", trace_id=p.trace_id,
                            rows=p.rows, latency_s=result.latency_s,
                            head_version=head_version,
                            batch_rows=batch_rows, topup=p.topup):
                results.append(result)
                p.future.set_result(result)
        return results

    def fail(self, pendings: Sequence[_Pending], exc: BaseException) -> None:
        for p in pendings:
            if not p.future.done():
                with trace.span("serve.complete", trace_id=p.trace_id,
                                rows=p.rows, topup=p.topup) as sp:
                    sp.fail(str(exc) or type(exc).__name__)
                    p.future.set_exception(exc)

    def drain_pending(self) -> List[_Pending]:
        """Pop EVERYTHING (shutdown without scoring — callers fail them)."""
        with self._lock:
            taken = [p for q in self._buckets.values() for p in q]
            self._buckets.clear()
            self._queued_rows = 0
        return taken
