"""repro.serve — the GNB serving subsystem (queue → batcher → kernel → head).

FedCGS produces a training-free linear head from ONE communication
round of feature statistics; this package is the deployment half of
that story (ROADMAP "GNB serving as a real endpoint"): a request
queue with shape-bucketed dynamic batching over the fused
``kernels.gnb_logits`` Pallas kernel, a versioned head registry with
atomic hot-swap fed by completed
:class:`~repro.core.stats_pipeline.StatsPipeline` rounds, a
thread-driven run loop with latency/throughput/occupancy metrics and
graceful drain, and a multi-worker front with admission control,
load shedding, and snapshot-driven registry replication.

Layers (each importable on its own):

- :mod:`repro.serve.scoring`   — stateless row scoring: block-padded
  kernel call locally, pad-to-shards + ``shard_map`` on a mesh, with
  the jnp/fused backend resolved per per-shard shape;
- :mod:`repro.serve.metrics`   — latency percentiles (true
  nearest-rank), throughput, batch-occupancy and pad-waste counters
  (plus the shared ``timed`` wall-clock helper the benchmarks reuse);
- :mod:`repro.serve.batcher`   — per-shape-bucket request queues +
  the continuous batcher (admission by max-rows / max-delay,
  pad-to-bucket targets from ``repro.tune`` with cross-bucket top-up,
  backpressure);
- :mod:`repro.serve.registry`  — versioned ``LinearHead`` store with
  atomic publish/restore and the one-call "FL round → live head"
  ingest;
- :mod:`repro.serve.server`    — ``GNBServer`` gluing them together;
- :mod:`repro.serve.front`     — ``ServeFront``: N workers behind
  join-shortest-queue routing, load shedding, and the asyncio
  JSON-lines socket shim (``fedcgs-front``);
- :mod:`repro.serve.replicate` — ``RegistryReplicator``: poll shared
  :mod:`repro.checkpoint.store` snapshots and hot-swap replicas.
"""

from repro.serve.batcher import DynamicBatcher, QueueFull, ServeResult
from repro.serve.front import FrontMetrics, ServeFront
from repro.serve.metrics import ServeMetrics, timed
from repro.serve.registry import HeadRegistry
from repro.serve.replicate import RegistryReplicator, publish_snapshot
from repro.serve.scoring import score_features
from repro.serve.server import GNBServer

__all__ = [
    "DynamicBatcher",
    "FrontMetrics",
    "GNBServer",
    "HeadRegistry",
    "QueueFull",
    "RegistryReplicator",
    "ServeFront",
    "ServeMetrics",
    "ServeResult",
    "publish_snapshot",
    "score_features",
    "timed",
]
