"""repro.serve — the GNB serving subsystem (queue → batcher → kernel → head).

FedCGS produces a training-free linear head from ONE communication
round of feature statistics; this package is the deployment half of
that story (ROADMAP "GNB serving as a real endpoint"): a request
queue with dynamic batching over the fused ``kernels.gnb_logits``
Pallas kernel, a versioned head registry with atomic hot-swap fed by
completed :class:`~repro.core.stats_pipeline.StatsPipeline` rounds,
and a thread-driven run loop with latency/throughput/occupancy
metrics and graceful drain.

Layers (each importable on its own):

- :mod:`repro.serve.scoring`  — stateless row scoring: block-padded
  kernel call locally, pad-to-shards + ``shard_map`` on a mesh;
- :mod:`repro.serve.metrics`  — latency percentiles, throughput,
  batch-occupancy and pad-waste counters (plus the shared ``timed``
  wall-clock helper the benchmarks reuse);
- :mod:`repro.serve.batcher`  — the request queue + dynamic batcher
  (admission by max-rows / max-delay, block-multiple padding so the
  whole workload costs a handful of jit traces, backpressure);
- :mod:`repro.serve.registry` — versioned ``LinearHead`` store with
  atomic publish and the one-call "FL round → live head" ingest;
- :mod:`repro.serve.server`   — ``GNBServer`` gluing them together.
"""

from repro.serve.batcher import DynamicBatcher, QueueFull, ServeResult
from repro.serve.metrics import ServeMetrics, timed
from repro.serve.registry import HeadRegistry
from repro.serve.scoring import score_features
from repro.serve.server import GNBServer

__all__ = [
    "DynamicBatcher",
    "GNBServer",
    "HeadRegistry",
    "QueueFull",
    "ServeMetrics",
    "ServeResult",
    "score_features",
    "timed",
]
