"""Versioned GNB-head registry with atomic hot-swap.

The serving loop reads heads from here; one-shot FL rounds write them.
A head is an immutable :class:`~repro.core.classifier.LinearHead`
published under a monotonically increasing version; ``current()``
returns the live ``(version, head)`` as ONE tuple grabbed under the
registry lock, so a reader can never observe version i paired with
head j or a half-written (W, b) pair — swap atomicity is by
construction (immutable value, single reference assignment), not by
cooperation of the callers.

``refit_from_round`` is the "one-shot FL round → live model update"
call the tentpole asks for: give it a :class:`StatsPipeline` (ANY cell
of the backend × placement × privacy knob matrix, dropout recovery
included) plus the round's client data, and it aggregates the
statistics, derives (μ, Σ, π), fits the training-free head via
``core.classifier.gnb_head``, and publishes it — queued requests keep
flowing and simply start scoring under the new version at their next
batch tick.
"""

from __future__ import annotations

import threading
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.classifier import LinearHead, gnb_head
from repro.core.statistics import FeatureStats, derive_global
from repro.obs import trace


class HeadRegistry:
    """Thread-safe versioned store of served heads."""

    def __init__(self, head: Optional[LinearHead] = None, *, keep: int = 8):
        if keep < 1:
            raise ValueError(f"keep must be >= 1, got {keep}")
        self._lock = threading.Lock()
        self._keep = keep
        self._heads: Dict[int, LinearHead] = {}
        self._live: Optional[Tuple[int, LinearHead]] = None
        self._next_version = 0
        self._subscribers: List[Callable[[int], None]] = []
        if head is not None:
            self.publish(head)

    # -- write side ---------------------------------------------------------

    def publish(self, head: LinearHead) -> int:
        """Atomically make ``head`` the live version; returns its number.

        Old versions are retained (up to ``keep``) so in-flight
        responses can still be audited against the exact head that
        scored them.
        """
        if head.W.ndim != 2 or head.b.shape != (head.W.shape[0],):
            raise ValueError(
                f"malformed head: W {head.W.shape}, b {head.b.shape}"
            )
        with trace.span("registry.publish") as sp:
            with self._lock:
                version = self._next_version
                self._next_version += 1
                self._heads[version] = head
                self._live = (version, head)
                while len(self._heads) > self._keep:
                    oldest = min(self._heads)
                    if oldest == version:
                        break
                    del self._heads[oldest]
                subscribers = list(self._subscribers)
            sp.set(version=version, subscribers=len(subscribers))
            for cb in subscribers:
                cb(version)
        return version

    def refit_from_stats(self, stats: FeatureStats, *, ridge=None) -> int:
        """Aggregated (A, B, N) → derive (μ, Σ, π) → GNB head → publish."""
        return self.publish(gnb_head(derive_global(stats), ridge=ridge))

    def refit_from_round(
        self,
        pipeline,
        clients: Sequence,
        *,
        feature_dim: Optional[int] = None,
        ridge=None,
        extractor=None,
    ) -> int:
        """Run one FedCGS aggregation round and hot-swap the result in.

        ``pipeline`` is a :class:`repro.core.stats_pipeline.StatsPipeline`
        carrying the round's knobs (backend, placement, privacy,
        dropout/min_survivors); ``clients`` is its ``from_cohort``
        cohort.  Pass ``extractor=`` (the Extractor protocol) when the
        cohort holds RAW inputs — the round then streams
        extractor-forward → fold, so backbone + GNB refit as one
        pipeline.  The registry stays serveable the whole time — the
        swap is the last, atomic step.
        """
        if extractor is not None:
            pipeline = pipeline.replace(extractor=extractor)
        stats = pipeline.from_cohort(clients, feature_dim=feature_dim)
        return self.refit_from_stats(stats, ridge=ridge)

    # -- durable snapshots (checkpoint.store) -------------------------------

    def snapshot(self, directory: str, *, step: Optional[int] = None) -> str:
        """Persist every retained head (and the live version) as a pytree.

        Written through :mod:`repro.checkpoint.store` (flat npz, atomic
        rename), so replicas can pick the same round's heads off shared
        storage.  ``step`` defaults to one past the directory's latest
        snapshot.  Returns the written path.
        """
        from repro.checkpoint import store

        with self._lock:
            heads = dict(self._heads)
            live = -1 if self._live is None else self._live[0]
            next_version = self._next_version
        if step is None:
            last = store.latest_step(directory)
            step = 0 if last is None else last + 1
        tree = {
            "meta": {
                "live": np.int64(live),
                "next_version": np.int64(next_version),
            },
            "heads": {
                str(v): {"W": np.asarray(h.W), "b": np.asarray(h.b)}
                for v, h in heads.items()
            },
        }
        return store.save_pytree(tree, directory, step)

    def restore(self, directory: str, *, step: Optional[int] = None) -> Optional[int]:
        """Load a :meth:`snapshot` back in (atomic swap of ALL state).

        Returns the restored live version (None if the snapshot had no
        published head).  Version numbering continues from the
        snapshot's counter, so publishes after a restore never reuse a
        persisted version number.

        A restore that CHANGES the live version is a hot-swap exactly
        like :meth:`publish` — subscribers fire with the new version, so
        a replica restoring a newer FL round off shared storage records
        its swap metric and wakes any watcher callback.
        """
        from repro.checkpoint import store

        with trace.span("registry.restore", directory=directory) as sp:
            flat = store.load_flat(directory, step)
            live = int(flat["meta/live"])
            next_version = int(flat["meta/next_version"])
            heads: Dict[int, LinearHead] = {}
            for key, arr in flat.items():
                parts = key.split("/")
                if parts[0] == "heads" and parts[-1] == "W":
                    v = int(parts[1])
                    heads[v] = LinearHead(
                        W=jnp.asarray(arr), b=jnp.asarray(flat[f"heads/{v}/b"])
                    )
            with self._lock:
                prev_live = None if self._live is None else self._live[0]
                self._heads = heads
                self._live = None if live < 0 else (live, heads[live])
                self._next_version = max(
                    next_version, (max(heads) + 1) if heads else 0
                )
                subscribers = list(self._subscribers)
            swapped = live >= 0 and live != prev_live
            sp.set(live=live, heads=len(heads), swapped=swapped)
            if swapped:
                for cb in subscribers:
                    cb(live)
        return None if live < 0 else live

    def subscribe(self, callback: Callable[[int], None]) -> None:
        """``callback(version)`` fires after every publish (metrics hook)."""
        with self._lock:
            self._subscribers.append(callback)

    # -- read side ----------------------------------------------------------

    def current(self) -> Tuple[int, LinearHead]:
        with self._lock:
            if self._live is None:
                raise LookupError("registry has no published head yet")
            return self._live

    def head(self, version: int) -> LinearHead:
        with self._lock:
            try:
                return self._heads[version]
            except KeyError:
                raise LookupError(
                    f"head version {version} unknown or evicted "
                    f"(retained: {sorted(self._heads)})"
                ) from None

    @property
    def latest_version(self) -> Optional[int]:
        with self._lock:
            return None if self._live is None else self._live[0]

    def versions(self) -> List[int]:
        with self._lock:
            return sorted(self._heads)

    def __len__(self) -> int:
        with self._lock:
            return len(self._heads)
