"""serve.front — the multi-worker serving front (fan-out + load shed).

One :class:`GNBServer` is a single worker thread; the production tier
puts a front in front of N of them.  :class:`ServeFront` owns the
routing and admission policy:

- **routing** is join-shortest-queue by queued rows — the worker with
  the least backlog gets the request, which keeps per-worker batchers
  warm without any shared state beyond the queue-depth reads;
- **admission control** is two-level: an optional front-wide
  ``max_queued_rows`` bound (cheap reject before any worker is
  touched), then the workers' own queue bounds.  A request no worker
  can take is SHED — counted in the front metrics and surfaced to the
  caller as :class:`~repro.serve.batcher.QueueFull`, so offered load
  beyond capacity degrades into a measurable shed ratio instead of
  unbounded latency;
- **replication-ready**: workers usually share one
  :class:`~repro.serve.registry.HeadRegistry` (``ServeFront.create``),
  but each worker can equally own a replica registry driven off shared
  snapshots by :mod:`repro.serve.replicate` — the front never touches
  heads.

The socket shim (:func:`serve_socket` / ``fedcgs-front``) is an asyncio
front-end speaking newline-delimited JSON — ``{"features": [[...]]}``
in, ``{"logits": ..., "predictions": ..., "head_version": ...}`` (or
``{"error": "shed"}``) out.  The event loop only parses and routes;
every kernel call stays on the worker threads, and the
``concurrent.futures`` future from ``submit`` bridges back into the
loop via ``asyncio.wrap_future``.
"""

from __future__ import annotations

import argparse
import asyncio
import itertools
import json
from concurrent.futures import Future
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.obs import trace
from repro.obs.expo import metrics_payload
from repro.obs.registry import MetricsRegistry, default_registry
from repro.serve.batcher import QueueFull, ServeResult
from repro.serve.server import GNBServer

_front_ids = itertools.count()


class FrontMetrics:
    """Accepted/shed views over the shared front instrument families.

    Like :class:`~repro.serve.metrics.ServeMetrics`, this holds no
    private counters since the ``repro.obs`` rebase — ``snapshot()``
    reads the same labeled registry instruments the Prometheus
    exposition renders, so the socket scrape and the dict view can
    never disagree.
    """

    def __init__(self, *, registry: Optional[MetricsRegistry] = None,
                 front: Optional[str] = None):
        reg = registry if registry is not None else default_registry()
        self.front = front if front is not None else f"f{next(_front_ids)}"
        labels = ("front",)
        lv = {"front": self.front}
        self._accepted = reg.counter(
            "fedcgs_front_accepted_total",
            "Requests the front routed to a worker", labels).labels(**lv)
        self._shed = reg.counter(
            "fedcgs_front_shed_total",
            "Requests shed at admission (front bound or all workers full)",
            labels).labels(**lv)
        self._queued_rows = reg.gauge(
            "fedcgs_front_queued_rows",
            "Rows currently queued across the front's workers",
            labels).labels(**lv)

    def record_accepted(self) -> None:
        self._accepted.inc()

    def record_shed(self) -> None:
        self._shed.inc()

    def set_queued_rows(self, rows: int) -> None:
        self._queued_rows.set(rows)

    def snapshot(self) -> Dict[str, float]:
        accepted = int(self._accepted.value)
        shed = int(self._shed.value)
        offered = accepted + shed
        return {
            "accepted": accepted,
            "shed": shed,
            "shed_ratio": (shed / offered) if offered else 0.0,
        }


class ServeFront:
    """Fan ragged scoring requests across N :class:`GNBServer` workers."""

    def __init__(
        self,
        workers: Sequence[GNBServer],
        *,
        max_queued_rows: Optional[int] = None,
    ):
        workers = list(workers)
        if not workers:
            raise ValueError("need at least one worker")
        dims = {w.batcher.feature_dim for w in workers}
        if len(dims) != 1:
            raise ValueError(f"workers disagree on feature_dim: {sorted(dims)}")
        self.workers = workers
        self.max_queued_rows = max_queued_rows
        self.metrics = FrontMetrics()

    @classmethod
    def create(
        cls,
        num_workers: int,
        *,
        registry=None,
        head=None,
        max_queued_rows: Optional[int] = None,
        **server_kwargs,
    ) -> "ServeFront":
        """Build N workers sharing ONE registry (every worker hot-swaps
        on the same publish) and wrap them in a front."""
        from repro.serve.registry import HeadRegistry

        if num_workers < 1:
            raise ValueError(f"need num_workers >= 1, got {num_workers}")
        if registry is None:
            registry = HeadRegistry()
        if head is not None:
            registry.publish(head)
        workers = [
            GNBServer(registry=registry, **server_kwargs)
            for _ in range(num_workers)
        ]
        return cls(workers, max_queued_rows=max_queued_rows)

    # -- lifecycle ----------------------------------------------------------

    def start(self) -> "ServeFront":
        for w in self.workers:
            w.start()
        return self

    def __enter__(self) -> "ServeFront":
        return self.start()

    def __exit__(self, exc_type, exc, tb) -> None:
        self.shutdown(drain=exc_type is None)

    def shutdown(self, *, drain: bool = True,
                 timeout: Optional[float] = None) -> None:
        for w in self.workers:
            w.shutdown(drain=drain, timeout=timeout)

    def drain(self, timeout: Optional[float] = None) -> None:
        for w in self.workers:
            w.drain(timeout)

    # -- request side -------------------------------------------------------

    @property
    def feature_dim(self) -> int:
        return self.workers[0].batcher.feature_dim

    @property
    def queued_rows(self) -> int:
        return sum(w.batcher.queued_rows for w in self.workers)

    def submit(self, features) -> Future:
        """Route to the least-loaded worker; shed when none can take it.

        Sheds (front bound exceeded, or every worker at its queue
        bound) raise :class:`QueueFull` after counting — callers see
        the same backpressure signal a single worker gives.
        """
        f = np.asarray(features, dtype=np.float32)
        if f.ndim != 2 or f.shape[1] != self.feature_dim:
            raise ValueError(
                f"expected (n, {self.feature_dim}) features, got {f.shape}"
            )
        # one trace per request, minted here: accepted requests carry
        # the ID through enqueue → score → complete; shed requests end
        # their chain right here with error="shed"
        with trace.span("serve.submit", rows=int(f.shape[0])) as sp:
            if (
                self.max_queued_rows is not None
                and self.queued_rows + f.shape[0] > self.max_queued_rows
            ):
                self.metrics.record_shed()
                sp.fail("shed")
                raise QueueFull(
                    f"front holds {self.queued_rows} rows; +{f.shape[0]} "
                    f"exceeds the {self.max_queued_rows} bound (request shed)"
                )
            for worker in sorted(
                self.workers, key=lambda w: w.batcher.queued_rows
            ):
                try:
                    fut = worker.submit(f, trace_id=sp.trace_id)
                except QueueFull:
                    continue
                self.metrics.record_accepted()
                sp.set(worker=worker.metrics.worker)
                return fut
            self.metrics.record_shed()
            sp.fail("shed")
            raise QueueFull(
                "every worker is at its queue bound (request shed)"
            )

    def score(self, features, timeout: Optional[float] = None) -> ServeResult:
        """Synchronous convenience: submit + wait."""
        return self.submit(features).result(timeout=timeout)

    # -- metrics ------------------------------------------------------------

    def snapshot(self) -> Dict[str, object]:
        """Front counters + the aggregated worker view (JSON-ready)."""
        self.metrics.set_queued_rows(self.queued_rows)
        per_worker = [w.metrics.snapshot() for w in self.workers]
        agg: Dict[str, float] = {}
        if per_worker:
            for key in ("requests", "rows", "batches", "rejected",
                        "head_swaps", "score_time_s"):
                agg[key] = sum(s[key] for s in per_worker)
            rows = sum(s["rows"] for s in per_worker)
            padded = [
                s["rows"] / (1.0 - s["pad_waste_frac"])
                for s in per_worker
                if s["rows"] and s["pad_waste_frac"] == s["pad_waste_frac"]
            ]
            agg["pad_waste_frac"] = (
                1.0 - rows / sum(padded) if padded and sum(padded) else float("nan")
            )
            agg["latency_p99_ms"] = max(
                (s["latency_p99_ms"] for s in per_worker), default=float("nan")
            )
        return {
            "front": self.metrics.snapshot(),
            "workers": per_worker,
            "aggregate": agg,
        }


# -- asyncio socket shim -----------------------------------------------------

# asyncio streams default to a 64 KiB line limit — one ~50-row float32
# request (or a metrics/trace admin response) overflows it and kills the
# connection mid-stream.  JSON-lines framing means one message is one
# line, so the limit must cover the largest message we expect.
_STREAM_LIMIT = 1 << 26  # 64 MiB


def _handle_admin(front: ServeFront, req: dict) -> Optional[dict]:
    """Admin ops on the scoring socket (None = not an admin request).

    ``{"op": "metrics"}`` — live Prometheus text + JSON rendering of
    the process registry (the same instruments ``snapshot()`` views);
    ``{"op": "trace", "limit": N}`` — the newest buffered spans.
    Both are read-only and answered inline on the event loop (no
    kernel work), so a scrape can never queue behind traffic.
    """
    op = req.get("op")
    if op is None:
        return None
    if op == "metrics":
        front.metrics.set_queued_rows(front.queued_rows)
        payload = metrics_payload()
        payload["snapshot"] = front.snapshot()
        return payload
    if op == "trace":
        limit = req.get("limit")
        return {
            "tracing_enabled": trace.enabled(),
            "spans": trace.spans(limit=int(limit) if limit else None),
        }
    return {"error": f"unknown op: {op!r}"}


async def _handle_client(
    front: ServeFront,
    reader: asyncio.StreamReader,
    writer: asyncio.StreamWriter,
) -> None:
    try:
        while True:
            line = await reader.readline()
            if not line:
                break
            try:
                req = json.loads(line)
                resp = _handle_admin(front, req)
                if resp is None:
                    feats = np.asarray(req["features"], dtype=np.float32)
                    fut = front.submit(feats)
                    res = await asyncio.wrap_future(fut)
                    resp = {
                        "logits": np.asarray(res.logits).tolist(),
                        "predictions": np.asarray(res.predictions).tolist(),
                        "head_version": res.head_version,
                    }
            except QueueFull:
                resp = {"error": "shed"}
            except (KeyError, TypeError, ValueError,
                    json.JSONDecodeError) as exc:
                resp = {"error": f"bad request: {exc}"}
            writer.write((json.dumps(resp) + "\n").encode())
            await writer.drain()
    finally:
        writer.close()


async def serve_socket(
    front: ServeFront, host: str = "127.0.0.1", port: int = 0
):
    """Start the asyncio TCP front; returns the ``asyncio.Server``
    (bind address via ``server.sockets[0].getsockname()``)."""

    async def handler(reader, writer):
        await _handle_client(front, reader, writer)

    return await asyncio.start_server(
        handler, host, port, limit=_STREAM_LIMIT
    )


async def request_scores(
    host: str, port: int, requests: Sequence[np.ndarray]
) -> List[dict]:
    """Minimal JSON-lines client (tests, the smoke path): send every
    request over one connection, gather the decoded responses in order."""
    reader, writer = await asyncio.open_connection(
        host, port, limit=_STREAM_LIMIT
    )
    out: List[dict] = []
    try:
        for req in requests:
            msg = json.dumps({"features": np.asarray(req).tolist()}) + "\n"
            writer.write(msg.encode())
            await writer.drain()
            out.append(json.loads(await reader.readline()))
    finally:
        writer.close()
    return out


async def admin_request(host: str, port: int, req: dict) -> dict:
    """One admin op (``{"op": "metrics"}`` / ``{"op": "trace"}``) over a
    fresh connection; returns the decoded response."""
    reader, writer = await asyncio.open_connection(
        host, port, limit=_STREAM_LIMIT
    )
    try:
        writer.write((json.dumps(req) + "\n").encode())
        await writer.drain()
        return json.loads(await reader.readline())
    finally:
        writer.close()


# -- CLI ---------------------------------------------------------------------


def verify_span_chains(span_dicts: Sequence[dict], *, served: int,
                       shed: int) -> None:
    """Assert every request left a complete span chain in the buffer.

    Accepted requests must show ``serve.submit`` (no error) whose trace
    ID also appears on a ``serve.enqueue`` and an error-free
    ``serve.complete`` — the full submit → complete chain, including
    requests that rode another bucket's batch as top-ups.  Shed
    requests must show exactly their count of ``serve.submit`` spans
    stamped ``error="shed"`` (the chain ends at admission).
    """
    submits = [s for s in span_dicts if s["name"] == "serve.submit"]
    ok_submits = [s for s in submits if "error" not in s]
    shed_submits = [s for s in submits if s.get("error") == "shed"]
    enqueued = {s["trace_id"] for s in span_dicts
                if s["name"] == "serve.enqueue" and "error" not in s}
    completed = {s["trace_id"] for s in span_dicts
                 if s["name"] == "serve.complete" and "error" not in s}
    chains = [s for s in ok_submits
              if s["trace_id"] in enqueued and s["trace_id"] in completed]
    if len(chains) != served or len(ok_submits) != served:
        raise AssertionError(
            f"{served} served requests but {len(ok_submits)} accepted "
            f"submit spans, {len(chains)} with full submit→complete chains"
        )
    if len(shed_submits) != shed:
        raise AssertionError(
            f"{shed} shed requests but {len(shed_submits)} submit spans "
            "with error=\"shed\""
        )


async def _smoke(args) -> int:
    # deferred import: launch.serve_gnb itself imports repro.serve
    from repro.launch.serve_gnb import standin_head

    trace.enable()  # the smoke path always traces (self-check below)
    rng = np.random.default_rng(args.seed)
    head = standin_head(args.classes, args.feature_dim, args.seed)
    front = ServeFront.create(
        args.workers,
        head=head,
        max_delay_s=args.max_delay_ms * 1e-3,
        max_queued_rows=args.max_queued_rows,
    )
    sizes = np.clip(
        rng.poisson(args.batch, args.requests), 1, None
    ).astype(int)
    reqs = [
        rng.standard_normal((n, args.feature_dim)).astype(np.float32)
        for n in sizes
    ]
    with front:
        server = await serve_socket(front, args.host, args.port)
        host, port = server.sockets[0].getsockname()[:2]
        print(f"# fedcgs-front listening on {host}:{port} "
              f"({args.workers} workers)")
        responses = await request_scores(host, port, reqs)
        front.drain(timeout=120)
        admin = await admin_request(host, port, {"op": "metrics"})
        traced = await admin_request(
            host, port, {"op": "trace", "limit": 8}
        )
        server.close()
        await server.wait_closed()
        snap = front.snapshot()
    served = [r for r in responses if "logits" in r]
    shed = [r for r in responses if r.get("error") == "shed"]
    for res, req in zip(responses, reqs):
        if "logits" in res:
            assert len(res["logits"]) == req.shape[0], "row count mismatch"

    # self-check 1: the socket scrape parses as Prometheus text and
    # carries the same totals the in-process snapshot reports
    from repro.obs.expo import parse_prometheus

    prom = parse_prometheus(admin["metrics"])
    flabel = '{front="%s"}' % front.metrics.front
    assert prom["fedcgs_front_accepted_total"][flabel] == len(served), \
        "socket metrics disagree with served count"
    assert prom["fedcgs_front_shed_total"][flabel] == len(shed), \
        "socket metrics disagree with shed count"
    assert traced["tracing_enabled"] and traced["spans"], \
        "trace admin op returned no spans"

    # self-check 2: every request has a complete span chain
    all_spans = trace.spans()
    verify_span_chains(all_spans, served=len(served), shed=len(shed))

    if args.trace_out:
        n = trace.export_jsonl(args.trace_out)
        print(f"# wrote {n} spans to {args.trace_out}")
    if args.metrics_out:
        with open(args.metrics_out, "w") as fh:
            fh.write(admin["metrics"])
        print(f"# wrote metrics exposition to {args.metrics_out}")

    print(json.dumps(snap, indent=2))
    print(
        f"# served {len(served)}/{len(reqs)} requests over the socket "
        f"({len(shed)} shed, shed_ratio "
        f"{snap['front']['shed_ratio']:.3f}); "
        f"{len(all_spans)} spans, all chains complete"
    )
    return 0 if served else 1


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--workers", type=int, default=2,
                   help="number of GNBServer workers behind the front")
    p.add_argument("--host", default="127.0.0.1")
    p.add_argument("--port", type=int, default=0,
                   help="TCP port (0 = ephemeral)")
    p.add_argument("--requests", type=int, default=32,
                   help="ragged requests the smoke path pushes through")
    p.add_argument("--batch", type=int, default=48,
                   help="mean rows per request (ragged around it)")
    p.add_argument("--feature-dim", type=int, default=64)
    p.add_argument("--classes", type=int, default=10)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--max-delay-ms", type=float, default=2.0)
    p.add_argument("--max-queued-rows", type=int, default=None,
                   help="front-wide admission bound (rows)")
    p.add_argument("--trace-out", default=None,
                   help="write the buffered spans as JSONL here")
    p.add_argument("--metrics-out", default=None,
                   help="write the Prometheus text exposition here")
    p.add_argument("--smoke", action="store_true",
                   help="spin workers + socket, push synthetic traffic, "
                        "print the aggregated snapshot (what CI runs)")
    args = p.parse_args(argv)
    return asyncio.run(_smoke(args))


if __name__ == "__main__":
    raise SystemExit(main())
