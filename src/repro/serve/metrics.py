"""Serving metrics: latency percentiles, throughput, occupancy, pad waste.

Since the ``repro.obs`` rebase this module holds no private counters:
every number lives in a named, labeled instrument in the process-wide
:class:`repro.obs.MetricsRegistry` (one family per metric, one labeled
child per worker), and :meth:`ServeMetrics.snapshot` is a *view* over
those shared instruments — same dict, same keys as before, but the
same values are now scrapeable live as Prometheus text through
:mod:`repro.obs.expo` (the ``fedcgs-front`` socket's
``{"op": "metrics"}``).  Latency percentiles come from the registry
histogram: exact nearest-rank while the window holds every sample,
log-spaced bucket interpolation beyond it — snapshot cost is
O(#buckets), never the old sort of a 65536-entry deque under the lock.

The wall-clock primitive itself lives in the dependency-free
``repro.timing`` (re-exported here for the serve-facing API); the
benchmark reporter's ``timeit`` and the serve CLI wrap the same
function instead of hand-rolling ``time.time()`` pairs.
"""

from __future__ import annotations

import itertools
import math
import threading
import time
from typing import Dict, Optional

from repro.obs.registry import MetricsRegistry, default_registry
from repro.timing import timed

__all__ = ["ServeMetrics", "percentile", "timed"]

_worker_ids = itertools.count()


def percentile(sorted_values, q: float) -> float:
    """True nearest-rank percentile of an already-sorted sequence.

    The 1-based rank is ``ceil(q·N)`` (clamped to [1, N]), q in [0, 1].
    Not ``round()``: Python rounds half to even, so a rounded rank
    understates every percentile whose exact rank lands on .5 — the
    committed bench curves were reporting the sample *below* the true
    nearest rank at exactly the window sizes the smoke run produces.
    """
    n = len(sorted_values)
    if not n:
        return float("nan")
    rank = min(n, max(1, math.ceil(q * n)))
    return float(sorted_values[rank - 1])


class ServeMetrics:
    """Per-worker views over the shared serve instrument families.

    ``capacity_rows`` (the batcher's max-rows admission bound) turns the
    per-batch row counts into an occupancy fraction; without it the
    snapshot reports mean rows per batch instead.  A batch is accounted
    at ``max(capacity_rows, padded_rows)`` capacity: an oversized single
    request (admitted whole by the batcher's first-request rule) really
    occupied its padded shape, not the nominal bound — dividing it by
    ``capacity_rows`` alone reports occupancy > 1.0 and corrupts the
    bench curves.

    ``worker`` is this instance's label value in the registry (one is
    generated when omitted, so concurrent servers never share a
    child).  ``latency_window`` is accepted for API compatibility but
    superseded by the registry histogram's bounded exact window — the
    percentile path no longer retains (or sorts) the raw samples past
    it.
    """

    def __init__(self, *, capacity_rows: Optional[int] = None,
                 latency_window: int = 65536,
                 registry: Optional[MetricsRegistry] = None,
                 worker: Optional[str] = None):
        del latency_window  # superseded by the obs histogram window
        reg = registry if registry is not None else default_registry()
        self.worker = worker if worker is not None else f"w{next(_worker_ids)}"
        self._capacity_rows = capacity_rows
        labels = ("worker",)
        lv = {"worker": self.worker}
        self._requests = reg.counter(
            "fedcgs_serve_requests_total",
            "Requests scored by the serving loop", labels).labels(**lv)
        self._rows = reg.counter(
            "fedcgs_serve_rows_total",
            "Real feature rows scored", labels).labels(**lv)
        self._padded_rows = reg.counter(
            "fedcgs_serve_padded_rows_total",
            "Kernel rows including padding lanes", labels).labels(**lv)
        self._capacity_sum = reg.counter(
            "fedcgs_serve_capacity_rows_total",
            "Row capacity the formed batches were accounted at",
            labels).labels(**lv)
        self._batches = reg.counter(
            "fedcgs_serve_batches_total",
            "Batches formed and scored", labels).labels(**lv)
        self._score_s = reg.counter(
            "fedcgs_serve_score_seconds_total",
            "Wall seconds spent inside kernel scoring", labels).labels(**lv)
        self._swaps = reg.counter(
            "fedcgs_serve_head_swaps_total",
            "Registry hot-swaps observed after the initial head",
            labels).labels(**lv)
        self._rejected = reg.counter(
            "fedcgs_serve_rejected_total",
            "Submissions rejected at the worker queue bound",
            labels).labels(**lv)
        self._latency = reg.histogram(
            "fedcgs_serve_latency_seconds",
            "End-to-end request latency (enqueue to result)",
            labels).labels(**lv)
        # throughput-span anchors: plain attrs, guarded by one lock
        self._lock = threading.Lock()
        self._first_t: Optional[float] = None
        self._last_t: Optional[float] = None

    # -- recording ----------------------------------------------------------

    def record_batch(self, *, requests: int, rows: int, padded_rows: int,
                     score_s: float,
                     enqueued_t: Optional[float] = None) -> None:
        """Account one scored batch.

        ``enqueued_t`` is the batch's earliest request-submit time
        (``time.perf_counter()`` clock): the throughput span is
        anchored there, so the first window includes the queue wait.
        The old anchor ``now - score_s`` backdated only by the kernel
        time and overstated ``throughput_*`` whenever the first batch
        had waited in the queue.  Callers without a submit timestamp
        fall back to that old anchor.
        """
        now = time.perf_counter()
        self._batches.inc()
        self._requests.inc(requests)
        self._rows.inc(rows)
        self._padded_rows.inc(padded_rows)
        self._capacity_sum.inc(max(self._capacity_rows or 0, padded_rows))
        self._score_s.inc(score_s)
        anchor = enqueued_t if enqueued_t is not None else now - score_s
        with self._lock:
            if self._first_t is None or anchor < self._first_t:
                self._first_t = anchor
            self._last_t = now

    def record_latency(self, seconds: float) -> None:
        self._latency.observe(seconds)

    def record_swap(self) -> None:
        self._swaps.inc()

    def record_rejected(self) -> None:
        self._rejected.inc()

    # -- reading ------------------------------------------------------------

    def snapshot(self) -> Dict[str, float]:
        """A plain-dict view of everything (JSON-ready).

        Reads the shared instruments; each is individually consistent
        (its own lock) but the dict is not one atomic cut across all of
        them — the usual scrape semantics.
        """
        with self._lock:
            first_t, last_t = self._first_t, self._last_t
        span = (
            (last_t - first_t)
            if first_t is not None and last_t is not None and last_t > first_t
            else float("nan")
        )
        requests = self._requests.value
        rows = self._rows.value
        padded = self._padded_rows.value
        capacity_sum = self._capacity_sum.value
        batches = self._batches.value
        occupancy = (
            rows / capacity_sum
            if self._capacity_rows and capacity_sum
            else (rows / batches if batches else float("nan"))
        )
        return {
            "requests": int(requests),
            "rows": int(rows),
            "batches": int(batches),
            "rejected": int(self._rejected.value),
            "head_swaps": int(self._swaps.value),
            "latency_p50_ms": self._latency.percentile(0.50) * 1e3,
            "latency_p95_ms": self._latency.percentile(0.95) * 1e3,
            "latency_p99_ms": self._latency.percentile(0.99) * 1e3,
            "throughput_rps": (
                float("nan") if math.isnan(span) else requests / span
            ),
            "throughput_rows_s": (
                float("nan") if math.isnan(span) else rows / span
            ),
            "batch_occupancy": occupancy,
            "pad_waste_frac": (
                1.0 - rows / padded if padded else float("nan")
            ),
            "score_time_s": self._score_s.value,
        }
