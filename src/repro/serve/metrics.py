"""Serving metrics: latency percentiles, throughput, occupancy, pad waste.

One thread-safe accumulator the server's run loop feeds per tick.  The
counters answer the questions a dynamic batcher raises: how long do
requests wait end-to-end (p50/p95/p99), how full are the batches the
kernel actually sees (occupancy), and how many padded rows were burned
to keep the jit-trace count bounded (pad waste).

The wall-clock primitive itself lives in the dependency-free
``repro.timing`` (re-exported here for the serve-facing API); the
benchmark reporter's ``timeit`` and the serve CLI wrap the same
function instead of hand-rolling ``time.time()`` pairs.
"""

from __future__ import annotations

import collections
import math
import threading
import time
from typing import Dict, Optional

from repro.timing import timed

__all__ = ["ServeMetrics", "percentile", "timed"]


def percentile(sorted_values, q: float) -> float:
    """True nearest-rank percentile of an already-sorted sequence.

    The 1-based rank is ``ceil(q·N)`` (clamped to [1, N]), q in [0, 1].
    Not ``round()``: Python rounds half to even, so a rounded rank
    understates every percentile whose exact rank lands on .5 — the
    committed bench curves were reporting the sample *below* the true
    nearest rank at exactly the window sizes the smoke run produces.
    """
    n = len(sorted_values)
    if not n:
        return float("nan")
    rank = min(n, max(1, math.ceil(q * n)))
    return float(sorted_values[rank - 1])


class ServeMetrics:
    """Counters for the serving loop (all methods thread-safe).

    ``capacity_rows`` (the batcher's max-rows admission bound) turns the
    per-batch row counts into an occupancy fraction; without it the
    snapshot reports mean rows per batch instead.  A batch is accounted
    at ``max(capacity_rows, padded_rows)`` capacity: an oversized single
    request (admitted whole by the batcher's first-request rule) really
    occupied its padded shape, not the nominal bound — dividing it by
    ``capacity_rows`` alone reports occupancy > 1.0 and corrupts the
    bench curves.
    """

    def __init__(self, *, capacity_rows: Optional[int] = None,
                 latency_window: int = 65536):
        self._lock = threading.Lock()
        self._capacity_rows = capacity_rows
        self._latencies = collections.deque(maxlen=latency_window)
        self._requests = 0
        self._rows = 0
        self._padded_rows = 0
        self._capacity_sum = 0
        self._batches = 0
        self._score_s = 0.0
        self._swaps = 0
        self._rejected = 0
        self._first_t: Optional[float] = None
        self._last_t: Optional[float] = None

    # -- recording ----------------------------------------------------------

    def record_batch(self, *, requests: int, rows: int, padded_rows: int,
                     score_s: float) -> None:
        now = time.perf_counter()
        with self._lock:
            self._batches += 1
            self._requests += requests
            self._rows += rows
            self._padded_rows += padded_rows
            self._capacity_sum += max(self._capacity_rows or 0, padded_rows)
            self._score_s += score_s
            if self._first_t is None:
                self._first_t = now - score_s
            self._last_t = now

    def record_latency(self, seconds: float) -> None:
        with self._lock:
            self._latencies.append(seconds)

    def record_swap(self) -> None:
        with self._lock:
            self._swaps += 1

    def record_rejected(self) -> None:
        with self._lock:
            self._rejected += 1

    # -- reading ------------------------------------------------------------

    def snapshot(self) -> Dict[str, float]:
        """A plain-dict view of everything (JSON-ready)."""
        with self._lock:
            lat = sorted(self._latencies)
            span = (
                (self._last_t - self._first_t)
                if self._first_t is not None and self._last_t > self._first_t
                else float("nan")
            )
            occupancy = (
                self._rows / self._capacity_sum
                if self._capacity_rows and self._capacity_sum
                else (self._rows / self._batches if self._batches else float("nan"))
            )
            return {
                "requests": self._requests,
                "rows": self._rows,
                "batches": self._batches,
                "rejected": self._rejected,
                "head_swaps": self._swaps,
                "latency_p50_ms": percentile(lat, 0.50) * 1e3,
                "latency_p95_ms": percentile(lat, 0.95) * 1e3,
                "latency_p99_ms": percentile(lat, 0.99) * 1e3,
                "throughput_rps": (
                    self._requests / span if span == span else float("nan")
                ),
                "throughput_rows_s": (
                    self._rows / span if span == span else float("nan")
                ),
                "batch_occupancy": occupancy,
                "pad_waste_frac": (
                    1.0 - self._rows / self._padded_rows
                    if self._padded_rows
                    else float("nan")
                ),
                "score_time_s": self._score_s,
            }
