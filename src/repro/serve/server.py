"""GNBServer — the thread-driven run loop tying the subsystem together.

One worker thread owns every kernel call: it polls the batcher's
admission policy, forms a block-padded batch, reads the live
``(version, head)`` atomically from the registry, scores the batch
(locally or row-sharded over a mesh via :func:`serve.scoring`), and
resolves the per-request futures — recording latency percentiles,
throughput, batch occupancy and pad waste into :class:`ServeMetrics`.

Hot-swap is free here: the registry is read once per tick, so every
request in a batch is scored by exactly one head version (the one
recorded in its :class:`ServeResult`), and a ``refit_from_round``
landing mid-traffic simply takes effect at the next tick without
dropping anything queued.

Lifecycle: ``start()`` (or use as a context manager) → ``submit()`` /
``score()`` → ``drain()`` (flush the queue, keep serving) or
``shutdown()`` (graceful by default: stop admissions, drain, stop the
thread; ``drain=False`` fails whatever is still queued).
"""

from __future__ import annotations

import math
import threading
from concurrent.futures import Future
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.classifier import LinearHead
from repro.obs import trace
from repro.serve.batcher import DynamicBatcher, ServeResult
from repro.serve.metrics import ServeMetrics, timed
from repro.serve.registry import HeadRegistry
from repro.serve.scoring import num_shards, score_features

from repro import tune


class GNBServer:
    """Dynamic-batching server for the FedCGS GNB head."""

    def __init__(
        self,
        head: Optional[LinearHead] = None,
        *,
        registry: Optional[HeadRegistry] = None,
        feature_dim: Optional[int] = None,
        mesh=None,
        client_axes: Tuple[str, ...] = ("data",),
        interpret: Optional[bool] = None,
        max_batch_rows: Optional[int] = None,
        max_delay_s: float = 2e-3,
        max_queue_rows: Optional[int] = None,
        poll_interval_s: float = 1e-4,
    ):
        if registry is None:
            registry = HeadRegistry()
        if head is not None:
            registry.publish(head)
        if registry.latest_version is None:
            raise ValueError("need an initial head (or a non-empty registry)")
        self.registry = registry
        _, live = registry.current()
        d = int(live.W.shape[1]) if feature_dim is None else feature_dim
        classes = int(live.W.shape[0])
        self.mesh = mesh
        self.client_axes = client_axes
        self.interpret = interpret
        # pad alignment: every bucket target must divide the live shard
        # count so the mesh path never re-pads what the batcher padded;
        # batch capacity defaults still scale with the tuned row multiple
        align = tune.SERVE_ROW_ALIGN
        if mesh is not None:
            align = math.lcm(align, num_shards(mesh, client_axes))
        if max_batch_rows is None:
            max_batch_rows = 4 * tune.serve_row_multiple(d, classes)
        if max_queue_rows is None:
            max_queue_rows = 16 * max_batch_rows
        self.batcher = DynamicBatcher(
            d,
            num_classes=classes,
            max_batch_rows=max_batch_rows,
            max_delay_s=max_delay_s,
            max_queue_rows=max_queue_rows,
            row_multiple=align,
        )
        self.metrics = ServeMetrics(capacity_rows=max_batch_rows)
        # count hot-swaps AFTER the initial head: every later publish
        # (or replica restore) is one
        self.registry.subscribe(lambda _v: self.metrics.record_swap())
        self._poll_interval_s = poll_interval_s
        self._state_lock = threading.Lock()
        self._closed = False
        self._stop = threading.Event()
        self._tick_busy = threading.Event()
        self._thread: Optional[threading.Thread] = None

    # -- lifecycle ----------------------------------------------------------

    def start(self) -> "GNBServer":
        if self._thread is not None:
            raise RuntimeError("server already started")
        self._thread = threading.Thread(
            target=self._run_loop, name="gnb-serve", daemon=True
        )
        self._thread.start()
        return self

    def __enter__(self) -> "GNBServer":
        return self.start()

    def __exit__(self, exc_type, exc, tb) -> None:
        self.shutdown(drain=exc_type is None)

    @property
    def running(self) -> bool:
        return self._thread is not None and self._thread.is_alive()

    def drain(self, timeout: Optional[float] = None) -> None:
        """Block until everything queued has been scored (keeps serving).

        Raises ``RuntimeError`` when work is queued but no worker is
        alive to score it — a drain before ``start()`` (or after the
        worker died) would otherwise spin forever on a non-empty queue.
        """
        deadline = None if timeout is None else timeout + _now()
        while True:
            busy = self._tick_busy.is_set()
            if not self.batcher.pending_requests and not busy:
                return
            if not self.running:
                raise RuntimeError(
                    "drain() with work queued but no running worker — "
                    "start() the server (or check it did not die)"
                )
            if deadline is not None and _now() > deadline:
                raise TimeoutError("drain timed out")
            _sleep(self._poll_interval_s)

    def shutdown(self, *, drain: bool = True, timeout: Optional[float] = None) -> None:
        """Stop admissions; drain (default) or fail the queue; stop the thread.

        A drain timeout still stops the worker and fails whatever is
        left queued (then re-raises), so the server is never left
        half-shut with futures that can no longer resolve.
        """
        with self._state_lock:
            self._closed = True
        try:
            if drain and self.running:
                self.drain(timeout)
        finally:
            self._stop.set()
            if self._thread is not None:
                self._thread.join(timeout)
            leftovers = self.batcher.drain_pending()
            if leftovers:
                self.batcher.fail(
                    leftovers, RuntimeError("server shut down before scoring")
                )

    # -- request side -------------------------------------------------------

    def submit(self, features, *, trace_id: Optional[str] = None) -> Future:
        """Enqueue rows; the Future resolves to a :class:`ServeResult`.

        Raises :class:`serve.batcher.QueueFull` under backpressure and
        ``RuntimeError`` once the server stopped admitting.
        ``trace_id`` pins the request's trace (the front passes its
        per-request ID through; direct callers may omit it).
        """
        # enqueue under the state lock: a concurrent shutdown() cannot
        # close-and-fail the queue between our _closed check and the
        # enqueue, which would strand this request's future forever
        with self._state_lock:
            if self._closed:
                raise RuntimeError("server is shut down (not admitting)")
            try:
                return self.batcher.submit(features, trace_id=trace_id)
            except Exception:
                self.metrics.record_rejected()
                raise

    def score(self, features, timeout: Optional[float] = None) -> ServeResult:
        """Synchronous convenience: submit + wait."""
        return self.submit(features).result(timeout=timeout)

    # -- worker -------------------------------------------------------------

    def _run_loop(self) -> None:
        while True:
            if self._stop.is_set():
                return
            if self.batcher.ready():
                # the busy window is an Event (atomic set/clear/is_set),
                # not a bare bool: drain() reads it from other threads
                self._tick_busy.set()
                try:
                    self._tick()
                finally:
                    self._tick_busy.clear()
            else:
                _sleep(self._poll_interval_s)

    def _tick(self) -> None:
        pendings, padded, rows = self.batcher.form_batch()
        if not pendings:
            return
        version, head = self.registry.current()  # atomic (version, head) read
        try:
            with trace.span(
                "serve.score", trace_id=pendings[0].trace_id,
                rows=rows, padded_rows=int(padded.shape[0]),
                head_version=version,
            ) as sp:
                if trace.enabled():
                    sp.set(trace_ids=[p.trace_id for p in pendings])
                logits, dt = timed(self._score_padded, padded, head)
                logits = np.asarray(logits)[:rows]  # blocks until ready
        except Exception as exc:  # noqa: BLE001 — fail the batch, keep serving
            self.batcher.fail(pendings, exc)
            return
        results = self.batcher.complete(pendings, logits, version, batch_rows=rows)
        self.metrics.record_batch(
            requests=len(pendings), rows=rows, padded_rows=padded.shape[0],
            score_s=dt,
            enqueued_t=min(p.enqueued_at for p in pendings),
        )
        for r in results:
            self.metrics.record_latency(r.latency_s)

    def _score_padded(self, padded: np.ndarray, head: LinearHead):
        return score_features(
            padded, head.W, head.b,
            mesh=self.mesh, client_axes=self.client_axes,
            interpret=self.interpret,
        )


def _now() -> float:
    import time

    return time.perf_counter()


def _sleep(seconds: float) -> None:
    import time

    time.sleep(seconds)


def serve_requests(
    server: GNBServer, requests: Sequence[np.ndarray],
    timeout: Optional[float] = None,
) -> List[ServeResult]:
    """Submit a request list and gather results in order (test/CLI helper)."""
    futures = [server.submit(r) for r in requests]
    return [f.result(timeout=timeout) for f in futures]
