"""Stateless GNB scoring: feature rows → logits through the fused kernel
or its jnp twin — ``backend="auto"`` picks per shape via ``repro.tune``.

The one compute path every serving layer shares.  Locally the jit'd
``kernels.gnb_logits`` wrapper owns block padding; on a mesh the rows
are first padded to divide the live client axes (zero rows score
garbage logits that are sliced off — the head is replicated, logits
are row-parallel, so the shard_map needs no collective).  The batcher
feeds this function row counts that are already block multiples, so
the whole serving workload compiles to a handful of traces.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.kernels import gnb_logits, gnb_logits_jnp
from repro.kernels.ops import AUDITED_JITS as _KERNEL_JITS
from repro.obs import trace
from repro.sharding import shard_map

Array = jax.Array

# The jitted twins the serving hot path dispatches between — exported
# for the invariant-audit suite (repro.analysis.budgets): the whole
# serve workload must compile to a handful of traces on exactly these.
AUDITED_JITS = {
    "serve.scoring.gnb_logits": _KERNEL_JITS["kernels.gnb_logits"],
    "serve.scoring.gnb_logits_jnp": gnb_logits_jnp,
}

BACKENDS = ("auto", "jnp", "fused")


def resolve_backend(backend: str, rows: int, d: int, num_classes: int) -> str:
    """Resolve ``backend="auto"`` at the shape the kernel will actually
    see.  ``rows`` must be the PER-SHARD row count on a mesh — each
    shard scores ``n/shards`` rows, which can land in a different pow2
    bucket than the global batch, and the tuner's verdict only holds at
    the bucket it was measured on.
    """
    if backend == "auto":
        from repro import tune

        backend = tune.gnb_backend(int(rows), int(d), int(num_classes))
    if backend not in ("jnp", "fused"):
        raise ValueError(f"backend must be one of {BACKENDS}, got {backend!r}")
    return backend


def live_axes(mesh: Mesh, client_axes: Tuple[str, ...]) -> Tuple[str, ...]:
    return tuple(a for a in client_axes if a in mesh.axis_names)


def num_shards(mesh: Mesh, client_axes: Tuple[str, ...]) -> int:
    from repro.launch.stats_engine import _num_shards

    return _num_shards(mesh, live_axes(mesh, client_axes))


def score_features(
    features: Array,
    w: Array,
    b: Array,
    *,
    mesh: Optional[Mesh] = None,
    client_axes: Tuple[str, ...] = ("data",),
    interpret: Optional[bool] = None,
    extractor=None,
    backend: str = "auto",
) -> Array:
    """logits (n, C) for feature rows (n, d) under head (w (C, d), b (C,)).

    With ``mesh`` the rows are sharded over the live ``client_axes``:
    any row count is accepted — rows are zero-padded up to the shard
    count (pad-to-shards) and the padding is sliced back off, so ragged
    request batches never error out of the mesh path.

    With ``extractor=`` (the Extractor protocol), ``features`` is the
    RAW input batch and backbone + GNB score as one pipeline: the
    extractor's own jit runs first, then its rows flow through the
    audited scoring path unchanged (same traces, zero collectives).

    ``backend="auto"`` (default) asks ``repro.tune`` to pick the fused
    kernel vs its jitted jnp twin for this (rows, d, C) bucket — the
    tuner's measured winner, or the crossover heuristic when untuned
    (which keeps non-TPU hosts on the fused path, today's behaviour).
    Either twin compiles to one trace per padded shape, zero
    collectives, so the audited serving invariants hold regardless of
    the verdict.
    """
    if extractor is not None:
        features = extractor.features(features)
    features = jnp.asarray(features)
    n = features.shape[0]
    d, c = int(features.shape[1]), int(w.shape[0])

    def _score(f_: Array, w_: Array, b_: Array) -> Array:
        # the device-profile annotation names the audited jit being
        # dispatched, so a jax.profiler capture lines up with the host
        # `serve.score_features` span by name
        if backend == "jnp":
            with trace.annotate("serve.scoring.gnb_logits_jnp"):
                return gnb_logits_jnp(f_, w_, b_)
        with trace.annotate("serve.scoring.gnb_logits"):
            return gnb_logits(f_, w_, b_, interpret=interpret)

    if mesh is None:
        backend = resolve_backend(backend, n, d, c)
        with trace.span("serve.score_features", backend=backend,
                        rows=n, feature_dim=d):
            return _score(features, w, b)

    axes = live_axes(mesh, client_axes)
    if not axes:
        backend = resolve_backend(backend, n, d, c)
        with trace.span("serve.score_features", backend=backend,
                        rows=n, feature_dim=d):
            return _score(features, w, b)
    shards = num_shards(mesh, client_axes)
    pad = (-n) % shards
    if pad:
        features = jnp.pad(features, ((0, pad), (0, 0)))
    # pad-to-shards FIRST, then resolve on the per-shard shape: the tune
    # verdict must match the rows each shard's kernel call actually sees
    backend = resolve_backend(backend, features.shape[0] // shards, d, c)

    def shard_fn(f_shard: Array, w_: Array, b_: Array) -> Array:
        return _score(f_shard, w_, b_)

    fn = shard_map(
        shard_fn, mesh=mesh,
        in_specs=(P(axes), P(), P()),
        out_specs=P(axes),
        check_rep=False,  # pallas_call has no replication rule
    )
    with trace.span("serve.score_features", backend=backend, rows=n,
                    feature_dim=d, shards=shards):
        return fn(features, w, b)[:n]
