"""Stateless GNB scoring: feature rows → logits through the fused kernel.

The one compute path every serving layer shares.  Locally the jit'd
``kernels.gnb_logits`` wrapper owns block padding; on a mesh the rows
are first padded to divide the live client axes (zero rows score
garbage logits that are sliced off — the head is replicated, logits
are row-parallel, so the shard_map needs no collective).  The batcher
feeds this function row counts that are already block multiples, so
the whole serving workload compiles to a handful of traces.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.kernels import gnb_logits
from repro.sharding import shard_map

Array = jax.Array

# The one jitted kernel the serving hot path runs — exported for the
# invariant-audit suite (repro.analysis.budgets): the whole serve
# workload must compile to a handful of traces on exactly this jit.
AUDITED_JITS = {"serve.scoring.gnb_logits": gnb_logits}


def live_axes(mesh: Mesh, client_axes: Tuple[str, ...]) -> Tuple[str, ...]:
    return tuple(a for a in client_axes if a in mesh.axis_names)


def num_shards(mesh: Mesh, client_axes: Tuple[str, ...]) -> int:
    from repro.launch.stats_engine import _num_shards

    return _num_shards(mesh, live_axes(mesh, client_axes))


def score_features(
    features: Array,
    w: Array,
    b: Array,
    *,
    mesh: Optional[Mesh] = None,
    client_axes: Tuple[str, ...] = ("data",),
    interpret: Optional[bool] = None,
    extractor=None,
) -> Array:
    """logits (n, C) for feature rows (n, d) under head (w (C, d), b (C,)).

    With ``mesh`` the rows are sharded over the live ``client_axes``:
    any row count is accepted — rows are zero-padded up to the shard
    count (pad-to-shards) and the padding is sliced back off, so ragged
    request batches never error out of the mesh path.

    With ``extractor=`` (the Extractor protocol), ``features`` is the
    RAW input batch and backbone + GNB score as one pipeline: the
    extractor's own jit runs first, then its rows flow through the
    audited scoring path unchanged (same traces, zero collectives).
    """
    if extractor is not None:
        features = extractor.features(features)
    features = jnp.asarray(features)
    n = features.shape[0]
    if mesh is None:
        return gnb_logits(features, w, b, interpret=interpret)

    axes = live_axes(mesh, client_axes)
    if not axes:
        return gnb_logits(features, w, b, interpret=interpret)
    shards = num_shards(mesh, client_axes)
    pad = (-n) % shards
    if pad:
        features = jnp.pad(features, ((0, pad), (0, 0)))

    def shard_fn(f_shard: Array, w_: Array, b_: Array) -> Array:
        return gnb_logits(f_shard, w_, b_, interpret=interpret)

    fn = shard_map(
        shard_fn, mesh=mesh,
        in_specs=(P(axes), P(), P()),
        out_specs=P(axes),
        check_rep=False,  # pallas_call has no replication rule
    )
    return fn(features, w, b)[:n]
