"""Lock-discipline race checker for the serving subsystem.

``repro.serve`` promises thread safety by convention: every shared
``self._*`` field of the batcher/registry/server/metrics classes is
read and written under ``with self._lock`` (or ``self._state_lock``).
Nothing enforced that — a new method touching ``self._queue`` without
the lock would pass every existing test and race only under load.

This AST pass *learns* the convention instead of hard-coding a field
list: for each class it collects the attributes that are ever WRITTEN
inside a ``with self.<…lock>:`` block, then flags any read or write of
those same attributes outside such a block.  ``__init__`` is exempt
(construction happens-before publication to other threads), and the
body of a nested function is never considered guarded even when the
``def`` sits inside a locked block — the lock is held at definition
time, not call time.

Single-writer flags that are deliberately unguarded (e.g. the server's
``_in_tick``) are never written under a lock, so they are not tracked —
the checker flags inconsistency, not lock-freedom.
"""

from __future__ import annotations

import ast
import os
import re
from typing import List, Optional, Set

from repro.analysis.findings import Finding

LOCK_ATTR_RE = re.compile(r"^_\w*lock$")


def _is_self_attr(node: ast.AST) -> Optional[str]:
    if (
        isinstance(node, ast.Attribute)
        and isinstance(node.value, ast.Name)
        and node.value.id == "self"
    ):
        return node.attr
    return None


def _lock_items(node: ast.With) -> bool:
    for item in node.items:
        attr = _is_self_attr(item.context_expr)
        if attr is not None and LOCK_ATTR_RE.match(attr):
            return True
    return False


class _Access:
    __slots__ = ("attr", "is_write", "guarded", "line", "method")

    def __init__(self, attr, is_write, guarded, line, method):
        self.attr = attr
        self.is_write = is_write
        self.guarded = guarded
        self.line = line
        self.method = method


class _MethodVisitor(ast.NodeVisitor):
    """Collects self-attribute accesses with their lock context."""

    def __init__(self, method_name: str):
        self.method = method_name
        self.accesses: List[_Access] = []
        self._guard_depth = 0

    def visit_With(self, node: ast.With) -> None:
        for item in node.items:
            self.visit(item.context_expr)
            if item.optional_vars is not None:
                self.visit(item.optional_vars)
        if _lock_items(node):
            self._guard_depth += 1
            for stmt in node.body:
                self.visit(stmt)
            self._guard_depth -= 1
        else:
            for stmt in node.body:
                self.visit(stmt)

    # a nested def/lambda runs later, when the lock may not be held
    def _visit_unguarded(self, node: ast.AST) -> None:
        saved = self._guard_depth
        self._guard_depth = 0
        self.generic_visit(node)
        self._guard_depth = saved

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        self._visit_unguarded(node)

    def visit_AsyncFunctionDef(self, node: ast.AsyncFunctionDef) -> None:
        self._visit_unguarded(node)

    def visit_Lambda(self, node: ast.Lambda) -> None:
        self._visit_unguarded(node)

    def visit_Attribute(self, node: ast.Attribute) -> None:
        attr = _is_self_attr(node)
        if attr is not None and not LOCK_ATTR_RE.match(attr):
            self.accesses.append(_Access(
                attr=attr,
                is_write=isinstance(node.ctx, (ast.Store, ast.Del)),
                guarded=self._guard_depth > 0,
                line=node.lineno,
                method=self.method,
            ))
        self.generic_visit(node)


def check_class(node: ast.ClassDef, path: str) -> List[Finding]:
    accesses: List[_Access] = []
    for child in node.body:
        if not isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if child.name == "__init__":
            continue
        visitor = _MethodVisitor(child.name)
        visitor.visit(child)
        accesses.extend(visitor.accesses)

    guarded_attrs: Set[str] = {
        a.attr for a in accesses if a.is_write and a.guarded
    }
    findings: List[Finding] = []
    for a in accesses:
        if a.attr in guarded_attrs and not a.guarded:
            kind = "written" if a.is_write else "read"
            findings.append(Finding(
                rule="lock-discipline",
                path=path,
                line=a.line,
                message=(
                    f"{node.name}.{a.method} {kind} self.{a.attr} outside "
                    "the lock, but other methods write it under one "
                    "(torn read/lost update under concurrent access)"
                ),
            ))
    return findings


def check_source(source: str, path: str) -> List[Finding]:
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:
        return [Finding(
            rule="lock-discipline", path=path, line=e.lineno or 0,
            message=f"unparseable source: {e.msg}",
        )]
    findings: List[Finding] = []
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef):
            findings.extend(check_class(node, path))
    return findings


def check_tree(root: str, rel_to: Optional[str] = None) -> List[Finding]:
    """Run the checker over every ``.py`` file under ``root``."""
    findings: List[Finding] = []
    for dirpath, _dirnames, filenames in os.walk(root):
        for fname in sorted(filenames):
            if not fname.endswith(".py"):
                continue
            full = os.path.join(dirpath, fname)
            rel = os.path.relpath(full, rel_to) if rel_to else full
            with open(full) as fh:
                findings.extend(check_source(fh.read(), rel))
    return findings
