"""HLO-level invariant rules: donation survival and compiled collectives.

The jaxpr rules (:mod:`repro.analysis.jaxpr_audit`) check what we
*asked* jax for; these check what the compiler actually *kept*:

- donation/aliasing: ``donate_argnums`` + the carry kernel's
  ``input_output_aliases`` must survive to the compiled module as an
  ``input_output_alias`` directive — jax drops donation silently (a
  warning at best), and a dropped alias means every streaming fold pays
  a full (d+C, d) carry copy per batch;
- collective budget, post-SPMD: the partitioner is free to insert
  collectives the jaxpr never asked for (resharding, transpose-induced
  all-to-alls), so the one-psum claim is re-checked on the compiled
  per-device HLO via the loop-aware parser (``launch.hlo_parse`` — a
  psum hidden under a while loop counts ×trip).

Rules accept the text artifacts (``lowered.as_text()`` /
``compiled.as_text()``) rather than live jax objects, so fixtures in
tests can feed hand-written modules.
"""

from __future__ import annotations

from typing import Dict, List, Optional

from repro.analysis.findings import Finding
from repro.launch import hlo_parse

# What jax stamps on donated/aliased buffers at each stage.
STABLEHLO_ALIAS_MARKERS = ("tf.aliasing_output", "jax.buffer_donor")
COMPILED_ALIAS_MARKER = "input_output_alias"


def has_stablehlo_aliasing(lowered_text: str) -> bool:
    return any(m in lowered_text for m in STABLEHLO_ALIAS_MARKERS)


def has_compiled_aliasing(compiled_text: str) -> bool:
    return COMPILED_ALIAS_MARKER in compiled_text


def check_donated_aliasing(
    name: str,
    *,
    lowered_text: Optional[str] = None,
    compiled_text: Optional[str] = None,
) -> List[Finding]:
    """Donation must be visible at every stage it was given to.

    ``lowered_text`` checks the StableHLO (did the user-level donation
    reach the module at all); ``compiled_text`` checks the executable
    (did XLA honor it, or insert a silent defensive copy).
    """
    out: List[Finding] = []
    if lowered_text is not None and not has_stablehlo_aliasing(lowered_text):
        out.append(Finding(
            rule="donated-aliasing",
            path=f"hlo:{name}",
            message=(
                "no donation marker in the lowered module "
                f"(looked for {', '.join(STABLEHLO_ALIAS_MARKERS)}) — the "
                "carry is copied, not updated in place"
            ),
        ))
    if compiled_text is not None and not has_compiled_aliasing(compiled_text):
        out.append(Finding(
            rule="donated-aliasing",
            path=f"hlo:{name}",
            message=(
                "compiled executable carries no input_output_alias — XLA "
                "dropped the donation (silent full-buffer copy per fold)"
            ),
        ))
    return out


def collective_counts(compiled_text: str) -> Dict[str, float]:
    """Loop-corrected per-kind collective op counts of a compiled module."""
    return dict(hlo_parse.analyze(compiled_text).collective_count)


def check_hlo_collective_budget(
    name: str, compiled_text: str, expected_total: int
) -> List[Finding]:
    """Exact post-SPMD collective count (see jaxpr twin for rationale)."""
    counts = collective_counts(compiled_text)
    total = sum(counts.values())
    if total == expected_total:
        return []
    kinds = ", ".join(f"{k}={int(v)}" for k, v in counts.items() if v) or "none"
    return [Finding(
        rule="collective-budget",
        path=f"hlo:{name}",
        message=(
            f"compiled module holds {int(total)} collective(s) "
            f"({kinds}), expected exactly {expected_total}"
        ),
    )]
