"""fedcgs-audit — run every analysis rule and gate on the baseline.

    PYTHONPATH=src python -m repro.analysis --check

Static rules (AST, no jax): lock discipline over ``repro/serve`` and
``repro/obs``, repo lint over ``src/`` and ``benchmarks/``.  Dynamic rules (traced): the
collective budgets, donation survival, host-callback/dtype screens and
the retrace sentinel from ``repro.analysis.budgets`` — skipped with
``--static-only``.

Exit code 0 iff no finding survives baseline subtraction.  The
baseline (``analysis_baseline.json``) grandfathers old findings keyed
on (rule, path, message); every entry must carry a justification.

``--plant <rule>`` injects that rule's known-bad fixture into the run —
the exit code MUST go non-zero, which is how CI proves the gate can
actually fail (``tests/test_analysis.py`` drives this).
"""

from __future__ import annotations

import argparse
import os
import sys
from typing import List, Optional


def repo_root() -> str:
    """Nearest ancestor of this file holding pyproject.toml, else cwd."""
    cur = os.path.abspath(os.path.dirname(__file__))
    for _ in range(6):
        if os.path.exists(os.path.join(cur, "pyproject.toml")):
            return cur
        nxt = os.path.dirname(cur)
        if nxt == cur:
            break
        cur = nxt
    return os.getcwd()


def main(argv: Optional[List[str]] = None) -> int:
    from repro.analysis.plants import PLANTS

    parser = argparse.ArgumentParser(
        prog="fedcgs-audit", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    parser.add_argument(
        "--check", action="store_true",
        help="gate mode (the default behaviour; the flag exists so the "
             "CI invocation reads as what it is)",
    )
    parser.add_argument(
        "--static-only", action="store_true",
        help="AST rules only — no jax import, no tracing (fast)",
    )
    parser.add_argument(
        "--baseline", default=None,
        help="baseline JSON path (default: <repo>/analysis_baseline.json)",
    )
    parser.add_argument(
        "--plant", choices=sorted(PLANTS), default=None,
        help="inject the named rule's known-bad fixture (exit must be 1)",
    )
    parser.add_argument(
        "--json", action="store_true", dest="as_json",
        help="emit findings as JSON instead of human-readable lines",
    )
    args = parser.parse_args(argv)

    needs_jax = not args.static_only or args.plant in (
        "collective-budget", "donated-aliasing", "host-callback",
        "dtype-discipline", "retrace-sentinel",
    )
    if needs_jax and "jax" not in sys.modules and "XLA_FLAGS" not in os.environ:
        # BEFORE the first jax import: the HLO-level budget re-check
        # needs a real multi-shard partition for a psum to survive SPMD
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

    from repro.analysis import lint, lockcheck
    from repro.analysis.findings import Baseline, as_json

    root = repo_root()
    findings = []
    findings += lockcheck.check_tree(
        os.path.join(root, "src", "repro", "serve"), rel_to=root
    )
    findings += lockcheck.check_tree(
        os.path.join(root, "src", "repro", "obs"), rel_to=root
    )
    findings += lint.check_paths(
        [os.path.join(root, "src"), os.path.join(root, "benchmarks")],
        rel_to=root,
    )
    if not args.static_only:
        from repro.analysis import budgets

        findings += budgets.run_dynamic_audits()
    if args.plant:
        planted = PLANTS[args.plant]()
        if not planted:
            print(f"PLANT FAILURE: --plant {args.plant} produced no findings "
                  "(the rule cannot fail; the gate is vacuous)")
            return 2
        findings += planted

    baseline = Baseline.load(
        args.baseline or os.path.join(root, "analysis_baseline.json")
    )
    findings += baseline.validate()
    new, grandfathered = baseline.split(findings)

    if args.as_json:
        print(as_json(new))
    else:
        for f in new:
            print(f.format())
        mode = "static rules" if args.static_only else "static + traced rules"
        print(
            f"fedcgs-audit: {len(new)} finding(s) "
            f"({len(grandfathered)} baselined) [{mode}]"
        )
    return 1 if new else 0


if __name__ == "__main__":
    raise SystemExit(main())
