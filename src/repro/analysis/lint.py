"""Repo lint: AST rules codifying conventions hard-won in PRs 2–5.

Each rule exists because its violation already bit us once:

- ``shard-map-import``: ``jax.experimental.shard_map`` moved between
  jax 0.4.x and 0.5+; ``repro.sharding.shard_map`` papers over the skew
  (auto-axes fallback, check_rep semantics).  A raw import anywhere else
  reintroduces version-dependent behaviour — only ``repro/sharding.py``
  may touch the experimental module.
- ``time-time``: ``time.time()`` is not monotonic and every hand-rolled
  pair drifts from the repo's one wall-clock primitive
  (``serve.metrics.timed`` / ``repro.timing.timed``).  Timestamps via
  ``time.perf_counter()`` are fine — the rule targets the wall-clock
  call, not time handling in general.
- ``unseeded-np-random``: an unseeded RNG makes the FL equivalence
  tests (streaming == materialized, secure == plain-survivors)
  unreproducible.  Legacy global-state ``np.random.*`` calls are flagged
  outright; ``np.random.default_rng()`` must be given a seed.
- ``uncentred-second-moment``: computing a covariance as
  ``B − n·outer(μ, μ)`` cancels catastrophically in f32 when the
  common-mode mean dominates the per-class spread — PR 3 replaced every
  instance with centred sweeps (``class_conditional_moments``).  The
  rule flags a subtraction whose right side contains a self outer
  product (``outer(m, m)``, optionally scaled).
- ``block-constants``: kernel block sizes are the autotuner's business
  (``repro.tune``): a call site in ``launch/``, ``serve/``, or
  ``benchmarks/`` that imports the kernels' ``BLOCK_*`` module
  constants or passes a literal ``block_n=``/``block_d=``/``block_c=``/
  ``block_k=`` override hardcodes one shape's tile choice into every
  shape — exactly the 0.86×-at-n=4096 regression the tuner exists to
  kill — and desyncs from the tune cache's per-bucket verdicts.  Blocks
  must come through the ``repro.tune`` accessors (``stats_blocks``,
  ``gnb_blocks``, ``serve_row_multiple``, …); ``repro/tune.py`` itself
  and the kernel layer are the sanctioned owners.
- ``metric-funnel``: instrumentation in the serving tier funnels
  through ``repro.obs`` — PR 9's serve layer grew a private counter
  dict behind every component's own lock plus a 65536-entry latency
  deque sorted on every snapshot, none of it scrapeable.  In
  ``repro/serve/`` and ``repro/launch/`` the rule flags (a) bounded
  sample windows (``deque(maxlen=...)`` — an ad-hoc metric instrument;
  the registry histogram owns the bounded-window pattern) and (b)
  direct construction of the obs instrument classes (``Counter(...)``
  etc. imported from ``repro.obs``), which bypasses the registry's
  get-or-create name table and its type/label checks.
- ``extractor-protocol``: feature extraction outside ``fl/`` and
  ``models/`` must go through the Extractor protocol —
  ``extractor.features(x)`` / ``models.transformer.features()`` — so
  pooling, side-input stubs, and the raw-input StatsPipeline path stay
  in one place.  The rule flags direct ``Backbone.apply`` calls and
  direct model ``forward`` calls (via a tracked import alias of
  ``repro.models.transformer``) in ``launch/``, ``serve/``, and
  ``benchmarks/``.  Generation entry points (``prefill``,
  ``decode_step``) are not extraction and stay legal.
"""

from __future__ import annotations

import ast
import os
from typing import Iterable, List, Optional, Sequence

from repro.analysis.findings import Finding

# the one module allowed to import jax.experimental.shard_map
SHARD_MAP_HOME = "repro/sharding.py"

# consumers that must reach features through the Extractor protocol
EXTRACTOR_SCOPE = ("repro/launch/", "repro/serve/", "benchmarks/")

# consumers that must reach kernel block sizes through repro.tune
# (same scope: the kernel layer and the tuner itself are the owners)
BLOCK_SCOPE = EXTRACTOR_SCOPE
_BLOCK_KWARGS = frozenset({"block_n", "block_d", "block_c", "block_k"})

# components whose instrumentation must funnel through repro.obs
METRIC_SCOPE = ("repro/serve/", "repro/launch/")
_OBS_INSTRUMENTS = frozenset({"Counter", "Gauge", "Histogram"})

# np.random attributes that are NOT the legacy global-state API
_NP_RANDOM_OK = {"default_rng", "Generator", "SeedSequence", "BitGenerator",
                 "PCG64", "Philox", "RandomState"}


def _is_np_random(node: ast.AST) -> bool:
    """Matches ``np.random`` / ``numpy.random`` attribute chains."""
    return (
        isinstance(node, ast.Attribute)
        and node.attr == "random"
        and isinstance(node.value, ast.Name)
        and node.value.id in ("np", "numpy")
    )


def _self_outer_product(node: ast.AST) -> bool:
    """``outer(m, m)`` (same name twice) anywhere inside ``node``."""
    for sub in ast.walk(node):
        if not isinstance(sub, ast.Call) or len(sub.args) != 2:
            continue
        fn = sub.func
        fn_name = fn.attr if isinstance(fn, ast.Attribute) else (
            fn.id if isinstance(fn, ast.Name) else None
        )
        if fn_name != "outer":
            continue
        a, b = sub.args
        if (
            isinstance(a, ast.Name) and isinstance(b, ast.Name)
            and a.id == b.id
        ):
            return True
    return False


def _in_extractor_scope(path: str) -> bool:
    p = path.replace(os.sep, "/")
    return any(seg in p for seg in EXTRACTOR_SCOPE) or p.startswith("benchmarks/")


def _in_metric_scope(path: str) -> bool:
    p = path.replace(os.sep, "/")
    return any(seg in p for seg in METRIC_SCOPE)


class _LintVisitor(ast.NodeVisitor):
    def __init__(self, path: str):
        self.path = path
        self.findings: List[Finding] = []
        self._extractor_scope = _in_extractor_scope(path)
        self._block_scope = _in_extractor_scope(path)
        self._metric_scope = _in_metric_scope(path)
        # names the obs instrument classes were imported under (direct
        # construction through one of these is a metric-funnel finding)
        self._obs_instrument_aliases: set = set()
        # import aliases of repro.models.transformer (e.g. ``T``), and
        # bare names imported from it that are model entry points
        self._transformer_aliases: set = set()
        self._transformer_fns: set = set()
        # names bound to repro.kernels modules (``BLOCK_*`` attr access
        # through any of these is a block-constants finding)
        self._kernel_aliases: set = set()

    def _add(self, rule: str, line: int, message: str) -> None:
        self.findings.append(
            Finding(rule=rule, path=self.path, line=line, message=message)
        )

    # -- shard-map-import ---------------------------------------------------

    def visit_Import(self, node: ast.Import) -> None:
        for alias in node.names:
            if alias.name.startswith("jax.experimental.shard_map"):
                self._shard_map_finding(node.lineno)
            if alias.name == "repro.models.transformer" and alias.asname:
                self._transformer_aliases.add(alias.asname)
            if alias.name == "repro.kernels" or alias.name.startswith(
                "repro.kernels."
            ):
                # no asname: the chain is rooted at the bare top name
                self._kernel_aliases.add(alias.asname or alias.name.split(".")[0])
        self.generic_visit(node)

    def visit_ImportFrom(self, node: ast.ImportFrom) -> None:
        mod = node.module or ""
        if mod.startswith("jax.experimental.shard_map") or (
            mod == "jax.experimental"
            and any(a.name == "shard_map" for a in node.names)
        ):
            self._shard_map_finding(node.lineno)
        if mod == "repro.models" and any(
            a.name == "transformer" for a in node.names
        ):
            for a in node.names:
                if a.name == "transformer":
                    self._transformer_aliases.add(a.asname or "transformer")
        if mod == "repro.models.transformer":
            for a in node.names:
                if a.name == "forward":
                    self._transformer_fns.add(a.asname or "forward")
        if mod == "repro.obs" or mod.startswith("repro.obs."):
            for a in node.names:
                if a.name in _OBS_INSTRUMENTS:
                    self._obs_instrument_aliases.add(a.asname or a.name)
        if mod == "repro.kernels" or mod.startswith("repro.kernels."):
            for a in node.names:
                if self._block_scope and a.name.startswith("BLOCK_"):
                    self._add(
                        "block-constants", node.lineno,
                        f"kernel constant {a.name} imported outside the "
                        "tuner — block sizes come from repro.tune "
                        "accessors (stats_blocks / gnb_blocks / "
                        "serve_row_multiple), tuned per shape bucket",
                    )
                else:
                    self._kernel_aliases.add(a.asname or a.name)
        self.generic_visit(node)

    def visit_Attribute(self, node: ast.Attribute) -> None:
        if self._block_scope and node.attr.startswith("BLOCK_"):
            root = ast.unparse(node.value).split(".")[0]
            if root in self._kernel_aliases:
                self._add(
                    "block-constants", node.lineno,
                    f"kernel constant .{node.attr} read outside the tuner "
                    "— block sizes come from repro.tune accessors, tuned "
                    "per shape bucket",
                )
        self.generic_visit(node)

    def _shard_map_finding(self, line: int) -> None:
        if not self.path.replace(os.sep, "/").endswith(SHARD_MAP_HOME):
            self._add(
                "shard-map-import", line,
                "raw jax.experimental.shard_map import — use "
                "repro.sharding.shard_map (owns the 0.4.x/0.5+ API skew)",
            )

    # -- time-time / unseeded-np-random -------------------------------------

    def visit_Call(self, node: ast.Call) -> None:
        fn = node.func
        if (
            isinstance(fn, ast.Attribute)
            and fn.attr == "time"
            and isinstance(fn.value, ast.Name)
            and fn.value.id == "time"
        ):
            self._add(
                "time-time", node.lineno,
                "hand-rolled time.time() timing — wrap the call in "
                "serve.metrics.timed (one wall-clock primitive, monotonic)",
            )
        if isinstance(fn, ast.Attribute) and _is_np_random(fn.value):
            if fn.attr not in _NP_RANDOM_OK:
                self._add(
                    "unseeded-np-random", node.lineno,
                    f"legacy global-state np.random.{fn.attr}() — use a "
                    "seeded np.random.default_rng(seed)",
                )
            elif fn.attr == "default_rng" and not node.args and not node.keywords:
                self._add(
                    "unseeded-np-random", node.lineno,
                    "np.random.default_rng() without a seed — equivalence "
                    "tests need reproducible draws",
                )
        if self._extractor_scope:
            self._check_extractor_protocol(node, fn)
        if self._metric_scope:
            self._check_metric_funnel(node, fn)
        if self._block_scope:
            for kw in node.keywords:
                if kw.arg in _BLOCK_KWARGS and isinstance(kw.value, ast.Constant):
                    self._add(
                        "block-constants", node.lineno,
                        f"literal {kw.arg}={kw.value.value!r} override "
                        "outside the tuner — pass blocks from the "
                        "repro.tune accessors (or omit for the tuned "
                        "default) so the cache's per-bucket verdicts apply",
                    )
        self.generic_visit(node)

    # -- extractor-protocol --------------------------------------------------

    def _check_extractor_protocol(self, node: ast.Call, fn: ast.AST) -> None:
        """Direct Backbone.apply / model forward in launch/serve/benchmarks."""
        if isinstance(fn, ast.Attribute) and fn.attr == "forward" and (
            (
                isinstance(fn.value, ast.Name)
                and fn.value.id in self._transformer_aliases
            )
            or ast.unparse(fn) == "repro.models.transformer.forward"
        ):
            self._add(
                "extractor-protocol", node.lineno,
                "direct model forward() in an FL consumer — go through the "
                "Extractor protocol (models.transformer.features / "
                "fl.extractors; pooling + raw-input ingest live there)",
            )
        if isinstance(fn, ast.Name) and fn.id in self._transformer_fns:
            self._add(
                "extractor-protocol", node.lineno,
                "direct model forward() in an FL consumer — go through the "
                "Extractor protocol (models.transformer.features / "
                "fl.extractors; pooling + raw-input ingest live there)",
            )
        if (
            isinstance(fn, ast.Attribute)
            and fn.attr == "apply"
            and "backbone" in ast.unparse(fn.value).lower()
        ):
            self._add(
                "extractor-protocol", node.lineno,
                "direct Backbone.apply() in an FL consumer — call "
                "extractor.features(x) (the Extractor protocol) instead",
            )

    # -- metric-funnel -------------------------------------------------------

    def _check_metric_funnel(self, node: ast.Call, fn: ast.AST) -> None:
        """Ad-hoc instrumentation in serve/launch outside repro.obs."""
        fn_name = fn.attr if isinstance(fn, ast.Attribute) else (
            fn.id if isinstance(fn, ast.Name) else None
        )
        if fn_name == "deque" and any(
            kw.arg == "maxlen" for kw in node.keywords
        ):
            self._add(
                "metric-funnel", node.lineno,
                "bounded deque(maxlen=...) sample window — an ad-hoc "
                "metric instrument; route observations through a "
                "repro.obs registry histogram (bounded exact window + "
                "log-spaced buckets, scrapeable)",
            )
        if (
            isinstance(fn, ast.Name)
            and fn.id in self._obs_instrument_aliases
        ):
            self._add(
                "metric-funnel", node.lineno,
                f"direct {fn.id}(...) construction bypasses the metrics "
                "registry — use registry.counter/gauge/histogram "
                "(get-or-create, type- and label-checked, one shared "
                "family per name)",
            )

    # -- uncentred-second-moment --------------------------------------------

    def visit_BinOp(self, node: ast.BinOp) -> None:
        if isinstance(node.op, ast.Sub) and _self_outer_product(node.right):
            self._add(
                "uncentred-second-moment", node.lineno,
                "covariance via 'B - n*outer(mu, mu)' cancels in f32 — "
                "centre first, then sweep (see "
                "stats_pipeline.class_conditional_moments)",
            )
        self.generic_visit(node)


def check_source(source: str, path: str) -> List[Finding]:
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:
        return [Finding(
            rule="lint", path=path, line=e.lineno or 0,
            message=f"unparseable source: {e.msg}",
        )]
    visitor = _LintVisitor(path)
    visitor.visit(tree)
    return visitor.findings


def check_paths(
    roots: Sequence[str], rel_to: Optional[str] = None,
    exclude: Iterable[str] = (),
) -> List[Finding]:
    """Lint every ``.py`` under each root (files or directories)."""
    excluded = {os.path.normpath(e) for e in exclude}
    findings: List[Finding] = []
    for root in roots:
        files: List[str]
        if os.path.isfile(root):
            files = [root]
        else:
            files = []
            for dirpath, _dirnames, filenames in os.walk(root):
                files.extend(
                    os.path.join(dirpath, f)
                    for f in sorted(filenames) if f.endswith(".py")
                )
        for full in files:
            if os.path.normpath(full) in excluded:
                continue
            rel = os.path.relpath(full, rel_to) if rel_to else full
            with open(full) as fh:
                findings.extend(check_source(fh.read(), rel))
    return findings
