"""Declared invariant budgets + the dynamic (traced) audit suite.

Where the AST rules read source, these rules trace the real programs:
for every cell of the StatsPipeline knob matrix the streaming engine is
built on a host mesh, its fold/finalize are traced, and the jaxpr/HLO
rules are applied against the budgets DECLARED here — fold: zero
collectives, finalize: exactly one, per cohort, per cell.  Alongside
the collective budgets the same traces are screened for host callbacks
and dtype leaks, the carry kernel's donation is checked for survival
to the compiled module, and the retrace sentinel replays a ragged
batch stream against the one-trace-per-padded-shape contract.

The jitted functions under audit are reached through each layer's
``AUDITED_JITS`` registry (``core.stats_pipeline``, ``kernels.ops``,
``serve.scoring``) — a public export, so the audit never pokes at
privates and a renamed jit breaks the audit loudly instead of silently
auditing nothing.

Audit workloads use shapes unique to this module (``AUDIT_*``) and
clear the target jit's cache first, so the retrace counts stay exact
no matter what traced earlier in the process.
"""

from __future__ import annotations

from typing import Iterator, List, Tuple

import jax
import jax.numpy as jnp

from repro.analysis import hlo_audit, jaxpr_audit
from repro.analysis.findings import Finding

# Shapes no test or benchmark uses: the retrace sentinel counts NEW
# jit-cache entries, so a colliding shape elsewhere would mask a miss.
AUDIT_CLASSES = 7
AUDIT_DIM = 17
AUDIT_ROWS = 48

# The paper's one-shot claim as numbers: a streaming cohort costs ZERO
# collectives per fold and EXACTLY ONE at finalize — in every
# backend × privacy cell.  New sharded paths declare their budget here.
STREAM_FOLD_COLLECTIVES = 0
STREAM_FINALIZE_COLLECTIVES = 1
SCORING_COLLECTIVES = 0  # head replicated, logits row-parallel

# Post-SPMD, XLA lowers the single tree-psum to one all-reduce PER
# FeatureStats leaf (A, B, N) — still constant in the batch count,
# which is the claim; the leaf count is the budget at the HLO level.
STREAM_FINALIZE_HLO_COLLECTIVES = 3


def streaming_cells() -> Iterator[Tuple[str, str]]:
    for backend in ("jnp", "fused"):
        for privacy in ("plain", "secure"):
            yield backend, privacy


def _streaming_jaxprs(backend: str, privacy: str):
    from repro.launch.mesh import make_host_mesh
    from repro.launch.stats_engine import make_streaming_engine

    mesh = make_host_mesh(1)
    carry, fold, finalize = make_streaming_engine(
        AUDIT_CLASSES, AUDIT_DIM, mesh,
        use_kernel=(backend == "fused"), secure=(privacy == "secure"),
        mask_scale=10.0,
    )
    f = jnp.zeros((8, AUDIT_DIM))
    y = jnp.zeros((8,), jnp.int32)
    return jax.make_jaxpr(fold)(carry, f, y), jax.make_jaxpr(finalize)(carry)


def audit_streaming_collectives() -> List[Finding]:
    """Jaxpr-level budget + hygiene over every knob-matrix cell.

    Jaxpr counts are pre-SPMD, so one host device suffices and the
    numbers are device-count independent.
    """
    out: List[Finding] = []
    for backend, privacy in streaming_cells():
        cell = f"stream[{backend},{privacy}]"
        fold_jx, fin_jx = _streaming_jaxprs(backend, privacy)
        out += jaxpr_audit.check_collective_budget(
            f"{cell}.fold", fold_jx, STREAM_FOLD_COLLECTIVES
        )
        out += jaxpr_audit.check_collective_budget(
            f"{cell}.finalize", fin_jx, STREAM_FINALIZE_COLLECTIVES
        )
        out += jaxpr_audit.check_no_host_callbacks(f"{cell}.fold", fold_jx)
        out += jaxpr_audit.check_no_host_callbacks(f"{cell}.finalize", fin_jx)
        out += jaxpr_audit.check_dtype_discipline(f"{cell}.fold", fold_jx)
        out += jaxpr_audit.check_dtype_discipline(f"{cell}.finalize", fin_jx)
    return out


def audit_finalize_hlo() -> List[Finding]:
    """Post-SPMD re-check of the finalize budget on the compiled module.

    The partitioner may insert collectives the jaxpr never asked for
    (resharding), so the one-psum claim is re-counted on the per-device
    HLO — loop-aware, in case a collective ever hides under a while.
    Needs >1 device (the CLI forces 8 simulated CPU devices); on a
    single device the psum compiles away and the check is vacuous.
    """
    if len(jax.devices()) < 2:
        return []
    from repro.launch.mesh import make_host_mesh
    from repro.launch.stats_engine import make_streaming_engine

    mesh = make_host_mesh(1)
    out: List[Finding] = []
    for privacy in ("plain", "secure"):
        carry, _fold, finalize = make_streaming_engine(
            AUDIT_CLASSES, AUDIT_DIM, mesh,
            use_kernel=False, secure=(privacy == "secure"), mask_scale=10.0,
        )
        compiled = jax.jit(finalize).lower(carry).compile()
        out += hlo_audit.check_hlo_collective_budget(
            f"stream[jnp,{privacy}].finalize", compiled.as_text(),
            STREAM_FINALIZE_HLO_COLLECTIVES,
        )
    return out


def audit_scoring() -> List[Finding]:
    """The serving scorer: collective-free, callback-free, dtype-clean —
    both the local block-padded path and the pad-to-shards mesh path."""
    from repro.launch.mesh import make_host_mesh
    from repro.serve.scoring import score_features

    f = jnp.zeros((AUDIT_ROWS, AUDIT_DIM))
    w = jnp.zeros((AUDIT_CLASSES, AUDIT_DIM))
    b = jnp.zeros((AUDIT_CLASSES,))
    out: List[Finding] = []
    local = jax.make_jaxpr(
        lambda f_, w_, b_: score_features(f_, w_, b_, interpret=True)
    )(f, w, b)
    out += jaxpr_audit.check_collective_budget(
        "serve.score_features[local]", local, SCORING_COLLECTIVES
    )
    out += jaxpr_audit.check_no_host_callbacks("serve.score_features[local]", local)
    out += jaxpr_audit.check_dtype_discipline("serve.score_features[local]", local)

    mesh = make_host_mesh(1)
    sharded = jax.make_jaxpr(
        lambda f_, w_, b_: score_features(
            f_, w_, b_, mesh=mesh, interpret=True
        )
    )(f, w, b)
    out += jaxpr_audit.check_collective_budget(
        "serve.score_features[sharded]", sharded, SCORING_COLLECTIVES
    )
    out += jaxpr_audit.check_no_host_callbacks(
        "serve.score_features[sharded]", sharded
    )
    return out


def audit_carry_donation(*, plant_missing: bool = False) -> List[Finding]:
    """The streaming carry fold's donation must survive to the compiled
    module — jax drops donation with at most a warning, and a dropped
    alias costs a full (d+C, d) carry copy on every batch.

    ``plant_missing`` audits the deliberately NON-donating twin of the
    same fold (kept for CPU hosts, which can't donate) — the known-bad
    fixture proving the rule can fail.
    """
    from repro import tune
    from repro.kernels import ops

    key = "kernels.stats_acc" if plant_missing else "kernels.stats_acc_donating"
    fold = ops.AUDITED_JITS[key]
    # the blocks the real fold would run with (tuned or default) — the
    # donation claim must hold for whatever the dispatch layer picks
    block_n, block_d = tune.stats_acc_blocks(
        AUDIT_CLASSES, AUDIT_DIM, rows=AUDIT_ROWS
    )
    m, n = ops.stats_carry_init(AUDIT_CLASSES, AUDIT_DIM, block_d=block_d)
    f = jnp.zeros((AUDIT_ROWS, AUDIT_DIM))
    y = jnp.zeros((AUDIT_ROWS,), jnp.int32)
    lowered = fold.lower(
        m, n, f, y, interpret=True, block_d=block_d, block_n=block_n
    )
    return hlo_audit.check_donated_aliasing(
        key,
        lowered_text=lowered.as_text(),
        compiled_text=lowered.compile().as_text(),
    )


def _clear_jit_cache(jitted) -> None:
    clear = getattr(jitted, "clear_cache", None)
    if clear is not None:
        clear()


def audit_retraces() -> List[Finding]:
    """One jit trace per padded shape, measured on the real data paths."""
    from repro.core import stats_pipeline
    from repro.kernels import ops
    from repro.serve.scoring import score_features

    out: List[Finding] = []

    # streaming fold: equal batches + a ragged tail, all padded to the
    # first-seen shape => ONE new trace on the shared jitted fold
    fold = stats_pipeline.AUDITED_JITS["stats_pipeline.fold_jnp"]
    _clear_jit_cache(fold)
    n = AUDIT_ROWS * 3 + 5  # forces a ragged tail batch
    x = jnp.arange(n * AUDIT_DIM, dtype=jnp.float32).reshape(n, AUDIT_DIM)
    y = jnp.arange(n, dtype=jnp.int32) % AUDIT_CLASSES

    # backend pinned: the sentinel counts entries on the jnp fold jit,
    # so the workload must not be re-routed by the auto dispatcher
    def stream_workload():
        return stats_pipeline.StatsPipeline(
            AUDIT_CLASSES, backend="jnp"
        ).from_batches(
            (x[i : i + AUDIT_ROWS], y[i : i + AUDIT_ROWS])
            for i in range(0, n, AUDIT_ROWS)
        )

    out += jaxpr_audit.check_single_trace(
        "stats_pipeline.fold_jnp", fold, stream_workload
    )

    # serving scorer: repeated same-shape batches => one trace on the
    # fused head kernel (the batcher pads rows to block multiples
    # precisely so this holds for the whole workload); backend pinned
    # for the same reason as above
    gnb = ops.AUDITED_JITS["kernels.gnb_logits"]
    _clear_jit_cache(gnb)
    w = jnp.zeros((AUDIT_CLASSES, AUDIT_DIM))
    b = jnp.zeros((AUDIT_CLASSES,))
    rows = jnp.zeros((AUDIT_ROWS, AUDIT_DIM))

    def score_workload():
        for _ in range(3):
            score_features(rows, w, b, interpret=True, backend="fused")

    out += jaxpr_audit.check_single_trace(
        "kernels.gnb_logits", gnb, score_workload
    )

    # the jnp twin the dispatcher can select must obey the same contract
    gnb_jnp = ops.AUDITED_JITS["kernels.gnb_logits_jnp"]
    _clear_jit_cache(gnb_jnp)

    def score_jnp_workload():
        for _ in range(3):
            score_features(rows, w, b, interpret=True, backend="jnp")

    out += jaxpr_audit.check_single_trace(
        "kernels.gnb_logits_jnp", gnb_jnp, score_jnp_workload
    )
    return out


def audit_tuned_budgets() -> List[Finding]:
    """The collective budgets must be block-size invariant.

    Records a synthetic tuned decision with NON-default fold blocks
    into a scoped cache, rebuilds the fused streaming engine under it,
    and re-counts fold/finalize collectives — proving the tuner can
    never buy throughput by smuggling a collective into the fold.
    """
    from repro import tune

    cache = tune.TuneCache()
    cache.record(
        tune.Decision(
            kernel="stats_acc", n=AUDIT_ROWS, d=AUDIT_DIM, c=AUDIT_CLASSES,
            winner="fused", blocks={"block_n": 256, "block_d": 128},
        )
    )
    out: List[Finding] = []
    with tune.using_cache(cache):
        cell = "stream[fused,plain,tuned]"
        fold_jx, fin_jx = _streaming_jaxprs("fused", "plain")
        out += jaxpr_audit.check_collective_budget(
            f"{cell}.fold", fold_jx, STREAM_FOLD_COLLECTIVES
        )
        out += jaxpr_audit.check_collective_budget(
            f"{cell}.finalize", fin_jx, STREAM_FINALIZE_COLLECTIVES
        )
    return out


def run_dynamic_audits() -> List[Finding]:
    """Every traced audit, in declaration order."""
    out: List[Finding] = []
    out += audit_streaming_collectives()
    out += audit_finalize_hlo()
    out += audit_scoring()
    out += audit_carry_donation()
    out += audit_retraces()
    out += audit_tuned_budgets()
    return out
