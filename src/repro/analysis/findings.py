"""The finding/severity model every analysis rule reports through.

A rule never prints or raises: it returns :class:`Finding` rows, and the
CLI (``repro.analysis.cli``) owns presentation, baseline subtraction,
and the exit code.  That keeps each rule unit-testable against planted
violations (``tests/test_analysis.py``) and lets CI gate on "no finding
that isn't baselined".

Baseline contract (``analysis_baseline.json``): grandfathered findings
are committed as ``{rule, path, message, justification}`` entries —
matching is on (rule, path, message), never line numbers, so moving
code around cannot silently un-baseline or re-baseline a violation.
Every entry MUST carry a non-empty justification; an unjustified entry
is itself a gating finding, so the baseline can't become a dumping
ground.
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, Iterable, List, Sequence, Tuple

SEVERITIES = ("error", "warning")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation.

    ``path`` is a repo-relative file for AST rules, or a symbolic target
    like ``jaxpr:stream-finalize[fused,secure]`` for traced audits.
    ``line`` is 0 when the finding has no source location.  ``message``
    must be stable across runs (no line numbers, no memory addresses) —
    it is part of the baseline identity.
    """

    rule: str
    path: str
    message: str
    line: int = 0
    severity: str = "error"

    def __post_init__(self):
        if self.severity not in SEVERITIES:
            raise ValueError(
                f"severity must be one of {SEVERITIES}, got {self.severity!r}"
            )

    @property
    def key(self) -> Tuple[str, str, str]:
        """Baseline identity: line-insensitive by design."""
        return (self.rule, self.path, self.message)

    def format(self) -> str:
        loc = f"{self.path}:{self.line}" if self.line else self.path
        return f"{loc}: [{self.rule}] {self.message}"


@dataclasses.dataclass
class Baseline:
    """Committed grandfathered findings, keyed like :attr:`Finding.key`."""

    entries: Dict[Tuple[str, str, str], str]  # key -> justification
    path: str = ""

    @classmethod
    def load(cls, path: str) -> "Baseline":
        if not os.path.exists(path):
            return cls(entries={}, path=path)
        with open(path) as fh:
            raw = json.load(fh)
        entries: Dict[Tuple[str, str, str], str] = {}
        for row in raw.get("findings", []):
            key = (row["rule"], row["path"], row["message"])
            entries[key] = row.get("justification", "")
        return cls(entries=entries, path=path)

    def validate(self) -> List[Finding]:
        """Unjustified baseline entries are findings themselves."""
        out = []
        for (rule, path, message), why in self.entries.items():
            if not str(why).strip():
                out.append(Finding(
                    rule="baseline-justification",
                    path=self.path or "analysis_baseline.json",
                    message=(
                        f"baselined finding [{rule}] at {path} has no "
                        f"justification: {message!r}"
                    ),
                ))
        return out

    def split(
        self, findings: Sequence[Finding]
    ) -> Tuple[List[Finding], List[Finding]]:
        """(new, grandfathered) partition of ``findings``."""
        new, old = [], []
        for f in findings:
            (old if f.key in self.entries else new).append(f)
        return new, old


def as_json(findings: Iterable[Finding]) -> str:
    return json.dumps(
        {"findings": [dataclasses.asdict(f) for f in findings]}, indent=2
    )
