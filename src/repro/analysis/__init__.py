"""repro.analysis — the repo's static-analysis and invariant-audit gate.

Three layers, one finding model, one CLI (``python -m repro.analysis``
/ ``fedcgs-audit``):

- :mod:`repro.analysis.jaxpr_audit` / :mod:`repro.analysis.hlo_audit` —
  traced-program rules: collective budgets (the one-psum-per-cohort
  claim), donation/aliasing survival to compiled HLO, dtype discipline,
  host-callback screening, and the retrace sentinel;
- :mod:`repro.analysis.lockcheck` — an AST race checker that learns
  which ``self._*`` attributes ``repro.serve`` guards with locks and
  flags accesses outside them;
- :mod:`repro.analysis.lint` — repo conventions as AST rules (raw
  shard_map imports, ``time.time()`` timing, unseeded RNGs, the
  uncentred-second-moment cancellation).

:mod:`repro.analysis.budgets` declares the numeric budgets and runs the
traced audits; :mod:`repro.analysis.plants` holds one known-bad fixture
per rule so the gate is provably able to fail.

This module deliberately imports NOTHING jax-flavoured: the CLI must be
able to set XLA_FLAGS before the first jax import, and the AST rules
must run in environments with no accelerator stack at all.
"""

from repro.analysis.findings import Baseline, Finding, as_json

__all__ = ["Baseline", "Finding", "as_json"]
