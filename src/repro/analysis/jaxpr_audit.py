"""Jaxpr-level invariant rules: collectives, callbacks, dtypes, retraces.

The statistics pipeline's one-shot guarantee is a *communication* claim
(one psum per cohort no matter how many batches streamed), and its
performance claims are *trace* claims (one jit trace per padded shape,
no host callback inside a hot path, no f64 sneaking into f32 kernels).
These rules check all of that on the jaxpr — pre-SPMD, so the counts
are device-count independent and runnable on any CPU host.

Every checker returns :class:`~repro.analysis.findings.Finding` rows;
``count_collectives`` is also the shared primitive the test suite uses
directly (``tests/test_stats_pipeline.py`` — one implementation, no
drift between the CI gate and the unit tests).
"""

from __future__ import annotations

from typing import Callable, Iterator, List, Optional, Sequence, Tuple, Union

import jax

from repro.analysis.findings import Finding

JaxprLike = Union["jax.core.Jaxpr", "jax.core.ClosedJaxpr"]

# Primitive-name prefixes that cost inter-device communication.  jax
# 0.4.x shard_map rewrites psum to psum2; matching on the prefix keeps
# the rule stable across that rename.
COLLECTIVE_PREFIXES: Tuple[str, ...] = (
    "psum", "pmax", "pmin", "all_gather", "all_to_all", "ppermute",
    "reduce_scatter", "pgather",
)

# Primitives that re-enter the host mid-trace: poison for a jitted hot
# path (they serialize the device stream on every call).
CALLBACK_PRIMS: Tuple[str, ...] = (
    "pure_callback", "io_callback", "debug_callback", "callback",
    "outside_call", "host_callback",
)

FORBIDDEN_DTYPES: Tuple[str, ...] = ("float64", "complex128")


def _as_jaxpr(jaxpr: JaxprLike) -> "jax.core.Jaxpr":
    return jaxpr.jaxpr if isinstance(jaxpr, jax.core.ClosedJaxpr) else jaxpr


def iter_eqns(jaxpr: JaxprLike) -> Iterator["jax.core.JaxprEqn"]:
    """Every equation, recursing through sub-jaxprs in eqn params."""
    jaxpr = _as_jaxpr(jaxpr)
    for eqn in jaxpr.eqns:
        yield eqn
        for v in eqn.params.values():
            subs = jax.tree_util.tree_leaves(
                v,
                is_leaf=lambda x: isinstance(
                    x, (jax.core.Jaxpr, jax.core.ClosedJaxpr)
                ),
            )
            for sub in subs:
                if isinstance(sub, (jax.core.Jaxpr, jax.core.ClosedJaxpr)):
                    yield from iter_eqns(sub)


def count_collectives(
    jaxpr: JaxprLike, kinds: Optional[Sequence[str]] = None
) -> int:
    """Number of collective equations (recursive; prefix-matched).

    ``kinds`` narrows to specific prefixes, e.g. ``("psum",)`` for the
    streaming engine's one-psum-per-cohort assertion.
    """
    prefixes = tuple(kinds) if kinds is not None else COLLECTIVE_PREFIXES
    return sum(
        1 for eqn in iter_eqns(jaxpr)
        if eqn.primitive.name.startswith(prefixes)
    )


def check_collective_budget(
    name: str,
    jaxpr: JaxprLike,
    expected: int,
    *,
    kinds: Optional[Sequence[str]] = None,
) -> List[Finding]:
    """The declared budget is EXACT: a missing collective means the
    aggregation silently stopped reducing, an extra one means the
    communication bill grew with the batch count."""
    got = count_collectives(jaxpr, kinds=kinds)
    if got == expected:
        return []
    return [Finding(
        rule="collective-budget",
        path=f"jaxpr:{name}",
        message=(
            f"expected exactly {expected} collective(s), traced {got} "
            f"(prefixes: {', '.join(kinds or COLLECTIVE_PREFIXES)})"
        ),
    )]


def check_no_host_callbacks(name: str, jaxpr: JaxprLike) -> List[Finding]:
    out = []
    for eqn in iter_eqns(jaxpr):
        if eqn.primitive.name in CALLBACK_PRIMS:
            out.append(Finding(
                rule="host-callback",
                path=f"jaxpr:{name}",
                message=(
                    f"host callback primitive {eqn.primitive.name!r} inside "
                    "a jitted hot path (serializes the device stream)"
                ),
            ))
    return out


def check_dtype_discipline(
    name: str,
    jaxpr: JaxprLike,
    *,
    forbidden: Sequence[str] = FORBIDDEN_DTYPES,
    forbid_weak_outputs: bool = True,
) -> List[Finding]:
    """No f64 leaks outside ``core.shamir``'s local enable_x64 scope, and
    no weak-type drift on a path's outputs (a weak output re-promotes at
    the caller and silently widens downstream arithmetic)."""
    out: List[Finding] = []
    seen = set()
    for eqn in iter_eqns(jaxpr):
        for var in eqn.outvars:
            dtype = getattr(var.aval, "dtype", None)
            if dtype is not None and str(dtype) in forbidden and str(dtype) not in seen:
                seen.add(str(dtype))
                out.append(Finding(
                    rule="dtype-discipline",
                    path=f"jaxpr:{name}",
                    message=(
                        f"{dtype} value produced by {eqn.primitive.name!r} — "
                        "wide dtypes are reserved for core/shamir.py's local "
                        "enable_x64 scope"
                    ),
                ))
    if forbid_weak_outputs:
        closed = jaxpr if isinstance(jaxpr, jax.core.ClosedJaxpr) else None
        avals = closed.out_avals if closed is not None else [
            v.aval for v in _as_jaxpr(jaxpr).outvars
        ]
        for i, aval in enumerate(avals):
            if getattr(aval, "weak_type", False):
                out.append(Finding(
                    rule="dtype-discipline",
                    path=f"jaxpr:{name}",
                    message=(
                        f"output {i} is weak-typed ({aval.dtype}) — the "
                        "caller's promotion rules, not the kernel's, would "
                        "pick the working dtype"
                    ),
                ))
    return out


# ---------------------------------------------------------------------------
# Retrace sentinel: jit-cache-miss counters around a canonical workload.
# ---------------------------------------------------------------------------


def cache_size(jitted) -> int:
    """Current compilation-cache entry count of a jitted function."""
    return jitted._cache_size()


def measure_new_traces(jitted, workload: Callable[[], object]) -> int:
    """Run ``workload`` and report how many NEW traces ``jitted`` took."""
    before = cache_size(jitted)
    workload()
    return cache_size(jitted) - before


def check_single_trace(
    name: str,
    jitted,
    workload: Callable[[], object],
    *,
    expected: int = 1,
) -> List[Finding]:
    """The "one trace per padded shape" claim, enforced.

    ``workload`` must feed ``jitted`` (directly or through the layer
    under audit) a stream of ragged inputs that all pad to one shape; if
    the padding discipline regresses, every ragged size costs its own
    trace and the count exceeds ``expected``.
    """
    got = measure_new_traces(jitted, workload)
    if got == expected:
        return []
    return [Finding(
        rule="retrace-sentinel",
        path=f"jit:{name}",
        message=(
            f"workload cost {got} new jit trace(s), expected {expected} — "
            "the one-trace-per-padded-shape contract is broken"
        ),
    )]
