"""Planted violations: one known-bad fixture per rule.

An analysis gate that has never failed is indistinguishable from one
that can't.  Each function here constructs a program or source fragment
that VIOLATES one rule and returns the rule's findings on it — the CLI
exposes them via ``--plant <name>`` (exit code must go non-zero) and
``tests/test_analysis.py`` asserts every plant yields findings while
the real repo yields none.
"""

from __future__ import annotations

import textwrap
from typing import Callable, Dict, List

from repro.analysis.findings import Finding


def plant_collective_budget() -> List[Finding]:
    """A shard_map body that psums twice, audited against a budget of 1."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.analysis import jaxpr_audit
    from repro.launch.mesh import make_host_mesh
    from repro.sharding import shard_map

    mesh = make_host_mesh(1)

    def body(x):
        return jax.lax.psum(jax.lax.psum(x, "data"), "data")

    fn = shard_map(body, mesh=mesh, in_specs=(P("data"),), out_specs=P())
    jx = jax.make_jaxpr(fn)(jnp.ones((mesh.shape["data"], 4)))
    return jaxpr_audit.check_collective_budget("planted.double-psum", jx, 1)


def plant_donated_aliasing() -> List[Finding]:
    """The real carry fold's NON-donating twin: no alias survives."""
    from repro.analysis.budgets import audit_carry_donation

    return audit_carry_donation(plant_missing=True)


def plant_host_callback() -> List[Finding]:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.analysis import jaxpr_audit

    def f(x):
        return jax.pure_callback(
            lambda a: np.asarray(a),
            jax.ShapeDtypeStruct((2,), jnp.float32),
            x,
        )

    jx = jax.make_jaxpr(f)(jnp.zeros((2,), jnp.float32))
    return jaxpr_audit.check_no_host_callbacks("planted.pure-callback", jx)


def plant_dtype_discipline() -> List[Finding]:
    import jax
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    from repro.analysis import jaxpr_audit

    with enable_x64():
        jx = jax.make_jaxpr(lambda x: x * x)(jnp.zeros((2,), jnp.float64))
    return jaxpr_audit.check_dtype_discipline("planted.f64-leak", jx)


def plant_retrace_sentinel() -> List[Finding]:
    """An unpadded ragged workload: every shape costs its own trace."""
    import jax
    import jax.numpy as jnp

    from repro.analysis import jaxpr_audit

    jitted = jax.jit(lambda x: x + 1)

    def workload():
        jitted(jnp.zeros((2,)))
        jitted(jnp.zeros((3,)))  # ragged: no padding discipline

    return jaxpr_audit.check_single_trace(
        "planted.ragged-workload", jitted, workload
    )


_BAD_LOCK_SRC = textwrap.dedent(
    '''
    import threading


    class Counter:
        """Writes _total under the lock, then reads it bare."""

        def __init__(self):
            self._lock = threading.Lock()
            self._total = 0

        def add(self, k):
            with self._lock:
                self._total += k

        def peek(self):
            return self._total  # unguarded read of a guarded attr
    '''
)


def plant_lock_discipline() -> List[Finding]:
    from repro.analysis import lockcheck

    return lockcheck.check_source(_BAD_LOCK_SRC, "planted/bad_lock.py")


_BAD_IMPORT_SRC = "from jax.experimental.shard_map import shard_map\n"


def plant_shard_map_import() -> List[Finding]:
    from repro.analysis import lint

    return lint.check_source(_BAD_IMPORT_SRC, "planted/bad_import.py")


_BAD_TIMING_SRC = textwrap.dedent(
    """
    import time

    def slow():
        t0 = time.time()
        work()
        return time.time() - t0
    """
)


def plant_time_time() -> List[Finding]:
    from repro.analysis import lint

    return lint.check_source(_BAD_TIMING_SRC, "planted/bad_timing.py")


_BAD_MOMENT_SRC = textwrap.dedent(
    """
    import numpy as np

    def cov_from_stats(B, mu, n):
        return (B - n * np.outer(mu, mu)) / (n - 1)
    """
)


def plant_uncentred_moment() -> List[Finding]:
    from repro.analysis import lint

    return lint.check_source(_BAD_MOMENT_SRC, "planted/bad_moment.py")


_BAD_EXTRACTION_SRC = textwrap.dedent(
    """
    from repro.models import transformer as T

    def client_features(params, cfg, batch, backbone, bparams):
        hidden, _ = T.forward(params, cfg, batch["tokens"])
        mlp_feats = backbone.apply(bparams, batch["x"])
        return hidden.reshape(-1, cfg.d_model), mlp_feats
    """
)


def plant_extractor_protocol() -> List[Finding]:
    from repro.analysis import lint

    # the path puts the fixture in scope (an FL consumer under launch/)
    return lint.check_source(
        _BAD_EXTRACTION_SRC, "src/repro/launch/planted_extract.py"
    )


_BAD_BLOCKS_SRC = textwrap.dedent(
    """
    from repro.kernels import client_stats
    from repro.kernels.stats_kernel import BLOCK_N

    def sweep(f, y, c):
        # hardcodes one shape's tile choice into every shape
        return client_stats(f, y, c, block_n=1024, block_d=128)

    def pad_rows(n):
        return ((n + BLOCK_N - 1) // BLOCK_N) * BLOCK_N
    """
)


def plant_block_constants() -> List[Finding]:
    from repro.analysis import lint

    # the path puts the fixture in scope (a kernel consumer under launch/)
    return lint.check_source(
        _BAD_BLOCKS_SRC, "src/repro/launch/planted_blocks.py"
    )


_BAD_METRICS_SRC = textwrap.dedent(
    """
    import collections

    from repro.obs import Counter


    class MyMetrics:
        def __init__(self):
            # bypasses the registry name table AND hand-rolls a window
            self.hits = Counter("serve_hits_total", "ad-hoc counter")
            self.latencies = collections.deque(maxlen=4096)
    """
)


def plant_metric_funnel() -> List[Finding]:
    from repro.analysis import lint

    # the path puts the fixture in scope (a serve-tier component)
    return lint.check_source(
        _BAD_METRICS_SRC, "src/repro/serve/planted_metrics.py"
    )


PLANTS: Dict[str, Callable[[], List[Finding]]] = {
    "collective-budget": plant_collective_budget,
    "donated-aliasing": plant_donated_aliasing,
    "host-callback": plant_host_callback,
    "dtype-discipline": plant_dtype_discipline,
    "retrace-sentinel": plant_retrace_sentinel,
    "lock-discipline": plant_lock_discipline,
    "shard-map-import": plant_shard_map_import,
    "time-time": plant_time_time,
    "uncentred-second-moment": plant_uncentred_moment,
    "extractor-protocol": plant_extractor_protocol,
    "block-constants": plant_block_constants,
    "metric-funnel": plant_metric_funnel,
}
