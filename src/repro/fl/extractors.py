"""The streaming Extractor protocol: one feature surface for FedCGS.

FedCGS's premise is "leveraging pre-trained models": clients run a
frozen backbone and upload only feature statistics.  Everything that
turns raw inputs into feature rows — the random-feature MLPs in
:mod:`repro.fl.backbone`, the full model zoo in :mod:`repro.models`,
and any feature expansion stacked on top — implements ONE protocol:

    extractor.feature_dim : int
    extractor.features(x) -> (rows, feature_dim)

:class:`repro.core.stats_pipeline.StatsPipeline` accepts any such
object via its ``extractor=`` knob and streams extractor-forward →
fold per batch; :mod:`repro.launch.extract` drives "config name →
client features → one-shot global head" as a single command; and
:mod:`repro.serve` scores raw inputs through the same object.  The
``extractor-protocol`` audit rule keeps direct ``forward``/``apply``
calls out of those consumers.

:class:`ModelExtractor` is the zoo-config implementation: a frozen,
jit-compiled pooled forward pass (one trace per input shape) over
deterministic seeded parameters, optionally mesh-sharded — activating
the mesh reuses the model stack's logical-axis ``constrain`` calls, so
the batch rows shard over the data axis exactly as in `launch/`.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Protocol, Tuple, Union, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.expansion import FeatureExpansion
from repro.models import transformer as T
from repro.models.common import init_params
from repro.models.config import ModelConfig
from repro.sharding import use_mesh

Array = jax.Array


@runtime_checkable
class Extractor(Protocol):
    """Anything mapping raw inputs to feature rows ``(rows, feature_dim)``.

    :class:`repro.fl.backbone.Backbone` satisfies this structurally;
    so do :class:`ModelExtractor` and :class:`ComposedExtractor`.
    """

    feature_dim: int

    def features(self, x: Array) -> Array:
        ...


class ModelExtractor:
    """Any zoo config as a frozen, jit-compiled feature extractor.

    Parameters are a deterministic function of ``seed`` (the offline
    stand-in for "pre-trained" weights, as in ``fl/backbone.py``), the
    pooled forward is jit-compiled once per token shape, and encoder /
    vision side-inputs (``frames``/``patches``) are seeded stubs cached
    per batch size so repeated calls are bit-identical.
    """

    def __init__(
        self,
        cfg: Union[ModelConfig, str],
        *,
        pooling: str = "mean",
        seed: int = 0,
        reduced: bool = True,
        params=None,
        mesh=None,
    ):
        if isinstance(cfg, str):
            from repro.configs import get_config  # local import, avoids cycle

            cfg = get_config(cfg, reduced=reduced)
        if pooling not in T.POOLINGS:
            raise ValueError(f"pooling must be one of {T.POOLINGS}, got {pooling!r}")
        self.cfg = cfg
        self.pooling = pooling
        self.seed = seed
        self.mesh = mesh
        self.params = (
            init_params(T.build_specs(cfg), jax.random.key(seed))
            if params is None
            else params
        )
        self.feature_dim = T.feature_dim(cfg)
        self._side_inputs: Dict[int, Dict[str, Array]] = {}
        self._pooled = jax.jit(
            functools.partial(T.features, cfg=cfg, pooling=pooling)
        )

    def rows_per_batch(self, batch: int, seq_len: int) -> int:
        """How many feature rows a (batch, seq_len) token block yields."""
        return batch * seq_len if self.pooling == "tokens" else batch

    def _extras(self, batch: int) -> Dict[str, Array]:
        """Seeded stub side-inputs (vision patches / encoder frames).

        Offline stand-ins, like the random-feature backbones: real
        deployments pass genuine patches/frames through ``features``'s
        keyword arguments instead.
        """
        cached = self._side_inputs.get(batch)
        if cached is not None:
            return cached
        rng = np.random.default_rng(self.seed + 1)
        kw: Dict[str, Array] = {}
        if self.cfg.vision_tokens:
            kw["patches"] = jnp.asarray(
                rng.standard_normal((batch, self.cfg.vision_tokens, self.cfg.d_model))
                * 0.02,
                jnp.float32,
            )
        if self.cfg.is_encdec:
            kw["frames"] = jnp.asarray(
                rng.standard_normal((batch, self.cfg.encoder_seq_len, self.cfg.d_model))
                * 0.02,
                jnp.float32,
            )
        self._side_inputs[batch] = kw
        return kw

    def features(self, x: Array, **side_inputs: Array) -> Array:
        """Pooled features for a ``(batch, seq_len)`` token block."""
        tokens = jnp.asarray(x)
        if tokens.ndim != 2:
            raise ValueError(f"expected (batch, seq_len) tokens, got {tokens.shape}")
        kw = dict(self._extras(tokens.shape[0]))
        kw.update(side_inputs)
        if self.mesh is not None:
            with use_mesh(self.mesh):
                return self._pooled(self.params, tokens=tokens, **kw)
        return self._pooled(self.params, tokens=tokens, **kw)


@dataclasses.dataclass(frozen=True)
class ComposedExtractor:
    """An extractor with a :class:`FeatureExpansion` stacked on top."""

    base: Extractor
    expansion: FeatureExpansion

    @property
    def feature_dim(self) -> int:
        return self.expansion.expanded_dim

    def features(self, x: Array) -> Array:
        return self.expansion(self.base.features(x))


def as_extractor(
    base: Extractor, expansion: Optional[FeatureExpansion] = None
) -> Extractor:
    """Normalize (backbone-or-extractor, optional expansion) to ONE extractor."""
    if expansion is None:
        return base
    return ComposedExtractor(base=base, expansion=expansion)


def token_labels(targets: Array) -> Array:
    """Per-row labels for ``pooling="tokens"``: class = next-token id."""
    return jnp.asarray(targets).reshape(-1)


def synthetic_token_clients(
    cfg: ModelConfig,
    *,
    clients: int,
    batches_per_client: int,
    batch: int,
    seq_len: int,
    seed: int = 0,
):
    """Synthetic client token streams for the extract driver / benches.

    Each client is a list of ``(tokens, targets)`` pairs drawn from a
    per-client Markov corpus (distinct branching → non-IID next-token
    distributions), shaped for :func:`ModelExtractor.features` with
    ``pooling="tokens"``.
    """
    from repro.data.tokens import TokenStream, synthetic_corpus

    out = []
    for c in range(clients):
        corpus = synthetic_corpus(
            cfg.vocab_size,
            batches_per_client * batch * (seq_len + 1) + seq_len + 1,
            seed=seed + 17 * c,
            branching=2 + (c % 3),
        )
        stream = iter(TokenStream(corpus, batch, seq_len, seed=seed + 31 * c))
        out.append([next(stream) for _ in range(batches_per_client)])
    return out
