"""Frozen "pre-trained" feature extractors for the FL experiments.

The paper uses ImageNet-pretrained CNNs (ResNet18/50, MobileNetV2,
EfficientNetB0); offline we substitute fixed random-feature MLPs of
varying width/depth (DESIGN.md §2).  Random-feature maps are a standard
stand-in: they are deterministic functions of a public seed, frozen, and
their quality ladder (wider/deeper => more separable features) mirrors
the paper's Table 5 pre-trained-model ladder.

Backbones are also *trainable* pytrees so the personalization
experiments (fine-tune the whole model, Eq. 12) and FedAvg-style
baselines can update them.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array
PyTree = Dict[str, Array]


@dataclasses.dataclass(frozen=True)
class Backbone:
    """An MLP feature extractor f: R^input_dim -> R^feature_dim."""

    name: str
    input_dim: int
    feature_dim: int
    hidden: Tuple[int, ...] = (256,)
    seed: int = 0

    def init(self, seed: int | None = None) -> PyTree:
        key = jax.random.key(self.seed if seed is None else seed)
        dims = (self.input_dim,) + self.hidden + (self.feature_dim,)
        params: PyTree = {}
        for i, (din, dout) in enumerate(zip(dims[:-1], dims[1:])):
            key, k = jax.random.split(key)
            params[f"w{i}"] = jax.random.normal(k, (din, dout)) / jnp.sqrt(din)
            params[f"b{i}"] = jnp.zeros((dout,))
        return params

    @property
    def num_layers(self) -> int:
        return len(self.hidden) + 1

    def apply(self, params: PyTree, x: Array) -> Array:
        h = x
        for i in range(self.num_layers):
            h = h @ params[f"w{i}"] + params[f"b{i}"]
            if i < self.num_layers - 1:
                h = jax.nn.gelu(h)
        # final nonlinearity: pre-trained-CNN features are post-ReLU
        return jax.nn.relu(h)

    def features(self, x: Array, *, params: PyTree | None = None) -> Array:
        return self.apply(self.init() if params is None else params, x)


def make_backbone(name: str, input_dim: int) -> Backbone:
    """The Table-5 ladder of 'pre-trained models'."""
    ladder = {
        # name:            (hidden,           feature_dim)
        "resnet18-like": ((256, 256), 128),
        "resnet50-like": ((512, 512, 512), 256),
        "mobilenet-like": ((128,), 64),
        "efficientnet-like": ((192, 192), 96),
    }
    hidden, feat = ladder[name]
    return Backbone(name=name, input_dim=input_dim, feature_dim=feat, hidden=hidden)


BACKBONES: List[str] = [
    "resnet18-like",
    "resnet50-like",
    "mobilenet-like",
    "efficientnet-like",
]
