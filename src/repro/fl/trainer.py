"""Generic local trainer: (backbone [+ linear head]) x SGD x epochs.

Shared by every backprop baseline (FedAvg, Ensemble, DENSE, FedPFT's
server-side head training, FedAvg-FT, Local-only, FedProto, and
FedCGS-personalized).  The jitted step is cached per (shapes, optimizer)
so sweeping 10 clients retraces nothing.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.fl.backbone import Backbone
from repro.optim import Optimizer, apply_updates

Array = jax.Array
PyTree = Any


@dataclasses.dataclass(frozen=True)
class ClassifierModel:
    """backbone + linear head; the trainable unit of all baselines."""

    backbone: Backbone
    num_classes: int

    def init(self, seed: int = 0) -> PyTree:
        bp = self.backbone.init(seed)
        key = jax.random.key(seed + 1)
        head_w = jax.random.normal(
            key, (self.backbone.feature_dim, self.num_classes)
        ) / jnp.sqrt(self.backbone.feature_dim)
        return {"backbone": bp, "head_w": head_w, "head_b": jnp.zeros((self.num_classes,))}

    def features(self, params: PyTree, x: Array) -> Array:
        return self.backbone.apply(params["backbone"], x)

    def logits(self, params: PyTree, x: Array) -> Array:
        return self.features(params, x) @ params["head_w"] + params["head_b"]

    def accuracy(self, params: PyTree, x: Array, y: Array) -> float:
        pred = jnp.argmax(self.logits(params, x), axis=-1)
        return float(jnp.mean((pred == y).astype(jnp.float32)))


def cross_entropy(logits: Array, labels: Array) -> Array:
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


@functools.lru_cache(maxsize=64)
def _jitted_step(
    model: ClassifierModel,
    opt: Optimizer,
    freeze_backbone: bool,
    proto_lambda: float,
):
    def loss_fn(params, x, y, prototypes):
        logits = model.logits(params, x)
        loss = cross_entropy(logits, y)
        if prototypes is not None and proto_lambda > 0.0:
            feats = model.features(params, x)
            mu_y = prototypes[y]  # (n, d)
            loss = loss + proto_lambda * jnp.mean(
                jnp.sum((feats - mu_y) ** 2, axis=-1)
            )
        return loss

    @functools.partial(jax.jit, static_argnames=())
    def step(params, opt_state, x, y, prototypes):
        loss, grads = jax.value_and_grad(loss_fn)(params, x, y, prototypes)
        if freeze_backbone:
            grads = dict(grads)
            grads["backbone"] = jax.tree_util.tree_map(
                jnp.zeros_like, grads["backbone"]
            )
        updates, opt_state = opt.update(grads, opt_state, params)
        return apply_updates(params, updates), opt_state, loss

    return step


def train_local(
    model: ClassifierModel,
    params: PyTree,
    x: np.ndarray,
    y: np.ndarray,
    opt: Optimizer,
    *,
    epochs: int = 10,
    batch_size: int = 128,
    seed: int = 0,
    freeze_backbone: bool = False,
    prototypes: Optional[Array] = None,
    proto_lambda: float = 0.0,
) -> Tuple[PyTree, float]:
    """Mini-batch SGD on one client's data. Returns (params, last loss)."""
    step = _jitted_step(model, opt, freeze_backbone, float(proto_lambda))
    opt_state = opt.init(params)
    n = len(x)
    bs = min(batch_size, n)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(x)
    y = jnp.asarray(y)
    loss = jnp.zeros(())
    for _ in range(epochs):
        order = rng.permutation(n)
        for start in range(0, n - bs + 1, bs):
            idx = order[start : start + bs]
            params, opt_state, loss = step(params, opt_state, x[idx], y[idx], prototypes)
    return params, float(loss)
