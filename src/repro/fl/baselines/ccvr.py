"""CCVR (Luo et al. 2021) — classifier calibration with virtual features.

Clients upload class-wise (mean, covariance, count) of their features;
the server combines them into global class-wise Gaussians, samples
virtual features, and retrains the classifier.  The paper contrasts
FedCGS against CCVR on three axes: CCVR uploads C covariance matrices
(C·d² floats — huge), its combination rule is incompatible with
SecureAgg (requires per-client moments), and sampled-feature retraining
is configuration-sensitive.
"""

from __future__ import annotations

from typing import Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.stats_pipeline import StatsPipeline, class_conditional_moments
from repro.fl.extractors import Extractor
from repro.fl.baselines.fedpft import _train_linear_head

Dataset = Tuple[np.ndarray, np.ndarray]


def run_ccvr(
    backbone: Extractor,
    client_data: Sequence[Dataset],
    num_classes: int,
    test_data: Dataset,
    *,
    samples_per_class: int = 500,
    epochs: int = 50,
    seed: int = 0,
) -> float:
    rng = np.random.default_rng(seed)
    d = backbone.feature_dim

    # --- clients upload per-class first+second moments (NOT SecureAgg-able:
    # the server needs every client's own mean to combine covariances).
    # The moments come out of the statistics pipeline, same data path as
    # FedCGS's own sweep.
    pipeline = StatsPipeline(num_classes)
    mu_c = np.zeros((len(client_data), num_classes, d))
    cov_c = np.zeros((len(client_data), num_classes, d, d))
    n_c = np.zeros((len(client_data), num_classes), dtype=np.int64)
    for i, (x, y) in enumerate(client_data):
        feats = backbone.features(jnp.asarray(x))
        mu_c[i], cov_c[i], n_c[i] = class_conditional_moments(pipeline, feats, y)

    # --- server: combine into global class-wise Gaussians (CCVR Eq. 3-4)
    synth_x, synth_y = [], []
    for c in range(num_classes):
        nc = n_c[:, c].sum()
        if nc < 2:
            continue
        mu = (n_c[:, c : c + 1] * mu_c[:, c]).sum(axis=0) / nc
        # law of total covariance over clients
        ex_cov = sum(
            (n_c[i, c] - 1) / (nc - 1) * cov_c[i, c] for i in range(len(client_data))
        )
        cov_mu = sum(
            n_c[i, c] / (nc - 1) * np.outer(mu_c[i, c] - mu, mu_c[i, c] - mu)
            for i in range(len(client_data))
        )
        cov = ex_cov + cov_mu
        cov += 1e-4 * np.trace(cov) / d * np.eye(d)
        samp = rng.multivariate_normal(mu, cov, size=samples_per_class)
        synth_x.append(np.maximum(samp, 0.0))  # features are post-ReLU
        synth_y.append(np.full(samples_per_class, c, dtype=np.int64))

    feats = np.concatenate(synth_x)
    labels = np.concatenate(synth_y)
    w, b = _train_linear_head(feats, labels, num_classes, epochs=epochs, seed=seed)

    xt = backbone.features(jnp.asarray(test_data[0]))
    pred = jnp.argmax(xt @ w + b, axis=-1)
    return float(jnp.mean((pred == jnp.asarray(test_data[1])).astype(jnp.float32)))


def ccvr_upload_floats(d: int, num_classes: int) -> int:
    """C·(d² + d + 1) — per-class covariance dominates."""
    return num_classes * (d * d + d + 1)
