"""FedPFT (Beitollahi et al. 2024) — the paper's closest baseline.

Each client fits a class-conditional diagonal-covariance GMM with K_g
components on its frozen-backbone features and uploads (means, vars,
weights, counts).  The server samples class-labelled synthetic features
from every client's GMMs (count-proportional) and trains a linear head
on them with SGD.

Upload size per client: (2d + 1)·K_g·C floats (paper §Communication).
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.fl.extractors import Extractor

Array = jax.Array
Dataset = Tuple[np.ndarray, np.ndarray]


@dataclasses.dataclass
class GMM:
    """Diagonal-covariance Gaussian mixture (one per client per class)."""

    means: np.ndarray  # (K, d)
    vars: np.ndarray  # (K, d)
    weights: np.ndarray  # (K,)
    count: int  # #samples this class had on this client


def fit_gmm(
    feats: np.ndarray, k: int, *, iters: int = 50, seed: int = 0, eps: float = 1e-4
) -> GMM:
    """Diagonal EM with k-means++-style seeding (numpy; small data)."""
    rng = np.random.default_rng(seed)
    n, d = feats.shape
    k = min(k, n)
    # -- init: distance-weighted center choice
    centers = [feats[rng.integers(n)]]
    for _ in range(1, k):
        d2 = np.min(
            [np.sum((feats - c) ** 2, axis=1) for c in centers], axis=0
        )
        p = d2 / max(d2.sum(), 1e-12)
        centers.append(feats[rng.choice(n, p=p)])
    means = np.stack(centers)
    vars_ = np.full((k, d), feats.var(axis=0) + eps)
    weights = np.full(k, 1.0 / k)

    for _ in range(iters):
        # E-step: responsibilities (log-domain)
        log_w = np.log(weights + 1e-12)[None]  # (1, K)
        diff = feats[:, None, :] - means[None]  # (n, K, d)
        log_p = (
            -0.5 * np.sum(diff**2 / vars_[None], axis=2)
            - 0.5 * np.sum(np.log(2 * np.pi * vars_), axis=1)[None]
        )
        log_r = log_w + log_p
        log_r -= log_r.max(axis=1, keepdims=True)
        r = np.exp(log_r)
        r /= r.sum(axis=1, keepdims=True)
        # M-step
        nk = r.sum(axis=0) + 1e-8  # (K,)
        means = (r.T @ feats) / nk[:, None]
        diff = feats[:, None, :] - means[None]
        vars_ = np.einsum("nk,nkd->kd", r, diff**2) / nk[:, None] + eps
        weights = nk / n
    return GMM(means=means, vars=vars_, weights=weights, count=n)


def gmm_sample(gmm: GMM, n: int, rng: np.random.Generator) -> np.ndarray:
    comp = rng.choice(len(gmm.weights), size=n, p=gmm.weights / gmm.weights.sum())
    return gmm.means[comp] + np.sqrt(gmm.vars[comp]) * rng.standard_normal(
        (n, gmm.means.shape[1])
    )


def _train_linear_head(
    feats: np.ndarray,
    labels: np.ndarray,
    num_classes: int,
    *,
    epochs: int = 50,
    lr: float = 0.01,
    momentum: float = 0.9,
    batch: int = 128,
    seed: int = 0,
) -> Tuple[Array, Array]:
    d = feats.shape[1]
    key = jax.random.key(seed)
    w = jax.random.normal(key, (d, num_classes)) / jnp.sqrt(d)
    b = jnp.zeros((num_classes,))
    mw, mb = jnp.zeros_like(w), jnp.zeros_like(b)

    @jax.jit
    def step(w, b, mw, mb, x, y):
        def loss_fn(w, b):
            logp = jax.nn.log_softmax(x @ w + b, axis=-1)
            return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))

        gw, gb = jax.grad(loss_fn, argnums=(0, 1))(w, b)
        mw2, mb2 = momentum * mw + gw, momentum * mb + gb
        return w - lr * mw2, b - lr * mb2, mw2, mb2

    rng = np.random.default_rng(seed)
    n = len(feats)
    bs = min(batch, n)
    xj, yj = jnp.asarray(feats, jnp.float32), jnp.asarray(labels)
    for _ in range(epochs):
        order = rng.permutation(n)
        for s in range(0, n - bs + 1, bs):
            idx = order[s : s + bs]
            w, b, mw, mb = step(w, b, mw, mb, xj[idx], yj[idx])
    return w, b


def run_fedpft(
    backbone: Extractor,
    client_data: Sequence[Dataset],
    num_classes: int,
    test_data: Dataset,
    *,
    k_components: int = 10,
    epochs: int = 50,
    seed: int = 0,
) -> float:
    """Full FedPFT: per-(client, class) GMM upload -> sample -> train head."""
    rng = np.random.default_rng(seed)
    # --- clients: fit class-conditional GMMs on frozen features.  GMM
    # fitting consumes RAW features, not sufficient statistics, so the
    # statistics pipeline has nothing to offer here; the >= 2 gating only
    # needs per-class counts, which bincount gives in O(n).
    gmms: List[List[Optional[GMM]]] = []
    for ci, (x, y) in enumerate(client_data):
        feats = np.asarray(backbone.features(jnp.asarray(x)))
        y_np = np.asarray(y)
        counts = np.bincount(y_np, minlength=num_classes)
        per_class: List[Optional[GMM]] = [
            fit_gmm(feats[y_np == c], k_components, seed=seed + 31 * ci + c)
            if counts[c] >= 2
            else None
            for c in range(num_classes)
        ]
        gmms.append(per_class)

    # --- server: count-matched sampling, then head training
    synth_x, synth_y = [], []
    for per_class in gmms:
        for c, g in enumerate(per_class):
            if g is None:
                continue
            synth_x.append(gmm_sample(g, g.count, rng))
            synth_y.append(np.full(g.count, c, dtype=np.int64))
    feats = np.concatenate(synth_x)
    labels = np.concatenate(synth_y)
    w, b = _train_linear_head(feats, labels, num_classes, epochs=epochs, seed=seed)

    xt = backbone.features(jnp.asarray(test_data[0]))
    pred = jnp.argmax(xt @ w + b, axis=-1)
    return float(jnp.mean((pred == jnp.asarray(test_data[1])).astype(jnp.float32)))


def fedpft_upload_floats(d: int, k: int, num_classes: int) -> int:
    """(2d + 1)·K_g·C — the paper's communication accounting."""
    return (2 * d + 1) * k * num_classes
