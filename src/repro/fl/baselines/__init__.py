from repro.fl.baselines.fedavg import (
    run_ensemble,
    run_fedavg_oneshot,
    run_fedavg_ft,
    run_fedavg_multiround,
    run_local_only,
)
from repro.fl.baselines.fedpft import run_fedpft
from repro.fl.baselines.ccvr import run_ccvr
from repro.fl.baselines.dense_kd import run_dense
from repro.fl.baselines.fedproto import run_fedproto

__all__ = [
    "run_fedavg_oneshot",
    "run_fedavg_multiround",
    "run_fedavg_ft",
    "run_local_only",
    "run_ensemble",
    "run_fedpft",
    "run_ccvr",
    "run_dense",
    "run_fedproto",
]
