"""FedProto (Tan et al. 2022) — multi-round prototype-sharing pFL baseline.

Each round: clients train locally with a prototype-alignment term toward
the CURRENT global prototypes, then upload their class prototypes; the
server re-averages them.  Contrast with FedCGS-personalized: FedCGS
downloads FIXED exact global prototypes once (one-shot), FedProto needs
``rounds`` communication rounds and its prototypes drift with training.
"""

from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.stats_pipeline import StatsPipeline
from repro.fl.backbone import Backbone
from repro.fl.trainer import ClassifierModel, train_local
from repro.optim import sgd

Dataset = Tuple[np.ndarray, np.ndarray]


def _client_prototypes(
    model: ClassifierModel,
    params,
    x: np.ndarray,
    y: np.ndarray,
    pipeline: StatsPipeline,
) -> Tuple[np.ndarray, np.ndarray]:
    """Per-class mean features — the pipeline's A/N slice (no Gram
    matrix; classes with no samples keep a zero prototype)."""
    feats = model.features(params, jnp.asarray(x))
    protos, counts = pipeline.class_means(feats, jnp.asarray(y).astype(jnp.int32))
    return np.asarray(protos), np.asarray(counts)


def run_fedproto(
    backbone: Backbone,
    client_data: Sequence[Dataset],
    client_test: Sequence[Dataset],
    num_classes: int,
    *,
    rounds: int = 100,
    local_epochs: int = 1,
    proto_lambda: float = 1.0,
    lr: float = 0.01,
    seed: int = 0,
) -> List[float]:
    model = ClassifierModel(backbone=backbone, num_classes=num_classes)
    opt = sgd(lr, momentum=0.5, weight_decay=5e-4)
    client_params = [model.init(seed + i) for i in range(len(client_data))]
    global_protos: Optional[jnp.ndarray] = None
    pipeline = StatsPipeline(num_classes)

    for r in range(rounds):
        protos_sum = np.zeros((num_classes, backbone.feature_dim))
        counts_sum = np.zeros(num_classes)
        for i, (x, y) in enumerate(client_data):
            client_params[i], _ = train_local(
                model, client_params[i], x, y, opt,
                epochs=local_epochs, seed=seed + 97 * r + i,
                prototypes=global_protos, proto_lambda=proto_lambda if r else 0.0,
            )
            p, c = _client_prototypes(model, client_params[i], x, y, pipeline)
            protos_sum += p * c[:, None]
            counts_sum += c
        global_protos = jnp.asarray(
            protos_sum / np.maximum(counts_sum, 1.0)[:, None], jnp.float32
        )

    return [
        model.accuracy(p, jnp.asarray(xt), jnp.asarray(yt))
        for p, (xt, yt) in zip(client_params, client_test)
    ]
