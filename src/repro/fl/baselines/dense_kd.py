"""DENSE-style data-free knowledge distillation (Zhang et al. 2022), compact.

The server (1) trains a conditional generator whose outputs make the
client ENSEMBLE confident and diverse (no real data touched), then
(2) distills the ensemble into a single global model on generated data.
Co-Boosting (Dai et al. 2024) adds ensemble re-weighting against the
hardest synthetic batch — we implement that as ``co_boost=True``.

This is exactly the kind of server-side compute + hyperparameter
sensitivity the paper holds against DFKD methods; the reproduction
keeps it honest but compact (MLP generator, Adam 1e-3, 30 epochs).
"""

from __future__ import annotations

from typing import Any, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.fl.backbone import Backbone
from repro.fl.baselines.fedavg import _train_clients
from repro.fl.trainer import ClassifierModel, cross_entropy
from repro.optim import adamw, apply_updates, sgd

Array = jax.Array
PyTree = Any
Dataset = Tuple[np.ndarray, np.ndarray]


def _generator_init(key: Array, noise_dim: int, num_classes: int, out_dim: int, hidden: int = 256) -> PyTree:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "embed": 0.1 * jax.random.normal(k1, (num_classes, noise_dim)),
        "w1": jax.random.normal(k2, (noise_dim, hidden)) / jnp.sqrt(noise_dim),
        "b1": jnp.zeros((hidden,)),
        "w2": jax.random.normal(k3, (hidden, out_dim)) / jnp.sqrt(hidden),
        "b2": jnp.zeros((out_dim,)),
    }


def _generate(gen: PyTree, z: Array, labels: Array) -> Array:
    h = z + gen["embed"][labels]
    h = jax.nn.gelu(h @ gen["w1"] + gen["b1"])
    return h @ gen["w2"] + gen["b2"]


def run_dense(
    backbone: Backbone,
    client_data: Sequence[Dataset],
    num_classes: int,
    test_data: Dataset,
    *,
    input_dim: int | None = None,
    local_epochs: int = 50,
    gen_epochs: int = 30,
    distill_epochs: int = 50,
    steps_per_epoch: int = 20,
    batch: int = 128,
    noise_dim: int = 64,
    seed: int = 0,
    co_boost: bool = False,
) -> float:
    """Train locals -> train generator vs ensemble -> distill global model."""
    input_dim = input_dim if input_dim is not None else backbone.input_dim
    model = ClassifierModel(backbone=backbone, num_classes=num_classes)
    locals_ = _train_clients(model, client_data, epochs=local_epochs, seed=seed)
    ens_w = jnp.ones((len(locals_),)) / len(locals_)

    def ensemble_logits(x: Array, w: Array) -> Array:
        probs = jnp.stack([jax.nn.softmax(model.logits(p, x), -1) for p in locals_])
        return jnp.log(jnp.einsum("m,mnc->nc", w, probs) + 1e-9)

    # ---- stage 1: generator training (confidence + batch-diversity) ----
    key = jax.random.key(seed)
    gen = _generator_init(key, noise_dim, num_classes, input_dim)
    gopt = adamw(1e-3)
    gstate = gopt.init(gen)

    @jax.jit
    def gen_step(gen, gstate, z, labels, w):
        def loss_fn(gen):
            x = _generate(gen, z, labels)
            logp = jax.nn.log_softmax(ensemble_logits(x, w), -1)
            ce = -jnp.mean(jnp.take_along_axis(logp, labels[:, None], 1))
            # information-entropy diversity: batch-mean prediction should be flat
            mean_p = jnp.mean(jnp.exp(logp), axis=0)
            div = jnp.sum(mean_p * jnp.log(mean_p + 1e-9))
            return ce + 0.5 * div
        loss, grads = jax.value_and_grad(loss_fn)(gen)
        upd, gstate = gopt.update(grads, gstate, gen)
        return apply_updates(gen, upd), gstate, loss

    rng = np.random.default_rng(seed)
    for _ in range(gen_epochs * steps_per_epoch):
        z = jnp.asarray(rng.standard_normal((batch, noise_dim)), jnp.float32)
        labels = jnp.asarray(rng.integers(0, num_classes, batch))
        gen, gstate, _ = gen_step(gen, gstate, z, labels, ens_w)

    # ---- optional Co-Boosting: reweight ensemble members on hard data ----
    if co_boost:
        z = jnp.asarray(rng.standard_normal((batch * 4, noise_dim)), jnp.float32)
        labels = jnp.asarray(rng.integers(0, num_classes, batch * 4))
        x = _generate(gen, z, labels)
        member_acc = jnp.stack(
            [
                jnp.mean((jnp.argmax(model.logits(p, x), -1) == labels).astype(jnp.float32))
                for p in locals_
            ]
        )
        ens_w = jax.nn.softmax(member_acc / 0.25)

    # ---- stage 2: distill ensemble -> global model on generated data ----
    student = model.init(seed + 1)
    sopt = sgd(0.01, momentum=0.9)
    sstate = sopt.init(student)

    @jax.jit
    def distill_step(student, sstate, z, labels, w):
        x = _generate(gen, z, labels)
        teacher = jax.nn.softmax(ensemble_logits(x, w), -1)

        def loss_fn(student):
            logp = jax.nn.log_softmax(model.logits(student, x), -1)
            return -jnp.mean(jnp.sum(teacher * logp, axis=-1))

        loss, grads = jax.value_and_grad(loss_fn)(student)
        upd, sstate = sopt.update(grads, sstate, student)
        return apply_updates(student, upd), sstate, loss

    for _ in range(distill_epochs * steps_per_epoch):
        z = jnp.asarray(rng.standard_normal((batch, noise_dim)), jnp.float32)
        labels = jnp.asarray(rng.integers(0, num_classes, batch))
        student, sstate, _ = distill_step(student, sstate, z, labels, ens_w)

    return model.accuracy(student, jnp.asarray(test_data[0]), jnp.asarray(test_data[1]))


def run_co_boosting(*args, **kwargs) -> float:
    kwargs["co_boost"] = True
    return run_dense(*args, **kwargs)
