"""FedAvg-family baselines: one-shot FedAvg, multi-round FedAvg,
FedAvg-FT, Local-only, and the Ensemble upper bound.

All train (backbone + linear head) with SGD exactly as the paper's
configuration (batch 128, momentum 0.9, lr 0.01, 50 local epochs for
the one-shot setting).
"""

from __future__ import annotations

from typing import Any, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.fl.backbone import Backbone
from repro.fl.trainer import ClassifierModel, train_local
from repro.optim import sgd

Array = jax.Array
PyTree = Any
Dataset = Tuple[np.ndarray, np.ndarray]


def _train_clients(
    model: ClassifierModel,
    client_data: Sequence[Dataset],
    *,
    epochs: int,
    lr: float = 0.01,
    momentum: float = 0.9,
    seed: int = 0,
    init_params: Optional[PyTree] = None,
) -> List[PyTree]:
    opt = sgd(lr, momentum=momentum)
    out = []
    for i, (x, y) in enumerate(client_data):
        params = model.init(seed + i) if init_params is None else init_params
        params, _ = train_local(
            model, params, x, y, opt, epochs=epochs, seed=seed + i
        )
        out.append(params)
    return out


def _weighted_average(params_list: Sequence[PyTree], sizes: Sequence[int]) -> PyTree:
    total = float(sum(sizes))
    w = [s / total for s in sizes]
    return jax.tree_util.tree_map(
        lambda *leaves: sum(wi * l for wi, l in zip(w, leaves)), *params_list
    )


def run_fedavg_oneshot(
    backbone: Backbone,
    client_data: Sequence[Dataset],
    num_classes: int,
    test_data: Dataset,
    *,
    epochs: int = 50,
    seed: int = 0,
) -> float:
    """ONE round: local training from a COMMON init, then parameter averaging."""
    model = ClassifierModel(backbone=backbone, num_classes=num_classes)
    common = model.init(seed)
    locals_ = _train_clients(
        model, client_data, epochs=epochs, seed=seed, init_params=common
    )
    avg = _weighted_average(locals_, [len(x) for x, _ in client_data])
    return model.accuracy(avg, jnp.asarray(test_data[0]), jnp.asarray(test_data[1]))


def run_fedavg_multiround(
    backbone: Backbone,
    client_data: Sequence[Dataset],
    num_classes: int,
    test_data: Dataset,
    *,
    rounds: int = 100,
    local_epochs: int = 1,
    seed: int = 0,
    return_params: bool = False,
):
    """Classic FedAvg (the personalized-FL baseline: 100 rounds, 1 epoch)."""
    model = ClassifierModel(backbone=backbone, num_classes=num_classes)
    global_params = model.init(seed)
    sizes = [len(x) for x, _ in client_data]
    for r in range(rounds):
        locals_ = _train_clients(
            model, client_data, epochs=local_epochs, seed=seed + r,
            init_params=global_params,
        )
        global_params = _weighted_average(locals_, sizes)
    acc = model.accuracy(
        global_params, jnp.asarray(test_data[0]), jnp.asarray(test_data[1])
    )
    if return_params:
        return acc, model, global_params
    return acc


def run_fedavg_ft(
    backbone: Backbone,
    client_data: Sequence[Dataset],
    client_test: Sequence[Dataset],
    num_classes: int,
    *,
    rounds: int = 100,
    ft_epochs: int = 10,
    seed: int = 0,
) -> List[float]:
    """FedAvg + local fine-tuning (the strong personalized baseline)."""
    model = ClassifierModel(backbone=backbone, num_classes=num_classes)
    # train the global model on the union via multi-round FedAvg
    _, model, global_params = run_fedavg_multiround(
        backbone, client_data, num_classes,
        (client_test[0][0], client_test[0][1]),
        rounds=rounds, seed=seed, return_params=True,
    )
    opt = sgd(0.01, momentum=0.5, weight_decay=5e-4)
    accs = []
    for i, ((x, y), (xt, yt)) in enumerate(zip(client_data, client_test)):
        params, _ = train_local(
            model, global_params, x, y, opt, epochs=ft_epochs, seed=seed + i
        )
        accs.append(model.accuracy(params, jnp.asarray(xt), jnp.asarray(yt)))
    return accs


def run_local_only(
    backbone: Backbone,
    client_data: Sequence[Dataset],
    client_test: Sequence[Dataset],
    num_classes: int,
    *,
    epochs: int = 200,
    seed: int = 0,
) -> List[float]:
    model = ClassifierModel(backbone=backbone, num_classes=num_classes)
    locals_ = _train_clients(model, client_data, epochs=epochs, seed=seed)
    return [
        model.accuracy(p, jnp.asarray(xt), jnp.asarray(yt))
        for p, (xt, yt) in zip(locals_, client_test)
    ]


def run_ensemble(
    backbone: Backbone,
    client_data: Sequence[Dataset],
    num_classes: int,
    test_data: Dataset,
    *,
    epochs: int = 50,
    seed: int = 0,
    return_models: bool = False,
):
    """Logit-ensemble of independently trained local models (upper bound
    for DENSE; heavy server storage — the paper's stated drawback)."""
    model = ClassifierModel(backbone=backbone, num_classes=num_classes)
    locals_ = _train_clients(model, client_data, epochs=epochs, seed=seed)
    xt, yt = jnp.asarray(test_data[0]), jnp.asarray(test_data[1])
    logits = sum(jax.nn.softmax(model.logits(p, xt), axis=-1) for p in locals_)
    acc = float(jnp.mean((jnp.argmax(logits, -1) == yt).astype(jnp.float32)))
    if return_models:
        return acc, model, locals_
    return acc
