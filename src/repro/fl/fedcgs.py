"""FedCGS end-to-end pipelines (the paper's Algorithm 1 + §personalized).

:func:`run_fedcgs` — global one-shot FL:
  1. every client extracts frozen-backbone features and computes
     (A_i, B_i, N_i)                                    [ClientStats]
  2. SecureAgg sums them                                [server, 1 round]
  3. (μ, Σ, π) derived, GNB head configured             [training-free]

:func:`run_fedcgs_personalized` — one EXTRA download round: clients
receive the global prototypes μ and fine-tune their whole local model
with the feature-alignment regularizer (Eq. 12).

All statistics flow through ONE data path —
:class:`repro.core.stats_pipeline.StatsPipeline` — so the
``use_kernel`` (fused Pallas sweep), ``distributed`` (mesh-sharded, one
psum), and ``use_secure_agg`` (pairwise-mask aggregation) switches
compose uniformly across the global AND personalized protocols instead
of each entry point hand-rolling its own plumbing.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.classifier import LinearHead, gnb_head
from repro.core.expansion import FeatureExpansion
from repro.core.statistics import FeatureStats, GlobalStatistics, derive_global
from repro.core.stats_pipeline import StatsPipeline
from repro.fl.backbone import Backbone
from repro.fl.extractors import as_extractor
from repro.fl.trainer import ClassifierModel, train_local
from repro.optim import sgd

Array = jax.Array


@dataclasses.dataclass
class FedCGSResult:
    head: LinearHead
    stats: GlobalStatistics
    uploaded_floats_per_client: int
    accuracy: Optional[float] = None


def _make_pipeline(
    num_classes: int,
    *,
    use_kernel: bool = False,
    distributed: bool = False,
    secure: bool = False,
    mesh=None,
    dropout: Sequence[int] = (),
    min_survivors: Optional[int] = None,
    extractor=None,
) -> StatsPipeline:
    """fl-layer switches -> the pipeline's knob matrix."""
    return StatsPipeline(
        num_classes,
        backend="fused" if use_kernel else "jnp",
        placement="sharded" if distributed else "local",
        privacy="secure" if secure else "plain",
        mesh=mesh,
        dropout=dropout,
        min_survivors=min_survivors,
        extractor=extractor,
    )


def client_stats_pass(
    backbone: Backbone,
    x: Array,
    y: Array,
    num_classes: int,
    *,
    expansion: Optional[FeatureExpansion] = None,
    use_kernel: bool = False,
    distributed: bool = False,
    mesh=None,
) -> FeatureStats:
    """One client's ClientStats(D_i): features -> (A, B, N).

    ``use_kernel=True`` computes the sweep with the fused single-pass
    Pallas engine.  ``distributed=True`` additionally shards the batch
    over ``mesh``'s client axes (default: a host mesh over all local
    devices) and aggregates with one psum — the multi-device engine in
    ``repro.launch.stats_engine``, reached through the pipeline.

    Extraction goes through the pipeline's ``extractor=`` knob (the
    Extractor protocol; backbone + optional expansion as ONE object),
    the same raw-input path every other consumer uses.
    """
    pipeline = _make_pipeline(
        num_classes, use_kernel=use_kernel, distributed=distributed, mesh=mesh,
        extractor=as_extractor(backbone, expansion),
    )
    return pipeline.from_arrays(jnp.asarray(x), jnp.asarray(y))


def aggregate_client_stats(
    backbone: Backbone,
    client_data: Sequence[Tuple[np.ndarray, np.ndarray]],
    num_classes: int,
    *,
    expansion: Optional[FeatureExpansion] = None,
    use_secure_agg: bool = True,
    use_kernel: bool = False,
    distributed: bool = False,
    mesh=None,
    dropout: Sequence[int] = (),
    min_survivors: Optional[int] = None,
) -> Tuple[FeatureStats, int]:
    """Rounds 1-2 of Algorithm 1 for a simulated cohort.

    Returns the aggregated statistics and the per-client upload size
    ((C+d)·d + C — a pure shape property, identical for every client).
    Clients named in ``dropout`` disconnect before upload; with
    ``use_secure_agg`` the server recovers their dangling masks from
    ≥ ``min_survivors`` Shamir shares (the paper's connection-drop
    story), so the aggregate is exactly the survivors' sum either way.
    """
    pipeline = _make_pipeline(
        num_classes, use_kernel=use_kernel, distributed=distributed,
        secure=use_secure_agg, mesh=mesh, dropout=dropout,
        min_survivors=min_survivors,
        extractor=as_extractor(backbone, expansion),
    )
    # raw (x, y) clients: the pipeline wraps each as a LAZY feature
    # stream, so only one client's feature matrix is ever resident
    agg = pipeline.from_cohort(list(client_data))
    return agg, FeatureStats.upload_size(num_classes, agg.feature_dim)


def run_fedcgs(
    backbone: Backbone,
    client_data: Sequence[Tuple[np.ndarray, np.ndarray]],
    num_classes: int,
    *,
    test_data: Optional[Tuple[np.ndarray, np.ndarray]] = None,
    expansion: Optional[FeatureExpansion] = None,
    use_secure_agg: bool = True,
    ridge: Optional[float] = None,
    use_kernel: bool = False,
    distributed: bool = False,
    mesh=None,
    dropout: Sequence[int] = (),
    min_survivors: Optional[int] = None,
) -> FedCGSResult:
    """The full one-shot protocol over simulated clients.

    ``dropout``/``min_survivors`` simulate mid-round disconnects: the
    head is fit on the exact survivor statistics (Shamir mask recovery
    when ``use_secure_agg``), provided ≥ ``min_survivors`` clients
    (default: majority) stay connected.
    """
    agg, uploaded = aggregate_client_stats(
        backbone, client_data, num_classes,
        expansion=expansion, use_secure_agg=use_secure_agg,
        use_kernel=use_kernel, distributed=distributed, mesh=mesh,
        dropout=dropout, min_survivors=min_survivors,
    )
    gstats = derive_global(agg)
    head = gnb_head(gstats, ridge=ridge)

    acc = None
    if test_data is not None:
        xt, yt = test_data
        feats = as_extractor(backbone, expansion).features(jnp.asarray(xt))
        acc = float(head.accuracy(feats, jnp.asarray(yt)))
    return FedCGSResult(
        head=head,
        stats=gstats,
        uploaded_floats_per_client=uploaded,
        accuracy=acc,
    )


def run_fedcgs_personalized(
    backbone: Backbone,
    client_data: Sequence[Tuple[np.ndarray, np.ndarray]],
    client_test: Sequence[Tuple[np.ndarray, np.ndarray]],
    num_classes: int,
    *,
    proto_lambda: float = 1.0,
    epochs: int = 200,
    lr: float = 0.01,
    momentum: float = 0.5,
    weight_decay: float = 5e-4,
    batch_size: int = 128,
    seed: int = 0,
    use_secure_agg: bool = True,
    use_kernel: bool = False,
    distributed: bool = False,
    mesh=None,
    dropout: Sequence[int] = (),
    min_survivors: Optional[int] = None,
) -> Tuple[List[float], GlobalStatistics]:
    """Personalized one-shot FL (paper Eq. 12 + Table 3 protocol).

    Round 1 (up):   clients upload statistics (as in run_fedcgs).
    Round 2 (down): clients download μ and fine-tune the ENTIRE local
                    model with the prototype-alignment regularizer.

    The statistics round goes through the same pipeline as
    :func:`run_fedcgs`, so ``use_kernel``/``distributed``/
    ``use_secure_agg``/``dropout``/``min_survivors`` behave identically
    here (the pre-pipeline version silently ignored the switches).
    Clients dropped in round 1 still personalize in round 2 — the
    download round happens later, when they may well have reconnected;
    only their statistics are missing from the global prototypes.

    Returns per-client test accuracies and the global statistics.
    """
    agg, _ = aggregate_client_stats(
        backbone, client_data, num_classes,
        use_secure_agg=use_secure_agg, use_kernel=use_kernel,
        distributed=distributed, mesh=mesh,
        dropout=dropout, min_survivors=min_survivors,
    )
    gstats = derive_global(agg)
    prototypes = gstats.mu  # downloaded, then FIXED (unlike FedProto)

    model = ClassifierModel(backbone=backbone, num_classes=num_classes)
    opt = sgd(lr, momentum=momentum, weight_decay=weight_decay)
    accs: List[float] = []
    for i, ((x, y), (xt, yt)) in enumerate(zip(client_data, client_test)):
        params = model.init(seed)
        params, _ = train_local(
            model, params, x, y, opt,
            epochs=epochs, batch_size=batch_size, seed=seed + i,
            prototypes=prototypes, proto_lambda=proto_lambda,
        )
        accs.append(model.accuracy(params, jnp.asarray(xt), jnp.asarray(yt)))
    return accs, gstats
