from repro.fl.backbone import Backbone, BACKBONES
from repro.fl.extractors import (
    ComposedExtractor,
    Extractor,
    ModelExtractor,
    as_extractor,
)
from repro.fl.fedcgs import (
    FedCGSResult,
    run_fedcgs,
    run_fedcgs_personalized,
)

__all__ = [
    "Backbone",
    "BACKBONES",
    "ComposedExtractor",
    "Extractor",
    "ModelExtractor",
    "as_extractor",
    "FedCGSResult",
    "run_fedcgs",
    "run_fedcgs_personalized",
]
