from repro.fl.backbone import Backbone, BACKBONES
from repro.fl.fedcgs import (
    FedCGSResult,
    run_fedcgs,
    run_fedcgs_personalized,
)

__all__ = [
    "Backbone",
    "BACKBONES",
    "FedCGSResult",
    "run_fedcgs",
    "run_fedcgs_personalized",
]
