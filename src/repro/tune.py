"""repro.tune — per-shape kernel autotuning + jnp↔fused dispatch.

The Pallas kernels' block constants (``stats_kernel.BLOCK_N/D``,
``classifier_kernel.BLOCK_N/C/K``) are one-size defaults: good tile
shapes at bench scale, 2× padding waste for a 256-row serving batch,
and — the committed ``kernel_bench.json`` regression — slower than the
plain XLA formulation at some shapes on some backends.  This module
makes every kernel call site shape-aware instead:

- **Tuner** (:func:`tune_stats` / :func:`tune_stats_acc` /
  :func:`tune_gnb`, driven by ``fedcgs-tune``): times a bounded grid of
  block candidates against the jnp reference at the same shape and
  records the winner in a :class:`TuneCache`.
- **Cache**: persistent JSON keyed ``(device_kind, kernel,
  shape_bucket)`` — shapes bucket to powers of two, so one tuning run
  covers a family.  A corrupt or absent cache loads as empty; every
  accessor's miss path returns today's compiled-in defaults, so
  behaviour without a cache is exactly the pre-tuning behaviour.
- **Dispatch accessors**: ``StatsPipeline(backend="auto")`` asks
  :func:`stats_backend`, ``serve.scoring.score_features`` asks
  :func:`gnb_backend`, the kernel wrappers ask ``*_blocks``, and
  ``serve.batcher`` derives its per-batch pad-to-bucket targets from
  :func:`serve_pad_target` (capacity defaults still come from
  :func:`serve_row_multiple`) — one funnel, so tuned blocks can never
  desync a caller's padding from the kernel's expectations.  On a cache
  miss the backend accessors fall back to a static crossover heuristic
  calibrated from the ``kernel_bench.py`` crossover sweep (see
  ``STATS_CROSSOVER_FLOPS`` / ``GNB_CROSSOVER_FLOPS``).

Cache resolution is deliberately explicit: :func:`get_cache` consults
only an in-process override (:func:`set_cache` / :func:`using_cache`)
or the ``FEDCGS_TUNE_CACHE`` env var — never the CWD or home directory,
so tests and CI can't be flipped by a stray file.

This module is the ONE sanctioned importer of the kernels' ``BLOCK_*``
constants outside ``repro.kernels`` itself — the ``block-constants``
lint rule (``repro.analysis.lint``) holds launch/serve/benchmarks to
that.
"""

from __future__ import annotations

import contextlib
import dataclasses
import json
import math
import os
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

from repro.kernels import classifier_kernel, stats_kernel
from repro.timing import timed

# today's compiled-in constants — the miss path of every accessor
DEFAULT_STATS_BLOCK_N = stats_kernel.BLOCK_N
DEFAULT_STATS_BLOCK_D = stats_kernel.BLOCK_D
DEFAULT_GNB_BLOCK_N = classifier_kernel.BLOCK_N
DEFAULT_GNB_BLOCK_C = classifier_kernel.BLOCK_C
DEFAULT_GNB_BLOCK_K = classifier_kernel.BLOCK_K

# A jnp-winner head needs no kernel block multiple; pad serving batches
# to a lane-aligned quantum instead (8× less pad waste than BLOCK_N).
JNP_ROW_MULTIPLE = 64

# The smallest row-pad step the serve batcher takes (sublane quantum).
# Bucketed batches pad to pow2 row buckets aligned to this, instead of
# to one block shape — see :func:`serve_pad_target`.
SERVE_ROW_ALIGN = 8

KERNELS = ("stats", "stats_acc", "gnb")

# Crossover thresholds for the untuned miss path, in stats/score FLOPs
# (2nd(d+C) and 2ndC respectively) — calibrated from the kernel_bench
# crossover sweep: off-TPU the Pallas kernels run in interpret mode
# (an emulation XLA always beats), so the fused stats path only pays on
# a real TPU once the sweep is big enough to amortize grid setup; the
# GNB kernel's padded block (256×512×128) sets its floor.
STATS_CROSSOVER_FLOPS = 1e8
GNB_CROSSOVER_FLOPS = 3.4e7


def _on_tpu() -> bool:
    import jax

    return jax.default_backend() == "tpu"


def bucket(x: int) -> int:
    """Power-of-two shape bucket: one tuning run covers a family."""
    return 1 << max(0, int(x) - 1).bit_length() if x > 1 else 1


def device_kind() -> str:
    """Sanitized accelerator kind (``cpu``, ``tpu_v5e``, …) — cache key."""
    import jax

    kind = jax.devices()[0].device_kind
    return "".join(c if c.isalnum() else "_" for c in kind.lower()).strip("_")


@dataclasses.dataclass(frozen=True)
class Decision:
    """One tuning verdict: measured winner + blocks at a shape bucket."""

    kernel: str  # "stats" | "stats_acc" | "gnb"
    n: int  # the ACTUAL tuned shape (buckets derive from it)
    d: int
    c: int
    winner: str  # "jnp" | "fused"
    blocks: Dict[str, int]
    jnp_ms: Optional[float] = None
    fused_ms: Optional[float] = None  # best fused candidate
    default_ms: Optional[float] = None  # fused at the default blocks

    def key(self, device: Optional[str] = None) -> str:
        device = device_kind() if device is None else device
        return (
            f"{device}/{self.kernel}/"
            f"n{bucket(self.n)}-d{bucket(self.d)}-C{bucket(self.c)}"
        )


class TuneCache:
    """Persistent (device_kind, kernel, shape_bucket) → Decision map."""

    VERSION = 1

    def __init__(self, entries: Optional[Dict[str, Decision]] = None):
        self._entries: Dict[str, Decision] = dict(entries or {})

    def __len__(self) -> int:
        return len(self._entries)

    def decisions(self) -> List[Decision]:
        return list(self._entries.values())

    def record(self, decision: Decision) -> None:
        if decision.kernel not in KERNELS:
            raise ValueError(
                f"kernel must be one of {KERNELS}, got {decision.kernel!r}"
            )
        if decision.winner not in ("jnp", "fused"):
            raise ValueError(f"winner must be jnp|fused, got {decision.winner!r}")
        self._entries[decision.key()] = decision

    def lookup(
        self,
        kernel: str,
        n: Optional[int],
        d: int,
        c: Optional[int] = None,
    ) -> Optional[Decision]:
        """Best-matching decision for this device, or None (miss).

        Exact bucket first; otherwise the nearest-``n`` entry whose
        ``d`` (and ``c``, when given) buckets match — a tuning run at
        one batch size still informs neighbouring batch sizes, which
        matters for callers like the serve batcher that must pick a pad
        multiple BEFORE any batch shape exists (``n=None``).
        """
        if not self._entries:  # stays jax-free on the empty-cache path
            return None
        dev = device_kind()
        if n is not None and c is not None:
            hit = self._entries.get(
                f"{dev}/{kernel}/n{bucket(n)}-d{bucket(d)}-C{bucket(c)}"
            )
            if hit is not None:
                return hit
        matches = [
            dec
            for key, dec in self._entries.items()
            if key.startswith(f"{dev}/{kernel}/")
            and bucket(dec.d) == bucket(d)
            and (c is None or bucket(dec.c) == bucket(c))
        ]
        if not matches:
            return None
        if n is None:
            return max(matches, key=lambda dec: bucket(dec.n))
        target = math.log2(bucket(n))
        return min(
            matches, key=lambda dec: abs(math.log2(bucket(dec.n)) - target)
        )

    # -- persistence ---------------------------------------------------------

    def save(self, path: str) -> None:
        payload = {
            "version": self.VERSION,
            "entries": {
                key: dataclasses.asdict(dec)
                for key, dec in sorted(self._entries.items())
            },
        }
        with open(path, "w") as fh:
            json.dump(payload, fh, indent=2)

    @classmethod
    def load(cls, path: str) -> "TuneCache":
        """Load a cache; corrupt/absent/foreign files yield an EMPTY cache
        (the miss path — today's defaults), never an error."""
        try:
            with open(path) as fh:
                payload = json.load(fh)
            if payload.get("version") != cls.VERSION:
                return cls()
            entries = {
                key: Decision(**raw)
                for key, raw in payload.get("entries", {}).items()
            }
            return cls(entries)
        except (OSError, ValueError, TypeError, KeyError, AttributeError):
            return cls()


# -- active-cache resolution (explicit only: override or env var) -----------

_EMPTY = TuneCache()
_active: Optional[TuneCache] = None
_env_cache: Optional[Tuple[str, TuneCache]] = None


def get_cache() -> TuneCache:
    global _env_cache
    if _active is not None:
        return _active
    path = os.environ.get("FEDCGS_TUNE_CACHE")
    if not path:
        return _EMPTY
    if _env_cache is None or _env_cache[0] != path:
        _env_cache = (path, TuneCache.load(path))
    return _env_cache[1]


def set_cache(cache: Optional[TuneCache]) -> None:
    global _active
    _active = cache


@contextlib.contextmanager
def using_cache(cache: Optional[TuneCache]) -> Iterator[TuneCache]:
    global _active
    prev, _active = _active, cache
    try:
        yield cache if cache is not None else get_cache()
    finally:
        _active = prev


def _resolve(cache: Optional[TuneCache]) -> TuneCache:
    return get_cache() if cache is None else cache


# -- dispatch accessors (the ONLY block/backend source for call sites) ------


def stats_blocks(
    n: int, d: int, num_classes: int, *, cache: Optional[TuneCache] = None
) -> Tuple[int, int]:
    """(block_n, block_d) for the one-shot fused stats sweep."""
    dec = _resolve(cache).lookup("stats", n, d, num_classes)
    if dec is None:
        return DEFAULT_STATS_BLOCK_N, DEFAULT_STATS_BLOCK_D
    return (
        int(dec.blocks.get("block_n", DEFAULT_STATS_BLOCK_N)),
        int(dec.blocks.get("block_d", DEFAULT_STATS_BLOCK_D)),
    )


def stats_acc_blocks(
    num_classes: int,
    feature_dim: int,
    *,
    rows: Optional[int] = None,
    cache: Optional[TuneCache] = None,
) -> Tuple[int, int]:
    """(block_n, block_d) for the streaming carry fold.

    ``rows`` is the per-batch row count when known; the carry layout
    (``block_d``) must be picked before the first batch arrives, which
    the nearest-``n`` lookup handles.
    """
    dec = _resolve(cache).lookup("stats_acc", rows, feature_dim, num_classes)
    if dec is None:
        return DEFAULT_STATS_BLOCK_N, DEFAULT_STATS_BLOCK_D
    return (
        int(dec.blocks.get("block_n", DEFAULT_STATS_BLOCK_N)),
        int(dec.blocks.get("block_d", DEFAULT_STATS_BLOCK_D)),
    )


def stats_backend(
    n: int, d: int, num_classes: int, *, cache: Optional[TuneCache] = None
) -> str:
    """Resolve ``backend="auto"`` for a statistics sweep: measured winner
    at the bucket, else the crossover heuristic."""
    dec = _resolve(cache).lookup("stats", n, d, num_classes)
    if dec is not None:
        return dec.winner
    if not _on_tpu():
        return "jnp"  # interpret-mode Pallas never beats compiled XLA
    flops = 2.0 * n * d * (d + num_classes)
    return "fused" if flops >= STATS_CROSSOVER_FLOPS else "jnp"


def gnb_blocks(
    n: int, d: int, num_classes: int, *, cache: Optional[TuneCache] = None
) -> Tuple[int, int, int]:
    """(block_n, block_c, block_k) for the GNB scoring kernel."""
    dec = _resolve(cache).lookup("gnb", n, d, num_classes)
    if dec is None:
        return DEFAULT_GNB_BLOCK_N, DEFAULT_GNB_BLOCK_C, DEFAULT_GNB_BLOCK_K
    return (
        int(dec.blocks.get("block_n", DEFAULT_GNB_BLOCK_N)),
        int(dec.blocks.get("block_c", DEFAULT_GNB_BLOCK_C)),
        int(dec.blocks.get("block_k", DEFAULT_GNB_BLOCK_K)),
    )


def gnb_backend(
    n: int, d: int, num_classes: int, *, cache: Optional[TuneCache] = None
) -> str:
    """Resolve ``backend="auto"`` for GNB scoring.

    Untuned non-TPU hosts stay on the fused kernel — the serving tests
    pin bit-exactness against exactly that path, and only a MEASURED
    jnp win (a cache entry) may flip it.  On TPU the heuristic routes
    sub-block batches to the jnp matmul (the kernel would pad a 32-row
    request up to a full 256×512×128 block of wasted MXU work).
    """
    dec = _resolve(cache).lookup("gnb", n, d, num_classes)
    if dec is not None:
        return dec.winner
    if not _on_tpu():
        return "fused"
    flops = 2.0 * n * d * num_classes
    return "fused" if flops >= GNB_CROSSOVER_FLOPS else "jnp"


def serve_row_multiple(
    feature_dim: int,
    num_classes: Optional[int] = None,
    *,
    cache: Optional[TuneCache] = None,
) -> int:
    """The serve batcher's pad-to multiple, coupled to the tuned head.

    Fused winner → its tuned ``block_n`` (a smaller tuned block at low
    occupancy is a direct pad-waste win); jnp winner → the lane-aligned
    :data:`JNP_ROW_MULTIPLE`; untuned → the kernel default, exactly
    today's behaviour.
    """
    dec = _resolve(cache).lookup("gnb", None, feature_dim, num_classes)
    if dec is None:
        return DEFAULT_GNB_BLOCK_N
    if dec.winner == "jnp":
        return JNP_ROW_MULTIPLE
    return int(dec.blocks.get("block_n", DEFAULT_GNB_BLOCK_N))


def serve_pad_target(
    rows: int,
    feature_dim: int,
    num_classes: Optional[int] = None,
    *,
    align: int = 1,
    cache: Optional[TuneCache] = None,
) -> int:
    """Padded row count for a serving batch of ``rows`` real rows.

    The shape-bucketed batcher's pad-to-bucket rule: the row count
    buckets to a power of two (so the whole traffic mix still costs
    O(log max_rows) jit traces), then rounds up to the bucket's backend
    quantum — the tuned ``block_n`` when the bucket's measured verdict
    is the fused kernel (which pads to its block internally anyway, so
    anything finer would just hide the waste), or the sublane
    :data:`SERVE_ROW_ALIGN` when the verdict is the jnp matmul (which
    needs no block at all).  ``align`` folds in caller alignment (the
    mesh shard count) via lcm.  Untuned, every bucket resolves exactly
    like :func:`gnb_backend`'s miss path, so behaviour without a cache
    matches the pre-bucketing pad-to-block discipline.
    """
    rows = max(1, int(rows))
    target = bucket(rows)
    dec = _resolve(cache).lookup("gnb", target, feature_dim, num_classes)
    if dec is not None:
        winner = dec.winner
        block_n = int(dec.blocks.get("block_n", DEFAULT_GNB_BLOCK_N))
    else:
        block_n = DEFAULT_GNB_BLOCK_N
        if not _on_tpu():
            winner = "fused"  # gnb_backend's untuned non-TPU pin
        else:
            flops = 2.0 * target * feature_dim * (num_classes or 1)
            winner = "fused" if flops >= GNB_CROSSOVER_FLOPS else "jnp"
    quantum = block_n if winner == "fused" else SERVE_ROW_ALIGN
    quantum = math.lcm(int(quantum), max(1, int(align)))
    return ((target + quantum - 1) // quantum) * quantum


def serve_pad_targets(
    max_rows: int,
    feature_dim: int,
    num_classes: Optional[int] = None,
    *,
    align: int = 1,
    cache: Optional[TuneCache] = None,
) -> List[int]:
    """Every distinct padded shape batches of up to ``max_rows`` rows can
    produce — the trace-warming set for a serving worker."""
    targets = set()
    r = 1
    while r <= bucket(max(1, int(max_rows))):
        targets.add(serve_pad_target(
            r, feature_dim, num_classes, align=align, cache=cache
        ))
        r *= 2
    return sorted(targets)


# -- candidate grids --------------------------------------------------------


def stats_candidates(n: int, d: int, *, smoke: bool = False) -> List[Tuple[int, int]]:
    """Bounded (block_n, block_d) grid for the stats kernels.

    Respects the TPU minimum tile (8, 128): block_d stays a lane
    multiple, block_n a sublane multiple.  block_d never exceeds the
    padded feature dim (padding d twice over buys nothing), block_n is
    capped so a candidate never pads the row count more than the
    default would.
    """
    if smoke:
        grid = [(128, 128), (256, 128)]
    else:
        d_cap = max(128, bucket(d))
        n_cap = max(128, min(2048, bucket(n)))
        grid = [
            (bn, bd)
            for bn in (128, 256, 512, 1024, 2048)
            if bn <= n_cap
            for bd in (128, 256)
            if bd <= d_cap
        ]
    default = (DEFAULT_STATS_BLOCK_N, DEFAULT_STATS_BLOCK_D)
    if default not in grid:
        grid.append(default)
    return grid


def gnb_candidates(
    n: int, d: int, *, smoke: bool = False
) -> List[Tuple[int, int, int]]:
    """Bounded (block_n, block_c, block_k) grid for the scoring kernel."""
    if smoke:
        grid = [(64, 128, 128), (128, 128, 128)]
    else:
        k_cap = max(128, bucket(d))
        n_cap = max(64, min(1024, bucket(n)))
        grid = [
            (bn, 128, bk)
            for bn in (64, 128, 256, 512, 1024)
            if bn <= n_cap
            for bk in (128, 256, 512)
            if bk <= k_cap
        ]
    default = (DEFAULT_GNB_BLOCK_N, DEFAULT_GNB_BLOCK_C, DEFAULT_GNB_BLOCK_K)
    if default not in grid:
        grid.append(default)
    return grid


# -- timing + tuners --------------------------------------------------------


def _time_best_ms(fn, iters: int) -> float:
    """min-of-iters wall ms (one warm/compile call first).

    Minimum, not mean: scheduling noise only ever ADDS time, so the min
    is the stable estimator — a crossover decided by mean-of-3 flips
    between runs near the boundary.
    """
    import jax

    run = lambda: jax.block_until_ready(fn())  # noqa: E731
    run()  # compile + warm
    best = math.inf
    for _ in range(max(1, iters)):
        _, dt = timed(run)
        best = min(best, dt)
    return best * 1e3


def tune_stats(
    n: int,
    d: int,
    num_classes: int,
    *,
    cache: Optional[TuneCache] = None,
    candidates: Optional[Sequence[Tuple[int, int]]] = None,
    iters: int = 3,
    seed: int = 0,
    interpret: Optional[bool] = None,
    record: bool = True,
) -> Decision:
    """Tune the one-shot fused stats sweep at (n, d, C) vs its jnp twin.

    The jnp reference is timed through ``StatsPipeline(backend="jnp")``
    — the exact code ``backend="auto"`` would run on a jnp verdict,
    eager overheads included — so the recorded winner is a
    pipeline-level truth, not a kernel-microbenchmark one.
    """
    import jax

    from repro.core.stats_pipeline import StatsPipeline
    from repro.kernels import client_stats

    cache = _resolve(cache)
    k1, k2 = jax.random.split(jax.random.key(seed))
    f = jax.random.normal(k1, (n, d))
    y = jax.random.randint(k2, (n,), 0, num_classes)

    jnp_pipe = StatsPipeline(num_classes, backend="jnp")
    t_jnp = _time_best_ms(lambda: jnp_pipe.from_arrays(f, y), iters)

    def fused_at(bn: int, bd: int):
        return lambda: client_stats(
            f, y, num_classes, block_n=bn, block_d=bd, interpret=interpret
        )

    grid = list(candidates or stats_candidates(n, d))
    t_default = None
    best_ms, best_blocks = math.inf, grid[0]
    for bn, bd in grid:
        t = _time_best_ms(fused_at(bn, bd), iters)
        if (bn, bd) == (DEFAULT_STATS_BLOCK_N, DEFAULT_STATS_BLOCK_D):
            t_default = t
        if t < best_ms:
            best_ms, best_blocks = t, (bn, bd)
    if t_default is None:
        t_default = _time_best_ms(
            fused_at(DEFAULT_STATS_BLOCK_N, DEFAULT_STATS_BLOCK_D), iters
        )

    decision = Decision(
        kernel="stats", n=n, d=d, c=num_classes,
        winner="jnp" if t_jnp <= best_ms else "fused",
        blocks={"block_n": best_blocks[0], "block_d": best_blocks[1]},
        jnp_ms=t_jnp, fused_ms=best_ms, default_ms=t_default,
    )
    if record:
        cache.record(decision)
    return decision


def tune_stats_acc(
    n: int,
    d: int,
    num_classes: int,
    *,
    cache: Optional[TuneCache] = None,
    candidates: Optional[Sequence[Tuple[int, int]]] = None,
    iters: int = 3,
    seed: int = 0,
    interpret: Optional[bool] = None,
    record: bool = True,
) -> Decision:
    """Tune ONE streaming carry-fold step at batch shape (n, d, C).

    Each timed call re-inits the carry (the TPU fold donates its carry
    buffers, so a reused carry would be a use-after-donate) — the zeros
    alloc is identical across candidates, so the ranking is fair.
    """
    import jax

    from repro.core import stats_pipeline
    from repro.core.statistics import FeatureStats
    from repro.kernels import client_stats_acc, stats_carry_init

    cache = _resolve(cache)
    k1, k2 = jax.random.split(jax.random.key(seed))
    f = jax.random.normal(k1, (n, d))
    y = jax.random.randint(k2, (n,), 0, num_classes)

    fold_jnp = stats_pipeline.AUDITED_JITS["stats_pipeline.fold_jnp"]
    zero = FeatureStats.zeros(num_classes, d)
    t_jnp = _time_best_ms(lambda: fold_jnp(zero, f, y, num_classes), iters)

    def acc_at(bn: int, bd: int):
        def run():
            m, nn = stats_carry_init(num_classes, d, block_d=bd)
            return client_stats_acc(
                m, nn, f, y, block_n=bn, block_d=bd, interpret=interpret
            )

        return run

    grid = list(candidates or stats_candidates(n, d))
    t_default = None
    best_ms, best_blocks = math.inf, grid[0]
    for bn, bd in grid:
        t = _time_best_ms(acc_at(bn, bd), iters)
        if (bn, bd) == (DEFAULT_STATS_BLOCK_N, DEFAULT_STATS_BLOCK_D):
            t_default = t
        if t < best_ms:
            best_ms, best_blocks = t, (bn, bd)
    if t_default is None:
        t_default = _time_best_ms(
            acc_at(DEFAULT_STATS_BLOCK_N, DEFAULT_STATS_BLOCK_D), iters
        )

    decision = Decision(
        kernel="stats_acc", n=n, d=d, c=num_classes,
        winner="jnp" if t_jnp <= best_ms else "fused",
        blocks={"block_n": best_blocks[0], "block_d": best_blocks[1]},
        jnp_ms=t_jnp, fused_ms=best_ms, default_ms=t_default,
    )
    if record:
        cache.record(decision)
    return decision


def tune_gnb(
    n: int,
    d: int,
    num_classes: int,
    *,
    cache: Optional[TuneCache] = None,
    candidates: Optional[Sequence[Tuple[int, int, int]]] = None,
    iters: int = 3,
    seed: int = 0,
    interpret: Optional[bool] = None,
    record: bool = True,
) -> Decision:
    """Tune the GNB scoring kernel at (n, d, C) vs the jnp matmul."""
    import jax

    from repro.kernels import gnb_logits
    from repro.kernels.ops import gnb_logits_jnp

    cache = _resolve(cache)
    k1, k2, k3 = jax.random.split(jax.random.key(seed), 3)
    f = jax.random.normal(k1, (n, d))
    w = jax.random.normal(k2, (num_classes, d))
    b = jax.random.normal(k3, (num_classes,))

    t_jnp = _time_best_ms(lambda: gnb_logits_jnp(f, w, b), iters)

    def fused_at(bn: int, bc: int, bk: int):
        return lambda: gnb_logits(
            f, w, b, block_n=bn, block_c=bc, block_k=bk, interpret=interpret
        )

    grid = list(candidates or gnb_candidates(n, d))
    default = (DEFAULT_GNB_BLOCK_N, DEFAULT_GNB_BLOCK_C, DEFAULT_GNB_BLOCK_K)
    t_default = None
    best_ms, best_blocks = math.inf, grid[0]
    for blocks in grid:
        t = _time_best_ms(fused_at(*blocks), iters)
        if blocks == default:
            t_default = t
        if t < best_ms:
            best_ms, best_blocks = t, blocks
    if t_default is None:
        t_default = _time_best_ms(fused_at(*default), iters)

    decision = Decision(
        kernel="gnb", n=n, d=d, c=num_classes,
        winner="jnp" if t_jnp <= best_ms else "fused",
        blocks={
            "block_n": best_blocks[0],
            "block_c": best_blocks[1],
            "block_k": best_blocks[2],
        },
        jnp_ms=t_jnp, fused_ms=best_ms, default_ms=t_default,
    )
    if record:
        cache.record(decision)
    return decision


def tune_all(
    shapes: Sequence[Tuple[int, int, int]],
    *,
    cache: TuneCache,
    smoke: bool = False,
    iters: int = 3,
    seed: int = 0,
) -> List[Decision]:
    """Run all three tuners over a shape list, recording into ``cache``."""
    out: List[Decision] = []
    for n, d, c in shapes:
        out.append(tune_stats(
            n, d, c, cache=cache, iters=iters, seed=seed,
            candidates=stats_candidates(n, d, smoke=smoke),
        ))
        out.append(tune_stats_acc(
            n, d, c, cache=cache, iters=iters, seed=seed,
            candidates=stats_candidates(n, d, smoke=smoke),
        ))
        out.append(tune_gnb(
            n, d, c, cache=cache, iters=iters, seed=seed,
            candidates=gnb_candidates(n, d, smoke=smoke),
        ))
    return out
