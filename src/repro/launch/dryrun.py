"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) combo.

MUST be the very first lines — jax locks the device count on first init:
"""

import os

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=512 "
        + os.environ.get("XLA_FLAGS", "")
    )

# ruff: noqa: E402
import argparse
import dataclasses
import json
import sys
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import PUBLIC_IDS, get_config
from repro.launch import hlo_analysis, io_specs, steps
from repro.launch.mesh import _make_mesh, make_host_mesh, make_production_mesh
from repro.models import transformer as T
from repro.models.common import spec_shapes
from repro.models.config import INPUT_SHAPES, REDUCED_SHAPES, ModelConfig
from repro.optim import adamw, sgd
from repro.serve.metrics import timed
from repro.sharding import tree_shardings
from jax.sharding import NamedSharding, PartitionSpec as P


def _pick_optimizer(cfg: ModelConfig, name: str):
    if name == "auto":
        # 400B-scale: f32 AdamW moments (8 bytes/param) exceed v5e HBM at
        # 256 chips; momentum-SGD (4 bytes/param) is the deployable choice.
        name = "sgd" if cfg.name.startswith("llama4") else "adamw"
    if name == "sgd":
        return sgd(1e-2, momentum=0.9), name
    return adamw(3e-4), name


def build_step_and_inputs(
    cfg: ModelConfig,
    shape_name: str,
    mesh,
    *,
    optimizer: str = "auto",
    step_kind: Optional[str] = None,
    shapes: Optional[Dict[str, Any]] = None,
    rules=None,
    remat: Any = True,
    moe_dispatch: int = 1,
    stats_fold_dtype=jnp.float32,
):
    """Returns (wrapped jitted step, example kwargs of ShapeDtypeStructs,
    static metadata) for one (arch, shape).

    ``rules`` / ``remat`` are the §Perf hillclimbing knobs: a logical-axis
    rule-table override and the activation-checkpoint policy.
    """
    shape = (shapes or INPUT_SHAPES)[shape_name]
    cfg = io_specs.config_for_shape(cfg, shape)
    specs = T.build_specs(cfg)
    param_shapes = spec_shapes(specs, dtype=jnp.bfloat16)
    param_sh = tree_shardings(specs, mesh, rules)
    kind = step_kind or shape.kind

    meta: Dict[str, Any] = {"kind": kind}
    if kind == "train":
        opt, opt_name = _pick_optimizer(cfg, optimizer)
        meta["optimizer"] = opt_name
        opt_shapes = jax.eval_shape(opt.init, param_shapes)
        opt_sh = steps.opt_state_shardings(opt, specs, param_sh, mesh)
        batch = io_specs.train_inputs(cfg, shape)
        batch_sh = io_specs.batch_shardings(batch, mesh)
        fn = steps.jit_step(
            steps.make_train_step(
                cfg, opt, remat=remat, moe_dispatch_shards=moe_dispatch
            ),
            mesh,
            (param_sh, opt_sh, batch_sh),
            donate_argnums=(0, 1),
            rules=rules,
        )
        args = (param_shapes, opt_shapes, batch)
        tokens = shape.tokens
        model_flops = 3 * T.model_flops(cfg, tokens, shape.seq_len)
    elif kind == "prefill":
        batch = io_specs.prefill_inputs(cfg, shape)
        batch_sh = io_specs.batch_shardings(batch, mesh)
        fn = steps.jit_step(
            steps.make_prefill_step(cfg, moe_dispatch_shards=moe_dispatch),
            mesh, (param_sh, batch_sh), rules=rules,
        )
        args = (param_shapes, batch)
        model_flops = T.model_flops(cfg, shape.tokens, shape.seq_len)
    elif kind == "decode":
        inputs = io_specs.decode_inputs(cfg, shape)
        in_sh = io_specs.decode_shardings(cfg, inputs, mesh)
        fn = steps.jit_step(
            steps.make_serve_step(cfg),
            mesh,
            (param_sh, in_sh),
            donate_argnums=(1,),
            rules=rules,
        )
        args = (param_shapes, inputs)
        model_flops = T.model_flops(
            cfg, shape.global_batch, shape.seq_len, decode=True
        )
    elif kind == "stats":
        table = shapes or INPUT_SHAPES
        base_shape = table["prefill_32k"] if shape.kind == "decode" else shape
        batch = io_specs.stats_inputs(cfg, base_shape)
        batch_sh = io_specs.batch_shardings(batch, mesh)
        fn = steps.jit_step(
            steps.make_stats_step(
                cfg, moe_dispatch_shards=moe_dispatch, fold_dtype=stats_fold_dtype
            ),
            mesh, (param_sh, batch_sh), rules=rules,
        )
        args = (param_shapes, batch)
        model_flops = T.model_flops(cfg, base_shape.tokens, base_shape.seq_len)
    else:
        raise ValueError(kind)
    meta["model_flops"] = model_flops
    meta["config_variant"] = cfg.name + (
        f"+sw{cfg.sliding_window}" if cfg.sliding_window else ""
    )
    return fn, args, meta


def run_one(
    arch: str,
    shape_name: str,
    *,
    multi_pod: bool = False,
    optimizer: str = "auto",
    step_kind: Optional[str] = None,
    verbose: bool = True,
    reduced: bool = False,
    act_shard: str = "replicated",
    mesh_shape: Optional[str] = None,
    remat: Any = True,
    moe_dispatch: int = 1,
    stats_fold: str = "f32",
    attn_chunks: Optional[str] = None,
    weight_layout: str = "fsdp",
) -> Dict[str, Any]:
    """One lower+compile+analyze run.

    §Perf knobs: ``act_shard`` ∈ {replicated, model} re-maps the
    layer-boundary "act_embed" axis; ``mesh_shape`` re-tiles the 256/512
    chips (e.g. "32x8"); ``remat`` picks the checkpoint policy
    (True="full", "dots", "none").
    """
    cfg = get_config(arch, reduced=reduced)
    if attn_chunks:
        qc, kc = (int(x) for x in attn_chunks.split("x"))
        cfg = dataclasses.replace(cfg, attn_q_chunk=qc, attn_kv_chunk=kc)
    shapes = REDUCED_SHAPES if reduced else INPUT_SHAPES
    shape = shapes[shape_name]
    if not io_specs.supported(cfg, shape):
        return {"arch": arch, "shape": shape_name, "skipped": True,
                "reason": "enc-dec audio model; no sub-quadratic variant (DESIGN.md §Skips)"}

    if reduced:
        n = len(jax.devices())
        mesh = make_host_mesh(2 if n % 2 == 0 and n > 1 else 1)
    elif mesh_shape:
        dims = tuple(int(d) for d in mesh_shape.split("x"))
        axes = ("pod", "data", "model") if len(dims) == 3 else ("data", "model")
        mesh = _make_mesh(dims, axes)
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)

    from repro.sharding import DEFAULT_RULES, SERVE_RULES, merge_rules

    base_rules = SERVE_RULES if weight_layout == "serve" else DEFAULT_RULES
    rules = None
    if act_shard == "model":
        rules = merge_rules(base_rules, act_embed=("model",))
    elif weight_layout == "serve":
        rules = base_rules

    chips = mesh.devices.size
    if moe_dispatch == -1:  # auto: one dispatch shard per (pod, data) slice
        moe_dispatch = 1
        for a in ("pod", "data"):
            if a in mesh.axis_names:
                moe_dispatch *= mesh.shape[a]

    def build_and_lower():
        fn, args, meta = build_step_and_inputs(
            cfg, shape_name, mesh, optimizer=optimizer, step_kind=step_kind,
            shapes=shapes, rules=rules, remat=remat, moe_dispatch=moe_dispatch,
            stats_fold_dtype=jnp.bfloat16 if stats_fold == "bf16" else jnp.float32,
        )
        return fn.lower(*args), meta

    (lowered, meta), t_lower = timed(build_and_lower)
    meta["variant"] = (
        f"act_shard={act_shard},mesh={mesh_shape or 'default'},remat={remat},"
        f"moe_dispatch={moe_dispatch},stats_fold={stats_fold}"
    )
    compiled, t_compile = timed(lowered.compile)

    mem = compiled.memory_analysis()
    mem_dict = {
        k: int(getattr(mem, k))
        for k in (
            "argument_size_in_bytes",
            "output_size_in_bytes",
            "temp_size_in_bytes",
            "generated_code_size_in_bytes",
        )
        if hasattr(mem, k)
    }
    from repro.launch import hlo_parse

    costs = hlo_parse.analyze(compiled.as_text())
    roof = hlo_analysis.Roofline(
        hlo_flops=float(costs.flops),
        hlo_bytes=float(costs.bytes),
        collective_bytes_per_chip=float(costs.total_collective_bytes),
        chips=chips,
        model_flops=meta.get("model_flops"),
    )

    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "skipped": False,
        **meta,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": mem_dict,
        "roofline": roof.as_dict(),
        "collectives": {
            "bytes_by_kind": costs.collective_bytes,
            "count_by_kind": costs.collective_count,
        },
    }
    if verbose:
        print(json.dumps(result, indent=2))
    return result


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", required=True, choices=PUBLIC_IDS + ["all"])
    p.add_argument("--shape", required=True, choices=list(INPUT_SHAPES) + ["all"])
    p.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    p.add_argument("--step", default=None, choices=[None, "train", "prefill", "decode", "stats"])
    p.add_argument("--optimizer", default="auto", choices=["auto", "sgd", "adamw"])
    p.add_argument("--out", default=None, help="directory for JSON artifacts")
    p.add_argument(
        "--reduced", action="store_true",
        help="reduced configs + shapes on a host-sized mesh (smoke mode)",
    )
    p.add_argument("--act-shard", default="replicated", choices=["replicated", "model"])
    p.add_argument("--mesh-shape", default=None, help='e.g. "32x8" or "2x32x8"')
    p.add_argument("--remat", default="full", choices=["full", "dots", "none"])
    p.add_argument(
        "--moe-dispatch", type=int, default=1,
        help="MoE dispatch shards (1=global baseline, -1=one per data slice)",
    )
    p.add_argument("--stats-fold", default="f32", choices=["f32", "bf16"])
    p.add_argument("--attn-chunks", default=None, help='e.g. "1024x4096" (QxKV)')
    p.add_argument(
        "--weight-layout", default="fsdp", choices=["fsdp", "serve"],
        help="serve = replicate weights over data (kills per-token gathers)",
    )
    p.add_argument("--suffix", default=None, help="artifact filename suffix")
    args = p.parse_args(argv)

    archs = PUBLIC_IDS if args.arch == "all" else [args.arch]
    shapes = list(INPUT_SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    failures = []
    for arch in archs:
        for shape in shapes:
            for multi in meshes:
                tag = f"{arch}|{shape}|{'multi' if multi else 'single'}"
                try:
                    res = run_one(
                        arch, shape, multi_pod=multi,
                        optimizer=args.optimizer, step_kind=args.step,
                        verbose=(args.out is None), reduced=args.reduced,
                        act_shard=args.act_shard, mesh_shape=args.mesh_shape,
                        remat=args.remat, moe_dispatch=args.moe_dispatch,
                        stats_fold=args.stats_fold, attn_chunks=args.attn_chunks,
                        weight_layout=args.weight_layout,
                    )
                    if args.out:
                        os.makedirs(args.out, exist_ok=True)
                        fname = f"{arch.replace('.', 'p')}__{shape}__{'multi' if multi else 'single'}"
                        if args.step:
                            fname += f"__{args.step}"
                        if args.suffix:
                            fname += f"__{args.suffix}"
                        with open(os.path.join(args.out, fname + ".json"), "w") as f:
                            json.dump(res, f, indent=2)
                        status = "SKIP" if res.get("skipped") else "OK"
                        extra = ""
                        if not res.get("skipped"):
                            extra = (
                                f" compile={res['compile_s']:.0f}s"
                                f" dominant={res['roofline']['dominant']}"
                            )
                        print(f"[{status}] {tag}{extra}", flush=True)
                except Exception as e:  # noqa: BLE001 — report and continue
                    failures.append((tag, repr(e)))
                    print(f"[FAIL] {tag}: {e!r}", flush=True)
    if failures:
        print(f"{len(failures)} FAILURES:")
        for tag, err in failures:
            print(f"  {tag}: {err[:200]}")
        return 1
    print("all dry-runs passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
