"""fedcgs-tune — tune kernel block shapes and persist the winners.

Times the bounded candidate grids from :mod:`repro.tune` for all three
Pallas entry points (one-shot stats sweep, streaming carry fold, GNB
scoring) at each requested shape, records the per-bucket winners into a
:class:`repro.tune.TuneCache`, and saves it as JSON.  Point
``FEDCGS_TUNE_CACHE`` at the saved file and every ``backend="auto"``
call site dispatches on the measured verdicts instead of the static
crossover heuristic.

``--smoke`` shrinks both the shape list and the candidate grids to a
seconds-long run — CI uses it to prove the tune→save→dispatch loop
end to end on every push.
"""

from __future__ import annotations

import argparse
from typing import List, Tuple

# shapes that matter to this repo: serve-batch scale through bench scale
DEFAULT_SHAPES: Tuple[Tuple[int, int, int], ...] = (
    (1024, 512, 100),
    (4096, 512, 100),
    (16384, 512, 100),
)
SMOKE_SHAPES: Tuple[Tuple[int, int, int], ...] = ((256, 128, 16),)


def _parse_shape(text: str) -> Tuple[int, int, int]:
    try:
        n, d, c = (int(part) for part in text.split(","))
    except ValueError:
        raise argparse.ArgumentTypeError(
            f"shape must be 'n,d,C' (got {text!r})"
        ) from None
    return n, d, c


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="fedcgs-tune", description=__doc__.splitlines()[0]
    )
    parser.add_argument(
        "--cache", default="tune_cache.json",
        help="cache JSON to load, merge into, and save (default: %(default)s)",
    )
    parser.add_argument(
        "--shapes", type=_parse_shape, nargs="*", metavar="N,D,C",
        help="shapes to tune (default: a serve-to-bench ladder)",
    )
    parser.add_argument(
        "--smoke", action="store_true",
        help="tiny shape + candidate grids: seconds, not minutes (CI)",
    )
    parser.add_argument("--iters", type=int, default=3, help="timing reps per candidate")
    parser.add_argument("--seed", type=int, default=0, help="input data seed")
    args = parser.parse_args(argv)

    from repro import tune

    shapes: List[Tuple[int, int, int]] = list(
        args.shapes or (SMOKE_SHAPES if args.smoke else DEFAULT_SHAPES)
    )
    cache = tune.TuneCache.load(args.cache)  # merge into prior runs
    print(f"device={tune.device_kind()}  cache={args.cache} ({len(cache)} entries)")
    decisions = tune.tune_all(
        shapes, cache=cache, smoke=args.smoke,
        iters=max(1, args.iters), seed=args.seed,
    )
    cache.save(args.cache)

    header = f"{'kernel':<10}{'shape':<20}{'winner':<8}{'blocks':<28}" \
             f"{'jnp ms':>10}{'fused ms':>10}{'default ms':>12}"
    print(header)
    print("-" * len(header))
    for dec in decisions:
        blocks = ",".join(f"{k}={v}" for k, v in sorted(dec.blocks.items()))
        print(
            f"{dec.kernel:<10}{f'({dec.n},{dec.d},{dec.c})':<20}"
            f"{dec.winner:<8}{blocks:<28}"
            f"{dec.jnp_ms:>10.3f}{dec.fused_ms:>10.3f}{dec.default_ms:>12.3f}"
        )
    print(f"saved {len(cache)} entries -> {args.cache}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
