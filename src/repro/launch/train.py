"""Training driver — runs REAL steps on whatever devices exist.

On the CPU host this trains reduced configs (examples, smoke tests);
pointed at a TPU slice the same code path trains the full configs via
``--full`` (the dry-run proves those lower+compile).

Example:
    PYTHONPATH=src python -m repro.launch.train \
        --arch gemma-2b --steps 20 --batch 8 --seq 256
"""

from __future__ import annotations

import argparse
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_pytree
from repro.configs import PUBLIC_IDS, get_config
from repro.data.tokens import TokenStream, synthetic_corpus
from repro.launch import io_specs, steps
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as T
from repro.models.common import init_params
from repro.models.config import InputShape
from repro.optim import adamw, sgd
from repro.serve.metrics import timed
from repro.sharding import tree_shardings, use_mesh


def train(
    arch: str,
    *,
    num_steps: int = 20,
    batch: int = 8,
    seq: int = 256,
    lr: float = 3e-4,
    optimizer: str = "adamw",
    reduced: bool = True,
    seed: int = 0,
    log_every: int = 5,
    checkpoint_dir: Optional[str] = None,
    proto_lambda: float = 0.0,
    prototypes=None,
):
    cfg = get_config(arch, reduced=reduced)
    mesh = make_host_mesh(1)
    shape = InputShape("custom", seq, batch, "train")

    specs = T.build_specs(cfg)
    params = init_params(specs, jax.random.key(seed))
    opt = adamw(lr) if optimizer == "adamw" else sgd(lr, momentum=0.9)
    opt_state = opt.init(params)
    param_sh = tree_shardings(specs, mesh)
    opt_sh = steps.opt_state_shardings(opt, specs, param_sh, mesh)
    batch_tree = io_specs.train_inputs(cfg, shape)
    batch_sh = io_specs.batch_shardings(batch_tree, mesh)

    step = steps.jit_step(
        steps.make_train_step(cfg, opt, proto_lambda=proto_lambda),
        mesh, (param_sh, opt_sh, batch_sh),
    )

    corpus = synthetic_corpus(cfg.vocab_size, max(200_000, seq * batch * 4), seed=seed)
    stream = iter(TokenStream(corpus, batch, seq, seed=seed))
    rng = np.random.default_rng(seed)

    losses = []
    elapsed = 0.0
    for i in range(num_steps):
        tokens, targets = next(stream)
        feed = {"tokens": jnp.asarray(tokens), "targets": jnp.asarray(targets)}
        if cfg.rope == "mrope":
            pos = np.broadcast_to(np.arange(seq, dtype=np.int32), (batch, seq))
            feed["positions"] = jnp.asarray(np.broadcast_to(pos, (3, batch, seq)))
        if cfg.vision_tokens:
            feed["patches"] = jnp.asarray(
                rng.standard_normal((batch, cfg.vision_tokens, cfg.d_model)) * 0.02,
                jnp.float32,
            )
        if cfg.is_encdec:
            feed["frames"] = jnp.asarray(
                rng.standard_normal((batch, cfg.encoder_seq_len, cfg.d_model)) * 0.02,
                jnp.float32,
            )
        (params, opt_state, metrics), dt_step = timed(step, params, opt_state, feed)
        elapsed += dt_step
        losses.append(float(metrics["loss"]))
        if i % log_every == 0 or i == num_steps - 1:
            print(
                f"step {i:4d}  loss {losses[-1]:.4f}  nll {float(metrics['nll']):.4f}"
                f"  ({elapsed:.1f}s)", flush=True,
            )
    if checkpoint_dir:
        path = save_pytree({"params": params}, checkpoint_dir, num_steps)
        print(f"checkpoint -> {path}")
    return params, losses


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", required=True, choices=PUBLIC_IDS)
    p.add_argument("--steps", type=int, default=20)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=256)
    p.add_argument("--lr", type=float, default=3e-4)
    p.add_argument("--optimizer", default="adamw", choices=["adamw", "sgd"])
    p.add_argument("--full", action="store_true", help="full-size config (TPU)")
    p.add_argument("--checkpoint-dir", default=None)
    args = p.parse_args(argv)
    _, losses = train(
        args.arch, num_steps=args.steps, batch=args.batch, seq=args.seq,
        lr=args.lr, optimizer=args.optimizer, reduced=not args.full,
        checkpoint_dir=args.checkpoint_dir,
    )
    print(f"final loss {losses[-1]:.4f} (start {losses[0]:.4f})")
    return 0 if losses[-1] < losses[0] else 1


if __name__ == "__main__":
    raise SystemExit(main())
