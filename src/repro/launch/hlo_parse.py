"""Loop-aware cost extraction from compiled (post-SPMD) HLO text.

``compiled.cost_analysis()`` counts each while-loop BODY ONCE — a
48-layer model stacked under ``lax.scan`` under-reports FLOPs ~48×
(verified: a 10-iteration scanned matmul reports 1 matmul of FLOPs).
This module parses the per-device HLO text, recovers each while loop's
trip count from the constant in its condition computation, and computes
loop-corrected:

- dot FLOPs            (recursing into fusions, whiles ×trip, calls)
- collective bytes     (all-gather/all-reduce/reduce-scatter/all-to-all/
                        collective-permute; whiles ×trip)
- HBM traffic estimate (operand+result bytes of top-level ops; fusion
                        internals NOT counted — a fusion reads its
                        operands and writes its result once)

The traffic estimate is an *optimistic* roofline bound (assumes every
fusion is perfectly fused); peak-memory questions use
``memory_analysis`` which is loop-independent.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4,
    "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# `%name = TYPE opcode(...)` — TYPE may be a tuple (...)
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^)]*\))|(?:[\w\[\],{}\/]+))\s+([\w\-]+)\((.*)$"
)
_COMP_START_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\((.*?)\)\s*->.*\{")


def _shape_elems_bytes(type_str: str) -> Tuple[int, int]:
    elems_total, bytes_total = 0, 0
    for m in _SHAPE_RE.finditer(type_str):
        dtype, dims = m.group(1), m.group(2)
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems_total += n
        bytes_total += n * _DTYPE_BYTES[dtype]
    return elems_total, bytes_total


@dataclasses.dataclass
class Instruction:
    name: str
    type_str: str
    opcode: str
    rest: str  # everything after the opening paren (operands + attrs)
    is_root: bool = False

    @property
    def operands(self) -> List[str]:
        # operand list = %names before the closing paren of the call
        depth, ops, cur = 1, [], []
        for ch in self.rest:
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
            if depth >= 1:
                cur.append(ch)
        arglist = "".join(cur)
        for tok in arglist.split(","):
            tok = tok.strip()
            # older XLA dumps print operands WITH their type, e.g.
            # ``dot(f32[256,256]{1,0} %lhs, f32[256,256]{1,0} %rhs)`` —
            # the operand name is the trailing %name of the token
            typed = re.search(r"%([\w.\-]+)\s*$", tok)
            if typed:
                ops.append(typed.group(1))
            elif tok.startswith("%"):
                ops.append(tok[1:])
            elif re.fullmatch(r"[\w.\-]+", tok) and not tok.isdigit():
                ops.append(tok)
        return ops

    def attr(self, key: str) -> Optional[str]:
        m = re.search(rf"{key}=%?([\w.\-]+)", self.rest)
        return m.group(1) if m else None

    def attr_list(self, key: str) -> List[str]:
        m = re.search(rf"{key}=\{{([^}}]*)\}}", self.rest)
        if not m:
            return []
        return [t.strip() for t in m.group(1).split(",") if t.strip()]


@dataclasses.dataclass
class Computation:
    name: str
    instructions: Dict[str, Instruction]
    param_types: Dict[str, str]

    def type_of(self, operand: str) -> Optional[str]:
        if operand in self.instructions:
            return self.instructions[operand].type_str
        return self.param_types.get(operand)


@dataclasses.dataclass
class Costs:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVES}
    )
    collective_count: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVES}
    )

    def add(self, other: "Costs", mult: float = 1.0) -> None:
        self.flops += mult * other.flops
        self.bytes += mult * other.bytes
        for k in _COLLECTIVES:
            self.collective_bytes[k] += mult * other.collective_bytes[k]
            self.collective_count[k] += mult * other.collective_count[k]

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


class HloModule:
    def __init__(self, text: str):
        self.computations: Dict[str, Computation] = {}
        self.entry: Optional[str] = None
        self._parse(text)
        self._memo: Dict[str, Costs] = {}

    # ------------------------------------------------------------------
    def _parse(self, text: str) -> None:
        cur: Optional[Computation] = None
        for raw in text.splitlines():
            line = raw.rstrip()
            m = _COMP_START_RE.match(line.strip())
            if m and line.strip().endswith("{"):
                cur = Computation(m.group(1), {}, {})
                # parameter declarations: name: type pairs
                for pm in re.finditer(r"([\w.\-]+):\s*([^,)]+)", m.group(2)):
                    cur.param_types[pm.group(1)] = pm.group(2).strip()
                self.computations[cur.name] = cur
                if line.strip().startswith("ENTRY"):
                    self.entry = cur.name
                continue
            if cur is None:
                continue
            if line.strip() == "}":
                cur = None
                continue
            im = _INSTR_RE.match(line)
            if im:
                instr = Instruction(
                    name=im.group(1), type_str=im.group(2),
                    opcode=im.group(3), rest=im.group(4),
                    is_root=line.lstrip().startswith("ROOT"),
                )
                cur.instructions[instr.name] = instr

    # ------------------------------------------------------------------
    def _trip_count(self, cond_name: str) -> float:
        """Recover the loop bound from the condition's compare constant."""
        comp = self.computations.get(cond_name)
        if comp is None:
            return 1.0
        for instr in comp.instructions.values():
            if instr.opcode != "compare":
                continue
            for op in instr.operands:
                src = comp.instructions.get(op)
                if src is not None and src.opcode == "constant":
                    m = re.search(r"constant\((-?\d+)\)", "constant(" + src.rest)
                    if m:
                        return max(1.0, float(m.group(1)))
        return 1.0

    def _dot_flops(self, comp: Computation, instr: Instruction) -> float:
        out_elems, _ = _shape_elems_bytes(instr.type_str)
        ops = instr.operands
        if not ops:
            return 0.0
        lhs_t = comp.type_of(ops[0])
        if lhs_t is None:
            return 2.0 * out_elems  # conservative: K unknown
        lhs_dims = []
        m = _SHAPE_RE.search(lhs_t)
        if m:
            lhs_dims = [int(d) for d in m.group(2).split(",") if d]
        contract = instr.attr_list("lhs_contracting_dims")
        k = 1
        for c in contract:
            ci = int(c)
            if ci < len(lhs_dims):
                k *= lhs_dims[ci]
        return 2.0 * out_elems * k

    # bytes rules:
    #   free (layout/metadata only, or double-count-avoidance):
    _FREE_OPS = frozenset({
        "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
        "reshape", "copy-start", "copy-done", "after-all", "partition-id",
        "all-gather-done", "all-reduce-done", "collective-permute-done",
        "async-done", "opt-barrier",
        # control-flow shells: carries stay in place; bodies are counted
        "while", "conditional", "call",
    })
    #   read only what they produce (not the full operand):
    _SLICE_OPS = frozenset({
        "slice", "dynamic-slice", "gather", "broadcast", "iota", "pad",
        "reverse", "concatenate",
    })

    def _op_bytes(self, comp: Computation, instr: Instruction) -> float:
        """Estimated HBM traffic of one top-level op.

        Optimistic-roofline rules: slicing ops move only the slice
        (result×2: read + write); in-place-able updates move the update;
        everything else moves operands + result once.
        """
        op = instr.opcode
        if op in self._FREE_OPS:
            return 0.0
        _, rb = _shape_elems_bytes(instr.type_str)
        if op in self._SLICE_OPS:
            return 2.0 * rb
        if op in ("dynamic-update-slice", "scatter"):
            # read+write the updated region ~ update operand size ×2
            upd_b = 0
            ops = instr.operands
            if len(ops) >= 2:
                t = comp.type_of(ops[1])
                if t:
                    upd_b = _shape_elems_bytes(t)[1]
            return 2.0 * (upd_b if upd_b else rb)
        if op == "fusion":
            # fusions whose root is a slice/update must not count the whole
            # sliced buffer as traffic (per-layer fetch from a lax.scan
            # param stack; in-place KV-cache writes)
            called = self.computations.get(instr.attr("calls") or "")
            if called:
                root = next(
                    (i for i in called.instructions.values() if i.is_root), None
                )
                if root is not None and root.opcode in (
                    "dynamic-update-slice", "scatter"
                ):
                    return self._op_bytes(called, root)
                if root is not None and root.opcode in self._SLICE_OPS | {
                    "bitcast", "reshape"
                }:
                    # walk back through layout ops to find a slicing root
                    cur = root
                    seen = 0
                    while cur is not None and seen < 4:
                        if cur.opcode in ("dynamic-slice", "slice", "gather"):
                            return 2.0 * rb
                        ops_ = cur.operands
                        cur = called.instructions.get(ops_[0]) if ops_ else None
                        seen += 1
        ob = 0
        for o in instr.operands:
            t = comp.type_of(o)
            if t:
                ob += _shape_elems_bytes(t)[1]
        return float(rb + ob)

    def _comp_costs(self, name: str) -> Costs:
        if name in self._memo:
            return self._memo[name]
        comp = self.computations.get(name)
        costs = Costs()
        self._memo[name] = costs  # break cycles defensively
        if comp is None:
            return costs
        for instr in comp.instructions.values():
            op = instr.opcode
            base = op[:-6] if op.endswith("-start") else op
            if base in _COLLECTIVES:
                _, b = _shape_elems_bytes(instr.type_str)
                costs.collective_bytes[base] += b
                costs.collective_count[base] += 1
            if op in ("dot", "dot_general"):
                costs.flops += self._dot_flops(comp, instr)
            # ---- bytes: HBM traffic estimate, per-opcode rules ----
            costs.bytes += self._op_bytes(comp, instr)
            # ---- recurse into called computations ----
            if op == "while":
                body = instr.attr("body")
                cond = instr.attr("condition")
                # XLA annotates statically-known loops:
                # backend_config={"known_trip_count":{"n":"24"}, ...}
                m = re.search(r'known_trip_count[":{\s]*n["\s:]*"?(\d+)', instr.rest)
                if m:
                    trips = float(m.group(1))
                else:
                    trips = self._trip_count(cond) if cond else 1.0
                if body:
                    costs.add(self._comp_costs(body), trips)
                if cond:
                    costs.add(self._comp_costs(cond), trips)
            elif op == "fusion":
                called = instr.attr("calls")
                if called:
                    sub = self._comp_costs(called)
                    # fusion internals: FLOPs count, BYTES don't (fused)
                    costs.flops += sub.flops
                    for k in _COLLECTIVES:
                        costs.collective_bytes[k] += sub.collective_bytes[k]
                        costs.collective_count[k] += sub.collective_count[k]
            elif op in ("call", "custom-call", "async-start"):
                called = instr.attr("to_apply") or instr.attr("called_computation")
                if called:
                    costs.add(self._comp_costs(called))
            elif op == "conditional":
                for key in ("true_computation", "false_computation"):
                    called = instr.attr(key)
                    if called:
                        costs.add(self._comp_costs(called))
                for called in instr.attr_list("branch_computations"):
                    costs.add(self._comp_costs(called.lstrip("%")))
        return costs

    # ------------------------------------------------------------------
    def entry_costs(self) -> Costs:
        if self.entry is None:
            # fall back: largest computation
            if not self.computations:
                return Costs()
            self.entry = max(
                self.computations, key=lambda n: len(self.computations[n].instructions)
            )
        return self._comp_costs(self.entry)


def analyze(hlo_text: str) -> Costs:
    return HloModule(hlo_text).entry_costs()
