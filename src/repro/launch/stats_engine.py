"""Sharded FedCGS statistics engine (the fused kernel at mesh scale).

One entry point, ``sharded_client_stats``, takes a feature batch — one
huge client, or many simulated clients concatenated — shards the rows
over the mesh's client axes, runs the fused single-pass Pallas engine
(``repro.kernels.client_stats``) on every shard, and realizes the
paper's server aggregation as ONE ``psum`` over the FeatureStats tree.
Partition-invariance (paper Table 4) is what makes the row-assignment
arbitrary: any shard layout sums to the same global statistics.

Shape hygiene lives here: rows are padded with label −1 / zero features
to divide evenly across shards, and the padding provably contributes
zero to A, B, and N (kernel masks label −1 in-register; the jnp
fallback's one_hot maps it to all-zeros).

``sharded_cohort_stats`` is the many-clients convenience: it
concatenates per-client batches and delegates — the psum then IS the
server's sum over clients, optionally with SecureAgg masks folded in
(``secure=True``) so no unmasked per-shard statistic ever leaves its
shard.
"""

from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.federated import distributed_client_stats, masked_distributed_stats
from repro.core.statistics import FeatureStats
from repro.launch.mesh import make_host_mesh

Array = jax.Array


def _num_shards(mesh: Mesh, axes: Tuple[str, ...]) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _pad_rows(features: Array, labels: Array, multiple: int):
    """Zero-pad features / −1-pad labels so rows divide the shard count."""
    n = features.shape[0]
    pad = (-n) % multiple
    if pad == 0:
        return features, labels
    f = jnp.pad(features, ((0, pad), (0, 0)))
    y = jnp.pad(labels.astype(jnp.int32), (0, pad), constant_values=-1)
    return f, y


def batch_shardings(
    mesh: Mesh, axes: Tuple[str, ...] = ("data",)
) -> Tuple[NamedSharding, NamedSharding]:
    """(features, labels) shardings: rows over the client axes."""
    live = tuple(a for a in axes if a in mesh.axis_names)
    spec = live if len(live) > 1 else (live[0] if live else None)
    return (
        NamedSharding(mesh, P(spec)),
        NamedSharding(mesh, P(spec)),
    )


def sharded_client_stats(
    features: Array,
    labels: Array,
    num_classes: int,
    *,
    mesh: Optional[Mesh] = None,
    client_axes: Tuple[str, ...] = ("data",),
    use_kernel: bool = True,
    secure: bool = False,
    base_seed: int = 0,
    mask_scale: float = 1e3,
) -> FeatureStats:
    """Global (A, B, N) for a row-sharded feature batch.

    features: (n, d) float; labels: (n,) int in [0, num_classes).  The
    batch is padded to the shard count, device_put along the client
    axes, swept once per shard by the fused kernel, and reduced with a
    single collective.  With ``secure=True`` the shards mask their
    contribution with pairwise-cancelling noise before the psum.
    """
    mesh = mesh if mesh is not None else make_host_mesh(1)
    axes = tuple(a for a in client_axes if a in mesh.axis_names)
    features = jnp.asarray(features)
    labels = jnp.asarray(labels).astype(jnp.int32)
    f, y = _pad_rows(features, labels, _num_shards(mesh, axes))
    f_sh, y_sh = batch_shardings(mesh, axes)
    f, y = jax.device_put(f, f_sh), jax.device_put(y, y_sh)
    if secure:
        return masked_distributed_stats(
            f, y, num_classes, mesh,
            base_seed=base_seed, mask_scale=mask_scale,
            client_axes=axes, use_kernel=use_kernel,
        )
    return distributed_client_stats(
        f, y, num_classes, mesh, client_axes=axes, use_kernel=use_kernel
    )


def sharded_cohort_stats(
    client_batches: Sequence[Tuple[np.ndarray, np.ndarray]],
    num_classes: int,
    *,
    mesh: Optional[Mesh] = None,
    client_axes: Tuple[str, ...] = ("data",),
    use_kernel: bool = True,
    secure: bool = False,
    base_seed: int = 0,
    mask_scale: float = 1e3,
) -> FeatureStats:
    """Aggregate statistics for MANY simulated clients in one collective.

    Client batches are concatenated and row-sharded; partition
    invariance guarantees the psum equals the per-client sum the paper's
    server loop would compute.
    """
    feats = jnp.concatenate([jnp.asarray(f) for f, _ in client_batches], axis=0)
    labels = jnp.concatenate(
        [jnp.asarray(y).astype(jnp.int32) for _, y in client_batches], axis=0
    )
    return sharded_client_stats(
        feats, labels, num_classes,
        mesh=mesh, client_axes=client_axes, use_kernel=use_kernel,
        secure=secure, base_seed=base_seed, mask_scale=mask_scale,
    )
