"""Sharded FedCGS statistics engine (the fused kernel at mesh scale).

The mesh placement cells of ``core.stats_pipeline.StatsPipeline`` live
here.  ``sharded_client_stats`` takes a feature batch — one huge client,
or many simulated clients concatenated — shards the rows over the
mesh's client axes, runs the fused single-pass Pallas engine
(``repro.kernels.client_stats``) on every shard, and realizes the
paper's server aggregation as ONE ``psum`` over the FeatureStats tree.
Partition-invariance (paper Table 4) is what makes the row-assignment
arbitrary: any shard layout sums to the same global statistics.

``streaming_sharded_stats`` is the same contract for clients whose
datasets never fit in device memory: each shard keeps a RUNNING
FeatureStats carry, every batch is row-sharded and folded into the
carry under shard_map with no collective at all, and a separate
finalize step issues the single psum per cohort — one collective
regardless of how many batches streamed through (asserted by a
jaxpr collective-count in tests).  ``make_streaming_engine`` exposes
the (init, fold, finalize) triple so tests can introspect the traces.

Shape hygiene lives here: rows are padded with label −1 / zero features
to divide evenly across shards (and, when streaming, ragged tail
batches are padded up to the first-seen batch shape so the whole stream
costs one fold trace).  The padding provably contributes zero to A, B,
and N (kernel masks label −1 in-register; the jnp fallback's one_hot
maps it to all-zeros).

``sharded_cohort_stats`` is the many-clients entry point: clients are
(features, labels) pairs OR per-client batch iterators; materialized
cohorts are concatenated into one sharded sweep, while any iterator in
the cohort routes the whole cohort through the streaming fold — the
psum then IS the server's sum over clients, optionally with SecureAgg
masks folded in (``secure=True``) so no unmasked per-shard statistic
ever leaves its shard.

Dropout tolerance: every entry point takes ``dropped_shards=`` (shards
that went dark mid-round) and ``min_survivors=`` (the Shamir threshold
t).  Lost shards contribute zero to the psum; for ``secure`` rounds the
drivers then reconstruct the lost shards' pair-seed secrets from the
survivors' t-of-K shares (``core.shamir``) and subtract the dangling
masks host-side — the exact survivor statistics, still one collective.
"""

from __future__ import annotations

import itertools
from typing import Callable, Iterable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.federated import (
    apply_pair_masks,
    distributed_client_stats,
    drop_shard_contribution,
    masked_distributed_stats,
    shard_index,
    _local_stats,
)
from repro.core.statistics import FeatureStats
from repro.launch.mesh import make_host_mesh
from repro.sharding import shard_map

Array = jax.Array


def _num_shards(mesh: Mesh, axes: Tuple[str, ...]) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _pad_rows(features: Array, labels: Array, multiple: int):
    """Zero-pad features / −1-pad labels so rows divide the shard count."""
    n = features.shape[0]
    pad = (-n) % multiple
    if pad == 0:
        return features, labels
    f = jnp.pad(features, ((0, pad), (0, 0)))
    y = jnp.pad(labels.astype(jnp.int32), (0, pad), constant_values=-1)
    return f, y


def batch_shardings(
    mesh: Mesh, axes: Tuple[str, ...] = ("data",)
) -> Tuple[NamedSharding, NamedSharding]:
    """(features, labels) shardings: rows over the client axes."""
    live = tuple(a for a in axes if a in mesh.axis_names)
    spec = live if len(live) > 1 else (live[0] if live else None)
    return (
        NamedSharding(mesh, P(spec)),
        NamedSharding(mesh, P(spec)),
    )


def sharded_client_stats(
    features: Array,
    labels: Array,
    num_classes: int,
    *,
    mesh: Optional[Mesh] = None,
    client_axes: Tuple[str, ...] = ("data",),
    use_kernel: bool = True,
    secure: bool = False,
    base_seed: int = 0,
    mask_scale: float = 1e3,
    interpret: Optional[bool] = None,
    dropped_shards: Tuple[int, ...] = (),
    min_survivors: Optional[int] = None,
) -> FeatureStats:
    """Global (A, B, N) for a row-sharded feature batch.

    features: (n, d) float; labels: (n,) int in [0, num_classes).  The
    batch is padded to the shard count, device_put along the client
    axes, swept once per shard by the fused kernel, and reduced with a
    single collective.  With ``secure=True`` the shards mask their
    contribution with pairwise-cancelling noise before the psum; shards
    listed in ``dropped_shards`` go dark mid-round and the server
    recovers their dangling masks from ≥ ``min_survivors`` Shamir shares
    (``core.secure_agg``), so the result is the exact statistics of the
    surviving shards' rows.
    """
    mesh = mesh if mesh is not None else make_host_mesh(1)
    axes = tuple(a for a in client_axes if a in mesh.axis_names)
    if dropped_shards:
        from repro.core.secure_agg import round_plan

        # reject bogus shard ids / sub-threshold survivor sets up front
        # (the plain path would otherwise silently ignore both knobs)
        round_plan(
            _num_shards(mesh, axes), dropped_shards,
            min_survivors=min_survivors, secure=secure,
        )
    features = jnp.asarray(features)
    labels = jnp.asarray(labels).astype(jnp.int32)
    f, y = _pad_rows(features, labels, _num_shards(mesh, axes))
    f_sh, y_sh = batch_shardings(mesh, axes)
    f, y = jax.device_put(f, f_sh), jax.device_put(y, y_sh)
    if secure:
        return masked_distributed_stats(
            f, y, num_classes, mesh,
            base_seed=base_seed, mask_scale=mask_scale,
            client_axes=axes, use_kernel=use_kernel, interpret=interpret,
            dropped_shards=dropped_shards, min_survivors=min_survivors,
        )
    return distributed_client_stats(
        f, y, num_classes, mesh,
        client_axes=axes, use_kernel=use_kernel, interpret=interpret,
        dropped_shards=dropped_shards,
    )


# ---------------------------------------------------------------------------
# Streaming: per-shard running FeatureStats, ONE psum per cohort.
# ---------------------------------------------------------------------------


def make_streaming_engine(
    num_classes: int,
    feature_dim: int,
    mesh: Mesh,
    *,
    client_axes: Tuple[str, ...] = ("data",),
    use_kernel: bool = True,
    secure: bool = False,
    base_seed: int = 0,
    mask_scale: float = 1e3,
    interpret: Optional[bool] = None,
    dropped_shards: Tuple[int, ...] = (),
    min_survivors: Optional[int] = None,
) -> Tuple[FeatureStats, Callable, Callable]:
    """(carry0, fold, finalize) for the streaming sharded statistics path.

    ``carry0`` holds one running statistic PER SHARD (leading shard
    axis, sharded over the client axes).  ``fold(carry, f, y)``
    row-shards a batch and folds each shard's local sweep into its own
    carry — NO collective in its trace.  ``finalize(carry)`` masks each
    shard's running statistic (if ``secure``) and reduces with the
    cohort's single psum.  Exposed separately so tests can count
    collectives in each jaxpr; ``streaming_sharded_stats`` is the
    driver.  The carry layout is an implementation detail of the
    triple: FeatureStats on the jnp backend, and the fused kernel's
    padded in-place (M, N) carry (``kernels.client_stats_acc``) with
    ``use_kernel=True`` — B's triangle mirror then happens once per
    stream in finalize, not once per batch.

    ``dropped_shards`` models shards that go dark before upload: their
    (masked) running statistic is zeroed inside the finalize body — the
    psum stays the ONE collective — and, when ``secure``, the finalize
    wrapper afterwards reconstructs the lost shards' pair-seed secrets
    from ≥ ``min_survivors`` Shamir shares and subtracts the dangling
    masks, returning the exact statistics over the surviving shards.
    """
    from repro import tune
    from repro.kernels.ops import (
        _client_stats_acc_impl,
        _padded_dims,
        stats_carry_finalize,
    )

    # tuned fold blocks for this (d, C) family (kernel defaults on a
    # cache miss); the carry layout and every fold share one block_d
    block_n, block_d = tune.stats_acc_blocks(num_classes, feature_dim)

    axes = tuple(a for a in client_axes if a in mesh.axis_names)
    n_shards = _num_shards(mesh, axes)
    shard_sharding = NamedSharding(mesh, P(axes))

    if use_kernel:
        d_pad, c_pad = _padded_dims(num_classes, feature_dim, block_d)
        carry0 = (
            jnp.zeros((n_shards, d_pad + c_pad, d_pad), jnp.float32),
            jnp.zeros((n_shards, 1, c_pad), jnp.float32),
        )
        carry_spec = (P(axes), P(axes))

        def fold_body(carry, f: Array, y: Array):
            m, n = _client_stats_acc_impl(
                carry[0][0], carry[1][0], f, y,
                interpret=(jax.default_backend() != "tpu"
                           if interpret is None else interpret),
                block_d=block_d, block_n=block_n,
            )
            return m[None], n[None]

        def unpack(carry) -> FeatureStats:
            A, B, N = stats_carry_finalize(
                carry[0][0], carry[1][0], num_classes, feature_dim
            )
            return FeatureStats(A=A, B=B, N=N)

    else:
        carry0 = FeatureStats(
            A=jnp.zeros((n_shards, num_classes, feature_dim), jnp.float32),
            B=jnp.zeros((n_shards, feature_dim, feature_dim), jnp.float32),
            N=jnp.zeros((n_shards, num_classes), jnp.float32),
        )
        carry_spec = FeatureStats(A=P(axes), B=P(axes), N=P(axes))

        def fold_body(carry: FeatureStats, f: Array, y: Array) -> FeatureStats:
            local = _local_stats(f, y, num_classes, use_kernel=False)
            return jax.tree_util.tree_map(
                lambda c, l: c + l[None], carry, local
            )

        def unpack(carry: FeatureStats) -> FeatureStats:
            return jax.tree_util.tree_map(lambda c: c[0], carry)

    carry0 = jax.device_put(
        carry0, jax.tree_util.tree_map(lambda _: shard_sharding, carry0)
    )

    fold = jax.jit(
        shard_map(
            fold_body, mesh=mesh,
            in_specs=(carry_spec, P(axes), P(axes)),
            out_specs=carry_spec,
            check_rep=not use_kernel,  # pallas_call has no replication rule
        ),
        # donate the carry so the kernel's input_output_aliases is a true
        # in-place update (CPU can't donate; avoid the warning there)
        donate_argnums=(0,) if jax.default_backend() == "tpu" else (),
    )

    dropped = tuple(sorted({int(d) for d in dropped_shards}))
    if dropped:
        from repro.core.secure_agg import round_plan

        # validate at engine build time, before any batch is folded
        survivors, threshold = round_plan(
            n_shards, dropped, min_survivors=min_survivors, secure=secure
        )
    if secure:
        from repro.core.secure_agg import pair_seed_matrix

        # derived OUTSIDE the trace: check_rep's rewrite tracer would
        # lift host-side field arithmetic into the shard_map body
        seeds = pair_seed_matrix(base_seed, n_shards)

    def finalize_body(carry) -> FeatureStats:
        local = unpack(carry)
        me = shard_index(mesh, axes)
        if secure:
            local = apply_pair_masks(
                local, me, n_shards,
                base_seed=base_seed, mask_scale=mask_scale, seeds=seeds,
            )
        local = drop_shard_contribution(local, me, dropped)
        return jax.lax.psum(local, axes)  # THE one collective of the cohort

    finalize = jax.jit(
        shard_map(
            finalize_body, mesh=mesh,
            in_specs=(carry_spec,),
            out_specs=FeatureStats(A=P(), B=P(), N=P()),
        )
    )
    if secure and dropped:
        from repro.core.secure_agg import recover_partial_sum, setup_round

        setup = setup_round(n_shards, threshold, base_seed=base_seed)
        psum_finalize = finalize

        def finalize(carry) -> FeatureStats:
            # un-mask AFTER the collective: pure per-host arithmetic, so
            # the cohort's communication bill stays at one psum
            return recover_partial_sum(
                psum_finalize(carry), survivors, setup, mask_scale=mask_scale
            )

    return carry0, fold, finalize


def streaming_sharded_stats(
    batches: Iterable[Tuple[np.ndarray, np.ndarray]],
    num_classes: int,
    *,
    feature_dim: Optional[int] = None,
    mesh: Optional[Mesh] = None,
    client_axes: Tuple[str, ...] = ("data",),
    use_kernel: bool = True,
    secure: bool = False,
    base_seed: int = 0,
    mask_scale: float = 1e3,
    interpret: Optional[bool] = None,
    dropped_shards: Tuple[int, ...] = (),
    min_survivors: Optional[int] = None,
) -> FeatureStats:
    """Global (A, B, N) from a stream of (features, labels) batches.

    Device memory holds one row-sharded batch plus the per-shard carry;
    every fold step is collective-free and the single psum happens once,
    at the end — the ROADMAP's "streaming-client sharding" shape.
    Batches after the first are padded (zero rows, label −1) up to the
    first batch's padded row count, so any number of equal-shaped
    batches plus a ragged tail costs exactly one fold trace.
    ``dropped_shards`` loses those shards' slices of every batch; with
    ``secure=True`` the finalize recovers their dangling masks via the
    Shamir share machinery (see :func:`make_streaming_engine`).
    """
    from repro.core.stats_pipeline import canonical_batch_stream

    mesh = mesh if mesh is not None else make_host_mesh(1)
    axes = tuple(a for a in client_axes if a in mesh.axis_names)
    n_shards = _num_shards(mesh, axes)
    f_sh, y_sh = batch_shardings(mesh, axes)

    it = iter(batches)
    first = next(it, None)
    if first is None:
        if feature_dim is None:
            raise ValueError(
                "empty batch stream: pass feature_dim= for the zero statistic"
            )
        return FeatureStats.zeros(num_classes, feature_dim)

    d = jnp.asarray(first[0]).shape[1]
    carry, fold, finalize = make_streaming_engine(
        num_classes, d, mesh,
        client_axes=client_axes, use_kernel=use_kernel, secure=secure,
        base_seed=base_seed, mask_scale=mask_scale, interpret=interpret,
        dropped_shards=dropped_shards, min_survivors=min_survivors,
    )

    def shard_divisible():
        # rows must divide the shard count BEFORE the one-trace-per-shape
        # canonicalization; the pad delta stays a shard multiple, so the
        # canonical row count divides evenly too
        for fb, yb in itertools.chain([first], it):
            yield _pad_rows(
                jnp.asarray(fb), jnp.asarray(yb).astype(jnp.int32), n_shards
            )

    for fb, yb in canonical_batch_stream(shard_divisible()):
        fb = jax.device_put(fb, f_sh)
        yb = jax.device_put(yb, y_sh)
        carry = fold(carry, fb, yb)
    return finalize(carry)


def sharded_cohort_stats(
    clients: Sequence,
    num_classes: int,
    *,
    feature_dim: Optional[int] = None,
    mesh: Optional[Mesh] = None,
    client_axes: Tuple[str, ...] = ("data",),
    use_kernel: bool = True,
    secure: bool = False,
    base_seed: int = 0,
    mask_scale: float = 1e3,
    interpret: Optional[bool] = None,
    dropped_shards: Tuple[int, ...] = (),
    min_survivors: Optional[int] = None,
) -> FeatureStats:
    """Aggregate statistics for MANY simulated clients in one collective.

    Each client is a (features, labels) pair or an iterator of such
    batches.  A fully-materialized cohort is concatenated and row-
    sharded in one sweep; a cohort containing any batch iterator streams
    every client's batches through the per-shard running fold instead —
    either way partition invariance guarantees the single psum equals
    the per-client sum the paper's server loop would compute.
    ``dropped_shards``/``min_survivors`` forward the lost-shard recovery
    story of the underlying engines.
    """
    from repro.core.stats_pipeline import _is_array_pair

    kwargs = dict(
        mesh=mesh, client_axes=client_axes, use_kernel=use_kernel,
        secure=secure, base_seed=base_seed, mask_scale=mask_scale,
        interpret=interpret, dropped_shards=dropped_shards,
        min_survivors=min_survivors,
    )
    clients = list(clients)
    if all(_is_array_pair(c) for c in clients):
        feats = jnp.concatenate([jnp.asarray(f) for f, _ in clients], axis=0)
        labels = jnp.concatenate(
            [jnp.asarray(y).astype(jnp.int32) for _, y in clients], axis=0
        )
        return sharded_client_stats(feats, labels, num_classes, **kwargs)

    def batch_stream():
        for c in clients:
            if _is_array_pair(c):
                yield c
            else:
                yield from c

    return streaming_sharded_stats(
        batch_stream(), num_classes, feature_dim=feature_dim, **kwargs
    )
