"""Serving driver: prefill a batched prompt, then decode tokens.

Runs the exact serve_step the decode dry-runs lower, on host devices
with reduced configs.  Greedy sampling (argmax) — the driver is about
the runtime path, not generation quality.

Example:
    PYTHONPATH=src python -m repro.launch.serve \
        --arch mamba2-2.7b --batch 4 --prompt-len 64 --gen 32
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import PUBLIC_IDS, get_config
from repro.models import transformer as T
from repro.models.common import init_params
from repro.serve.metrics import timed


def serve(
    arch: str,
    *,
    batch: int = 4,
    prompt_len: int = 64,
    gen_tokens: int = 32,
    reduced: bool = True,
    seed: int = 0,
    cache_dtype=jnp.float32,
):
    cfg = get_config(arch, reduced=reduced)
    params = init_params(T.build_specs(cfg), jax.random.key(seed))
    rng = np.random.default_rng(seed)
    prompt = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (batch, prompt_len)), jnp.int32
    )
    kw = {}
    if cfg.vision_tokens:
        kw["patches"] = jnp.asarray(
            rng.standard_normal((batch, cfg.vision_tokens, cfg.d_model)) * 0.02,
            jnp.float32,
        )
    if cfg.is_encdec:
        kw["frames"] = jnp.asarray(
            rng.standard_normal((batch, cfg.encoder_seq_len, cfg.d_model)) * 0.02,
            jnp.float32,
        )

    prefill = jax.jit(
        lambda p, t, **k: T.prefill(
            p, cfg, t, cache_dtype=cache_dtype,
            cache_len=prompt_len + gen_tokens, **k,
        )
    )
    def run_prefill():
        hidden, cache = prefill(params, prompt, **kw)
        return (
            jnp.argmax(T.unembed(params, cfg, hidden[:, -1:]), axis=-1)[:, 0],
            cache,
        )

    (last, cache), t_prefill = timed(run_prefill)

    @jax.jit
    def decode_one(p, tok, cache):
        h, cache = T.decode_step(p, cfg, tok, cache)
        logits = T.unembed(p, cfg, h[:, None])[:, 0]
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

    out_tokens = [np.asarray(last)]

    def run_decode(tok, cache):
        for _ in range(gen_tokens - 1):
            tok, cache = decode_one(params, tok, cache)
            out_tokens.append(np.asarray(tok))
        return cache

    _, t_decode = timed(run_decode, last.astype(jnp.int32), cache)
    gen = np.stack(out_tokens, axis=1)  # (B, gen)
    return gen, {"prefill_s": t_prefill, "decode_s": t_decode,
                 "tokens_per_s": batch * (gen_tokens - 1) / max(t_decode, 1e-9)}


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", required=True, choices=PUBLIC_IDS)
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=64)
    p.add_argument("--gen", type=int, default=32)
    p.add_argument("--full", action="store_true")
    args = p.parse_args(argv)
    gen, stats = serve(
        args.arch, batch=args.batch, prompt_len=args.prompt_len,
        gen_tokens=args.gen, reduced=not args.full,
    )
    print(f"generated shape {gen.shape}; first row: {gen[0][:16].tolist()}")
    print(
        f"prefill {stats['prefill_s']:.2f}s, decode {stats['decode_s']:.2f}s, "
        f"{stats['tokens_per_s']:.1f} tok/s"
    )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
