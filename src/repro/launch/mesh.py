"""Production mesh definitions (DESIGN.md §3).

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state (the dry-run must set XLA_FLAGS before any
device query; smoke tests must keep seeing 1 device).

``_make_mesh`` papers over the jax API skew around explicit axis types:
``jax.make_mesh`` only grew ``axis_types=`` (and ``jax.sharding`` only
grew ``AxisType``) after 0.4.x, and Auto is the default there anyway.
"""

from __future__ import annotations

from typing import Sequence, Tuple

import jax
from jax.sharding import Mesh

SINGLE_POD = (16, 16)  # 256 chips (TPU v5e pod slice)
MULTI_POD = (2, 16, 16)  # 2 pods = 512 chips


def _make_mesh(shape: Sequence[int], axes: Tuple[str, ...]) -> Mesh:
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:  # older jax: Auto is the only (implicit) behaviour
        return jax.make_mesh(tuple(shape), axes)
    return jax.make_mesh(
        tuple(shape), axes, axis_types=(axis_type.Auto,) * len(axes)
    )


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = MULTI_POD if multi_pod else SINGLE_POD
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 1) -> Mesh:
    """Tiny mesh over whatever devices the host actually has (tests)."""
    n = len(jax.devices())
    assert n % model_axis == 0
    return _make_mesh((n // model_axis, model_axis), ("data", "model"))
