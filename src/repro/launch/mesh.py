"""Production mesh definitions (DESIGN.md §3).

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state (the dry-run must set XLA_FLAGS before any
device query; smoke tests must keep seeing 1 device).
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh

SINGLE_POD = (16, 16)  # 256 chips (TPU v5e pod slice)
MULTI_POD = (2, 16, 16)  # 2 pods = 512 chips


def _auto(n: int):
    return (jax.sharding.AxisType.Auto,) * n


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = MULTI_POD if multi_pod else SINGLE_POD
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=_auto(len(axes)))


def make_host_mesh(model_axis: int = 1) -> Mesh:
    """Tiny mesh over whatever devices the host actually has (tests)."""
    n = len(jax.devices())
    assert n % model_axis == 0
    return jax.make_mesh(
        (n // model_axis, model_axis), ("data", "model"), axis_types=_auto(2)
    )
