# Launch layer: production mesh, input specs, jit-able steps, dry-run
# driver, training and serving entry points.
