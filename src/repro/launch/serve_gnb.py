"""Launch adapter for GNB serving — thin shims over :mod:`repro.serve`.

``launch.serve`` serves LM decode; this module serves what FedCGS
actually produces — the training-free linear head configured from
global feature statistics.  The actual subsystem (dynamic batcher,
versioned hot-swappable head registry, metrics, run loop) lives in
``repro.serve``; this adapter keeps the historical library entry point
:func:`gnb_serve` (one-shot scoring of a feature batch, row-sharded
over a mesh when given one — any row count, pad-to-shards is handled
inside) and the CLI, which now drives a real :class:`GNBServer` under
synthetic ragged traffic and prints the metrics snapshot.

Example:
    PYTHONPATH=src python -m repro.launch.serve_gnb --requests 64
    fedcgs-serve --requests 64          # installed console script
    fedcgs-serve --requests 64 --workers 4   # multi-worker ServeFront

With ``--workers N > 1`` the same workload fans out across N
``GNBServer`` workers behind a :class:`~repro.serve.front.ServeFront`
(shared registry, join-shortest-queue routing); the socket-facing
front with load shedding is the separate ``fedcgs-front`` script.
"""

from __future__ import annotations

import argparse
import json
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core.classifier import LinearHead
from repro.serve import GNBServer
from repro.serve.scoring import score_features
from repro.serve.server import serve_requests
from repro.timing import timed

Array = jax.Array


def gnb_serve(
    head: LinearHead,
    features: Array,
    *,
    mesh: Optional[Mesh] = None,
    client_axes: Tuple[str, ...] = ("data",),
    interpret: Optional[bool] = None,
) -> Tuple[Array, Array]:
    """(logits, predictions) for a feature batch under the GNB head.

    One-shot library call — no queue, no thread.  The kernel wrapper
    owns block padding; the scoring layer owns mesh placement (rows
    padded to divide the live client axes and sliced back, so ragged
    batches work on any mesh).
    """
    logits = score_features(
        jnp.asarray(features), head.W, head.b,
        mesh=mesh, client_axes=client_axes, interpret=interpret,
    )
    return logits, jnp.argmax(logits, axis=-1)


def standin_head(classes: int, feature_dim: int, seed: int) -> LinearHead:
    # stand-in head (shared with benchmarks/serve_bench): the path under
    # test is the serving stack, statistics -> head fitting is fl.fedcgs's job
    rng = np.random.default_rng(seed)
    return LinearHead(
        W=jnp.asarray(rng.standard_normal((classes, feature_dim)), jnp.float32),
        b=jnp.zeros((classes,), jnp.float32),
    )


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--requests", type=int, default=32,
                   help="number of ragged requests to push through the server")
    p.add_argument("--batch", type=int, default=512,
                   help="mean rows per request (sizes are ragged around it)")
    p.add_argument("--feature-dim", type=int, default=128)
    p.add_argument("--classes", type=int, default=10)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--max-batch-rows", type=int, default=1024)
    p.add_argument("--max-delay-ms", type=float, default=2.0)
    p.add_argument("--workers", type=int, default=1,
                   help="GNBServer workers (>1 fans out via ServeFront)")
    p.add_argument("--direct", action="store_true",
                   help="one-shot gnb_serve() call instead of the server loop")
    args = p.parse_args(argv)

    rng = np.random.default_rng(args.seed)
    head = standin_head(args.classes, args.feature_dim, args.seed)

    if args.direct:
        feats = jnp.asarray(
            rng.standard_normal((args.batch, args.feature_dim)), jnp.float32
        )
        (logits, pred), dt = timed(
            lambda: jax.block_until_ready(gnb_serve(head, feats))
        )
        print(
            f"scored {args.batch} x {args.feature_dim} -> {logits.shape[1]} "
            f"classes in {dt*1e3:.1f} ms ({args.batch / max(dt, 1e-9):.0f} samples/s)"
        )
        return 0

    sizes = np.clip(
        rng.poisson(args.batch, args.requests), 1, None
    ).astype(int)
    requests = [
        rng.standard_normal((n, args.feature_dim)).astype(np.float32)
        for n in sizes
    ]
    total_rows = int(sum(sizes))
    kwargs = dict(
        max_batch_rows=args.max_batch_rows,
        max_delay_s=args.max_delay_ms * 1e-3,
        # serve_requests submits the whole workload up front — the queue
        # bound must admit it all or the CLI would trip its own backpressure
        max_queue_rows=max(2 * total_rows, 64 * args.max_batch_rows),
    )
    if args.workers > 1:
        from repro.serve import ServeFront

        front = ServeFront.create(args.workers, head=head, **kwargs)
        with front:
            results, dt = timed(serve_requests, front, requests, 300.0)
        snap = front.snapshot()
        p95 = max(w["latency_p95_ms"] for w in snap["workers"])
        waste = snap["aggregate"]["pad_waste_frac"]
    else:
        server = GNBServer(head, **kwargs)
        with server:
            results, dt = timed(serve_requests, server, requests, 300.0)
        snap = server.metrics.snapshot()
        p95 = snap["latency_p95_ms"]
        waste = snap["pad_waste_frac"]
    print(json.dumps(snap, indent=2))
    rows = sum(r.logits.shape[0] for r in results)
    print(
        f"served {len(results)} requests / {rows} rows in {dt*1e3:.1f} ms "
        f"across {args.workers} worker(s) "
        f"(p95 {p95:.2f} ms, pad waste {waste*100:.1f}%)"
    )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
