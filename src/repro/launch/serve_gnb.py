"""Serving path for the FedCGS product: batched GNB-head classification.

``launch.serve`` serves LM decode; this module serves what FedCGS
actually produces — the training-free linear head configured from
global feature statistics (ROADMAP "Serve the GNB head").  One entry
point, :func:`gnb_serve`, scores a feature batch through the fused
Pallas logits kernel (``kernels.gnb_logits_kernel`` via the jit'd
``kernels.gnb_logits`` wrapper, which pads rows/classes/features to
block multiples and slices the result back).  Given a mesh, the batch
is row-sharded over the data axes — each shard runs the kernel on its
rows, no collective needed because the head is replicated and logits
are row-parallel.

Example:
    PYTHONPATH=src python -m repro.launch.serve_gnb --batch 512
"""

from __future__ import annotations

import argparse
import time
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.classifier import LinearHead
from repro.kernels import gnb_logits
from repro.sharding import shard_map

Array = jax.Array


def gnb_serve(
    head: LinearHead,
    features: Array,
    *,
    mesh: Optional[Mesh] = None,
    client_axes: Tuple[str, ...] = ("data",),
    interpret: Optional[bool] = None,
) -> Tuple[Array, Array]:
    """(logits, predictions) for a feature batch under the GNB head.

    features: (n, d).  The kernel wrapper owns block padding; this layer
    owns mesh placement: with ``mesh`` the rows are sharded over the
    live ``client_axes`` (padded to divide evenly, sliced back after)
    and every shard computes its own logits tile — embarrassingly
    data-parallel, zero collectives.
    """
    features = jnp.asarray(features)
    n = features.shape[0]
    if mesh is None:
        logits = gnb_logits(features, head.W, head.b, interpret=interpret)
        return logits, jnp.argmax(logits, axis=-1)

    from repro.launch.stats_engine import _num_shards

    axes = tuple(a for a in client_axes if a in mesh.axis_names)
    shards = _num_shards(mesh, axes)
    pad = (-n) % shards
    if pad:
        features = jnp.pad(features, ((0, pad), (0, 0)))

    def shard_fn(f_shard: Array, w: Array, b: Array) -> Array:
        return gnb_logits(f_shard, w, b, interpret=interpret)

    fn = shard_map(
        shard_fn, mesh=mesh,
        in_specs=(P(axes), P(), P()),
        out_specs=P(axes),
        check_rep=False,  # pallas_call has no replication rule
    )
    logits = fn(features, head.W, head.b)[:n]
    return logits, jnp.argmax(logits, axis=-1)


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--batch", type=int, default=512)
    p.add_argument("--feature-dim", type=int, default=128)
    p.add_argument("--classes", type=int, default=10)
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)

    # stand-in head + features: the path under test is the serving stack,
    # statistics -> head fitting is fl.fedcgs's job
    rng = np.random.default_rng(args.seed)
    head = LinearHead(
        W=jnp.asarray(rng.standard_normal((args.classes, args.feature_dim)), jnp.float32),
        b=jnp.zeros((args.classes,), jnp.float32),
    )
    feats = jnp.asarray(
        rng.standard_normal((args.batch, args.feature_dim)), jnp.float32
    )
    t0 = time.time()
    logits, pred = gnb_serve(head, feats)
    jax.block_until_ready(pred)
    dt = time.time() - t0
    print(
        f"scored {args.batch} x {args.feature_dim} -> {logits.shape[1]} classes "
        f"in {dt*1e3:.1f} ms ({args.batch / max(dt, 1e-9):.0f} samples/s)"
    )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
