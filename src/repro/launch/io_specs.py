"""ShapeDtypeStruct stand-ins + shardings for every model input.

``input_specs(cfg, shape)`` builds the exact argument tree each step
function consumes — weak-type-correct, shardable, ZERO device
allocation — so the dry-run can lower a 400B training step on a laptop.

Sharding policy for inputs:
- batch dims shard over ("pod", "data") when divisible, else replicate
  (long_500k has batch 1);
- KV-cache slabs prefer kv-head sharding over "model"; when the arch's
  kv_heads don't divide the axis (GQA kv=8 on a 16-way axis) the CACHE
  SEQUENCE dim is sharded instead — attention over a seq-sharded cache
  is a partial-softmax reduce that GSPMD handles with an all-reduce;
- SSM decode states shard over heads ("model") with batch over data.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import InputShape, ModelConfig
from repro.models import transformer as T

PyTree = Any

# the sub-quadratic variant window used by dense archs on long_500k
LONG_CONTEXT_WINDOW = 8192


def config_for_shape(cfg: ModelConfig, shape: InputShape) -> ModelConfig:
    """Arch variant actually lowered for this input shape.

    Dense/MoE/VLM archs get a sliding-window attention variant for the
    500k-token decode (explicitly flagged; DESIGN.md §4).  SSM/hybrid
    archs run long_500k natively.
    """
    if shape.name == "long_500k" and not cfg.attention_free and cfg.family != "hybrid":
        if cfg.is_encdec:
            raise ValueError(f"{cfg.name} skips long_500k (DESIGN.md §Skips)")
        return cfg.with_sliding_window(LONG_CONTEXT_WINDOW)
    return cfg


def supported(cfg: ModelConfig, shape: InputShape) -> bool:
    """The 39-of-40 support matrix (whisper-tiny × long_500k is the skip)."""
    if shape.name == "long_500k" and cfg.is_encdec:
        return False
    return True


# ---------------------------------------------------------------------------
# spec builders
# ---------------------------------------------------------------------------


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def train_inputs(cfg: ModelConfig, shape: InputShape) -> Dict[str, PyTree]:
    b, s = shape.global_batch, shape.seq_len
    batch: Dict[str, PyTree] = {
        "tokens": _sds((b, s), jnp.int32),
        "targets": _sds((b, s), jnp.int32),
    }
    if cfg.rope == "mrope":
        batch["positions"] = _sds((3, b, s), jnp.int32)
    if cfg.vision_tokens:
        batch["patches"] = _sds((b, cfg.vision_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.is_encdec:
        batch["frames"] = _sds((b, cfg.encoder_seq_len, cfg.d_model), jnp.bfloat16)
    return batch


def prefill_inputs(cfg: ModelConfig, shape: InputShape) -> Dict[str, PyTree]:
    batch = train_inputs(cfg, shape)
    del batch["targets"]
    return batch


def decode_inputs(
    cfg: ModelConfig, shape: InputShape, cache_dtype=jnp.bfloat16
) -> Dict[str, PyTree]:
    """ONE new token + a seq_len KV cache (index = seq_len - 1 valid)."""
    b, s = shape.global_batch, shape.seq_len
    return {
        "token": _sds((b,), jnp.int32),
        "cache": T.cache_specs(cfg, b, s, cache_dtype),
    }


def stats_inputs(cfg: ModelConfig, shape: InputShape) -> Dict[str, PyTree]:
    """The FedCGS ClientStats pass at scale: tokens + running (A, B, N)."""
    batch = train_inputs(cfg, shape)
    d, v = cfg.d_model, cfg.vocab_size
    batch["stats"] = {
        "A": _sds((v, d), jnp.float32),
        "B": _sds((d, d), jnp.float32),
        "N": _sds((v,), jnp.float32),
    }
    return batch


# ---------------------------------------------------------------------------
# shardings
# ---------------------------------------------------------------------------


def _batch_axes(mesh: Mesh, size: int) -> Optional[Tuple[str, ...]]:
    cand = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    total = 1
    for a in cand:
        total *= mesh.shape[a]
    return cand if size % total == 0 else None


def batch_shardings(batch: PyTree, mesh: Mesh) -> PyTree:
    """Shardings for a train/prefill input tree (leading dim = batch,
    except mrope positions where batch is dim 1)."""

    def shard(path, leaf):
        names = [getattr(p, "key", "") for p in path]
        if "positions" in names:  # (3, B, S)
            axes = _batch_axes(mesh, leaf.shape[1])
            return NamedSharding(mesh, P(None, axes, None))
        if "stats" in names:
            return stats_shardings_one(names[-1], leaf, mesh)
        axes = _batch_axes(mesh, leaf.shape[0])
        return NamedSharding(mesh, P(axes, *([None] * (leaf.ndim - 1))))

    return jax.tree_util.tree_map_with_path(shard, batch)


def stats_shardings_one(name: str, leaf, mesh: Mesh) -> NamedSharding:
    """(A, B, N): A like an unembedding (vocab over model), B row-sharded."""
    model_ok = lambda dim: "model" in mesh.axis_names and dim % mesh.shape["model"] == 0
    if name == "A":  # (V, d)
        return NamedSharding(
            mesh, P("model" if model_ok(leaf.shape[0]) else None, None)
        )
    if name == "B":  # (d, d)
        return NamedSharding(
            mesh, P("model" if model_ok(leaf.shape[0]) else None, None)
        )
    return NamedSharding(mesh, P(None))  # N


def cache_shardings(cfg: ModelConfig, cache: PyTree, mesh: Mesh) -> PyTree:
    """Sharding tree matching cache_specs' structure (policy in module doc)."""
    model = mesh.shape.get("model", 1)

    def shard(path, leaf):
        names = [getattr(p, "key", "") for p in path]
        if leaf.ndim == 0 or "positions" in names or "index" in names:
            return NamedSharding(mesh, P())
        batch_axes = _batch_axes(mesh, leaf.shape[1])
        if "ssm" in names:  # (R, B, H, P, N)
            heads = "model" if leaf.shape[2] % model == 0 else None
            return NamedSharding(mesh, P(None, batch_axes, heads, None, None))
        if "conv" in names:  # (R, B, W-1, CH)
            ch = "model" if leaf.shape[3] % model == 0 else None
            return NamedSharding(mesh, P(None, batch_axes, None, ch))
        # kv slabs: (R, B, S_c, Hkv, Dh)
        if leaf.shape[3] % model == 0:
            return NamedSharding(mesh, P(None, batch_axes, None, "model", None))
        if leaf.shape[2] % model == 0:
            return NamedSharding(mesh, P(None, batch_axes, "model", None, None))
        return NamedSharding(mesh, P(None, batch_axes, None, None, None))

    return jax.tree_util.tree_map_with_path(shard, cache)


def decode_shardings(cfg: ModelConfig, inputs: PyTree, mesh: Mesh) -> PyTree:
    token_axes = _batch_axes(mesh, inputs["token"].shape[0])
    return {
        "token": NamedSharding(mesh, P(token_axes)),
        "cache": cache_shardings(cfg, inputs["cache"], mesh),
    }
