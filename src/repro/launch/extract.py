"""fedcgs-extract — config name → client features → one-shot global head.

The paper's deployment story ("leveraging pre-trained models") as ONE
command: pick any zoo config, wrap it as a frozen
:class:`~repro.fl.extractors.ModelExtractor`, stream synthetic
per-client token batches through extractor-forward → fold
(:class:`~repro.core.stats_pipeline.StatsPipeline` with ``extractor=``,
which reuses ``launch.stats_engine``'s streaming mesh path when
``--placement sharded``), derive the global statistics, and fit the
training-free GNB head — then score a held-out batch through the same
extractor + head to close the loop.

Examples:
    PYTHONPATH=src python -m repro.launch.extract --config whisper_tiny
    fedcgs-extract --config gemma_2b --placement sharded --backend fused
    PYTHONPATH=src python -m repro.launch.extract --smoke   # CI smoke
"""

from __future__ import annotations

import argparse
import json
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.classifier import LinearHead, gnb_head
from repro.core.statistics import FeatureStats, derive_global
from repro.core.stats_pipeline import StatsPipeline
from repro.fl.extractors import ModelExtractor, synthetic_token_clients
from repro.timing import timed

Array = jax.Array


def _client_batches(pooling: str, batches) -> List[Tuple[Array, Array]]:
    """Align labels to the pooling mode: per-token targets for ``tokens``,
    the final next-token id (one label per sequence) for ``mean``/``last``."""
    if pooling == "tokens":
        return list(batches)
    return [(toks, tgts[:, -1]) for toks, tgts in batches]


def run_extract(
    config: str = "whisper_tiny",
    *,
    pooling: str = "tokens",
    clients: int = 4,
    batches_per_client: int = 2,
    batch: int = 4,
    seq_len: int = 16,
    seed: int = 0,
    backend: str = "jnp",
    placement: str = "local",
    secure: bool = False,
    ridge: Optional[float] = None,
    reduced: bool = True,
) -> Dict[str, object]:
    """The whole one-shot pipeline; returns a JSON-able report."""
    ext = ModelExtractor(config, pooling=pooling, seed=seed, reduced=reduced)
    cfg = ext.cfg
    num_classes = cfg.vocab_size  # class = next-token id for every pooling

    mesh = None
    if placement == "sharded":
        from repro.launch.mesh import make_host_mesh

        mesh = make_host_mesh()

    raw = synthetic_token_clients(
        cfg, clients=clients, batches_per_client=batches_per_client,
        batch=batch, seq_len=seq_len, seed=seed,
    )
    cohort = [_client_batches(pooling, c) for c in raw]

    pipeline = StatsPipeline(
        num_classes,
        backend=backend,
        placement=placement,
        privacy="secure" if secure else "plain",
        mesh=mesh,
        extractor=ext,
    )
    def _round() -> FeatureStats:
        agg = pipeline.from_cohort(cohort)
        jax.block_until_ready(agg.A)
        return agg

    agg, dt_round = timed(_round)
    gstats = derive_global(agg)
    head, dt_head = timed(lambda: gnb_head(gstats, ridge=ridge))

    # close the loop: held-out batch → same extractor → GNB head accuracy
    holdout = _client_batches(
        pooling,
        synthetic_token_clients(
            cfg, clients=1, batches_per_client=1,
            batch=batch, seq_len=seq_len, seed=seed + 9973,
        )[0],
    )
    xh, yh = holdout[0]
    acc = float(head.accuracy(ext.features(xh), jnp.asarray(yh).reshape(-1)))

    rows = int(np.asarray(agg.N).sum())
    return {
        "config": config,
        "pooling": pooling,
        "feature_dim": ext.feature_dim,
        "num_classes": num_classes,
        "clients": clients,
        "rows_folded": rows,
        "backend": backend,
        "placement": placement,
        "secure": secure,
        "upload_floats_per_client": FeatureStats.upload_size(
            num_classes, ext.feature_dim
        ),
        "round_seconds": dt_round,
        "head_fit_seconds": dt_head,
        "holdout_accuracy": acc,
        "head_shape": list(np.asarray(head.W).shape),
    }


def fit_head_from_stats(stats: FeatureStats, *, ridge=None) -> LinearHead:
    """Aggregated statistics → the closed-form GNB head (re-export for
    callers that already hold a round's statistics)."""
    return gnb_head(derive_global(stats), ridge=ridge)


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--config", default="whisper_tiny",
                   help="any id repro.configs.get_config accepts")
    p.add_argument("--pooling", default="tokens",
                   choices=("tokens", "mean", "last"))
    p.add_argument("--clients", type=int, default=4)
    p.add_argument("--batches-per-client", type=int, default=2)
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--seq-len", type=int, default=16)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--backend", default="jnp", choices=("jnp", "fused"))
    p.add_argument("--placement", default="local", choices=("local", "sharded"))
    p.add_argument("--secure", action="store_true",
                   help="SecureAgg the per-client statistics")
    p.add_argument("--ridge", type=float, default=None)
    p.add_argument("--full-size", action="store_true",
                   help="use the config at full size (default: reduced)")
    p.add_argument("--smoke", action="store_true",
                   help="tiny CPU-friendly sizes (the CI smoke step)")
    args = p.parse_args(argv)

    kw = dict(
        pooling=args.pooling,
        clients=args.clients,
        batches_per_client=args.batches_per_client,
        batch=args.batch,
        seq_len=args.seq_len,
        seed=args.seed,
        backend=args.backend,
        placement=args.placement,
        secure=args.secure,
        ridge=args.ridge,
        reduced=not args.full_size,
    )
    if args.smoke:
        kw.update(clients=2, batches_per_client=2, batch=2, seq_len=8)

    report = run_extract(args.config, **kw)
    print(json.dumps(report, indent=2))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
