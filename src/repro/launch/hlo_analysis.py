"""Roofline-term extraction from compiled dry-run artifacts (§Roofline).

- compute term    = HLO_FLOPs / (chips × peak)        [cost_analysis]
- memory term     = HLO_bytes / (chips × HBM bw)      [cost_analysis]
- collective term = collective_bytes / (chips × ICI)  [parsed from HLO]

cost_analysis does NOT expose collective traffic, so we parse the
compiled (post-SPMD-partitioning) HLO text and sum OPERAND sizes of
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute.  Sizes come from the result-type annotation on the
op line; bytes counted are per-participant (the compiled module is the
per-device program, so these are bytes moved per chip per step).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12  # bf16
HBM_BW = 819e9  # bytes/s
ICI_BW = 50e9  # bytes/s/link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4,
    "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    """Total bytes of a result type like 'f32[16,128]' or a tuple."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dtype, dims = m.group(1), m.group(2)
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, int]
    count_by_kind: Dict[str, int]

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    @property
    def total_count(self) -> int:
        return sum(self.count_by_kind.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum result sizes of every collective op in (post-SPMD) HLO text."""
    bytes_by: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    count_by: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        # lines look like:  %name = f32[8,128]{1,0} all-reduce(...), replica_groups=...
        m = re.match(r"%?\S+\s*=\s*(\([^)]*\)|\S+)\s+([\w-]+)", stripped)
        if not m:
            continue
        op = m.group(2)
        if op.endswith("-start"):
            op = op[: -len("-start")]
        if op not in _COLLECTIVES:
            continue
        bytes_by[op] += _shape_bytes(m.group(1))
        count_by[op] += 1
    return CollectiveStats(bytes_by_kind=bytes_by, count_by_kind=count_by)


@dataclasses.dataclass
class Roofline:
    """All byte/FLOP counts are PER-DEVICE: ``cost_analysis`` runs on the
    post-SPMD-partitioning per-device module (verified empirically — a
    (1024,1024)@(1024,1024) matmul sharded 8-way reports 2·1024³/8), and
    the collective parse reads the same per-device program."""

    hlo_flops: float  # per-chip FLOPs per step
    hlo_bytes: float  # per-chip HBM bytes touched per step
    collective_bytes_per_chip: float
    chips: int
    model_flops: Optional[float] = None  # GLOBAL useful model FLOPs

    @property
    def compute_s(self) -> float:
        return self.hlo_flops / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.hlo_bytes / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_bytes_per_chip / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> Optional[float]:
        """MODEL_FLOPS / (per-chip HLO FLOPs × chips) — remat/redundancy."""
        if self.model_flops is None or self.hlo_flops == 0:
            return None
        return self.model_flops / (self.hlo_flops * self.chips)

    def as_dict(self) -> Dict:
        return {
            "hlo_flops": self.hlo_flops,
            "hlo_bytes": self.hlo_bytes,
            "collective_bytes_per_chip": self.collective_bytes_per_chip,
            "chips": self.chips,
            "model_flops": self.model_flops,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "useful_flops_ratio": self.useful_flops_ratio,
        }


def roofline_from_compiled(
    compiled, chips: int, model_flops: Optional[float] = None
) -> Roofline:
    """Loop-corrected terms via the HLO parser (``hlo_parse``).

    ``cost_analysis()`` counts while-loop bodies once, so a scanned
    48-layer stack under-reports ~48x; the parser multiplies by the
    compiler-annotated known_trip_count instead.
    """
    from repro.launch import hlo_parse

    costs = hlo_parse.analyze(compiled.as_text())
    return Roofline(
        hlo_flops=float(costs.flops),
        hlo_bytes=float(costs.bytes),
        collective_bytes_per_chip=float(costs.total_collective_bytes),
        chips=chips,
        model_flops=model_flops,
    )
