"""Jit-able step functions per architecture (DESIGN.md §3).

- ``train_step``  — LM loss fwd+bwd+optimizer update (train_4k).
- ``prefill_step``— forward + KV/SSM cache build (prefill_32k).
- ``serve_step``  — ONE token against a seq_len cache (decode shapes).
- ``stats_step``  — the paper's contribution at scale: fold a batch of
  final hidden states into the running FedCGS statistics (A, B, N) with
  class = next-token id.  The cross-shard summation that FedCGS calls
  "the server aggregation" is exactly the psum GSPMD inserts for the
  batch-sharded contributions.

Factories return pure functions; ``jit_step`` wires shardings.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.launch import io_specs
from repro.models import transformer as T
from repro.models.common import spec_shapes
from repro.models.config import InputShape, ModelConfig
from repro.optim import Optimizer, apply_updates
from repro.sharding import tree_shardings, use_mesh

PyTree = Any


# ---------------------------------------------------------------------------
# step factories
# ---------------------------------------------------------------------------


def make_train_step(
    cfg: ModelConfig,
    opt: Optimizer,
    *,
    remat: bool = True,
    proto_lambda: float = 0.0,
    moe_dispatch_shards: int = 1,
) -> Callable:
    def train_step(params, opt_state, batch, prototypes=None):
        def loss_fn(p):
            return T.lm_loss(
                p, cfg,
                batch["tokens"], batch["targets"],
                positions=batch.get("positions"),
                patches=batch.get("patches"),
                frames=batch.get("frames"),
                remat=remat,
                prototypes=prototypes,
                proto_lambda=proto_lambda,
                moe_dispatch_shards=moe_dispatch_shards,
            )

        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        updates, opt_state = opt.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        metrics = dict(metrics)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


def make_prefill_step(
    cfg: ModelConfig, *, cache_dtype=jnp.bfloat16, moe_dispatch_shards: int = 1
) -> Callable:
    def prefill_step(params, batch):
        hidden, cache = T.prefill(
            params, cfg,
            batch["tokens"],
            positions=batch.get("positions"),
            patches=batch.get("patches"),
            frames=batch.get("frames"),
            cache_dtype=cache_dtype,
            moe_dispatch_shards=moe_dispatch_shards,
        )
        # next-token logits for the LAST position only (what serving emits)
        logits = T.unembed(params, cfg, hidden[:, -1:])
        return logits[:, 0], cache

    return prefill_step


def make_serve_step(cfg: ModelConfig) -> Callable:
    def serve_step(params, batch):
        hidden, cache = T.decode_step(params, cfg, batch["token"], batch["cache"])
        logits = T.unembed(params, cfg, hidden[:, None])[:, 0]
        return logits, cache

    return serve_step


def make_stats_step(
    cfg: ModelConfig, *, moe_dispatch_shards: int = 1, fold_dtype=jnp.float32
) -> Callable:
    """FedCGS ClientStats over a token batch (class = next token).

    Big-vocab adaptation (DESIGN.md §6): A uses a scatter-add over the
    vocab dim — a (T, V) one-hot matmul would materialize 10^11 elements
    at train_4k shapes.  On-TPU, per-tile one-hot matmuls live in the
    Pallas kernel; at the XLA level scatter lowers fine and its FLOPs
    are negligible next to the backbone forward.
    """

    def stats_step(params, batch):
        # per-token feature rows via the Extractor protocol's models-layer
        # entry point (class = next token, so pooling="tokens")
        rows = T.features(
            params, cfg,
            batch["tokens"],
            pooling="tokens",
            positions=batch.get("positions"),
            patches=batch.get("patches"),
            frames=batch.get("frames"),
            remat=False,
            moe_dispatch_shards=moe_dispatch_shards,
        )
        # §Perf knob: fold in bf16 (halves scatter/Gram read traffic) with
        # f32 accumulation via preferred_element_type — the running (A, B)
        # stay f32 so the paper's exactness claim is unaffected at the
        # aggregate level (validated in tests at reduced scale).
        feats = rows.astype(fold_dtype)
        labels = batch["targets"].reshape(-1)
        stats = batch["stats"]
        A = stats["A"].at[labels].add(feats.astype(jnp.float32))
        B = stats["B"] + jax.lax.dot_general(
            feats, feats, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        N = stats["N"].at[labels].add(1.0)
        return {"A": A, "B": B, "N": N}

    return stats_step


# ---------------------------------------------------------------------------
# jit wiring
# ---------------------------------------------------------------------------


def opt_state_shardings(opt: Optimizer, param_specs, param_shardings, mesh: Mesh):
    """Optimizer-state shardings: moments like params, counters replicated."""
    shapes = spec_shapes(param_specs)
    state_shape = jax.eval_shape(opt.init, shapes)
    flat_params, _ = jax.tree_util.tree_flatten(param_shardings)
    by_shape = {}
    for spec_leaf, shard_leaf in zip(
        jax.tree_util.tree_leaves(shapes), flat_params
    ):
        by_shape.setdefault((spec_leaf.shape, str(spec_leaf.dtype)), shard_leaf)

    def assign(leaf):
        # moments share their parameter's shape (dtype may be f32)
        for (shape, _), shard in by_shape.items():
            if tuple(leaf.shape) == tuple(shape):
                return shard
        return NamedSharding(mesh, P())  # scalars / counters

    return jax.tree_util.tree_map(assign, state_shape)


def jit_step(
    step: Callable,
    mesh: Mesh,
    in_shardings,
    out_shardings=None,
    *,
    donate_argnums: Tuple[int, ...] = (),
    rules=None,
):
    """jit with (mesh, rules) activated for internal constrain() calls.

    ``rules`` overrides the logical-axis rule table (e.g. the §Perf
    act-shard knob maps "act_embed" -> ("model",)).
    """

    jitted = jax.jit(
        step,
        in_shardings=in_shardings,
        out_shardings=out_shardings,
        donate_argnums=donate_argnums,
    )

    class _Wrapped:
        def __init__(self):
            self._fn = jitted

        def lower(self, *args, **kwargs):
            with use_mesh(mesh, rules):
                return self._fn.lower(*args, **kwargs)

        def __call__(self, *args, **kwargs):
            with use_mesh(mesh, rules):
                return self._fn(*args, **kwargs)

    return _Wrapped()
