"""StatsPipeline — THE one way from (features, labels) to FeatureStats.

FedCGS's heterogeneity resistance rests on (A, B, N) being a *sum*
(paper §3, Table 4): any way of slicing the data — one array, a stream
of batches, a cohort of simulated clients, shards of a mesh — folds to
the same global statistic.  This module is the single data path that
exploits that: every producer in the repo (``core.statistics`` wrappers,
``launch.stats_engine``, ``fl.fedcgs``, the stats-consuming baselines)
routes through a :class:`StatsPipeline`, so backend, placement, and
privacy compose uniformly instead of living in per-call-site switch
combinations.

Inputs (one pipeline, three ingest shapes):

- :meth:`from_arrays`  — a single (features, labels) array pair;
- :meth:`from_batches` — an iterator of (features, labels) batches,
  folded into a running FeatureStats (datasets that never fit in device
  memory); ONE jit trace per distinct batch shape — ragged tails are
  padded up to the first-seen batch shape with zero features and label
  −1 rows, which provably contribute nothing to A, B, or N;
- :meth:`from_cohort`  — a sequence of simulated clients, each either
  an array pair or a batch iterator; per-client statistics are computed
  with the same fold and aggregated the way the knobs say.

``extractor=`` (the Extractor protocol: ``feature_dim`` +
``features(x) -> (rows, feature_dim)``, see ``repro.fl.extractors``)
lets all three ingest shapes accept RAW inputs (tokens, images): each
batch streams extractor-forward → fold as one per-batch step, then the
pipeline delegates to itself with ``extractor=None`` — so the fold and
finalize traces, and therefore the audited fold-0/finalize-1 psum
budgets, are byte-identical to the features-in path.  Labels ride
along flattened (``y.reshape(-1)``), which is the identity for (B,)
labels and the next-token alignment for ``pooling="tokens"`` (B, S)
targets.

Knob matrix (all orthogonal):

| knob        | values                | effect                                    |
|-------------|-----------------------|-------------------------------------------|
| ``backend`` | ``"auto"`` (default)  | per-shard sweep: XLA matmuls vs the       |
|             | | ``"jnp"`` | ``"fused"`` | single-pass Pallas engine (carry      |
|             |                       | variant ``kernels.client_stats_acc`` when |
|             |                       | streaming: in-place padded (M, N) folds). |
|             |                       | ``auto`` peeks the ingest shape and asks  |
|             |                       | ``repro.tune`` — the measured jnp-vs-fused|
|             |                       | winner for the (device, shape) bucket, or |
|             |                       | the crossover heuristic when untuned —    |
|             |                       | then delegates to that concrete backend,  |
|             |                       | so results are bitwise those of the       |
|             |                       | backend it picked.  Fused block sizes     |
|             |                       | come from the same tune cache (kernel     |
|             |                       | defaults on a miss).                      |
| ``placement``| ``"local"`` | ``"sharded"`` | this host vs row-sharded over a   |
|             |                       | mesh's client axes (``launch.stats_engine``; |
|             |                       | streaming keeps a per-shard running carry |
|             |                       | and issues ONE psum per cohort)           |
| ``privacy`` | ``"plain"`` | ``"secure"`` | aggregation sums raw statistics vs   |
|             |                       | SecureAgg pairwise-mask-then-sum.  The    |
|             |                       | privacy boundary of a cohort is always    |
|             |                       | the CLIENT (the paper's protocol) —       |
|             |                       | placement only moves where each client's  |
|             |                       | sweep runs.  A single sharded source      |
|             |                       | masks per shard instead; a single local   |
|             |                       | source has no peer to hide from and       |
|             |                       | ignores the knob by construction.         |

``interpret`` follows the kernels' convention (None => interpret off
TPU); ``mesh``/``client_axes``/``base_seed``/``mask_scale`` parameterize
the sharded and secure cells and are ignored elsewhere.

Dropout axis (orthogonal to all of the above): ``dropout=`` names the
parties lost mid-round — client indices for :meth:`from_cohort`, shard
indices for a single sharded source — and ``min_survivors=`` is the
Shamir threshold t (default: majority for ``secure``; plain rounds,
which reconstruct nothing, enforce it only when given).  A ``plain``
round simply sums the survivors; a ``secure`` round receives only the
survivors' MASKED
views, reconstructs the dropped parties' pair-seed secrets from any
t survivor shares (``core.shamir``, the Bonawitz §4 recovery), subtracts
the dangling masks, and returns the exact survivor statistics.  Any
survivor set of size ≥ t is tolerated; smaller raises instead of
degrading.  A local single source has no parties to lose, so setting
``dropout`` there is an error rather than a silent no-op.

Equivalence across every cell of the matrix — streaming × sharded ×
secure × fused against the materialized one-shot ``from_arrays`` — is
pinned by ``tests/test_stats_pipeline.py`` (hypothesis over batch
splits; subprocess multi-shard mesh; a collective-count check that the
streaming sharded path performs exactly one psum per cohort).
"""

from __future__ import annotations

import functools
import itertools
from typing import Any, Iterable, Iterator, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core.statistics import FeatureStats, aggregate
from repro.obs import trace

Array = jax.Array
Batch = Tuple[Any, Any]
# a cohort client: a materialized (features, labels) pair or a batch stream
ClientData = Union[Batch, Iterable[Batch]]

BACKENDS = ("auto", "jnp", "fused")
PLACEMENTS = ("local", "sharded")
PRIVACY = ("plain", "secure")


def _stats_jnp(
    features: Array, labels: Array, num_classes: int, *, accum_dtype=jnp.float32
) -> FeatureStats:
    """ClientStats(D_i) from Algorithm 1 as MXU matmuls (no scatter).

    ``one_hot`` maps out-of-range labels (the −1 padding convention) to
    all-zero rows, so padded rows contribute nothing to A, B, or N.
    """
    f = features.astype(accum_dtype)
    onehot = jax.nn.one_hot(labels, num_classes, dtype=accum_dtype)  # (n, C)
    return FeatureStats(A=onehot.T @ f, B=f.T @ f, N=jnp.sum(onehot, axis=0))


def _stats_fused(
    features: Array,
    labels: Array,
    num_classes: int,
    *,
    interpret: Optional[bool] = None,
) -> FeatureStats:
    from repro import tune
    from repro.kernels import client_stats  # deferred: keeps core jnp-only

    f = jnp.asarray(features)
    block_n, block_d = tune.stats_blocks(
        int(f.shape[0]), int(f.shape[1]), num_classes
    )
    A, B, N = client_stats(
        f, jnp.asarray(labels).astype(jnp.int32), num_classes,
        interpret=interpret, block_n=block_n, block_d=block_d,
    )
    return FeatureStats(A=A, B=B, N=N)


@functools.partial(jax.jit, static_argnames=("num_classes", "accum_dtype"))
def _fold_jnp(
    carry: FeatureStats,
    features: Array,
    labels: Array,
    num_classes: int,
    accum_dtype=jnp.float32,
) -> FeatureStats:
    """One streaming fold step — jit caches one trace per batch shape."""
    return carry + _stats_jnp(features, labels, num_classes, accum_dtype=accum_dtype)


# Jitted hot paths the invariant-audit suite (repro.analysis.budgets)
# reaches by name: the retrace sentinel counts cache entries on these,
# so renaming one must break the audit loudly, not silently skip it.
AUDITED_JITS = {"stats_pipeline.fold_jnp": _fold_jnp}


def _pad_batch(
    features: Array, labels: Array, rows: int
) -> Tuple[Array, Array]:
    """Pad a ragged tail batch up to ``rows`` with zero/−1 rows."""
    pad = rows - features.shape[0]
    if pad <= 0:
        return features, labels
    f = jnp.pad(features, ((0, pad), (0, 0)))
    y = jnp.pad(
        jnp.asarray(labels).astype(jnp.int32), (0, pad), constant_values=-1
    )
    return f, y


def canonical_batch_stream(batches: Iterable[Batch]) -> Iterator[Tuple[Array, Array]]:
    """Normalize a batch stream to the one-trace-per-shape contract.

    Ragged batches are padded (zero features, label −1) up to the
    FIRST-seen batch's row count so the whole stream reuses one jitted
    fold trace; oversized batches pass through untouched (their own
    cached trace).  Both the local and the mesh-sharded streaming folds
    consume this, so the padding contract can't drift between layers.
    """
    it = iter(batches)
    first = next(it, None)
    if first is None:
        return
    rows = jnp.asarray(first[0]).shape[0]
    for fb, yb in itertools.chain([first], it):
        fb = jnp.asarray(fb)
        yb = jnp.asarray(yb).astype(jnp.int32)
        if fb.shape[0] <= rows:
            yield _pad_batch(fb, yb, rows)
        else:
            yield fb, yb


class StatsPipeline:
    """The single (features, labels) → aggregated FeatureStats path."""

    def __init__(
        self,
        num_classes: int,
        *,
        backend: str = "auto",
        placement: str = "local",
        privacy: str = "plain",
        mesh=None,
        client_axes: Tuple[str, ...] = ("data",),
        base_seed: int = 0,
        mask_scale: float = 1e3,
        accum_dtype=jnp.float32,
        interpret: Optional[bool] = None,
        dropout: Optional[Sequence[int]] = None,
        min_survivors: Optional[int] = None,
        extractor=None,
    ):
        if backend not in BACKENDS:
            raise ValueError(f"backend must be one of {BACKENDS}, got {backend!r}")
        if placement not in PLACEMENTS:
            raise ValueError(
                f"placement must be one of {PLACEMENTS}, got {placement!r}"
            )
        if privacy not in PRIVACY:
            raise ValueError(f"privacy must be one of {PRIVACY}, got {privacy!r}")
        if placement == "sharded" and accum_dtype != jnp.float32:
            raise ValueError(
                "sharded placement accumulates in float32 (the mesh engine's "
                "carry/psum dtype); accum_dtype is a local-placement knob"
            )
        dropped = tuple(sorted({int(d) for d in dropout})) if dropout else ()
        if any(d < 0 for d in dropped):
            raise ValueError(f"dropout indices must be >= 0, got {dropped}")
        if min_survivors is not None and min_survivors < 1:
            raise ValueError(f"min_survivors must be >= 1, got {min_survivors}")
        if extractor is not None and not (
            hasattr(extractor, "features") and hasattr(extractor, "feature_dim")
        ):
            raise TypeError(
                "extractor must satisfy the Extractor protocol "
                "(feature_dim + features(x)); see repro.fl.extractors"
            )
        self.num_classes = num_classes
        self.backend = backend
        self.placement = placement
        self.privacy = privacy
        self.mesh = mesh
        self.client_axes = client_axes
        self.base_seed = base_seed
        self.mask_scale = mask_scale
        self.accum_dtype = accum_dtype
        self.interpret = interpret
        self.dropout = dropped
        self.min_survivors = min_survivors
        self.extractor = extractor

    # -- knob helpers -------------------------------------------------------

    @property
    def use_kernel(self) -> bool:
        if self.backend == "auto":
            raise RuntimeError(
                "backend='auto' is resolved per ingest (shape peek → "
                "tune.stats_backend) before any kernel choice is read — "
                "reaching use_kernel unresolved is a pipeline bug"
            )
        return self.backend == "fused"

    def _resolved(self, rows: int, dim: int) -> "StatsPipeline":
        """Pin ``backend="auto"`` to the tuner's verdict for this shape."""
        if self.backend != "auto":
            return self
        from repro import tune

        return self.replace(
            backend=tune.stats_backend(int(rows), int(dim), self.num_classes)
        )

    @property
    def secure(self) -> bool:
        return self.privacy == "secure"

    def _engine_kwargs(self) -> dict:
        return dict(
            mesh=self.mesh,
            client_axes=self.client_axes,
            use_kernel=self.use_kernel,
            secure=self.secure,
            base_seed=self.base_seed,
            mask_scale=self.mask_scale,
            interpret=self.interpret,
            dropped_shards=self.dropout,
            min_survivors=self.min_survivors,
        )

    def _require_parties_for_dropout(self) -> None:
        if self.dropout and self.placement == "local":
            raise ValueError(
                "dropout needs parties to lose: a local single source has "
                "none — use from_cohort() or placement='sharded' (where "
                "dropout indexes shards)"
            )

    # -- raw-input extraction (the Extractor protocol) ----------------------

    def _extract(self, x: Any, y: Any) -> Tuple[Array, Array]:
        """One extractor-forward step: raw batch → aligned feature rows."""
        feats = self.extractor.features(x)
        labels = jnp.asarray(y).astype(jnp.int32).reshape(-1)
        if labels.shape[0] != feats.shape[0]:
            raise ValueError(
                f"extractor emitted {feats.shape[0]} feature rows but the "
                f"batch carries {labels.shape[0]} labels — labels must be "
                "one per feature row (flattened (B, S) targets for "
                'pooling="tokens", (B,) labels otherwise)'
            )
        return feats, labels

    def _extracting(self, batches: Iterable[Batch]) -> Iterator[Tuple[Array, Array]]:
        """Stream extractor-forward → fold: one raw batch resident at a time."""
        for x, y in batches:
            yield self._extract(x, y)

    def _extracted_client(self, client: ClientData) -> Iterator[Tuple[Array, Array]]:
        """One raw client as a LAZY feature stream: extraction happens when
        the pipeline consumes this client, so only one client's features
        are ever resident."""
        def gen():
            if _is_array_pair(client):
                yield self._extract(client[0], client[1])
            else:
                yield from self._extracting(client)

        return gen()

    def _featurized(self) -> "StatsPipeline":
        """This pipeline with extraction already done (the delegate)."""
        return self.replace(extractor=None)

    # -- single array pair --------------------------------------------------

    def from_arrays(self, features: Array, labels: Array) -> FeatureStats:
        """Materialized one-shot sweep — the reference cell of the matrix.

        With ``extractor=`` set, ``features`` is the RAW input batch
        (e.g. (B, S) tokens) and extraction runs first.
        """
        if self.extractor is not None:
            return self._featurized().from_arrays(*self._extract(features, labels))
        if self.backend == "auto":
            f = jnp.asarray(features)
            return self._resolved(f.shape[0], f.shape[1]).from_arrays(f, labels)
        self._require_parties_for_dropout()
        if self.placement == "sharded":
            from repro.launch.stats_engine import sharded_client_stats

            return sharded_client_stats(
                features, labels, self.num_classes, **self._engine_kwargs()
            )
        if self.use_kernel:
            return _stats_fused(
                features, labels, self.num_classes, interpret=self.interpret
            )
        return _stats_jnp(
            features, labels, self.num_classes, accum_dtype=self.accum_dtype
        )

    # -- streaming batches --------------------------------------------------

    def from_batches(
        self,
        batches: Iterable[Batch],
        *,
        feature_dim: Optional[int] = None,
    ) -> FeatureStats:
        """Fold a batch stream into a running FeatureStats.

        The device never holds more than one batch plus the carry; ragged
        tails are padded to the first-seen batch shape so the whole
        stream costs one jit trace.  ``feature_dim`` is only needed for
        an empty stream (the zero statistic's shape).

        With ``extractor=`` set, batches are RAW ``(x, y)`` pairs and
        each one streams extractor-forward → fold as one step; the
        delegate's fold traces (and psum budget) are unchanged.
        """
        if self.extractor is not None:
            return self._featurized().from_batches(
                self._extracting(batches),
                feature_dim=(
                    feature_dim if feature_dim is not None
                    else self.extractor.feature_dim
                ),
            )
        if self.backend == "auto":
            # resolve on the FIRST batch's shape (what the fold kernel
            # sees), then delegate with the peeked batch re-chained
            it = iter(batches)
            first = next(it, None)
            if first is None:
                return self.replace(backend="jnp").from_batches(
                    iter(()), feature_dim=feature_dim
                )
            fb = jnp.asarray(first[0])
            return self._resolved(fb.shape[0], fb.shape[1]).from_batches(
                itertools.chain([first], it), feature_dim=feature_dim
            )
        self._require_parties_for_dropout()
        if self.placement == "sharded":
            from repro.launch.stats_engine import streaming_sharded_stats

            return streaming_sharded_stats(
                batches, self.num_classes, feature_dim=feature_dim,
                **self._engine_kwargs(),
            )

        it = iter(batches)
        first = next(it, None)
        if first is None:
            if feature_dim is None:
                raise ValueError(
                    "empty batch stream: pass feature_dim= for the zero statistic"
                )
            return FeatureStats.zeros(self.num_classes, feature_dim)

        rows, d = jnp.asarray(first[0]).shape
        stream = canonical_batch_stream(itertools.chain([first], it))

        if self.use_kernel:
            return self._fold_fused(stream, d, rows=rows)

        with trace.span("pipeline.fold", backend="jnp",
                        feature_dim=int(d), batch_rows=int(rows)) as sp:
            carry = FeatureStats.zeros(self.num_classes, d, self.accum_dtype)
            batches_folded = 0
            for fb, yb in stream:
                carry = _fold_jnp(
                    carry, fb, yb, self.num_classes,
                    accum_dtype=self.accum_dtype,
                )
                batches_folded += 1
            sp.set(batches=batches_folded)
        return carry

    def _fold_fused(
        self,
        stream: Iterator[Tuple[Array, Array]],
        d: int,
        rows: Optional[int] = None,
    ) -> FeatureStats:
        """Streaming fold through the carry/accumulate Pallas kernel.

        The carry stays in the kernel's padded (M, N) layout across the
        whole stream — updated in place via input-donation — and is
        unpacked to (A, B, N) exactly once at the end.  Block sizes come
        from the tune cache at the (batch rows, d, C) bucket (kernel
        defaults on a miss); the carry layout is allocated with the same
        ``block_d`` the folds use, so they cannot desync.
        """
        from repro import tune
        from repro.kernels import (
            client_stats_acc,
            stats_carry_finalize,
            stats_carry_init,
        )

        block_n, block_d = tune.stats_acc_blocks(
            self.num_classes, d, rows=rows
        )
        with trace.span("pipeline.fold", backend="fused",
                        feature_dim=int(d)) as sp:
            m, n = stats_carry_init(self.num_classes, d, block_d=block_d)
            batches_folded = 0
            for fb, yb in stream:
                m, n = client_stats_acc(
                    m, n, fb, yb, interpret=self.interpret,
                    block_n=block_n, block_d=block_d,
                )
                batches_folded += 1
            sp.set(batches=batches_folded)
        with trace.span("pipeline.finalize", backend="fused",
                        feature_dim=int(d)):
            A, B, N = stats_carry_finalize(m, n, self.num_classes, d)
        return FeatureStats(A=A, B=B, N=N)

    # -- simulated-client cohorts -------------------------------------------

    def from_cohort(
        self,
        clients: Sequence[ClientData],
        *,
        feature_dim: Optional[int] = None,
    ) -> FeatureStats:
        """Aggregate statistics over a cohort of simulated clients.

        Each client is a (features, labels) pair or an iterator of such
        batches.  The privacy boundary of a cohort is always the CLIENT
        (the paper's protocol): with ``privacy="secure"``, per-client
        statistics are pairwise-masked and summed regardless of
        placement, so ``sharded`` changes only WHERE each client's sweep
        runs (row-sharded over the mesh), never who gets masked.
        A plain sharded cohort instead concatenates or streams everyone
        through the mesh engine and reduces with one psum.

        ``dropout`` indexes CLIENTS here: dropped clients vanish before
        upload.  A plain round sums the survivors; a secure round gets
        only the survivors' masked views and runs the Shamir mask
        recovery (``core.secure_agg.recover_round``) — both land on the
        exact statistics of the surviving clients, provided at least
        ``min_survivors`` remain (default: a majority for secure rounds;
        plain rounds enforce the knob only when it is given).

        With ``extractor=`` set, clients hold RAW data; each becomes a
        lazy feature stream so only one client's feature matrix is
        resident at a time, then the cohort aggregates as usual.
        """
        if self.extractor is not None:
            wrapped = [self._extracted_client(c) for c in clients]
            return self._featurized().from_cohort(
                wrapped,
                feature_dim=(
                    feature_dim if feature_dim is not None
                    else self.extractor.feature_dim
                ),
            )
        clients = list(clients)
        if not clients:
            raise ValueError("from_cohort() needs at least one client")
        if self.backend == "auto":
            # one verdict for the whole cohort, from the first client's
            # shape — clients of one round are statistically alike, and
            # a uniform backend keeps the sharded/secure paths on one
            # trace family
            peeked, clients = _peek_client_shape(clients)
            resolved = (
                self._resolved(*peeked)
                if peeked is not None
                else self.replace(backend="jnp")
            )
            return resolved.from_cohort(clients, feature_dim=feature_dim)
        from repro.core.secure_agg import round_plan

        k = len(clients)
        dropped = self.dropout
        # validates dropout ids and the survivor threshold for BOTH
        # privacy cells (plain rounds honor an explicit min_survivors
        # too; only the default differs — see secure_agg.round_plan)
        survivors, threshold = round_plan(
            k, dropped, min_survivors=self.min_survivors, secure=self.secure
        )
        if self.secure:
            from repro.core.secure_agg import (
                masked_survivor_views,
                recover_round,
                secure_sum,
                setup_round,
            )

            # each client's own sweep is plain — masks exist between
            # clients, not inside one client's computation
            plain = self.replace(privacy="plain", dropout=None)
            per_client = {
                i: plain._single_source(clients[i], feature_dim=feature_dim)
                for i in survivors
            }
            if not dropped:
                return secure_sum(
                    [per_client[i] for i in survivors],
                    base_seed=self.base_seed, mask_scale=self.mask_scale,
                )
            setup = setup_round(k, threshold, base_seed=self.base_seed)
            views = masked_survivor_views(
                per_client, survivors, k,
                base_seed=self.base_seed, mask_scale=self.mask_scale,
            )
            return recover_round(
                views, survivors, setup, mask_scale=self.mask_scale
            )
        alive = self if not dropped else self.replace(dropout=None)
        clients = [clients[i] for i in survivors]
        if self.placement == "sharded":
            from repro.launch.stats_engine import sharded_cohort_stats

            return sharded_cohort_stats(
                clients, self.num_classes, feature_dim=feature_dim,
                **alive._engine_kwargs(),
            )
        per_client = [
            alive.client_statistics(c, feature_dim=feature_dim)
            for c in clients
        ]
        return aggregate(per_client)

    def _single_source(
        self, client: ClientData, *, feature_dim: Optional[int] = None
    ) -> FeatureStats:
        """One source's statistics under the CURRENT placement knob."""
        if _is_array_pair(client):
            return self.from_arrays(jnp.asarray(client[0]), jnp.asarray(client[1]))
        return self.from_batches(client, feature_dim=feature_dim)

    def client_statistics(
        self, client: ClientData, *, feature_dim: Optional[int] = None
    ) -> FeatureStats:
        """One client's (A, B, N) — local sweep regardless of placement.

        This is what each party computes BEFORE any aggregation (or
        masking) happens, so it is always a local computation; the
        placement knob only governs how the cohort aggregate is formed.
        """
        if self.extractor is not None:
            return self._featurized().client_statistics(
                self._extracted_client(client),
                feature_dim=(
                    feature_dim if feature_dim is not None
                    else self.extractor.feature_dim
                ),
            )
        if _is_array_pair(client):
            f, y = client
            if self.backend == "auto":
                fa = jnp.asarray(f)
                return self._resolved(
                    fa.shape[0], fa.shape[1]
                ).client_statistics(client, feature_dim=feature_dim)
            if self.use_kernel:
                return _stats_fused(
                    jnp.asarray(f), jnp.asarray(y), self.num_classes,
                    interpret=self.interpret,
                )
            return _stats_jnp(
                jnp.asarray(f), jnp.asarray(y), self.num_classes,
                accum_dtype=self.accum_dtype,
            )
        # one party's own sweep: no placement, no peers to drop
        local = (
            self
            if self.placement == "local" and not self.dropout
            else self.replace(placement="local", dropout=None)
        )
        return local.from_batches(client, feature_dim=feature_dim)

    def class_means(
        self, features: Array, labels: Array
    ) -> Tuple[Array, Array]:
        """Per-class mean features and counts — the A/N slice.

        Mean-only consumers (prototype baselines) skip the (d, d) Gram
        matrix entirely on the jnp backend; the fused kernel is a
        single k-sweep for all three statistics, so there it costs
        nothing extra.  Empty classes keep a zero mean.
        """
        if self.backend == "auto":
            f = jnp.asarray(features)
            return self._resolved(f.shape[0], f.shape[1]).class_means(
                features, labels
            )
        if self.use_kernel:
            stats = self.from_arrays(features, labels)
            A, N = stats.A, stats.N
        else:
            f = jnp.asarray(features).astype(self.accum_dtype)
            onehot = jax.nn.one_hot(
                labels, self.num_classes, dtype=self.accum_dtype
            )
            A, N = onehot.T @ f, jnp.sum(onehot, axis=0)
        return A / jnp.maximum(N, 1.0)[:, None], N

    def replace(self, **overrides) -> "StatsPipeline":
        kwargs = dict(
            backend=self.backend,
            placement=self.placement,
            privacy=self.privacy,
            mesh=self.mesh,
            client_axes=self.client_axes,
            base_seed=self.base_seed,
            mask_scale=self.mask_scale,
            accum_dtype=self.accum_dtype,
            interpret=self.interpret,
            dropout=self.dropout,
            min_survivors=self.min_survivors,
            extractor=self.extractor,
        )
        kwargs.update(overrides)
        return StatsPipeline(self.num_classes, **kwargs)


def class_conditional_moments(
    pipeline: StatsPipeline, features: Array, labels: Array
) -> Tuple[Array, Array, Array]:
    """Per-class (mean (C, d), covariance (C, d, d), count (C,)).

    What the moment-uploading baselines (CCVR et al.) need from a
    client's features — derived from per-class FeatureStats sweeps of
    the SAME pipeline instead of bespoke numpy loops, so their moment
    math inherits the backend knob.  Each class subset is CENTERED
    (float64 host mean) before its single-class sweep, so ``B`` is the
    centred scatter matrix and  cov = B / (n − 1)  directly — the
    uncentred identity (B − n μμᵀ) would cancel catastrophically in
    f32 when the common-mode mean dominates the per-class spread.
    Classes with < 1 (mean) / < 2 (cov) samples stay zero.
    """
    import numpy as np

    feats = np.asarray(features)
    y = np.asarray(labels)
    C, d = pipeline.num_classes, feats.shape[1]
    # single-class local sweep of the centred subset: B = scatter matrix
    single = StatsPipeline(
        1, backend=pipeline.backend, interpret=pipeline.interpret,
        accum_dtype=pipeline.accum_dtype,
    )
    mu = np.zeros((C, d), feats.dtype)
    cov = np.zeros((C, d, d), feats.dtype)
    counts = np.zeros((C,), np.int64)
    for c in range(C):
        sel = feats[y == c]
        n = len(sel)
        counts[c] = n
        if n < 1:
            continue
        m = sel.mean(axis=0, dtype=np.float64)
        mu[c] = m
        if n >= 2:
            centered = (sel - m).astype(feats.dtype)
            stats = single.from_arrays(
                jnp.asarray(centered), jnp.zeros((n,), jnp.int32)
            )
            cov[c] = np.asarray(stats.B) / (n - 1)
    return mu, cov, counts


def _peek_client_shape(clients):
    """((rows, dim) or None, clients) — first client's batch shape.

    A batch-stream first client is consumed one batch deep and handed
    back re-chained, so the peek is invisible to the caller.
    """
    first = clients[0]
    if _is_array_pair(first):
        f = jnp.asarray(first[0])
        return (f.shape[0], f.shape[1]), clients
    it = iter(first)
    b0 = next(it, None)
    if b0 is None:
        return None, clients
    f = jnp.asarray(b0[0])
    rest = list(clients[1:])
    return (f.shape[0], f.shape[1]), [itertools.chain([b0], it)] + rest


def _is_array_pair(client: ClientData) -> bool:
    """A (features, labels) pair of array-likes — tuple OR list, both
    historically accepted — vs a batch iterable."""
    if isinstance(client, (tuple, list)) and len(client) == 2:
        f = client[0]
        return hasattr(f, "shape") and getattr(f, "ndim", 0) == 2
    return False
