"""FedCGS sufficient statistics (paper §3, Eqs. 3-8).

Each client computes, from frozen-backbone features ``F = f(D_i)``:

- ``A_i[j] = Σ_{x∈D_i, y=j} f(x)``  — per-class feature sums, (C, d)
- ``B_i   = Σ_{x∈D_i}  f(x)ᵀ f(x)`` — uncentred second moment,  (d, d)
- ``N_i[j] = |D_i^j|``               — per-class counts,          (C,)

The server aggregates by *summation only* (SecureAgg-compatible) and
derives the exact global prototypes and shared covariance:

    μ^j = A^j / N^j                                         (Eq. 6)
    Σ   = (B − μ̄ᵀĀ − Āᵀμ̄ + N μ̄ᵀμ̄) / (N − 1)                (Eq. 7)

where μ̄ = A / N is the global (class-agnostic) feature mean (Eq. 8).

These are *algebraic identities* — the result is independent of how the
data is partitioned across clients, which is the paper's central
heterogeneity-resistance claim (Table 4).  ``tests/test_statistics.py``
verifies partition-invariance with hypothesis.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Optional, Sequence

import jax
import jax.numpy as jnp

Array = jax.Array


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class FeatureStats:
    """The FedCGS sufficient-statistics triple (A, B, N).

    A pytree, so it flows through jit / psum / tree arithmetic directly.
    ``N`` is float so that SecureAgg masks (real-valued) apply uniformly.
    """

    A: Array  # (C, d) per-class feature sums
    B: Array  # (d, d) uncentred second moment  Σ fᵀf
    N: Array  # (C,)  per-class counts

    @property
    def num_classes(self) -> int:
        return self.A.shape[0]

    @property
    def feature_dim(self) -> int:
        return self.A.shape[1]

    def __add__(self, other: "FeatureStats") -> "FeatureStats":
        return FeatureStats(self.A + other.A, self.B + other.B, self.N + other.N)

    @staticmethod
    def zeros(num_classes: int, feature_dim: int, dtype=jnp.float32) -> "FeatureStats":
        return FeatureStats(
            A=jnp.zeros((num_classes, feature_dim), dtype),
            B=jnp.zeros((feature_dim, feature_dim), dtype),
            N=jnp.zeros((num_classes,), dtype),
        )

    def num_elements(self) -> int:
        """Uploaded parameter count — the paper's (C+d)·d + C."""
        C, d = self.A.shape
        return (C + d) * d + C


def client_statistics(
    features: Array,
    labels: Array,
    num_classes: int,
    *,
    accum_dtype=jnp.float32,
) -> FeatureStats:
    """ClientStats(D_i) from Algorithm 1, reformulated for the MXU.

    The per-class scatter-sum A is computed as ``onehot(y)ᵀ F`` and the
    Gram matrix as ``Fᵀ F`` — both matmuls, no scatter (hardware
    adaptation noted in DESIGN.md §6).

    Args:
      features: (n, d) frozen-backbone features for this client's data.
      labels:   (n,) int class labels in [0, num_classes).
    """
    f = features.astype(accum_dtype)
    onehot = jax.nn.one_hot(labels, num_classes, dtype=accum_dtype)  # (n, C)
    A = onehot.T @ f  # (C, d)
    B = f.T @ f  # (d, d)
    N = jnp.sum(onehot, axis=0)  # (C,)
    return FeatureStats(A=A, B=B, N=N)


def client_statistics_fused(
    features: Array,
    labels: Array,
    num_classes: int,
    *,
    interpret: Optional[bool] = None,
) -> FeatureStats:
    """ClientStats via the fused single-pass Pallas engine.

    Same contract as :func:`client_statistics`; one kernel computes A, B,
    and N in a single sweep over the feature rows (``repro.kernels``).
    """
    from repro.kernels import client_stats  # deferred: keeps core jnp-only

    A, B, N = client_stats(
        features, jnp.asarray(labels).astype(jnp.int32), num_classes,
        interpret=interpret,
    )
    return FeatureStats(A=A, B=B, N=N)


def aggregate(stats: Iterable[FeatureStats]) -> FeatureStats:
    """Server aggregation (Algorithm 1 lines 4-11): pure summation."""
    stats = list(stats)
    if not stats:
        raise ValueError("aggregate() needs at least one client's statistics")
    out = stats[0]
    for s in stats[1:]:
        out = out + s
    return out


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class GlobalStatistics:
    """Derived global quantities: prototypes, shared covariance, priors."""

    mu: Array  # (C, d) class prototypes μ^j
    sigma: Array  # (d, d) shared empirical covariance Σ
    pi: Array  # (C,)  class priors π_j = N^j / N
    counts: Array  # (C,)  N^j (kept for personalization / diagnostics)

    @property
    def num_classes(self) -> int:
        return self.mu.shape[0]

    @property
    def feature_dim(self) -> int:
        return self.mu.shape[1]


def derive_global(stats: FeatureStats, *, min_count: float = 1e-12) -> GlobalStatistics:
    """Compute (μ, Σ, π) from aggregated (A, B, N) — Eqs. 6-8.

    Classes with zero observed count get a zero prototype and -inf-safe
    prior (π_j = 0); the GNB head gives them log π_j = -inf so they are
    never predicted, matching the centralized behaviour.
    """
    A, B, N = stats.A, stats.B, stats.N
    n_total = jnp.sum(N)
    # Eq. 6 — per-class prototypes; guard empty classes.
    mu = A / jnp.maximum(N, min_count)[:, None]
    mu = jnp.where((N > 0)[:, None], mu, 0.0)
    # Eq. 8 — global mean from the *summed* A (not the per-class means).
    a_total = jnp.sum(A, axis=0)  # (d,)
    mean = a_total / jnp.maximum(n_total, min_count)
    # Eq. 7 — shared covariance.  μ̄ᵀĀ + Āᵀμ̄ = outer(mean, a) + outer(a, mean).
    cross = jnp.outer(mean, a_total)
    sigma = (B - cross - cross.T + n_total * jnp.outer(mean, mean)) / jnp.maximum(
        n_total - 1.0, 1.0
    )
    pi = N / jnp.maximum(n_total, min_count)
    return GlobalStatistics(mu=mu, sigma=sigma, pi=pi, counts=N)


def centralized_statistics(
    features: Array, labels: Array, num_classes: int
) -> GlobalStatistics:
    """Ground-truth (μ̂, Σ̂) computed on pooled data — the paper's Table 4
    reference. Uses the direct definition (centered sum of outer products),
    *not* the A/B identity, so the exactness test compares two genuinely
    different computations."""
    f = features.astype(jnp.float32)
    onehot = jax.nn.one_hot(labels, num_classes, dtype=jnp.float32)
    N = jnp.sum(onehot, axis=0)
    mu = (onehot.T @ f) / jnp.maximum(N, 1e-12)[:, None]
    mean = jnp.mean(f, axis=0)
    centered = f - mean[None, :]
    sigma = (centered.T @ centered) / jnp.maximum(f.shape[0] - 1.0, 1.0)
    pi = N / f.shape[0]
    return GlobalStatistics(mu=mu, sigma=sigma, pi=pi, counts=N)


def statistics_deviation(
    ours: GlobalStatistics, ref: GlobalStatistics
) -> tuple[Array, Array]:
    """(Δμ, ΔΣ) L2 errors, the paper's Table 4 metric."""
    dmu = jnp.linalg.norm(ours.mu - ref.mu)
    dsigma = jnp.linalg.norm(ours.sigma - ref.sigma)
    return dmu, dsigma


# ---------------------------------------------------------------------------
# Streaming / batched accumulation — clients with datasets too large for one
# forward pass fold batches into a running FeatureStats.
# ---------------------------------------------------------------------------


def accumulate_batch(
    running: FeatureStats, features: Array, labels: Array
) -> FeatureStats:
    """Fold one batch of (features, labels) into a running statistic."""
    batch = client_statistics(features, labels, running.num_classes)
    return running + batch


def client_statistics_batched(
    feature_batches: Sequence[Array],
    label_batches: Sequence[Array],
    num_classes: int,
    feature_dim: Optional[int] = None,
) -> FeatureStats:
    d = feature_dim if feature_dim is not None else feature_batches[0].shape[-1]
    out = FeatureStats.zeros(num_classes, d)
    for f, y in zip(feature_batches, label_batches):
        out = accumulate_batch(out, f, y)
    return out
