"""FedCGS sufficient statistics (paper §3, Eqs. 3-8).

Each client computes, from frozen-backbone features ``F = f(D_i)``:

- ``A_i[j] = Σ_{x∈D_i, y=j} f(x)``  — per-class feature sums, (C, d)
- ``B_i   = Σ_{x∈D_i}  f(x)ᵀ f(x)`` — uncentred second moment,  (d, d)
- ``N_i[j] = |D_i^j|``               — per-class counts,          (C,)

The server aggregates by *summation only* (SecureAgg-compatible) and
derives the exact global prototypes and shared covariance:

    μ^j = A^j / N^j                                         (Eq. 6)
    Σ   = (B − μ̄ᵀĀ − Āᵀμ̄ + N μ̄ᵀμ̄) / (N − 1)                (Eq. 7)

where μ̄ = A / N is the global (class-agnostic) feature mean (Eq. 8).

These are *algebraic identities* — the result is independent of how the
data is partitioned across clients, which is the paper's central
heterogeneity-resistance claim (Table 4).  ``tests/test_statistics.py``
verifies partition-invariance with hypothesis.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Iterable, Optional, Sequence

import jax
import jax.numpy as jnp

Array = jax.Array


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class FeatureStats:
    """The FedCGS sufficient-statistics triple (A, B, N).

    A pytree, so it flows through jit / psum / tree arithmetic directly.
    ``N`` is float so that SecureAgg masks (real-valued) apply uniformly.
    """

    A: Array  # (C, d) per-class feature sums
    B: Array  # (d, d) uncentred second moment  Σ fᵀf
    N: Array  # (C,)  per-class counts

    @property
    def num_classes(self) -> int:
        return self.A.shape[0]

    @property
    def feature_dim(self) -> int:
        return self.A.shape[1]

    def __add__(self, other: "FeatureStats") -> "FeatureStats":
        # tree_map, not field-by-field: a future field addition shows up
        # here automatically and can't silently desync from the SecureAgg
        # mask tree (which flattens the same registered dataclass).
        return jax.tree_util.tree_map(jnp.add, self, other)

    @staticmethod
    def zeros(num_classes: int, feature_dim: int, dtype=jnp.float32) -> "FeatureStats":
        return FeatureStats(
            A=jnp.zeros((num_classes, feature_dim), dtype),
            B=jnp.zeros((feature_dim, feature_dim), dtype),
            N=jnp.zeros((num_classes,), dtype),
        )

    def num_elements(self) -> int:
        """Uploaded parameter count — the paper's (C+d)·d + C."""
        C, d = self.A.shape
        return FeatureStats.upload_size(C, d)

    @staticmethod
    def upload_size(num_classes: int, feature_dim: int) -> int:
        """(C+d)·d + C from shapes alone — no arrays materialized."""
        return (num_classes + feature_dim) * feature_dim + num_classes


def client_statistics(
    features: Array,
    labels: Array,
    num_classes: int,
    *,
    accum_dtype=jnp.float32,
) -> FeatureStats:
    """ClientStats(D_i) from Algorithm 1, reformulated for the MXU.

    Thin wrapper over :class:`repro.core.stats_pipeline.StatsPipeline`
    (backend="jnp") — the per-class scatter-sum A is computed as
    ``onehot(y)ᵀ F`` and the Gram matrix as ``Fᵀ F``, both matmuls, no
    scatter (hardware adaptation noted in DESIGN.md §6).

    Args:
      features: (n, d) frozen-backbone features for this client's data.
      labels:   (n,) int class labels in [0, num_classes).
    """
    from repro.core.stats_pipeline import StatsPipeline  # deferred: no cycle

    return StatsPipeline(
        num_classes, backend="jnp", accum_dtype=accum_dtype
    ).from_arrays(features, labels)


def client_statistics_fused(
    features: Array,
    labels: Array,
    num_classes: int,
    *,
    interpret: Optional[bool] = None,
) -> FeatureStats:
    """ClientStats via the fused single-pass Pallas engine.

    Same contract as :func:`client_statistics`; thin wrapper over the
    pipeline's ``backend="fused"`` cell — one kernel computes A, B, and
    N in a single sweep over the feature rows (``repro.kernels``).
    """
    from repro.core.stats_pipeline import StatsPipeline  # deferred: no cycle

    return StatsPipeline(
        num_classes, backend="fused", interpret=interpret
    ).from_arrays(features, labels)


def aggregate(stats: Iterable[FeatureStats]) -> FeatureStats:
    """Server aggregation (Algorithm 1 lines 4-11): pure summation.

    One tree_map over all clients at once — each leaf is summed in a
    single expression instead of a Python chain of pairwise adds.
    """
    stats = list(stats)
    if not stats:
        raise ValueError("aggregate() needs at least one client's statistics")
    return jax.tree_util.tree_map(
        lambda *leaves: functools.reduce(jnp.add, leaves), *stats
    )


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class GlobalStatistics:
    """Derived global quantities: prototypes, shared covariance, priors."""

    mu: Array  # (C, d) class prototypes μ^j
    sigma: Array  # (d, d) shared empirical covariance Σ
    pi: Array  # (C,)  class priors π_j = N^j / N
    counts: Array  # (C,)  N^j (kept for personalization / diagnostics)

    @property
    def num_classes(self) -> int:
        return self.mu.shape[0]

    @property
    def feature_dim(self) -> int:
        return self.mu.shape[1]


def derive_global(stats: FeatureStats, *, min_count: float = 1e-12) -> GlobalStatistics:
    """Compute (μ, Σ, π) from aggregated (A, B, N) — Eqs. 6-8.

    Classes with zero observed count get a zero prototype and -inf-safe
    prior (π_j = 0); the GNB head gives them log π_j = -inf so they are
    never predicted, matching the centralized behaviour.
    """
    A, B, N = stats.A, stats.B, stats.N
    n_total = jnp.sum(N)
    # Eq. 6 — per-class prototypes; guard empty classes.
    mu = A / jnp.maximum(N, min_count)[:, None]
    mu = jnp.where((N > 0)[:, None], mu, 0.0)
    # Eq. 8 — global mean from the *summed* A (not the per-class means).
    a_total = jnp.sum(A, axis=0)  # (d,)
    mean = a_total / jnp.maximum(n_total, min_count)
    # Eq. 7 — shared covariance.  μ̄ᵀĀ + Āᵀμ̄ = outer(mean, a) + outer(a, mean).
    cross = jnp.outer(mean, a_total)
    sigma = (B - cross - cross.T + n_total * jnp.outer(mean, mean)) / jnp.maximum(
        n_total - 1.0, 1.0
    )
    pi = N / jnp.maximum(n_total, min_count)
    return GlobalStatistics(mu=mu, sigma=sigma, pi=pi, counts=N)


def centralized_statistics(
    features: Array, labels: Array, num_classes: int
) -> GlobalStatistics:
    """Ground-truth (μ̂, Σ̂) computed on pooled data — the paper's Table 4
    reference. Uses the direct definition (centered sum of outer products),
    *not* the A/B identity, so the exactness test compares two genuinely
    different computations."""
    f = features.astype(jnp.float32)
    onehot = jax.nn.one_hot(labels, num_classes, dtype=jnp.float32)
    N = jnp.sum(onehot, axis=0)
    mu = (onehot.T @ f) / jnp.maximum(N, 1e-12)[:, None]
    mean = jnp.mean(f, axis=0)
    centered = f - mean[None, :]
    sigma = (centered.T @ centered) / jnp.maximum(f.shape[0] - 1.0, 1.0)
    pi = N / f.shape[0]
    return GlobalStatistics(mu=mu, sigma=sigma, pi=pi, counts=N)


def statistics_deviation(
    ours: GlobalStatistics, ref: GlobalStatistics
) -> tuple[Array, Array]:
    """(Δμ, ΔΣ) L2 errors, the paper's Table 4 metric."""
    dmu = jnp.linalg.norm(ours.mu - ref.mu)
    dsigma = jnp.linalg.norm(ours.sigma - ref.sigma)
    return dmu, dsigma


# ---------------------------------------------------------------------------
# Streaming / batched accumulation — thin wrappers over the pipeline's
# streaming fold (one jitted fold per batch shape, ragged tails padded
# with label −1; see core.stats_pipeline).
# ---------------------------------------------------------------------------


def accumulate_batch(
    running: FeatureStats, features: Array, labels: Array
) -> FeatureStats:
    """Fold one batch of (features, labels) into a running statistic."""
    from repro.core.stats_pipeline import _fold_jnp  # deferred: no cycle

    return _fold_jnp(running, features, labels, running.num_classes)


def client_statistics_batched(
    feature_batches: Sequence[Array],
    label_batches: Sequence[Array],
    num_classes: int,
    feature_dim: Optional[int] = None,
) -> FeatureStats:
    from repro.core.stats_pipeline import StatsPipeline  # deferred: no cycle

    return StatsPipeline(num_classes).from_batches(
        zip(feature_batches, label_batches), feature_dim=feature_dim
    )
