"""t-of-K Shamir secret sharing over GF(2³¹ − 1) for SecureAgg dropout recovery.

The dropout-recovery half of Bonawitz et al. 2017 (§4): every client
Shamir-shares its pair-seed secret to its K peers at round setup, so any
``threshold`` survivors can hand the server enough shares to reconstruct
a dropped client's secret and regenerate — then subtract — the pairwise
masks the dropped client left dangling in the partial sum.  This module
is the field machinery; the protocol lives in :mod:`repro.core.secure_agg`.

Field: the Mersenne prime p = 2³¹ − 1.  Every secret, share, and derived
pair seed is a field element — 32-bit seed material, exactly what
``jax.random.key`` consumes.  All arithmetic is vectorized ``jnp``
``uint64`` under a local :func:`jax.experimental.enable_x64` scope
(products of two field elements stay < 2⁶², so ``(a * b) % p`` is
overflow-free); the public API takes/returns numpy ``uint32`` so callers
never depend on the x64 flag.

Key agreement: a textbook Diffie-Hellman stand-in over the same field
(generator 7, a primitive root of p — the Lehmer-RNG multiplier base).
Client i publishes ``pk_i = 7^{u_i}``; the pair seed
``s_ij = pk_j^{u_i} = pk_i^{u_j} = 7^{u_i·u_j}`` is computable by both
endpoints but by the server only AFTER reconstructing one endpoint's
secret from ≥ threshold shares.  31 bits is of course not
cryptographically hard — the point is the *structure*: recovery must go
through share reconstruction, exactly as in the real protocol.

Shares are (x, y) pairs with x = 1..K; :func:`reconstruct_secret` is
Lagrange interpolation at 0 and is exact for ANY subset of ≥ threshold
shares (property-tested in ``tests/test_shamir.py``).
"""

from __future__ import annotations

from typing import Tuple

import contextlib

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64


@contextlib.contextmanager
def _host_field_scope():
    """uint64 field arithmetic, evaluated NOW even under an active trace.

    The protocol helpers are host-side by contract (setup/recovery run at
    the server), but the sharded engines may first touch them while a
    shard_map body is being traced — ``ensure_compile_time_eval`` keeps
    the numpy boundary an eager constant instead of a leaked tracer.
    """
    with enable_x64(), jax.ensure_compile_time_eval():
        yield

PRIME = (1 << 31) - 1  # Mersenne prime M31
GENERATOR = 7  # primitive root mod PRIME
MAX_SHARES = PRIME - 1  # shares live at x = 1..K; any K < p works

_MAGIC = b"SHAM1"


def _mulmod(a, b):
    """(a * b) mod p for uint64 field elements — products < 2⁶² fit."""
    return (a * b) % jnp.uint64(PRIME)


def _powmod(base, exp):
    """base^exp mod p, square-and-multiply over the 31 exponent bits.

    Broadcasts like ``base * exp``; both are uint64 field elements.
    """
    base = jnp.asarray(base, jnp.uint64) % jnp.uint64(PRIME)
    exp = jnp.asarray(exp, jnp.uint64)
    result = jnp.ones(jnp.broadcast_shapes(base.shape, exp.shape), jnp.uint64)
    for _ in range(31):  # exponents are field elements: < 2³¹
        result = jnp.where(exp & 1 == 1, _mulmod(result, base), result)
        base = _mulmod(base, base)
        exp = exp >> 1
    return result


def _invmod(a):
    """Multiplicative inverse via Fermat: a^(p−2) mod p (0 maps to 0)."""
    return _powmod(a, jnp.uint64(PRIME - 2))


def field_uniform(key: jax.Array, shape: Tuple[int, ...]) -> np.ndarray:
    """Uniform-ish field elements in [0, p) from a jax PRNG key."""
    with _host_field_scope():
        bits = jax.random.bits(key, shape, jnp.uint64)
        return np.asarray(bits % jnp.uint64(PRIME), np.uint32)


def split_secret(
    secrets,
    threshold: int,
    num_shares: int,
    *,
    key: jax.Array,
) -> Tuple[np.ndarray, np.ndarray]:
    """Shamir-share field-element secrets into ``num_shares`` (x, y) pairs.

    ``secrets`` is any array of field elements (shape ``batch``); every
    secret gets its own independent degree-(threshold−1) polynomial with
    constant term the secret, evaluated at x = 1..num_shares (Horner,
    vectorized over shares × batch).  Returns ``(xs, ys)``:
    ``xs`` (num_shares,) uint32 and ``ys`` (num_shares, *batch) uint32,
    where ``ys[j]`` is share x=j+1 of every secret.  Any ``threshold``
    of the rows reconstruct; fewer reveal nothing about the secrets.
    """
    if not 1 <= threshold <= num_shares:
        raise ValueError(
            f"need 1 <= threshold <= num_shares, got t={threshold}, K={num_shares}"
        )
    if num_shares > MAX_SHARES:
        raise ValueError(f"num_shares must be < field size {PRIME}")
    with _host_field_scope():
        s = jnp.asarray(np.asarray(secrets), jnp.uint64) % jnp.uint64(PRIME)
        coeffs = jnp.asarray(
            field_uniform(key, (threshold - 1,) + s.shape), jnp.uint64
        )
        xs = jnp.arange(1, num_shares + 1, dtype=jnp.uint64)
        xb = xs.reshape((num_shares,) + (1,) * s.ndim)
        # Horner from the highest coefficient down to the secret
        acc = jnp.zeros((num_shares,) + s.shape, jnp.uint64)
        for m in range(threshold - 2, -1, -1):
            acc = (_mulmod(acc, xb) + coeffs[m]) % jnp.uint64(PRIME)
        ys = (_mulmod(acc, xb) + s) % jnp.uint64(PRIME)
        return np.asarray(xs, np.uint32), np.asarray(ys, np.uint32)


def reconstruct_secret(xs, ys) -> np.ndarray:
    """Lagrange-interpolate the secrets at x = 0 from ≥ threshold shares.

    ``xs`` (t,) distinct share abscissae, ``ys`` (t, *batch) the matching
    share values.  Exact for any subset of at least ``threshold`` shares
    of the same secret (extra shares are consistent and only
    over-determine the polynomial).  Returns uint32 field elements of
    shape ``batch``.
    """
    xs = np.asarray(xs, np.uint64)
    if xs.ndim != 1 or xs.size == 0:
        raise ValueError("xs must be a non-empty 1-d array of share indices")
    if len(np.unique(xs)) != len(xs):
        raise ValueError("duplicate share indices: each x may appear once")
    with _host_field_scope():
        x = jnp.asarray(xs, jnp.uint64) % jnp.uint64(PRIME)
        y = jnp.asarray(np.asarray(ys), jnp.uint64) % jnp.uint64(PRIME)
        t = x.shape[0]
        eye = np.eye(t, dtype=bool)
        xj = jnp.broadcast_to(x[None, :], (t, t))
        diff = (xj + jnp.uint64(PRIME) - x[:, None]) % jnp.uint64(PRIME)
        num_f = jnp.where(eye, jnp.uint64(1), xj)
        den_f = jnp.where(eye, jnp.uint64(1), diff)
        lam_num = jnp.ones((t,), jnp.uint64)
        lam_den = jnp.ones((t,), jnp.uint64)
        for j in range(t):  # modular row products (jnp.prod would overflow)
            lam_num = _mulmod(lam_num, num_f[:, j])
            lam_den = _mulmod(lam_den, den_f[:, j])
        lam = _mulmod(lam_num, _invmod(lam_den))  # (t,) Lagrange weights at 0
        lamb = lam.reshape((t,) + (1,) * (y.ndim - 1))
        terms = _mulmod(lamb, y)
        secret = jnp.zeros(y.shape[1:], jnp.uint64)
        for i in range(t):  # incremental mod keeps the sum < 2³²
            secret = (secret + terms[i]) % jnp.uint64(PRIME)
        return np.asarray(secret, np.uint32)


def _powmod_host(base, exp) -> np.ndarray:
    """Pure-numpy base^exp mod p (broadcasts like ``base * exp``).

    Same square-and-multiply as :func:`_powmod` but immune to EVERY jax
    trace context: eager ``shard_map`` bodies (``check_rep``'s rewrite
    tracer lifts even constant-only jnp ops, and
    ``ensure_compile_time_eval`` cannot escape it) may derive pair seeds
    mid-trace.  Cross-parity with the jnp path is pinned in
    ``tests/test_shamir.py``.
    """
    base = np.asarray(base, np.uint64) % np.uint64(PRIME)
    exp = np.asarray(exp, np.uint64)
    base, exp = np.broadcast_arrays(base, exp)
    base, exp = base.copy(), exp.copy()
    result = np.ones(base.shape, np.uint64)
    for _ in range(31):  # exponents are field elements: < 2³¹
        result = np.where(exp & 1 == 1, (result * base) % np.uint64(PRIME),
                          result)
        base = (base * base) % np.uint64(PRIME)
        exp >>= np.uint64(1)
    return result


def dh_public(secrets) -> np.ndarray:
    """pk = GENERATOR^secret mod p — the published half of key agreement."""
    return _powmod_host(GENERATOR, secrets).astype(np.uint32)


def dh_shared(secret, peer_public) -> np.ndarray:
    """Pair seed pk_peer^secret = GENERATOR^(u·v) — symmetric in the pair."""
    return _powmod_host(peer_public, secret).astype(np.uint32)


# ---------------------------------------------------------------------------
# Serialization — what a client actually puts on the wire per peer.
# ---------------------------------------------------------------------------


def serialize_shares(xs: np.ndarray, ys: np.ndarray) -> bytes:
    """Pack an (xs, ys) share bundle into bytes (versioned, shape-tagged)."""
    xs = np.ascontiguousarray(np.asarray(xs, np.uint32))
    ys = np.ascontiguousarray(np.asarray(ys, np.uint32))
    if xs.ndim != 1 or ys.shape[:1] != xs.shape:
        raise ValueError("ys must have one leading row per entry of xs")
    header = np.asarray([len(xs), ys.ndim] + list(ys.shape[1:]), np.uint32)
    return (
        _MAGIC
        + np.uint32(header.size).tobytes()
        + header.tobytes()
        + xs.tobytes()
        + ys.tobytes()
    )


def deserialize_shares(data: bytes) -> Tuple[np.ndarray, np.ndarray]:
    """Inverse of :func:`serialize_shares` (exact round-trip, tested)."""
    if data[: len(_MAGIC)] != _MAGIC:
        raise ValueError("not a serialized share bundle (bad magic)")
    off = len(_MAGIC)
    (hsize,) = np.frombuffer(data, np.uint32, 1, off)
    off += 4
    header = np.frombuffer(data, np.uint32, int(hsize), off)
    off += 4 * int(hsize)
    k, ndim = int(header[0]), int(header[1])
    batch = tuple(int(v) for v in header[2:])
    if len(batch) != ndim - 1:
        raise ValueError("corrupt share bundle header")
    xs = np.frombuffer(data, np.uint32, k, off).copy()
    off += 4 * k
    count = k * int(np.prod(batch, dtype=np.int64)) if ndim > 1 else k
    ys = np.frombuffer(data, np.uint32, count, off).reshape((k,) + batch).copy()
    return xs, ys
