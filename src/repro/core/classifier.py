"""Training-free Gaussian Naive Bayes head (paper Eq. 10-11, appendix Eq. 13-14).

Shared-covariance Gaussian class-conditionals + class priors give a
*linear* decision rule:

    w_j = Σ⁻¹ μ^j
    b_j = log π_j − ½ μ^jᵀ Σ⁻¹ μ^j

(The paper's Eq. 11 prints ``b_j = log π_j − ½ μᵀ Σ μ`` — a typo; the
appendix derivation Eq. 13 makes clear the quadratic form uses Σ⁻¹.
We implement the correct form and verify against explicit Gaussian
log-densities in tests.)

Numerics: Σ is symmetrized and ridge-regularized (Σ + εI) before the
solve; we use Cholesky (SPD) with an eigenvalue-floor fallback.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.statistics import GlobalStatistics

Array = jax.Array


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class LinearHead:
    """W (C, d) + b (C,) — the classifier produced by FedCGS."""

    W: Array
    b: Array

    def logits(self, features: Array) -> Array:
        return features @ self.W.T + self.b

    def predict(self, features: Array) -> Array:
        return jnp.argmax(self.logits(features), axis=-1)

    def accuracy(self, features: Array, labels: Array) -> Array:
        return jnp.mean((self.predict(features) == labels).astype(jnp.float32))


def _solve_spd(sigma: Array, rhs: Array, ridge: float) -> Array:
    """Solve (Σ + ridge·I) x = rhs via Cholesky."""
    d = sigma.shape[0]
    sym = 0.5 * (sigma + sigma.T) + ridge * jnp.eye(d, dtype=sigma.dtype)
    chol = jnp.linalg.cholesky(sym)
    return jax.scipy.linalg.cho_solve((chol, True), rhs)


def gnb_head(
    stats: GlobalStatistics,
    *,
    ridge: Optional[float] = None,
    prior_floor: float = 1e-30,
) -> LinearHead:
    """Configure the parameter-free classifier from global statistics.

    Args:
      stats: (μ, Σ, π) from :func:`repro.core.statistics.derive_global`.
      ridge: Tikhonov term added to Σ. Defaults to 1e-4 · mean(diag Σ),
        scale-invariant so the head works for any backbone's feature scale.
    """
    mu, sigma, pi = stats.mu, stats.sigma, stats.pi
    if ridge is None:
        ridge = 1e-4 * float(jnp.mean(jnp.diag(sigma)))
        ridge = max(ridge, 1e-8)
    # W = Σ⁻¹ μᵀ solved for all classes at once: (d, C)
    Wt = _solve_spd(sigma, mu.T, ridge)
    W = Wt.T  # (C, d)
    # b_j = log π_j − ½ μ^jᵀ Σ⁻¹ μ^j ; the quadratic form reuses W.
    quad = jnp.sum(mu * W, axis=1)  # μ^jᵀ Σ⁻¹ μ^j
    b = jnp.log(jnp.maximum(pi, prior_floor)) - 0.5 * quad
    return LinearHead(W=W, b=b)


def gnb_log_posterior(
    stats: GlobalStatistics, features: Array, *, ridge: Optional[float] = None
) -> Array:
    """Full log p(y|x) (Eq. 10) — softmax over the linear logits."""
    head = gnb_head(stats, ridge=ridge)
    return jax.nn.log_softmax(head.logits(features), axis=-1)


# ---------------------------------------------------------------------------
# Reference implementation via explicit Gaussian log densities — used by
# tests to confirm the closed-form W, b match Eq. 10 exactly.
# ---------------------------------------------------------------------------


def gaussian_posterior_reference(
    stats: GlobalStatistics, features: Array, ridge: float
) -> Array:
    """log p(y=j | f) from N(f | μ^j, Σ) densities (numerically explicit)."""
    d = stats.feature_dim
    sigma = 0.5 * (stats.sigma + stats.sigma.T) + ridge * jnp.eye(d)
    chol = jnp.linalg.cholesky(sigma)

    def logpdf_one_class(mu_j):
        diff = features - mu_j[None, :]  # (n, d)
        z = jax.scipy.linalg.solve_triangular(chol, diff.T, lower=True)  # (d, n)
        maha = jnp.sum(z * z, axis=0)
        logdet = 2.0 * jnp.sum(jnp.log(jnp.diag(chol)))
        return -0.5 * (maha + logdet + d * jnp.log(2 * jnp.pi))

    logpdf = jax.vmap(logpdf_one_class)(stats.mu)  # (C, n)
    log_prior = jnp.log(jnp.maximum(stats.pi, 1e-30))[:, None]
    return jax.nn.log_softmax((logpdf + log_prior).T, axis=-1)  # (n, C)


# ---------------------------------------------------------------------------
# LM-stats head (beyond-paper, DESIGN.md §3): class = next-token id.
# The same (A, B, N) over final hidden states with C = vocab yields a
# training-free language-model head.  Only difference is scale (C up to
# 256k), so the solve returns W sharded like an unembedding matrix.
# ---------------------------------------------------------------------------


def lm_head_from_stats(
    stats: GlobalStatistics, *, ridge: Optional[float] = None
) -> LinearHead:
    """Alias with LM-appropriate defaults (no prior floor surprises:
    unseen tokens get -inf-ish bias exactly like unseen classes)."""
    return gnb_head(stats, ridge=ridge)
