"""FedCGS aggregation as a mesh collective (DESIGN.md §3).

The paper's server loop — "sum every client's (A_i, B_i, N_i)" — is an
all-reduce over the client axis.  Here clients are assigned to the
("pod", "data") mesh shards; each shard computes the statistics of ITS
cohort's examples locally and a single ``psum`` over the whole
FeatureStats tree realizes the server aggregation.  SecureAgg composes:
masks cancel INSIDE the psum, so the reduction is literally the
protocol's trusted aggregator.

``distributed_client_stats`` is the shard_map entry point (explicit
collectives — auditable); the jit path in ``launch.steps.stats_step``
lets GSPMD insert the same psum implicitly.  Tests assert both agree
with the centralized oracle.

``use_kernel=True`` routes each shard's local sweep through the fused
single-pass Pallas engine (``repro.kernels.client_stats``) instead of
the jnp one-hot formulation — the production path on TPU.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.statistics import FeatureStats
from repro.sharding import shard_map

Array = jax.Array


def _local_stats(
    features: Array,
    labels: Array,
    num_classes: int,
    *,
    use_kernel: bool = False,
    interpret: Optional[bool] = None,
) -> FeatureStats:
    """One shard's sweep — the pipeline's per-shard building blocks.

    Both paths map padding labels (−1) to zero contributions: the kernel
    masks them in-register, the jnp one_hot maps them to all-zero rows.
    """
    from repro.core.stats_pipeline import _stats_fused, _stats_jnp

    if use_kernel:
        return _stats_fused(features, labels, num_classes, interpret=interpret)
    return _stats_jnp(features, labels, num_classes)


def shard_index(mesh: Mesh, axes: Tuple[str, ...]) -> Array:
    """Flat shard id inside a shard_map body (row-major over ``axes``)."""
    me = jax.lax.axis_index(axes[0])
    for a in axes[1:]:
        me = me * mesh.shape[a] + jax.lax.axis_index(a)
    return me


def apply_pair_masks(
    stat: FeatureStats,
    me: Array,
    n_shards: int,
    *,
    base_seed: int = 0,
    mask_scale: float = 1e3,
    seeds: Optional[np.ndarray] = None,
) -> FeatureStats:
    """Add this shard's pairwise-cancelling SecureAgg masks to ``stat``.

    Shard ``me`` adds +m_(me,other) for every other > me and −m_(other,me)
    for every other < me; summed over all shards the masks cancel exactly
    (up to float associativity).  Usable inside any shard_map body that
    wants to mask BEFORE a psum — both the one-shot and the streaming
    engines route through here.

    Mask seeds come from ``secure_agg.pair_seed_matrix`` (the DH-agreed
    per-pair seeds, embedded as a trace constant), so a host-side
    ``recover_partial_sum`` regenerates a lost shard's masks
    bit-identically to what this traced body applied.  Callers tracing
    this inside a shard_map body should precompute the matrix once at
    closure-build time and pass it via ``seeds=``.
    """
    if seeds is None:
        from repro.core.secure_agg import pair_seed_matrix

        seeds = pair_seed_matrix(base_seed, n_shards)
    seeds = jnp.asarray(np.asarray(seeds))  # (K, K) u32 trace constant

    def add_pair_mask(s, other):
        key = jax.random.key(seeds[me, other])
        leaves, treedef = jax.tree_util.tree_flatten(s)
        keys = jax.random.split(key, len(leaves))
        sign = jnp.where(me < other, 1.0, -1.0)
        masked = [
            leaf + sign * mask_scale * jax.random.normal(k, leaf.shape, leaf.dtype)
            for k, leaf in zip(keys, leaves)
        ]
        return jax.tree_util.tree_unflatten(treedef, masked)

    def body(i, s):
        return jax.lax.cond(i == me, lambda x: x, lambda x: add_pair_mask(x, i), s)

    return jax.lax.fori_loop(0, n_shards, body, stat)


def drop_shard_contribution(
    stat: FeatureStats, me: Array, dropped_shards: Tuple[int, ...]
) -> FeatureStats:
    """Zero ``stat`` on shards in ``dropped_shards`` (inside shard_map).

    Models a shard that went dark mid-round: its (masked) contribution
    never reaches the psum.  ``dropped_shards`` is static, so surviving
    shards trace to a no-op.
    """
    if not dropped_shards:
        return stat
    lost = jnp.isin(me, jnp.asarray(dropped_shards))
    return jax.tree_util.tree_map(
        lambda leaf: jnp.where(lost, jnp.zeros_like(leaf), leaf), stat
    )


def distributed_client_stats(
    features: Array,
    labels: Array,
    num_classes: int,
    mesh: Mesh,
    *,
    client_axes: Tuple[str, ...] = ("data",),
    use_kernel: bool = False,
    interpret: Optional[bool] = None,
    dropped_shards: Tuple[int, ...] = (),
) -> FeatureStats:
    """Global (A, B, N) from batch-sharded (features, labels).

    features: (n, d) sharded over ``client_axes``; labels: (n,).
    Returns fully-replicated global statistics — every shard (every
    "client") holds the aggregate, which is what the one-extra-download
    personalization round distributes anyway.  ``dropped_shards`` models
    shards lost mid-round: their rows contribute nothing, so the result
    is the exact statistics of the surviving shards' data.
    """
    axes = tuple(a for a in client_axes if a in mesh.axis_names)
    dropped = tuple(sorted({int(d) for d in dropped_shards}))
    if dropped:
        from repro.core.secure_agg import round_plan

        n_shards = 1
        for a in axes:
            n_shards *= mesh.shape[a]
        round_plan(n_shards, dropped, secure=False)  # reject bogus ids

    def shard_fn(f_shard: Array, y_shard: Array) -> FeatureStats:
        local = _local_stats(
            f_shard, y_shard, num_classes,
            use_kernel=use_kernel, interpret=interpret,
        )
        local = drop_shard_contribution(local, shard_index(mesh, axes), dropped)
        return jax.lax.psum(local, axes)  # ONE collective over the tree

    in_specs = (P(axes), P(axes))
    out_specs = FeatureStats(A=P(), B=P(), N=P())
    fn = shard_map(
        shard_fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=not use_kernel,  # pallas_call has no replication rule
    )
    return fn(features, labels)


def masked_distributed_stats(
    features: Array,
    labels: Array,
    num_classes: int,
    mesh: Mesh,
    *,
    base_seed: int = 0,
    mask_scale: float = 1e3,
    client_axes: Tuple[str, ...] = ("data",),
    use_kernel: bool = False,
    interpret: Optional[bool] = None,
    dropped_shards: Tuple[int, ...] = (),
    min_survivors: Optional[int] = None,
) -> FeatureStats:
    """SecureAgg-composed variant: each shard adds pairwise-cancelling
    masks BEFORE the psum, so no unmasked per-shard statistic ever exists
    outside its shard.  The psum output equals the unmasked aggregate up
    to float associativity (tested).

    ``dropped_shards`` models masking parties lost mid-round: their
    masked contributions never reach the psum, leaving the survivor ×
    dropped pair masks un-cancelled in it.  The server-side Shamir
    recovery (``secure_agg.recover_partial_sum``) reconstructs the lost
    shards' seed secrets from the surviving shards' shares — any
    ``min_survivors`` (default: majority) of them suffice — regenerates
    those masks, and subtracts them, yielding the exact statistics of
    the surviving shards' data.  Still exactly ONE collective.
    """
    from repro.core.secure_agg import pair_seed_matrix, round_plan

    axes = tuple(a for a in client_axes if a in mesh.axis_names)
    dropped = tuple(sorted({int(d) for d in dropped_shards}))
    # axis extents are static properties of the mesh (jax.lax.axis_size
    # only exists on newer jax)
    n_shards = 1
    for a in axes:
        n_shards *= mesh.shape[a]
    # validate the round BEFORE sweeping any data (bogus shard ids and
    # sub-threshold survivor sets must not silently return full stats)
    survivors, threshold = round_plan(
        n_shards, dropped, min_survivors=min_survivors
    )
    # derived OUTSIDE the trace: check_rep's rewrite tracer would lift it
    seeds = pair_seed_matrix(base_seed, n_shards)

    def shard_fn(f_shard: Array, y_shard: Array) -> FeatureStats:
        local = _local_stats(
            f_shard, y_shard, num_classes,
            use_kernel=use_kernel, interpret=interpret,
        )
        me = shard_index(mesh, axes)
        masked = apply_pair_masks(
            local, me, n_shards,
            base_seed=base_seed, mask_scale=mask_scale, seeds=seeds,
        )
        masked = drop_shard_contribution(masked, me, dropped)
        return jax.lax.psum(masked, axes)

    fn = shard_map(
        shard_fn, mesh=mesh, in_specs=(P(axes), P(axes)),
        out_specs=FeatureStats(A=P(), B=P(), N=P()),
        check_rep=not use_kernel,
    )
    out = fn(features, labels)
    if dropped:
        from repro.core.secure_agg import recover_partial_sum, setup_round

        setup = setup_round(n_shards, threshold, base_seed=base_seed)
        out = recover_partial_sum(
            out, survivors, setup, mask_scale=mask_scale
        )
    return out
