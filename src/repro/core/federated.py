"""FedCGS aggregation as a mesh collective (DESIGN.md §3).

The paper's server loop — "sum every client's (A_i, B_i, N_i)" — is an
all-reduce over the client axis.  Here clients are assigned to the
("pod", "data") mesh shards; each shard computes the statistics of ITS
cohort's examples locally and a single ``psum`` over the whole
FeatureStats tree realizes the server aggregation.  SecureAgg composes:
masks cancel INSIDE the psum, so the reduction is literally the
protocol's trusted aggregator.

``distributed_client_stats`` is the shard_map entry point (explicit
collectives — auditable); the jit path in ``launch.steps.stats_step``
lets GSPMD insert the same psum implicitly.  Tests assert both agree
with the centralized oracle.

``use_kernel=True`` routes each shard's local sweep through the fused
single-pass Pallas engine (``repro.kernels.client_stats``) instead of
the jnp one-hot formulation — the production path on TPU.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.statistics import FeatureStats, client_statistics_fused
from repro.sharding import shard_map

Array = jax.Array


def _local_stats(
    features: Array, labels: Array, num_classes: int, *, use_kernel: bool = False
) -> FeatureStats:
    if use_kernel:
        return client_statistics_fused(features, labels, num_classes)
    f = features.astype(jnp.float32)
    # one_hot maps out-of-range labels (padding rows' -1) to all-zeros,
    # so padded rows contribute nothing to A, B, or N.
    onehot = jax.nn.one_hot(labels, num_classes, dtype=jnp.float32)
    return FeatureStats(A=onehot.T @ f, B=f.T @ f, N=jnp.sum(onehot, axis=0))


def distributed_client_stats(
    features: Array,
    labels: Array,
    num_classes: int,
    mesh: Mesh,
    *,
    client_axes: Tuple[str, ...] = ("data",),
    use_kernel: bool = False,
) -> FeatureStats:
    """Global (A, B, N) from batch-sharded (features, labels).

    features: (n, d) sharded over ``client_axes``; labels: (n,).
    Returns fully-replicated global statistics — every shard (every
    "client") holds the aggregate, which is what the one-extra-download
    personalization round distributes anyway.
    """
    axes = tuple(a for a in client_axes if a in mesh.axis_names)

    def shard_fn(f_shard: Array, y_shard: Array) -> FeatureStats:
        local = _local_stats(f_shard, y_shard, num_classes, use_kernel=use_kernel)
        return jax.lax.psum(local, axes)  # ONE collective over the tree

    in_specs = (P(axes), P(axes))
    out_specs = FeatureStats(A=P(), B=P(), N=P())
    fn = shard_map(
        shard_fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=not use_kernel,  # pallas_call has no replication rule
    )
    return fn(features, labels)


def masked_distributed_stats(
    features: Array,
    labels: Array,
    num_classes: int,
    mesh: Mesh,
    *,
    base_seed: int = 0,
    mask_scale: float = 1e3,
    client_axes: Tuple[str, ...] = ("data",),
    use_kernel: bool = False,
) -> FeatureStats:
    """SecureAgg-composed variant: each shard adds pairwise-cancelling
    masks BEFORE the psum, so no unmasked per-shard statistic ever exists
    outside its shard.  The psum output equals the unmasked aggregate up
    to float associativity (tested)."""
    axes = tuple(a for a in client_axes if a in mesh.axis_names)

    def shard_fn(f_shard: Array, y_shard: Array) -> FeatureStats:
        local = _local_stats(f_shard, y_shard, num_classes, use_kernel=use_kernel)
        # axis extents are static properties of the mesh (jax.lax.axis_size
        # only exists on newer jax)
        me = jax.lax.axis_index(axes[0]) if len(axes) == 1 else (
            jax.lax.axis_index(axes[0]) * mesh.shape[axes[1]]
            + jax.lax.axis_index(axes[1])
        )
        n_shards = 1
        for a in axes:
            n_shards *= mesh.shape[a]

        def add_pair_mask(stat, other):
            key = jax.random.fold_in(
                jax.random.fold_in(jax.random.key(base_seed), jnp.minimum(me, other)),
                jnp.maximum(me, other),
            )
            leaves, treedef = jax.tree_util.tree_flatten(stat)
            keys = jax.random.split(key, len(leaves))
            sign = jnp.where(me < other, 1.0, -1.0)
            masked = [
                leaf + sign * mask_scale * jax.random.normal(k, leaf.shape, leaf.dtype)
                for k, leaf in zip(keys, leaves)
            ]
            return jax.tree_util.tree_unflatten(treedef, masked)

        def body(i, stat):
            return jax.lax.cond(
                i == me, lambda s: s, lambda s: add_pair_mask(s, i), stat
            )

        masked = jax.lax.fori_loop(0, n_shards, body, local)
        return jax.lax.psum(masked, axes)

    fn = shard_map(
        shard_fn, mesh=mesh, in_specs=(P(axes), P(axes)),
        out_specs=FeatureStats(A=P(), B=P(), N=P()),
        check_rep=not use_kernel,
    )
    return fn(features, labels)
