"""FedCGS aggregation as a mesh collective (DESIGN.md §3).

The paper's server loop — "sum every client's (A_i, B_i, N_i)" — is an
all-reduce over the client axis.  Here clients are assigned to the
("pod", "data") mesh shards; each shard computes the statistics of ITS
cohort's examples locally and a single ``psum`` over the whole
FeatureStats tree realizes the server aggregation.  SecureAgg composes:
masks cancel INSIDE the psum, so the reduction is literally the
protocol's trusted aggregator.

``distributed_client_stats`` is the shard_map entry point (explicit
collectives — auditable); the jit path in ``launch.steps.stats_step``
lets GSPMD insert the same psum implicitly.  Tests assert both agree
with the centralized oracle.

``use_kernel=True`` routes each shard's local sweep through the fused
single-pass Pallas engine (``repro.kernels.client_stats``) instead of
the jnp one-hot formulation — the production path on TPU.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.statistics import FeatureStats
from repro.sharding import shard_map

Array = jax.Array


def _local_stats(
    features: Array,
    labels: Array,
    num_classes: int,
    *,
    use_kernel: bool = False,
    interpret: Optional[bool] = None,
) -> FeatureStats:
    """One shard's sweep — the pipeline's per-shard building blocks.

    Both paths map padding labels (−1) to zero contributions: the kernel
    masks them in-register, the jnp one_hot maps them to all-zero rows.
    """
    from repro.core.stats_pipeline import _stats_fused, _stats_jnp

    if use_kernel:
        return _stats_fused(features, labels, num_classes, interpret=interpret)
    return _stats_jnp(features, labels, num_classes)


def shard_index(mesh: Mesh, axes: Tuple[str, ...]) -> Array:
    """Flat shard id inside a shard_map body (row-major over ``axes``)."""
    me = jax.lax.axis_index(axes[0])
    for a in axes[1:]:
        me = me * mesh.shape[a] + jax.lax.axis_index(a)
    return me


def apply_pair_masks(
    stat: FeatureStats,
    me: Array,
    n_shards: int,
    *,
    base_seed: int = 0,
    mask_scale: float = 1e3,
) -> FeatureStats:
    """Add this shard's pairwise-cancelling SecureAgg masks to ``stat``.

    Shard ``me`` adds +m_(me,other) for every other > me and −m_(other,me)
    for every other < me; summed over all shards the masks cancel exactly
    (up to float associativity).  Usable inside any shard_map body that
    wants to mask BEFORE a psum — both the one-shot and the streaming
    engines route through here.
    """

    def add_pair_mask(s, other):
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.key(base_seed), jnp.minimum(me, other)),
            jnp.maximum(me, other),
        )
        leaves, treedef = jax.tree_util.tree_flatten(s)
        keys = jax.random.split(key, len(leaves))
        sign = jnp.where(me < other, 1.0, -1.0)
        masked = [
            leaf + sign * mask_scale * jax.random.normal(k, leaf.shape, leaf.dtype)
            for k, leaf in zip(keys, leaves)
        ]
        return jax.tree_util.tree_unflatten(treedef, masked)

    def body(i, s):
        return jax.lax.cond(i == me, lambda x: x, lambda x: add_pair_mask(x, i), s)

    return jax.lax.fori_loop(0, n_shards, body, stat)


def distributed_client_stats(
    features: Array,
    labels: Array,
    num_classes: int,
    mesh: Mesh,
    *,
    client_axes: Tuple[str, ...] = ("data",),
    use_kernel: bool = False,
    interpret: Optional[bool] = None,
) -> FeatureStats:
    """Global (A, B, N) from batch-sharded (features, labels).

    features: (n, d) sharded over ``client_axes``; labels: (n,).
    Returns fully-replicated global statistics — every shard (every
    "client") holds the aggregate, which is what the one-extra-download
    personalization round distributes anyway.
    """
    axes = tuple(a for a in client_axes if a in mesh.axis_names)

    def shard_fn(f_shard: Array, y_shard: Array) -> FeatureStats:
        local = _local_stats(
            f_shard, y_shard, num_classes,
            use_kernel=use_kernel, interpret=interpret,
        )
        return jax.lax.psum(local, axes)  # ONE collective over the tree

    in_specs = (P(axes), P(axes))
    out_specs = FeatureStats(A=P(), B=P(), N=P())
    fn = shard_map(
        shard_fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=not use_kernel,  # pallas_call has no replication rule
    )
    return fn(features, labels)


def masked_distributed_stats(
    features: Array,
    labels: Array,
    num_classes: int,
    mesh: Mesh,
    *,
    base_seed: int = 0,
    mask_scale: float = 1e3,
    client_axes: Tuple[str, ...] = ("data",),
    use_kernel: bool = False,
    interpret: Optional[bool] = None,
) -> FeatureStats:
    """SecureAgg-composed variant: each shard adds pairwise-cancelling
    masks BEFORE the psum, so no unmasked per-shard statistic ever exists
    outside its shard.  The psum output equals the unmasked aggregate up
    to float associativity (tested)."""
    axes = tuple(a for a in client_axes if a in mesh.axis_names)

    def shard_fn(f_shard: Array, y_shard: Array) -> FeatureStats:
        local = _local_stats(
            f_shard, y_shard, num_classes,
            use_kernel=use_kernel, interpret=interpret,
        )
        # axis extents are static properties of the mesh (jax.lax.axis_size
        # only exists on newer jax)
        n_shards = 1
        for a in axes:
            n_shards *= mesh.shape[a]
        masked = apply_pair_masks(
            local, shard_index(mesh, axes), n_shards,
            base_seed=base_seed, mask_scale=mask_scale,
        )
        return jax.lax.psum(masked, axes)

    fn = shard_map(
        shard_fn, mesh=mesh, in_specs=(P(axes), P(axes)),
        out_specs=FeatureStats(A=P(), B=P(), N=P()),
        check_rep=not use_kernel,
    )
    return fn(features, labels)
