"""Feature expansion (paper Fig. 3).

A shared random projection with nonlinearity, injected between the
frozen backbone and the statistics:  g(x) = act(f(x) @ R / √d).

Every client uses the *same* R (derived from a public seed), so the
expanded statistics still aggregate exactly.  d_out > d trades
communication ((C+d)·d grows) for linear separability.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

Array = jax.Array

_ACTS = {
    "relu": jax.nn.relu,
    "gelu": jax.nn.gelu,
    "tanh": jnp.tanh,
    "identity": lambda x: x,
}


@dataclasses.dataclass(frozen=True)
class FeatureExpansion:
    in_dim: int
    out_dim: int
    seed: int = 0
    activation: str = "relu"
    concat_identity: bool = True  # keep original features alongside

    @property
    def expanded_dim(self) -> int:
        return self.out_dim + (self.in_dim if self.concat_identity else 0)

    def projection(self) -> Array:
        key = jax.random.key(self.seed)
        return jax.random.normal(key, (self.in_dim, self.out_dim)) / jnp.sqrt(
            float(self.in_dim)
        )

    def __call__(self, features: Array) -> Array:
        return expand_features(
            features,
            self.projection(),
            activation=self.activation,
            concat_identity=self.concat_identity,
        )


@partial(jax.jit, static_argnames=("activation", "concat_identity"))
def expand_features(
    features: Array,
    projection: Array,
    *,
    activation: str = "relu",
    concat_identity: bool = True,
) -> Array:
    act = _ACTS[activation]
    projected = act(features @ projection)
    if concat_identity:
        return jnp.concatenate([features, projected], axis=-1)
    return projected
