"""Pairwise-mask Secure Aggregation (Bonawitz et al. 2017) for FedCGS.

The paper (Algorithm 1 line 5 + §Privacy Discussion) notes that the
server only ever needs the *sums* A, B, N — so clients can add pairwise
cancelling masks before upload and the server learns nothing about any
individual client's statistics.

For every ordered client pair (i, j), i < j, both derive a shared mask
``m_ij = PRG(seed_ij)`` shaped like the statistic tree.  Client i adds
``+m_ij``, client j adds ``−m_ij``.  Summed over all clients the masks
cancel exactly (up to float associativity, ~1e-6 relative — tested).

Cost model: a masked round needs each of the K·(K−1)/2 pair masks
exactly once.  ``masked_round`` is the single-derivation entry point —
it streams over pairs, materializing ONE mask tree at a time, and both
``secure_sum`` and ``masked_views`` are thin wrappers over it.  (The
seed implementation re-derived every pair mask from scratch inside each
per-client ``mask_client_update`` call — K·(K−1) PRG tree expansions
per function, twice that when a pipeline needed both the views and the
sum.)  ``mask_client_update`` keeps the per-client protocol view for
tests of seed agreement; it derives only the K−1 masks client i is a
party to.

This is a faithful *functional* model of the protocol: we implement the
mask algebra and the seed agreement (here: hash of the pair), not the
networking/dropout-recovery machinery (Shamir shares), which is
orthogonal to the paper's claim.
"""

from __future__ import annotations

from typing import Any, List, Sequence, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


def _pair_seed(base_seed: int, i: int, j: int) -> jax.Array:
    """Deterministic shared key for pair (i, j) — both sides can derive it."""
    lo, hi = (i, j) if i < j else (j, i)
    key = jax.random.key(base_seed)
    return jax.random.fold_in(jax.random.fold_in(key, lo), hi)


def _mask_like(key: jax.Array, tree: PyTree, scale: float) -> PyTree:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    keys = jax.random.split(key, len(leaves))
    masks = [
        scale * jax.random.normal(k, leaf.shape, leaf.dtype)
        for k, leaf in zip(keys, leaves)
    ]
    return jax.tree_util.tree_unflatten(treedef, masks)


def mask_client_update(
    update: PyTree,
    client_id: int,
    num_clients: int,
    *,
    base_seed: int = 0,
    mask_scale: float = 1e3,
) -> PyTree:
    """Return ``update + Σ_{j>i} m_ij − Σ_{j<i} m_ji`` (client-side step)."""
    masked = update
    for other in range(num_clients):
        if other == client_id:
            continue
        key = _pair_seed(base_seed, client_id, other)
        mask = _mask_like(key, update, mask_scale)
        sign = 1.0 if client_id < other else -1.0
        masked = jax.tree_util.tree_map(lambda u, m: u + sign * m, masked, mask)
    return masked


def masked_round(
    updates: Sequence[PyTree], *, base_seed: int = 0, mask_scale: float = 1e3
) -> Tuple[List[PyTree], PyTree]:
    """One SecureAgg round: (per-client masked views, their server-side sum).

    Every pair mask is derived exactly once and applied ``+`` to the low
    client / ``−`` to the high client, so the round costs K·(K−1)/2 PRG
    tree expansions total regardless of whether the caller wants the
    views, the sum, or both.
    """
    views: List[PyTree] = list(updates)
    k = len(views)
    for i in range(k):
        for j in range(i + 1, k):
            mask = _mask_like(_pair_seed(base_seed, i, j), views[i], mask_scale)
            views[i] = jax.tree_util.tree_map(lambda u, m: u + m, views[i], mask)
            views[j] = jax.tree_util.tree_map(lambda u, m: u - m, views[j], mask)
    total = views[0]
    for v in views[1:]:
        total = jax.tree_util.tree_map(jnp.add, total, v)
    return views, total


def secure_sum(
    updates: Sequence[PyTree], *, base_seed: int = 0, mask_scale: float = 1e3
) -> PyTree:
    """End-to-end SecureAgg: mask every client, sum at the server.

    The server-side view is *only* the masked updates; the return value is
    their sum, in which the masks cancel.  Tests assert both (a) the sum
    matches the unmasked sum and (b) each individual masked update is
    statistically far from the true update (mask_scale dominates).
    """
    _, total = masked_round(updates, base_seed=base_seed, mask_scale=mask_scale)
    return total


def masked_views(
    updates: Sequence[PyTree], *, base_seed: int = 0, mask_scale: float = 1e3
) -> List[PyTree]:
    """What the server actually receives per client (for privacy tests)."""
    views, _ = masked_round(updates, base_seed=base_seed, mask_scale=mask_scale)
    return views
