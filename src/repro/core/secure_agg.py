"""Dropout-tolerant pairwise-mask Secure Aggregation for FedCGS.

The paper (Algorithm 1 line 5 + §Privacy Discussion) notes that the
server only ever needs the *sums* A, B, N — so clients can add pairwise
cancelling masks before upload and the server learns nothing about any
individual client's statistics.  This module implements the full
Bonawitz et al. 2017 round shape, including the §4 dropout recovery the
abstract's connection-drop risk demands:

1. **Setup** (:func:`setup_round`): every client i holds a secret field
   element ``u_i`` (GF(2³¹−1), :mod:`repro.core.shamir`), publishes
   ``pk_i = g^{u_i}``, and Shamir-shares ``u_i`` t-of-K to its peers.
   The returned :class:`RoundSetup` holds only the *public* transcript —
   pubkeys and the share matrix — never the secrets.
2. **Masking**: for every pair (i, j), both endpoints derive the same
   seed ``s_ij = pk_j^{u_i} = pk_i^{u_j}`` (key agreement) and expand it
   to a mask tree ``m_ij = PRG(s_ij)`` shaped like the statistics.  The
   low client adds ``+m_ij``, the high client ``−m_ij``; summed over all
   clients the masks cancel exactly (up to float associativity).
3. **Upload**: the server receives only masked views
   (:func:`masked_round` when everyone reports;
   :func:`masked_survivor_views` when some clients drop mid-round).
4. **Recovery** (:func:`recover_round`): masks between two survivors
   cancel in the partial sum; masks between a survivor and a dropped
   client do not.  The server collects ≥ t survivors' shares of each
   dropped ``u_d``, reconstructs it, recomputes ``s_sd = pk_s^{u_d}``
   for every survivor s (the same value s used — DH symmetry),
   regenerates those masks bit-identically, and subtracts them: the
   result is the EXACT statistics sum over survivors.  Fewer than t
   survivors ⇒ the round aborts (raises) rather than degrade.

Cost model: a full round needs each of the K·(K−1)/2 pair masks exactly
once; :func:`masked_survivor_views` derives only pairs with a surviving
endpoint, and recovery re-derives just the |S|·|D| survivor×dropped
masks.  ``mask_client_update`` keeps the per-client protocol view
(client i derives only its own K−1 masks) for seed-agreement tests.

Determinism contract: everything — secrets, shares, pair seeds, masks —
derives from ``base_seed`` through fixed PRGs (numpy PCG64 for the
simulated per-client secrets, jax threefry for shares and mask trees),
so two processes produce bit-identical masked views and recoveries.
The sharded engines (``core.federated.apply_pair_masks``) consume the
same :func:`pair_seed_matrix`, which keeps host-side recovery
bit-aligned with masks generated inside ``shard_map`` traces.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import shamir
from repro.obs import trace

PyTree = Any


def client_secrets(base_seed: int, num_clients: int) -> np.ndarray:
    """The simulated clients' private DH secrets, u_i ∈ [1, p−1).

    In deployment each client draws its own; the simulation derives them
    deterministically from ``base_seed`` (numpy PCG64 — bit-stable across
    processes) so rounds are reproducible.
    """
    rng = np.random.default_rng(int(base_seed) % (1 << 32))
    return rng.integers(
        1, shamir.PRIME - 1, size=num_clients, dtype=np.uint64
    ).astype(np.uint32)


@functools.lru_cache(maxsize=128)
def pair_seed_matrix(base_seed: int, num_clients: int) -> np.ndarray:
    """(K, K) uint32 of agreed pair seeds s_ij = g^{u_i·u_j}; diagonal 0.

    Symmetric by DH construction — entry [i, j] is what client i computes
    as pk_j^{u_i} and client j computes as pk_i^{u_j}.  Cached: the
    sharded engines embed it as a trace constant.  Treat as read-only.
    """
    u = client_secrets(base_seed, num_clients)
    pk = shamir.dh_public(u)
    seeds = shamir.dh_shared(u[:, None], pk[None, :])  # (K, K)
    np.fill_diagonal(seeds, 0)
    return seeds


def _pair_key(seed: int) -> jax.Array:
    """PRG key for one agreed pair seed (32-bit field element)."""
    return jax.random.key(jnp.uint32(seed))


def _mask_like(key: jax.Array, tree: PyTree, scale: float) -> PyTree:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    keys = jax.random.split(key, len(leaves))
    masks = [
        scale * jax.random.normal(k, leaf.shape, leaf.dtype)
        for k, leaf in zip(keys, leaves)
    ]
    return jax.tree_util.tree_unflatten(treedef, masks)


def round_plan(
    num_parties: int,
    dropped: Sequence[int],
    *,
    min_survivors: Optional[int] = None,
    secure: bool = True,
) -> Tuple[List[int], int]:
    """Validate a dropout round -> (survivors, threshold).

    THE one place the threshold default lives: ``min_survivors`` when
    given, else a majority for secure rounds (recovery needs t shares)
    and 1 for plain rounds (nothing to reconstruct — any non-empty
    survivor set sums fine).  Raises on out-of-range dropped ids (a
    silently-ignored drop would report full-cohort statistics as if
    recovery had run) and on survivor sets below the threshold.
    """
    drop = sorted({int(d) for d in dropped})
    if any(d < 0 or d >= num_parties for d in drop):
        raise ValueError(
            f"dropped ids {drop} out of range for {num_parties} parties"
        )
    survivors = [i for i in range(num_parties) if i not in set(drop)]
    if min_survivors is not None:
        threshold = min_survivors
    else:
        threshold = num_parties // 2 + 1 if secure else 1
    if not 1 <= threshold <= num_parties:
        raise ValueError(
            f"need 1 <= threshold <= num_parties, got t={threshold}, "
            f"K={num_parties}"
        )
    if len(survivors) < threshold:
        raise ValueError(
            f"unrecoverable round: {len(survivors)} survivors < "
            f"threshold {threshold}"
        )
    return survivors, threshold


# ---------------------------------------------------------------------------
# Round setup: secrets shared, pubkeys published.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RoundSetup:
    """Transcript of the setup phase (pubkeys + the Shamir share matrix).

    ``share_ys[j, i]`` is peer j's Shamir share of client i's secret
    ``u_i`` (evaluated at x = ``share_xs[j]`` = j+1); ``pubkeys[i]`` is
    ``g^{u_i}``.  The secrets themselves are deliberately absent:
    recovery MUST reconstruct them from ≥ ``threshold`` shares.

    Simulation gap, stated plainly: in deployment row j of ``share_ys``
    lives on client j, and the server receives ONLY the dropped clients'
    columns, from ≥ t surviving donors, at recovery time — it can never
    reconstruct a *survivor's* secret and strip that client's masks.
    This in-process simulation has no per-party storage, so the whole
    matrix sits in one object; the recovery code keeps the protocol
    honest by construction instead, reading exactly
    ``share_ys[donors, dropped]`` (see :func:`recover_mask_residual`)
    — never a surviving client's column.
    """

    num_clients: int
    threshold: int
    base_seed: int
    pubkeys: np.ndarray  # (K,) uint32
    share_xs: np.ndarray  # (K,) uint32, 1..K
    share_ys: np.ndarray  # (K, K) uint32: [holder j, secret owner i]


def setup_round(
    num_clients: int, threshold: int, *, base_seed: int = 0
) -> RoundSetup:
    """Run the setup phase for a K-client round with a t-of-K threshold."""
    if num_clients < 1:
        raise ValueError("need at least one client")
    if not 1 <= threshold <= num_clients:
        raise ValueError(
            f"need 1 <= threshold <= num_clients, got t={threshold}, "
            f"K={num_clients}"
        )
    u = client_secrets(base_seed, num_clients)
    key = jax.random.fold_in(jax.random.key(int(base_seed) % (1 << 32)),
                             num_clients)
    xs, ys = shamir.split_secret(u, threshold, num_clients, key=key)
    return RoundSetup(
        num_clients=num_clients,
        threshold=threshold,
        base_seed=base_seed,
        pubkeys=shamir.dh_public(u),
        share_xs=xs,
        share_ys=ys,
    )


# ---------------------------------------------------------------------------
# Client-side masking.
# ---------------------------------------------------------------------------


def mask_client_update(
    update: PyTree,
    client_id: int,
    num_clients: int,
    *,
    base_seed: int = 0,
    mask_scale: float = 1e3,
) -> PyTree:
    """Return ``update + Σ_{j>i} m_ij − Σ_{j<i} m_ji`` (client-side step)."""
    seeds = pair_seed_matrix(base_seed, num_clients)
    masked = update
    for other in range(num_clients):
        if other == client_id:
            continue
        mask = _mask_like(
            _pair_key(seeds[client_id, other]), update, mask_scale
        )
        sign = 1.0 if client_id < other else -1.0
        masked = jax.tree_util.tree_map(lambda u, m: u + sign * m, masked, mask)
    return masked


def masked_survivor_views(
    updates: Sequence[PyTree],
    survivors: Sequence[int],
    num_clients: int,
    *,
    base_seed: int = 0,
    mask_scale: float = 1e3,
) -> List[PyTree]:
    """Masked views of the surviving clients (aligned with ``survivors``).

    ``updates`` may be the full K-length round (dropped entries are never
    touched) or a dict-like keyed by client id.  Every mask with at
    least one surviving endpoint is derived exactly once and applied
    ``+`` to the low / ``−`` to the high survivor; masks between two
    dropped clients are never materialized.
    """
    surv = sorted(set(int(s) for s in survivors))
    if any(s < 0 or s >= num_clients for s in surv):
        raise ValueError(f"survivor ids must be in [0, {num_clients})")
    # works for a K-length sequence and an id-keyed mapping alike
    views: Dict[int, PyTree] = {s: updates[s] for s in surv}
    seeds = pair_seed_matrix(base_seed, num_clients)
    in_round = set(surv)
    for i in range(num_clients):
        for j in range(i + 1, num_clients):
            if i not in in_round and j not in in_round:
                continue
            template = views[i] if i in in_round else views[j]
            mask = _mask_like(_pair_key(seeds[i, j]), template, mask_scale)
            if i in in_round:
                views[i] = jax.tree_util.tree_map(
                    lambda u, m: u + m, views[i], mask
                )
            if j in in_round:
                views[j] = jax.tree_util.tree_map(
                    lambda u, m: u - m, views[j], mask
                )
    return [views[s] for s in surv]


def masked_round(
    updates: Sequence[PyTree], *, base_seed: int = 0, mask_scale: float = 1e3
) -> Tuple[List[PyTree], PyTree]:
    """One full SecureAgg round: (per-client masked views, their sum).

    Every pair mask is derived exactly once; the sum is what the server
    computes when nobody drops (the masks cancel inside it).
    """
    k = len(updates)
    views = masked_survivor_views(
        updates, range(k), k, base_seed=base_seed, mask_scale=mask_scale
    )
    total = views[0]
    for v in views[1:]:
        total = jax.tree_util.tree_map(jnp.add, total, v)
    return views, total


def secure_sum(
    updates: Sequence[PyTree], *, base_seed: int = 0, mask_scale: float = 1e3
) -> PyTree:
    """End-to-end SecureAgg: mask every client, sum at the server.

    The server-side view is *only* the masked updates; the return value is
    their sum, in which the masks cancel.  Tests assert both (a) the sum
    matches the unmasked sum and (b) each individual masked update is
    statistically far from the true update (mask_scale dominates).
    """
    _, total = masked_round(updates, base_seed=base_seed, mask_scale=mask_scale)
    return total


def masked_views(
    updates: Sequence[PyTree], *, base_seed: int = 0, mask_scale: float = 1e3
) -> List[PyTree]:
    """What the server actually receives per client (for privacy tests)."""
    views, _ = masked_round(updates, base_seed=base_seed, mask_scale=mask_scale)
    return views


# ---------------------------------------------------------------------------
# Server-side dropout recovery.
# ---------------------------------------------------------------------------


def recover_mask_residual(
    setup: RoundSetup,
    survivors: Sequence[int],
    like: PyTree,
    *,
    mask_scale: float = 1e3,
) -> PyTree:
    """The un-cancelled mask residue left in a survivor partial sum.

    For each dropped client d the server reconstructs ``u_d`` from the
    first ``threshold`` survivors' shares, re-derives the agreed seed
    ``s_sd = pk_s^{u_d}`` for every survivor s, and regenerates the mask
    trees bit-identically to what s applied.  The returned tree is
    ``Σ_{s∈S, d∈D} sign(s, d) · m_sd`` with sign +1 when s < d — exactly
    what must be SUBTRACTED from the partial sum.
    """
    surv = sorted(set(int(s) for s in survivors))
    if any(s < 0 or s >= setup.num_clients for s in surv):
        raise ValueError(f"survivor ids must be in [0, {setup.num_clients})")
    dropped = [i for i in range(setup.num_clients) if i not in set(surv)]
    if len(surv) < setup.threshold:
        raise ValueError(
            f"unrecoverable round: {len(surv)} survivors < "
            f"threshold {setup.threshold}"
        )
    residual = jax.tree_util.tree_map(jnp.zeros_like, like)
    if not dropped:
        return residual
    donors = surv[: setup.threshold]
    xs = setup.share_xs[donors]
    for d in dropped:
        u_d = shamir.reconstruct_secret(xs, setup.share_ys[donors, d])
        for s in surv:
            seed = int(shamir.dh_shared(u_d, setup.pubkeys[s]))
            sign = 1.0 if s < d else -1.0
            mask = _mask_like(_pair_key(seed), like, mask_scale)
            residual = jax.tree_util.tree_map(
                lambda r, m: r + sign * m, residual, mask
            )
    return residual


def recover_partial_sum(
    partial: PyTree,
    survivors: Sequence[int],
    setup: RoundSetup,
    *,
    mask_scale: float = 1e3,
) -> PyTree:
    """Un-mask a survivor-only partial sum → the exact survivor sum.

    ``partial`` is the sum of the survivors' masked views (masks between
    two survivors have already cancelled inside it).
    """
    residual = recover_mask_residual(
        setup, survivors, partial, mask_scale=mask_scale
    )
    return jax.tree_util.tree_map(jnp.subtract, partial, residual)


def recover_round(
    views: Sequence[PyTree],
    survivors: Sequence[int],
    setup: RoundSetup,
    *,
    mask_scale: float = 1e3,
) -> PyTree:
    """Server-side round completion under dropout.

    ``views`` are the masked uploads of ``survivors`` (aligned, e.g. the
    output of :func:`masked_survivor_views`).  Requires ≥ ``threshold``
    survivors; returns the exact statistics sum over the survivor set.
    """
    surv = sorted(set(int(s) for s in survivors))
    if len(views) != len(surv):
        raise ValueError("one masked view per survivor, aligned")
    with trace.span("secure_agg.recover", survivors=len(surv),
                    dropped=setup.num_clients - len(surv),
                    threshold=setup.threshold):
        partial = views[0]
        for v in views[1:]:
            partial = jax.tree_util.tree_map(jnp.add, partial, v)
        return recover_partial_sum(
            partial, surv, setup, mask_scale=mask_scale
        )
