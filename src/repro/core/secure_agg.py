"""Pairwise-mask Secure Aggregation (Bonawitz et al. 2017) for FedCGS.

The paper (Algorithm 1 line 5 + §Privacy Discussion) notes that the
server only ever needs the *sums* A, B, N — so clients can add pairwise
cancelling masks before upload and the server learns nothing about any
individual client's statistics.

For every ordered client pair (i, j), i < j, both derive a shared mask
``m_ij = PRG(seed_ij)`` shaped like the statistic tree.  Client i adds
``+m_ij``, client j adds ``−m_ij``.  Summed over all clients the masks
cancel exactly (up to float associativity, ~1e-6 relative — tested).

This is a faithful *functional* model of the protocol: we implement the
mask algebra and the seed agreement (here: hash of the pair), not the
networking/dropout-recovery machinery (Shamir shares), which is
orthogonal to the paper's claim.
"""

from __future__ import annotations

from typing import Any, List, Sequence

import jax
import jax.numpy as jnp

PyTree = Any


def _pair_seed(base_seed: int, i: int, j: int) -> jax.Array:
    """Deterministic shared key for pair (i, j) — both sides can derive it."""
    lo, hi = (i, j) if i < j else (j, i)
    key = jax.random.key(base_seed)
    return jax.random.fold_in(jax.random.fold_in(key, lo), hi)


def _mask_like(key: jax.Array, tree: PyTree, scale: float) -> PyTree:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    keys = jax.random.split(key, len(leaves))
    masks = [
        scale * jax.random.normal(k, leaf.shape, leaf.dtype)
        for k, leaf in zip(keys, leaves)
    ]
    return jax.tree_util.tree_unflatten(treedef, masks)


def mask_client_update(
    update: PyTree,
    client_id: int,
    num_clients: int,
    *,
    base_seed: int = 0,
    mask_scale: float = 1e3,
) -> PyTree:
    """Return ``update + Σ_{j>i} m_ij − Σ_{j<i} m_ji`` (client-side step)."""
    masked = update
    for other in range(num_clients):
        if other == client_id:
            continue
        key = _pair_seed(base_seed, client_id, other)
        mask = _mask_like(key, update, mask_scale)
        sign = 1.0 if client_id < other else -1.0
        masked = jax.tree_util.tree_map(lambda u, m: u + sign * m, masked, mask)
    return masked


def secure_sum(
    updates: Sequence[PyTree], *, base_seed: int = 0, mask_scale: float = 1e3
) -> PyTree:
    """End-to-end SecureAgg: mask every client, sum at the server.

    The server-side view is *only* the masked updates; the return value is
    their sum, in which the masks cancel.  Tests assert both (a) the sum
    matches the unmasked sum and (b) each individual masked update is
    statistically far from the true update (mask_scale dominates).
    """
    masked: List[PyTree] = [
        mask_client_update(
            u, i, len(updates), base_seed=base_seed, mask_scale=mask_scale
        )
        for i, u in enumerate(updates)
    ]
    total = masked[0]
    for m in masked[1:]:
        total = jax.tree_util.tree_map(jnp.add, total, m)
    return total


def masked_views(
    updates: Sequence[PyTree], *, base_seed: int = 0, mask_scale: float = 1e3
) -> List[PyTree]:
    """What the server actually receives per client (for privacy tests)."""
    return [
        mask_client_update(
            u, i, len(updates), base_seed=base_seed, mask_scale=mask_scale
        )
        for i, u in enumerate(updates)
    ]
