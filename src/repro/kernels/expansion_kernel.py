"""Fused feature-expansion kernel: g = act(F · R)  (paper Fig. 3).

Same (i, j, k) tiling as the classifier head; the nonlinearity is
applied on the LAST k step, so the activation fuses with the matmul
epilogue instead of a second pass over the (n, d_out) output.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array

BLOCK_N = 256
BLOCK_O = 128
BLOCK_K = 512

_ACTS = {
    "relu": lambda x: jnp.maximum(x, 0.0),
    "gelu": jax.nn.gelu,
    "tanh": jnp.tanh,
    "identity": lambda x: x,
}


def _expand_kernel(f_ref, r_ref, out_ref, *, activation: str):
    k = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += jax.lax.dot_general(
        f_ref[...],
        r_ref[...],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == nk - 1)
    def _act():
        out_ref[...] = _ACTS[activation](out_ref[...])


def expand_kernel(
    features: Array,
    projection: Array,
    *,
    activation: str = "relu",
    block_n: int = BLOCK_N,
    block_o: int = BLOCK_O,
    block_k: int = BLOCK_K,
    interpret: bool = False,
) -> Array:
    n, d = features.shape
    d2, o = projection.shape
    assert d == d2 and n % block_n == 0 and d % block_k == 0 and o % block_o == 0
    grid = (n // block_n, o // block_o, d // block_k)
    return pl.pallas_call(
        functools.partial(_expand_kernel, activation=activation),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_k, block_o), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((block_n, block_o), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, o), jnp.float32),
        interpret=interpret,
    )(features, projection)
