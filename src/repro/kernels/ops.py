"""Public jit'd wrappers around the Pallas kernels.

The wrappers own all shape hygiene: inputs are zero-padded to block
multiples (padded rows carry label ``-1`` so they match no class and
contribute zeros to every statistic), outputs are sliced back.  On a
CPU-only host (no TPU) they transparently run in interpret mode.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels import classifier_kernel, expansion_kernel, flash_kernel, stats_kernel

Array = jax.Array


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x: Array, axis: int, multiple: int, value=0) -> Array:
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


@functools.partial(
    jax.jit,
    static_argnames=("num_classes", "interpret", "block_d", "block_n", "fused"),
)
def client_stats(
    features: Array,
    labels: Array,
    num_classes: int,
    *,
    interpret: bool | None = None,
    block_d: int = stats_kernel.BLOCK_D,
    block_n: int = stats_kernel.BLOCK_N,
    fused: bool = True,
) -> Tuple[Array, Array, Array]:
    """FedCGS ClientStats via the Pallas kernels: returns (A, B, N).

    features: (n, d) any float dtype; labels: (n,) int32 in [0, C).

    ``fused=True`` (default) runs the single-pass engine — one kernel,
    one sweep over the feature rows for A, B, AND N, symmetric-aware
    Gram tiles.  ``fused=False`` is the seed's two-kernel formulation,
    kept so ``benchmarks/kernel_bench.py`` can measure the difference.
    """
    interpret = (not _on_tpu()) if interpret is None else interpret
    n, d = features.shape
    f = _pad_to(_pad_to(features, 0, block_n), 1, block_d)
    # padded rows get label -1 => match no class => zero contribution
    y = _pad_to(labels.astype(jnp.int32)[:, None], 0, block_n, value=-1)
    c_pad = max(block_d, ((num_classes + block_d - 1) // block_d) * block_d)

    if fused:
        A, B, N = stats_kernel.fused_stats(
            f, y, c_pad, block_d=block_d, block_n=block_n, interpret=interpret
        )
        return A[:num_classes, :d], B[:d, :d], N[:num_classes]

    B = stats_kernel.gram(f, block_d=block_d, block_n=block_n, interpret=interpret)
    A = stats_kernel.class_sum(
        f, y, c_pad, block_c=block_d, block_d=block_d, block_n=block_n,
        interpret=interpret,
    )
    # O(n) count — never materializes the (n, C) one-hot the seed built.
    # Out-of-range labels (e.g. the -1 padding convention) go to an
    # overflow bucket that is sliced off, matching the fused kernel's
    # "match no class" behaviour (bincount would clip -1 to class 0).
    y_flat = labels.astype(jnp.int32)
    y_safe = jnp.where((y_flat >= 0) & (y_flat < num_classes), y_flat, num_classes)
    N = jnp.bincount(y_safe, length=num_classes + 1)[:num_classes].astype(jnp.float32)
    return A[:num_classes, :d], B[:d, :d], N


# ---------------------------------------------------------------------------
# Streaming carry: fold batches into a running (M, N) without allocating
# fresh outputs per step.  The carry lives in the kernel's padded layout —
# M (d_pad + c_pad, d_pad) stacks [B-upper-triangle | A], N is (1, c_pad) —
# so every fold is ONE pallas_call whose carry operands are donated
# (``input_output_aliases``) to the outputs.
# ---------------------------------------------------------------------------


def _padded_dims(num_classes: int, feature_dim: int, block_d: int) -> Tuple[int, int]:
    d_pad = ((feature_dim + block_d - 1) // block_d) * block_d
    c_pad = max(block_d, ((num_classes + block_d - 1) // block_d) * block_d)
    return d_pad, c_pad


def stats_carry_init(
    num_classes: int, feature_dim: int, *, block_d: int = stats_kernel.BLOCK_D
) -> Tuple[Array, Array]:
    """Zero carry buffers in the kernel's padded (M, N) layout."""
    d_pad, c_pad = _padded_dims(num_classes, feature_dim, block_d)
    return (
        jnp.zeros((d_pad + c_pad, d_pad), jnp.float32),
        jnp.zeros((1, c_pad), jnp.float32),
    )


def _client_stats_acc_impl(
    m_carry: Array,
    n_carry: Array,
    features: Array,
    labels: Array,
    *,
    interpret: bool,
    block_d: int,
    block_n: int,
) -> Tuple[Array, Array]:
    d_pad = m_carry.shape[1]
    f = _pad_to(_pad_to(features, 0, block_n), 1, block_d)
    assert f.shape[1] == d_pad, (f.shape, d_pad)
    y = _pad_to(labels.astype(jnp.int32)[:, None], 0, block_n, value=-1)
    return stats_kernel.fused_stats_acc(
        m_carry, n_carry, f, y, block_d=block_d, block_n=block_n,
        interpret=interpret,
    )


_ACC_STATIC = ("interpret", "block_d", "block_n")
_acc_jit = jax.jit(_client_stats_acc_impl, static_argnames=_ACC_STATIC)
_acc_jit_donating = jax.jit(
    _client_stats_acc_impl, static_argnames=_ACC_STATIC, donate_argnums=(0, 1)
)


def client_stats_acc(
    m_carry: Array,
    n_carry: Array,
    features: Array,
    labels: Array,
    *,
    interpret: bool | None = None,
    block_d: int = stats_kernel.BLOCK_D,
    block_n: int = stats_kernel.BLOCK_N,
) -> Tuple[Array, Array]:
    """Fold one (features, labels) batch into a running padded carry.

    features: (n, d) any float dtype with d matching the carry's logical
    feature dim; labels: (n,) int32 — padded rows get label −1 inside and
    contribute zero to every statistic.  One jit trace per batch shape;
    on TPU the carry buffers are donated so the fold is in-place.
    """
    interpret = (not _on_tpu()) if interpret is None else interpret
    fold = _acc_jit_donating if _on_tpu() else _acc_jit
    return fold(
        m_carry, n_carry, features, labels,
        interpret=interpret, block_d=block_d, block_n=block_n,
    )


def stats_carry_finalize(
    m_carry: Array, n_carry: Array, num_classes: int, feature_dim: int
) -> Tuple[Array, Array, Array]:
    """Unpack a padded (M, N) carry into unpadded (A, B, N).

    Only M's upper triangle was ever accumulated (B is symmetric); the
    mirror + slicing happen here, once per stream, not per batch.
    """
    d_pad = m_carry.shape[1]
    upper = jnp.triu(m_carry[:d_pad])
    B = upper + jnp.triu(m_carry[:d_pad], 1).T
    A = m_carry[d_pad:]
    return (
        A[:num_classes, :feature_dim],
        B[:feature_dim, :feature_dim],
        n_carry[0, :num_classes],
    )


@functools.partial(
    jax.jit, static_argnames=("interpret", "block_n", "block_c", "block_k")
)
def _gnb_logits_fused(
    features: Array,
    w: Array,
    b: Array,
    *,
    interpret: bool,
    block_n: int,
    block_c: int,
    block_k: int,
) -> Array:
    n, d = features.shape
    c = w.shape[0]
    f = _pad_to(_pad_to(features, 0, block_n), 1, block_k)
    wp = _pad_to(_pad_to(w, 0, block_c), 1, block_k)
    bp = _pad_to(b[None, :], 1, block_c)
    out = classifier_kernel.gnb_logits_kernel(
        f, wp, bp, block_n=block_n, block_c=block_c, block_k=block_k,
        interpret=interpret,
    )
    return out[:n, :c]


def gnb_logits(
    features: Array,
    w: Array,
    b: Array,
    *,
    interpret: bool | None = None,
    block_n: int | None = None,
    block_c: int | None = None,
    block_k: int | None = None,
) -> Array:
    """logits = features · wᵀ + b via the fused head kernel.

    Block sizes default to the tuner's verdict for this (n, d, C)
    bucket (``repro.tune.gnb_blocks``) — the kernel constants when no
    tune cache is active — so serving picks up tuned tiles without any
    call-site change.  One jit trace per (padded shape, blocks).
    """
    interpret = (not _on_tpu()) if interpret is None else interpret
    if block_n is None or block_c is None or block_k is None:
        from repro import tune  # deferred: dispatch layer sits above kernels

        tn, tc, tk = tune.gnb_blocks(
            int(features.shape[0]), int(features.shape[1]), int(w.shape[0])
        )
        block_n = tn if block_n is None else block_n
        block_c = tc if block_c is None else block_c
        block_k = tk if block_k is None else block_k
    return _gnb_logits_fused(
        features, w, b,
        interpret=interpret, block_n=block_n, block_c=block_c, block_k=block_k,
    )


@jax.jit
def gnb_logits_jnp(features: Array, w: Array, b: Array) -> Array:
    """The scoring kernel's XLA twin — what ``backend="auto"`` serving
    dispatches to when the tuner measured a jnp win at the bucket."""
    f = features.astype(jnp.float32)
    return f @ w.astype(jnp.float32).T + b.astype(jnp.float32)


# Jitted hot paths the invariant-audit suite (repro.analysis.budgets)
# reaches by name — donation survival is checked on the carry-fold pair
# (the donating twin must alias, the CPU twin is the known-bad fixture),
# the retrace sentinel counts cache entries on both scoring twins.
AUDITED_JITS = {
    "kernels.client_stats": client_stats,
    "kernels.stats_acc": _acc_jit,
    "kernels.stats_acc_donating": _acc_jit_donating,
    "kernels.gnb_logits": _gnb_logits_fused,
    "kernels.gnb_logits_jnp": gnb_logits_jnp,
}


@functools.partial(jax.jit, static_argnames=("causal", "interpret"))
def flash_attention(
    q: Array,
    k: Array,
    v: Array,
    *,
    causal: bool = True,
    interpret: bool | None = None,
) -> Array:
    """Fused attention. q: (B, Sq, Hq, d); k, v: (B, Skv, Hkv, d).

    GQA broadcast + (batch·heads) flattening + block padding happen here;
    padded KV rows are masked out via -inf scores (zero-valued K would
    otherwise attend).
    """
    interpret = (not _on_tpu()) if interpret is None else interpret
    b, sq, hq, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    if hkv != hq:
        k = jnp.repeat(k, hq // hkv, axis=2)
        v = jnp.repeat(v, hq // hkv, axis=2)
    block_q = min(flash_kernel.BLOCK_Q, sq)
    block_k = min(flash_kernel.BLOCK_K, skv)
    qf = jnp.moveaxis(q, 2, 1).reshape(b * hq, sq, d)
    kf = jnp.moveaxis(k, 2, 1).reshape(b * hq, skv, d)
    vf = jnp.moveaxis(v, 2, 1).reshape(b * hq, skv, d)
    qf = _pad_to(qf, 1, block_q)
    kf = _pad_to(kf, 1, block_k)
    vf = _pad_to(vf, 1, block_k)
    out = flash_kernel.flash_attention(
        qf, kf, vf, causal=causal, kv_len=skv,
        block_q=block_q, block_k=block_k, interpret=interpret,
    )
    out = out[:, :sq].reshape(b, hq, sq, d)
    return jnp.moveaxis(out, 1, 2).astype(q.dtype)


@functools.partial(jax.jit, static_argnames=("activation", "interpret"))
def expand_features(
    features: Array,
    projection: Array,
    *,
    activation: str = "relu",
    interpret: bool | None = None,
) -> Array:
    """g = act(features · projection) via the fused expansion kernel."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    n, d = features.shape
    o = projection.shape[1]
    bn, bo, bk = (
        expansion_kernel.BLOCK_N,
        expansion_kernel.BLOCK_O,
        expansion_kernel.BLOCK_K,
    )
    f = _pad_to(_pad_to(features, 0, bn), 1, bk)
    r = _pad_to(_pad_to(projection, 0, bk), 1, bo)
    out = expansion_kernel.expand_kernel(
        f, r, activation=activation, interpret=interpret
    )
    return out[:n, :o]
