"""FlashAttention Pallas TPU kernel — the prefill/train hot-spot.

The portable jnp path (``repro.models.attention.attend_chunked``) keeps
the online-softmax intermediates in HBM between fusions; on TPU this
kernel keeps the whole (q-block × kv-block) working set in VMEM, so the
(B, H, S, S) score tensor NEVER exists in HBM.  §Perf quantifies the
traffic this removes.

Tiling: grid = (batch·heads, q-blocks, kv-blocks); the kv dim is the
innermost (fastest) axis so the f32 accumulator + (m, l) statistics live
in VMEM scratch across the kv sweep.  The final kv step normalizes and
casts into the output block.  GQA is pre-broadcast by the wrapper
(ops-level repeat of K/V heads).

Block defaults (q=256, kv=512, d≤256) keep the working set
(256·d + 512·d + 256·512 floats ≈ 1.1 MB at d=128) comfortably inside
the ~16 MiB/core VMEM with double-buffering headroom.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array

BLOCK_Q = 256
BLOCK_K = 512

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref,  # (bq, d), (bk, d), (bk, d)
    o_ref,  # (bq, d) f32
    m_ref, l_ref, acc_ref,  # VMEM scratch: (bq,), (bq,), (bq, d)
    *,
    sm_scale: float,
    causal: bool,
    block_q: int,
    block_k: int,
    kv_len: int,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * sm_scale  # refs are (1, blk, d)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (bq, bk)

    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    valid = k_pos < kv_len  # zero-padded KV rows must not attend
    if causal:
        q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        valid &= k_pos <= q_pos
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=-1)
    acc_ref[...] = alpha[:, None] * acc_ref[...] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]).astype(
            o_ref.dtype
        )


def flash_attention(
    q: Array,  # (BH, Sq, d)
    k: Array,  # (BH, Skv, d)
    v: Array,  # (BH, Skv, d)
    *,
    causal: bool = True,
    kv_len: int | None = None,
    block_q: int = BLOCK_Q,
    block_k: int = BLOCK_K,
    interpret: bool = False,
) -> Array:
    """Fused attention over flattened (batch·heads) leading dim.

    Sq % block_q == 0 and Skv % block_k == 0 (ops.py pads);
    ``kv_len`` masks zero-padded KV rows (default: all valid).
    """
    bh, sq, d = q.shape
    skv = k.shape[1]
    block_q = min(block_q, sq)
    block_k = min(block_k, skv)
    assert sq % block_q == 0 and skv % block_k == 0
    grid = (bh, sq // block_q, skv // block_k)
    sm_scale = 1.0 / (d**0.5)

    kernel = functools.partial(
        _flash_kernel,
        sm_scale=sm_scale, causal=causal, block_q=block_q, block_k=block_k,
        kv_len=kv_len if kv_len is not None else skv,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), jnp.float32),
        scratch_shapes=[
            _vmem((block_q,), jnp.float32),
            _vmem((block_q,), jnp.float32),
            _vmem((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, dtype)
