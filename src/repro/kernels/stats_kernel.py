"""FedCGS statistics kernels: Gram matrix + per-class feature sums.

TPU adaptation (DESIGN.md §6): a GPU implementation would scatter-add
``A[y[i]] += f[i]``; scatters are hostile to the TPU's systolic MXU, so
both statistics are reformulated as tiled matmuls:

    B = Fᵀ F               (d, d)   Gram / uncentred second moment
    A = onehot(y)ᵀ F       (C, d)   per-class sums

Tiling: grid (i, j, k) over (rows-of-output, cols-of-output, n-chunks).
Each step loads an (nk, bi) and (nk, bj) feature block into VMEM,
multiplies on the MXU and accumulates into the (bi, bj) f32 output
block, which stays resident in VMEM across the k-sweep (output
index_map ignores k).  All dims padded to block multiples by ``ops``.

The one-hot block for A is built IN-KERNEL from a (nk, 1) label block
via ``broadcasted_iota`` comparison — no (n, C) one-hot ever hits HBM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array

# hardware-aligned defaults: MXU is 128x128, VMEM ~16 MiB/core.
BLOCK_D = 128  # output tile (both dims)
BLOCK_N = 512  # row-chunk per grid step


def _gram_kernel(f_i_ref, f_j_ref, out_ref):
    """One (i, j, k) step: out[bi, bj] += f_i[nk, bi]ᵀ @ f_j[nk, bj]."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += jax.lax.dot_general(
        f_i_ref[...],
        f_j_ref[...],
        dimension_numbers=(((0,), (0,)), ((), ())),  # contract over rows
        preferred_element_type=jnp.float32,
    )


def gram(
    features: Array,
    *,
    block_d: int = BLOCK_D,
    block_n: int = BLOCK_N,
    interpret: bool = False,
) -> Array:
    """B = FᵀF. features: (n, d) padded to (block_n, block_d) multiples."""
    n, d = features.shape
    assert n % block_n == 0 and d % block_d == 0, (n, d)
    grid = (d // block_d, d // block_d, n // block_n)
    return pl.pallas_call(
        _gram_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, block_d), lambda i, j, k: (k, i)),
            pl.BlockSpec((block_n, block_d), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((block_d, block_d), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((d, d), jnp.float32),
        interpret=interpret,
    )(features, features)


def _classsum_kernel(labels_ref, f_ref, out_ref, *, block_c: int):
    """One (i, j, k) step: out[ci, dj] += onehot(labels[nk])ᵀ @ f[nk, dj].

    The (nk, bc) one-hot block is built in-register from the label chunk:
    onehot[r, c] = (labels[r] == ci*block_c + c).
    """
    i, k = pl.program_id(0), pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    labels = labels_ref[...]  # (nk, 1) int32
    class_base = i * block_c
    cls = class_base + jax.lax.broadcasted_iota(jnp.int32, (1, block_c), 1)
    onehot = (labels == cls).astype(jnp.float32)  # (nk, bc)
    out_ref[...] += jax.lax.dot_general(
        onehot,
        f_ref[...],
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def class_sum(
    features: Array,
    labels: Array,
    num_classes: int,
    *,
    block_c: int = BLOCK_D,
    block_d: int = BLOCK_D,
    block_n: int = BLOCK_N,
    interpret: bool = False,
) -> Array:
    """A = onehot(labels)ᵀ F. labels: (n, 1) int32; dims pre-padded."""
    n, d = features.shape
    assert labels.shape == (n, 1)
    assert n % block_n == 0 and d % block_d == 0 and num_classes % block_c == 0
    grid = (num_classes // block_c, d // block_d, n // block_n)
    return pl.pallas_call(
        functools.partial(_classsum_kernel, block_c=block_c),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, 1), lambda i, j, k: (k, 0)),
            pl.BlockSpec((block_n, block_d), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((block_c, block_d), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((num_classes, d), jnp.float32),
        interpret=interpret,
    )(labels, features)
