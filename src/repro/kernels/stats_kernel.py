"""FedCGS statistics kernels: Gram matrix + per-class feature sums.

TPU adaptation (DESIGN.md §6): a GPU implementation would scatter-add
``A[y[i]] += f[i]``; scatters are hostile to the TPU's systolic MXU, so
both statistics are reformulated as tiled matmuls:

    B = Fᵀ F               (d, d)   Gram / uncentred second moment
    A = onehot(y)ᵀ F       (C, d)   per-class sums
    N = onehot(y)ᵀ 1       (C,)     per-class counts

Tiling: grid (i, j, k) over (rows-of-output, cols-of-output, n-chunks).
Each step loads an (nk, bi) and (nk, bj) feature block into VMEM,
multiplies on the MXU and accumulates into the (bi, bj) f32 output
block, which stays resident in VMEM across the k-sweep (output
index_map ignores k).  All dims padded to block multiples by ``ops``.

The one-hot block for A is built IN-KERNEL from a (nk, 1) label block
via ``broadcasted_iota`` comparison — no (n, C) one-hot ever hits HBM.

``fused_stats`` is the production path: ONE kernel computes all three
statistics over a single stacked output

    M = [F | onehot(y)]ᵀ F   —  rows [0, d) are B, rows [d, d+C) are A

so the row-tile axis i ranges over d-tiles THEN class-tiles, and N is
accumulated in-register from the same one-hot block during A's k-sweep.
Because B is symmetric the kernel skips the strictly-lower-triangular
gram tiles (i > j) entirely — ~half the Gram MXU work — and the wrapper
mirrors the upper triangle.  ``gram``/``class_sum`` below are the seed's
two-kernel formulation, retained as the benchmark baseline.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

Array = jax.Array

# hardware-aligned defaults: MXU is 128x128, VMEM ~16 MiB/core.
BLOCK_D = 128  # output tile (both dims)
BLOCK_N = 512  # row-chunk per grid step


def _gram_kernel(f_i_ref, f_j_ref, out_ref):
    """One (i, j, k) step: out[bi, bj] += f_i[nk, bi]ᵀ @ f_j[nk, bj]."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += jax.lax.dot_general(
        f_i_ref[...],
        f_j_ref[...],
        dimension_numbers=(((0,), (0,)), ((), ())),  # contract over rows
        preferred_element_type=jnp.float32,
    )


def gram(
    features: Array,
    *,
    block_d: int = BLOCK_D,
    block_n: int = BLOCK_N,
    interpret: bool = False,
) -> Array:
    """B = FᵀF. features: (n, d) padded to (block_n, block_d) multiples."""
    n, d = features.shape
    assert n % block_n == 0 and d % block_d == 0, (n, d)
    grid = (d // block_d, d // block_d, n // block_n)
    return pl.pallas_call(
        _gram_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, block_d), lambda i, j, k: (k, i)),
            pl.BlockSpec((block_n, block_d), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((block_d, block_d), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((d, d), jnp.float32),
        interpret=interpret,
    )(features, features)


def _classsum_kernel(labels_ref, f_ref, out_ref, *, block_c: int):
    """One (i, j, k) step: out[ci, dj] += onehot(labels[nk])ᵀ @ f[nk, dj].

    The (nk, bc) one-hot block is built in-register from the label chunk:
    onehot[r, c] = (labels[r] == ci*block_c + c).
    """
    i, k = pl.program_id(0), pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    labels = labels_ref[...]  # (nk, 1) int32
    class_base = i * block_c
    cls = class_base + jax.lax.broadcasted_iota(jnp.int32, (1, block_c), 1)
    onehot = (labels == cls).astype(jnp.float32)  # (nk, bc)
    out_ref[...] += jax.lax.dot_general(
        onehot,
        f_ref[...],
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def _fused_kernel(
    row_ref, col_ref, f_i_ref, f_j_ref, labels_ref, m_ref, n_ref, *, d_tiles: int
):
    """One (tile, k) step of the fused engine.

    The tile axis enumerates ONLY the work that exists — the upper
    triangle of the Gram tiles (B is symmetric; the wrapper mirrors it)
    followed by the class tiles — via the scalar-prefetched (row, col)
    maps.  Row-tiles < d_tiles are Gram tiles (left operand = feature
    block); the rest are class tiles (left operand = in-register
    one-hot).  Per-class counts N ride along on class tiles' first
    column (col == 0) during the same k-sweep.
    """
    g, k = pl.program_id(0), pl.program_id(1)
    i, j = row_ref[g], col_ref[g]
    is_gram = i < d_tiles
    block_c = f_j_ref.shape[1]  # == block_d; class tiles share the width

    def _match():  # (nk, bc) one-hot block; all-False on padded (-1) rows
        labels = labels_ref[...]  # (nk, 1) int32
        class_base = (i - d_tiles) * block_c
        cls = class_base + jax.lax.broadcasted_iota(jnp.int32, (1, block_c), 1)
        return labels == cls

    @pl.when(k == 0)
    def _init():
        m_ref[...] = jnp.zeros_like(m_ref)

    # branch on the tile KIND so gram tiles never pay for the one-hot
    left = jax.lax.cond(
        is_gram,
        lambda: f_i_ref[...],
        lambda: _match().astype(f_i_ref.dtype),
    )
    m_ref[...] += jax.lax.dot_general(
        left,
        f_j_ref[...],
        dimension_numbers=(((0,), (0,)), ((), ())),  # contract over rows
        preferred_element_type=jnp.float32,
    )

    @pl.when(jnp.logical_and(~is_gram, j == 0))
    def _counts():
        @pl.when(k == 0)
        def _init_n():
            n_ref[...] = jnp.zeros_like(n_ref)

        n_ref[...] += jnp.sum(_match().astype(jnp.float32), axis=0, keepdims=True)


def _tile_maps(d_tiles: int, c_tiles: int):
    """(row, col) tile coordinates: gram upper triangle, then class tiles.

    Ordering is lexicographic in (row, col), so every output block's
    visits are consecutive and the N block index is non-decreasing —
    the Pallas output-revisiting contract.
    """
    rows, cols = [], []
    for i in range(d_tiles):
        for j in range(i, d_tiles):
            rows.append(i)
            cols.append(j)
    for ci in range(c_tiles):
        for j in range(d_tiles):
            rows.append(d_tiles + ci)
            cols.append(j)
    return np.asarray(rows, np.int32), np.asarray(cols, np.int32)


def fused_stats(
    features: Array,
    labels: Array,
    num_classes: int,
    *,
    block_d: int = BLOCK_D,
    block_n: int = BLOCK_N,
    interpret: bool = False,
) -> tuple[Array, Array, Array]:
    """Single-pass (A, B, N) from pre-padded inputs.

    features: (n, d) with n % block_n == 0 and d % block_d == 0; labels:
    (n, 1) int32 with padded rows set to -1; num_classes % block_d == 0.
    Returns A (C, d), B (d, d) f32, N (C,) f32 — still block-padded.

    Grid steps: (T(T+1)/2 + C/bd·T) · n-chunks with T = d/bd — ~35% fewer
    than the seed's two kernels at (d=768, C=128) because the lower
    Gram triangle is never visited at all.
    """
    from jax.experimental.pallas import tpu as pltpu

    n, d = features.shape
    assert labels.shape == (n, 1), labels.shape
    assert n % block_n == 0 and d % block_d == 0, (n, d)
    assert num_classes % block_d == 0, num_classes
    d_tiles = d // block_d
    c_tiles = num_classes // block_d
    row_map, col_map = _tile_maps(d_tiles, c_tiles)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(len(row_map), n // block_n),
        in_specs=[
            # left feature tile; clamped to a valid column on class rows
            # (read but unused there — inputs are read-only, so harmless)
            pl.BlockSpec(
                (block_n, block_d),
                lambda g, k, row, col: (k, jnp.minimum(row[g], d_tiles - 1)),
            ),
            pl.BlockSpec(
                (block_n, block_d), lambda g, k, row, col: (k, col[g])
            ),
            pl.BlockSpec((block_n, 1), lambda g, k, row, col: (k, 0)),
        ],
        out_specs=[
            pl.BlockSpec(
                (block_d, block_d), lambda g, k, row, col: (row[g], col[g])
            ),
            # one N block per class row-tile; parked on block 0 during the
            # gram tiles (index constant => never copied out unwritten)
            pl.BlockSpec(
                (1, block_d),
                lambda g, k, row, col: (0, jnp.maximum(row[g] - d_tiles, 0)),
            ),
        ],
    )
    m, counts = pl.pallas_call(
        functools.partial(_fused_kernel, d_tiles=d_tiles),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((d + num_classes, d), jnp.float32),
            jax.ShapeDtypeStruct((1, num_classes), jnp.float32),
        ],
        interpret=interpret,
    )(jnp.asarray(row_map), jnp.asarray(col_map), features, features, labels)
    upper = jnp.triu(m[:d])  # lower-tri gram tiles were never visited
    B = upper + jnp.triu(m[:d], 1).T
    A = m[d:]
    N = counts[0]
    return A, B, N


def _fused_acc_kernel(
    row_ref,
    col_ref,
    f_i_ref,
    f_j_ref,
    labels_ref,
    m_carry_ref,
    n_carry_ref,
    m_ref,
    n_ref,
    *,
    d_tiles: int,
):
    """One (tile, k) step of the STREAMING fused engine.

    Identical tile walk to :func:`_fused_kernel`, but the k==0 step seeds
    each output block from the carry instead of zeros, so one kernel call
    folds a whole batch into a running (M, N).  The wrapper aliases the
    carry buffers onto the outputs (``input_output_aliases``) so the fold
    updates the running statistic in place — no fresh (d+C, d) allocation
    per batch step.
    """
    g, k = pl.program_id(0), pl.program_id(1)
    i, j = row_ref[g], col_ref[g]
    is_gram = i < d_tiles
    block_c = f_j_ref.shape[1]

    def _match():
        labels = labels_ref[...]  # (nk, 1) int32
        class_base = (i - d_tiles) * block_c
        cls = class_base + jax.lax.broadcasted_iota(jnp.int32, (1, block_c), 1)
        return labels == cls

    @pl.when(k == 0)
    def _init():
        m_ref[...] = m_carry_ref[...]

    left = jax.lax.cond(
        is_gram,
        lambda: f_i_ref[...],
        lambda: _match().astype(f_i_ref.dtype),
    )
    m_ref[...] += jax.lax.dot_general(
        left,
        f_j_ref[...],
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(jnp.logical_and(~is_gram, j == 0))
    def _counts():
        @pl.when(k == 0)
        def _init_n():
            n_ref[...] = n_carry_ref[...]

        n_ref[...] += jnp.sum(_match().astype(jnp.float32), axis=0, keepdims=True)


def fused_stats_acc(
    m_carry: Array,
    n_carry: Array,
    features: Array,
    labels: Array,
    *,
    block_d: int = BLOCK_D,
    block_n: int = BLOCK_N,
    interpret: bool = False,
) -> tuple[Array, Array]:
    """Fold one pre-padded batch into a running stacked statistic.

    m_carry: (d + C, d) f32 — rows [0, d) hold B's UPPER triangle (the
    lower triangle is never read or written), rows [d, d+C) hold A.
    n_carry: (1, C) f32 per-class counts.  features/labels follow the
    :func:`fused_stats` padding contract; C and d are inferred from the
    carry shapes.  Returns the updated (m, n), still in carry layout —
    the carry inputs are donated to the outputs, so a streaming loop
    reuses one buffer across every batch step.
    """
    from jax.experimental.pallas import tpu as pltpu

    n, d = features.shape
    num_classes = n_carry.shape[1]
    assert labels.shape == (n, 1), labels.shape
    assert n % block_n == 0 and d % block_d == 0, (n, d)
    assert num_classes % block_d == 0, num_classes
    assert m_carry.shape == (d + num_classes, d), m_carry.shape
    d_tiles = d // block_d
    c_tiles = num_classes // block_d
    row_map, col_map = _tile_maps(d_tiles, c_tiles)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(len(row_map), n // block_n),
        in_specs=[
            pl.BlockSpec(
                (block_n, block_d),
                lambda g, k, row, col: (k, jnp.minimum(row[g], d_tiles - 1)),
            ),
            pl.BlockSpec(
                (block_n, block_d), lambda g, k, row, col: (k, col[g])
            ),
            pl.BlockSpec((block_n, 1), lambda g, k, row, col: (k, 0)),
            # carry blocks mirror the output blocks exactly
            pl.BlockSpec(
                (block_d, block_d), lambda g, k, row, col: (row[g], col[g])
            ),
            pl.BlockSpec(
                (1, block_d),
                lambda g, k, row, col: (0, jnp.maximum(row[g] - d_tiles, 0)),
            ),
        ],
        out_specs=[
            pl.BlockSpec(
                (block_d, block_d), lambda g, k, row, col: (row[g], col[g])
            ),
            pl.BlockSpec(
                (1, block_d),
                lambda g, k, row, col: (0, jnp.maximum(row[g] - d_tiles, 0)),
            ),
        ],
    )
    m, counts = pl.pallas_call(
        functools.partial(_fused_acc_kernel, d_tiles=d_tiles),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((d + num_classes, d), jnp.float32),
            jax.ShapeDtypeStruct((1, num_classes), jnp.float32),
        ],
        # inputs 0-1 are the scalar-prefetch tile maps, 2-4 the batch;
        # 5 (m_carry) and 6 (n_carry) are donated in place to the outputs
        input_output_aliases={5: 0, 6: 1},
        interpret=interpret,
    )(
        jnp.asarray(row_map), jnp.asarray(col_map), features, features, labels,
        m_carry, n_carry,
    )
    return m, counts


def class_sum(
    features: Array,
    labels: Array,
    num_classes: int,
    *,
    block_c: int = BLOCK_D,
    block_d: int = BLOCK_D,
    block_n: int = BLOCK_N,
    interpret: bool = False,
) -> Array:
    """A = onehot(labels)ᵀ F. labels: (n, 1) int32; dims pre-padded."""
    n, d = features.shape
    assert labels.shape == (n, 1)
    assert n % block_n == 0 and d % block_d == 0 and num_classes % block_c == 0
    grid = (num_classes // block_c, d // block_d, n // block_n)
    return pl.pallas_call(
        functools.partial(_classsum_kernel, block_c=block_c),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, 1), lambda i, j, k: (k, 0)),
            pl.BlockSpec((block_n, block_d), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((block_c, block_d), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((num_classes, d), jnp.float32),
        interpret=interpret,
    )(labels, features)
