"""Fused GNB-head logits kernel: logits = F · Wᵀ + b.

Grid (i, j, k) over (row tiles, class tiles, d chunks); f32 VMEM
accumulator; the bias joins on the LAST k step so the add is fused with
the final accumulation (no separate elementwise pass over (n, C)).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array

BLOCK_N = 256
BLOCK_C = 128
BLOCK_K = 512


def _logits_kernel(f_ref, w_ref, b_ref, out_ref):
    k = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += jax.lax.dot_general(
        f_ref[...],
        w_ref[...],
        dimension_numbers=(((1,), (1,)), ((), ())),  # (n, dk) x (C, dk)ᵀ
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == nk - 1)
    def _bias():
        out_ref[...] += b_ref[...]  # (1, bc) broadcasts over rows


def gnb_logits_kernel(
    features: Array,
    w: Array,
    b: Array,
    *,
    block_n: int = BLOCK_N,
    block_c: int = BLOCK_C,
    block_k: int = BLOCK_K,
    interpret: bool = False,
) -> Array:
    """features (n, d), w (C, d), b (1, C) — all pre-padded to blocks."""
    n, d = features.shape
    c = w.shape[0]
    assert n % block_n == 0 and d % block_k == 0 and c % block_c == 0
    grid = (n // block_n, c // block_c, d // block_k)
    return pl.pallas_call(
        _logits_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_c, block_k), lambda i, j, k: (j, k)),
            pl.BlockSpec((1, block_c), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_n, block_c), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, c), jnp.float32),
        interpret=interpret,
    )(features, w, b)
