"""Pallas TPU kernels for the FedCGS compute hot-spots (DESIGN.md §6).

- ``stats_kernel``      — Gram matrix B = FᵀF and class-sum A = onehot(y)ᵀF
                          as MXU matmuls with f32 VMEM accumulation.
- ``classifier_kernel`` — fused GNB logits F·Wᵀ + b.
- ``expansion_kernel``  — fused feature expansion act(F·R).

``ops`` holds the jit'd public wrappers; ``ref`` the pure-jnp oracles the
tests sweep against.  Kernels target TPU (BlockSpec / VMEM) and are
validated with ``interpret=True`` on CPU.
"""

from repro.kernels.ops import (
    client_stats,
    client_stats_acc,
    expand_features,
    flash_attention,
    gnb_logits,
    gnb_logits_jnp,
    stats_carry_finalize,
    stats_carry_init,
)

__all__ = [
    "client_stats",
    "client_stats_acc",
    "stats_carry_init",
    "stats_carry_finalize",
    "gnb_logits",
    "gnb_logits_jnp",
    "expand_features",
    "flash_attention",
]
