"""Pure-jnp oracles for every kernel (the tests' ground truth)."""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def client_stats_ref(
    features: Array, labels: Array, num_classes: int
) -> Tuple[Array, Array, Array]:
    """(A, B, N): class-sums, Gram matrix, class counts — f32 accumulation."""
    f = features.astype(jnp.float32)
    onehot = jax.nn.one_hot(labels, num_classes, dtype=jnp.float32)
    return onehot.T @ f, f.T @ f, jnp.sum(onehot, axis=0)


def gnb_logits_ref(features: Array, w: Array, b: Array) -> Array:
    """features (n, d) · w (C, d)ᵀ + b (C,) in f32."""
    return features.astype(jnp.float32) @ w.astype(jnp.float32).T + b.astype(
        jnp.float32
    )


def flash_attention_ref(q: Array, k: Array, v: Array, *, causal: bool) -> Array:
    """Dense softmax attention over (BH, S, d) — the flash kernel's oracle."""
    d = q.shape[-1]
    s = jnp.einsum(
        "bqd,bkd->bqk", q.astype(jnp.float32), k.astype(jnp.float32)
    ) / jnp.sqrt(jnp.asarray(d, jnp.float32))
    if causal:
        sq, skv = q.shape[1], k.shape[1]
        mask = jnp.arange(skv)[None, :] <= jnp.arange(sq)[:, None]
        s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32))


def expand_features_ref(features: Array, projection: Array, activation: str) -> Array:
    h = features.astype(jnp.float32) @ projection.astype(jnp.float32)
    if activation == "relu":
        return jax.nn.relu(h)
    if activation == "gelu":
        return jax.nn.gelu(h)
    if activation == "tanh":
        return jnp.tanh(h)
    if activation == "identity":
        return h
    raise ValueError(activation)
