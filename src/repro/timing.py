"""The repo's one wall-clock primitive.

Every layer that times something — the benchmark reporter, the serving
metrics, the launch CLIs — wraps :func:`timed` instead of hand-rolling
``time.perf_counter()`` pairs, so timing semantics can't drift between
them.  Deliberately dependency-free: importing this pulls in nothing.
"""

from __future__ import annotations

import time
from typing import Callable, Tuple


def timed(fn: Callable, *args, **kwargs) -> Tuple[object, float]:
    """(result, wall_seconds) for one call."""
    t0 = time.perf_counter()
    out = fn(*args, **kwargs)
    return out, time.perf_counter() - t0
