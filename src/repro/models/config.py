"""Unified model configuration covering all ten assigned architectures.

One configurable decoder/enc-dec family expresses dense, MoE, SSM,
hybrid, VLM and audio backbones.  The stack is declared as a list of
:class:`BlockGroup`s — each group is a repeating *pattern* of block
kinds that is executed under one ``jax.lax.scan`` with layer-stacked
parameters.  This keeps the HLO size bounded (critical for compiling
48-layer models for 512 SPMD partitions on the CPU backend) while still
expressing heterogeneous stacks:

- llama4 MoE-interleave-2 -> pattern ("dense", "moe") x 24
- zamba2 hybrid           -> pattern ("mamba",)*6 + ("shared_attn",) x 6
                             + a tail group of 2 mamba blocks
- whisper enc-dec         -> encoder groups + decoder groups with
                             cross-attention blocks

Block kinds:
  dense        attn + dense MLP
  moe          attn + mixture-of-experts MLP (optionally + shared experts)
  mamba        Mamba2 SSD mixer (no MLP when d_ff == 0)
  shared_attn  a weight-TIED attention block (zamba2); parameters are
               declared once at stack level, not per group repeat
  encdec       self-attn + cross-attn + dense MLP (whisper decoder)
  enc          bidirectional attn + dense MLP (whisper encoder)
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

BLOCK_KINDS = ("dense", "moe", "mamba", "shared_attn", "encdec", "enc")


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    expert_d_ff: int
    num_shared_experts: int = 0
    shared_d_ff: int = 0  # d_ff of the always-on shared expert block
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01  # load-balance loss weight
    router_z_weight: float = 1e-3

    def without_shared(self) -> "MoEConfig":
        return dataclasses.replace(self, num_shared_experts=0, shared_d_ff=0)


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int  # N, the SSM state size per head
    head_dim: int = 64  # P, channels per SSD head
    expand: int = 2  # d_inner = expand * d_model
    conv_width: int = 4
    chunk_len: int = 64  # SSD chunk length (training/prefill)

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def num_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class BlockGroup:
    """``repeat`` x ``pattern`` executed under one lax.scan."""

    pattern: Tuple[str, ...]
    repeat: int

    def __post_init__(self):
        for k in self.pattern:
            if k not in BLOCK_KINDS:
                raise ValueError(f"unknown block kind {k!r}")

    @property
    def layers(self) -> int:
        return len(self.pattern) * self.repeat


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    d_model: int
    num_layers: int  # informative total (sum over groups must match)
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # default d_model // num_heads
    groups: Tuple[BlockGroup, ...] = ()
    # --- positional encoding ---
    rope: str = "standard"  # standard | 2d | mrope | none | learned
    rope_theta: float = 10000.0
    max_seq_len: int = 1 << 20
    # --- MLP ---
    mlp_act: str = "silu"  # silu (SwiGLU) | geglu | gelu (plain 2-mat)
    # --- attention variants ---
    causal: bool = True
    sliding_window: Optional[int] = None  # None = full attention
    attn_logit_softcap: Optional[float] = None
    # chunked-attention tile sizes (§Perf knob; VMEM-bounded on TPU)
    attn_q_chunk: int = 512
    attn_kv_chunk: int = 1024
    # --- mixtures / ssm ---
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # --- enc-dec (audio) ---
    encoder_layers: int = 0
    encoder_seq_len: int = 0  # frames after the (stubbed) conv frontend
    # --- multimodal stub ---
    vision_tokens: int = 0  # >0 => input_specs add patch embeddings
    # --- norms / misc ---
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    citation: str = ""

    def __post_init__(self):
        if self.groups:
            total = sum(g.layers for g in self.groups)
            # shared_attn blocks are "extra" relative to the advertised
            # layer count for zamba2 (38 mamba layers + tied attn blocks)
            main = sum(
                g.repeat * sum(1 for k in g.pattern if k != "shared_attn")
                for g in self.groups
            )
            if main != self.num_layers:
                raise ValueError(
                    f"{self.name}: groups give {main} main layers "
                    f"(+{total - main} shared) but num_layers={self.num_layers}"
                )

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.num_heads

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def attention_free(self) -> bool:
        return all(
            k == "mamba" for g in self.groups for k in g.pattern
        )

    @property
    def subquadratic(self) -> bool:
        """Can this config serve a 500k-token context?"""
        if self.attention_free:
            return True
        if self.family == "hybrid":
            # zamba2's attention blocks get a sliding window in long mode
            return True
        return self.sliding_window is not None

    def with_sliding_window(self, window: int) -> "ModelConfig":
        return dataclasses.replace(self, sliding_window=window)

    def reduced(
        self,
        *,
        d_model: int = 256,
        num_layers: Optional[int] = None,
        vocab_size: int = 512,
        max_experts: int = 4,
        seq_len_cap: int = 128,
    ) -> "ModelConfig":
        """Smoke-test variant of the SAME family: <=2-ish layers,
        d_model<=512, <=4 experts, tiny vocab.  The group structure is
        preserved (one repeat of each distinct pattern) so the smoke test
        exercises the real heterogeneous stack."""
        groups = tuple(BlockGroup(g.pattern, 1) for g in self.groups[:2]) or (
            BlockGroup(("dense",), 2),
        )
        main = sum(
            g.repeat * sum(1 for k in g.pattern if k != "shared_attn")
            for g in groups
        )
        heads = max(2, min(self.num_heads, 4))
        kv = max(1, min(self.num_kv_heads, heads))
        while heads % kv:
            kv -= 1
        head_dim = max(16, d_model // heads)
        moe = None
        if self.moe is not None:
            moe = dataclasses.replace(
                self.moe,
                num_experts=min(self.moe.num_experts, max_experts),
                top_k=min(self.moe.top_k, min(self.moe.num_experts, max_experts)),
                expert_d_ff=max(32, d_model // 2),
                num_shared_experts=min(self.moe.num_shared_experts, 1),
                shared_d_ff=max(32, d_model // 2) if self.moe.num_shared_experts else 0,
            )
        ssm = None
        if self.ssm is not None:
            ssm = dataclasses.replace(
                self.ssm, state_dim=min(self.ssm.state_dim, 16), head_dim=32,
                chunk_len=16,
            )
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            d_model=d_model,
            num_layers=main,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=head_dim,
            d_ff=0 if self.d_ff == 0 else max(64, d_model * 2),
            vocab_size=vocab_size,
            groups=groups,
            moe=moe,
            ssm=ssm,
            encoder_layers=min(self.encoder_layers, 2),
            encoder_seq_len=min(self.encoder_seq_len, 32),
            vision_tokens=min(self.vision_tokens, 16),
            sliding_window=(
                min(self.sliding_window, seq_len_cap // 2)
                if self.sliding_window
                else None
            ),
            max_seq_len=seq_len_cap * 4,
        )


# ---------------------------------------------------------------------------
# Input shapes assigned to this paper.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}

# shrunken counterparts (same kinds) used by smoke tests / --reduced runs
REDUCED_SHAPES = {
    "train_4k": InputShape("train_4k", 256, 8, "train"),
    "prefill_32k": InputShape("prefill_32k", 512, 4, "prefill"),
    "decode_32k": InputShape("decode_32k", 512, 8, "decode"),
    "long_500k": InputShape("long_500k", 2_048, 1, "decode"),
}
