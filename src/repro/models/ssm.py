"""Mamba2 SSD (state-space duality) mixer — arXiv:2405.21060.

Implements the chunked SSD algorithm: within a chunk the recurrence is
computed as attention-like dense matmuls (MXU-friendly), across chunks a
``jax.lax.associative_scan`` propagates the (decay, state) pair.  A naive
token-by-token recurrence lives in ``ssd_reference`` and is what the
tests compare against.

Recurrence (per head h, channels P=head_dim, state N=state_dim):

    h_t = exp(Δ_t a) · h_{t-1} + Δ_t · B_t ⊗ x_t           (B_t ∈ R^N, x_t ∈ R^P)
    y_t = C_tᵀ h_t + D · x_t

with a = −exp(A_log) < 0 and Δ_t = softplus(dt_t + dt_bias).

Decode serving keeps ``(ssm_state, conv_state)`` caches and advances one
token in O(H·P·N) — this is what makes mamba2/zamba2 the native
long_500k architectures.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ParamSpec
from repro.models.config import SSMConfig
from repro.models.mlp import rmsnorm

Array = jax.Array


# ---------------------------------------------------------------------------
# parameter specs
# ---------------------------------------------------------------------------


def mamba_specs(
    d_model: int, cfg: SSMConfig, *, prefix_layers: int = 0
) -> Dict[str, ParamSpec]:
    """One (optionally layer-stacked) Mamba2 mixer's parameters.

    in_proj emits [z (d_in), x (d_in), B (N), C (N), dt (H)] in one matmul;
    a depthwise causal conv runs over the concatenated (x, B, C) channels.
    """
    d_in = cfg.d_inner(d_model)
    H = cfg.num_heads(d_model)
    N = cfg.state_dim
    conv_ch = d_in + 2 * N
    L = (prefix_layers,) if prefix_layers else ()
    lx = ("layers",) if prefix_layers else ()
    return {
        "in_proj": ParamSpec(
            L + (d_model, 2 * d_in + 2 * N + H), lx + ("embed", "inner")
        ),
        "conv_w": ParamSpec(L + (cfg.conv_width, conv_ch), lx + (None, "inner")),
        "conv_b": ParamSpec(L + (conv_ch,), lx + ("inner",), init="zeros"),
        "A_log": ParamSpec(L + (H,), lx + (None,), init="zeros"),
        "dt_bias": ParamSpec(L + (H,), lx + (None,), init="zeros"),
        "D": ParamSpec(L + (H,), lx + (None,), init="ones"),
        "norm": ParamSpec(L + (d_in,), lx + ("inner",), init="zeros"),
        "out_proj": ParamSpec(L + (d_in, d_model), lx + ("inner", "embed")),
    }


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SSMCache:
    """Decode-time state for a stack of mamba layers.

    ssm_state: (L, B, H, P, N); conv_state: (L, B, W-1, conv_ch).
    """

    ssm_state: Array
    conv_state: Array

    @staticmethod
    def zeros(
        layers: int, batch: int, d_model: int, cfg: SSMConfig, dtype=jnp.float32
    ) -> "SSMCache":
        d_in = cfg.d_inner(d_model)
        H = cfg.num_heads(d_model)
        conv_ch = d_in + 2 * cfg.state_dim
        return SSMCache(
            ssm_state=jnp.zeros(
                (layers, batch, H, cfg.head_dim, cfg.state_dim), dtype
            ),
            conv_state=jnp.zeros((layers, batch, cfg.conv_width - 1, conv_ch), dtype),
        )


# ---------------------------------------------------------------------------
# depthwise causal conv
# ---------------------------------------------------------------------------


def causal_conv(x: Array, w: Array, b: Array) -> Array:
    """Depthwise causal conv over (B, S, CH) with taps (W, CH)."""
    width = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for tap in range(width):  # width is 4 — unrolled adds, no conv primitive
        out = out + pad[:, tap : tap + x.shape[1], :] * w[tap]
    return out + b


def causal_conv_step(
    x_t: Array, conv_state: Array, w: Array, b: Array
) -> Tuple[Array, Array]:
    """One-token conv using the (B, W-1, CH) tail state; returns new state."""
    window = jnp.concatenate([conv_state, x_t[:, None, :]], axis=1)  # (B, W, CH)
    out = jnp.einsum("bwc,wc->bc", window, w) + b
    return out, window[:, 1:, :]


# ---------------------------------------------------------------------------
# SSD chunked scan
# ---------------------------------------------------------------------------


def ssd_chunked(
    x: Array,
    dt: Array,
    A_log: Array,
    B: Array,
    C: Array,
    *,
    chunk: int,
    initial_state: Optional[Array] = None,
) -> Tuple[Array, Array]:
    """Chunked SSD forward.

    Args:
      x:  (b, s, H, P) input heads.
      dt: (b, s, H) post-softplus step sizes.
      A_log: (H,) — a = −exp(A_log).
      B, C: (b, s, N) shared across heads (n_groups = 1).
      chunk: chunk length Q (s must be divisible by Q; callers pad).
      initial_state: optional (b, H, P, N) carried state (decode-continuation).

    Returns:
      y: (b, s, H, P) outputs (without the D·x skip — caller adds it),
      final_state: (b, H, P, N).
    """
    b, s, H, P = x.shape
    N = B.shape[-1]
    assert s % chunk == 0, f"seq {s} not divisible by chunk {chunk}"
    nc, q = s // chunk, chunk
    f32 = jnp.float32

    xc = x.reshape(b, nc, q, H, P).astype(f32)
    dtc = dt.reshape(b, nc, q, H).astype(f32)
    Bc = B.reshape(b, nc, q, N).astype(f32)
    Cc = C.reshape(b, nc, q, N).astype(f32)
    a = -jnp.exp(A_log.astype(f32))  # (H,)
    dA = dtc * a  # (b, nc, q, H)  (negative)
    cum = jnp.cumsum(dA, axis=2)  # (b, nc, q, H)

    # ---- intra-chunk (diagonal blocks): attention-like matmuls ----
    # Contribution of step j's input to step i's output decays by
    # exp(Σ_{j<t≤i} dA_t) = exp(cum_i − cum_j); the dt_j factor applies
    # separately.  This matches the recurrence where step j's own decay
    # multiplies the PREVIOUS state, not its own input.
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (b,nc,i,j,H)
    tri = jnp.tril(jnp.ones((q, q), bool))
    Lmat = jnp.where(tri[None, None, :, :, None], jnp.exp(seg), 0.0)
    scores = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)  # (b,nc,q,q)
    w = scores[..., None] * Lmat * dtc[:, :, None, :, :]  # (b,nc,i,j,H)
    y_diag = jnp.einsum("bcijh,bcjhp->bcihp", w, xc)

    # ---- chunk summary states ----
    # S_c = Σ_j exp(cum_last − cum_j) · dt_j · B_j ⊗ x_j   (b,nc,H,P,N)
    decay_out = jnp.exp(cum[:, :, -1:, :] - cum)  # (b,nc,q,H)
    S = jnp.einsum("bcjn,bcjh,bcjhp->bchpn", Bc, dtc * decay_out, xc)

    # ---- inter-chunk recurrence over nc via associative scan ----
    g = jnp.exp(cum[:, :, -1, :])  # (b,nc,H) whole-chunk decay
    if initial_state is None:
        init = jnp.zeros((b, H, P, N), f32)
    else:
        init = initial_state.astype(f32)

    def combine(left, right):
        g1, s1 = left
        g2, s2 = right
        return g1 * g2, g2[..., None, None] * s1 + s2

    gs, states = jax.lax.associative_scan(combine, (g, S), axis=1)
    # states[c] = state AFTER chunk c assuming zero init; fold init in:
    states = states + gs[..., None, None] * init[:, None]
    final_state = states[:, -1]
    # h_prev[c] = state BEFORE chunk c
    h_prev = jnp.concatenate([init[:, None], states[:, :-1]], axis=1)

    # ---- off-diagonal: y_off[i] = exp(cum_i)·C_i · h_prev ----
    decay_in = jnp.exp(cum)  # (b,nc,q,H)
    y_off = jnp.einsum("bcqn,bchpn,bcqh->bcqhp", Cc, h_prev, decay_in)

    y = (y_diag + y_off).reshape(b, s, H, P)
    return y.astype(x.dtype), final_state


def ssd_step(
    x_t: Array,
    dt_t: Array,
    A_log: Array,
    B_t: Array,
    C_t: Array,
    state: Array,
) -> Tuple[Array, Array]:
    """One-token recurrence. x_t: (b,H,P); dt_t: (b,H); B_t/C_t: (b,N);
    state: (b,H,P,N). Returns (y_t, new_state)."""
    f32 = jnp.float32
    a = -jnp.exp(A_log.astype(f32))
    decay = jnp.exp(dt_t.astype(f32) * a)  # (b,H)
    upd = (
        dt_t.astype(f32)[..., None, None]
        * x_t.astype(f32)[..., None]
        * B_t.astype(f32)[:, None, None, :]
    )
    new_state = decay[..., None, None] * state.astype(f32) + upd
    y = jnp.einsum("bhpn,bn->bhp", new_state, C_t.astype(f32))
    return y.astype(x_t.dtype), new_state


def ssd_reference(
    x: Array, dt: Array, A_log: Array, B: Array, C: Array,
    initial_state: Optional[Array] = None,
) -> Tuple[Array, Array]:
    """Naive O(s) sequential oracle for tests."""
    b, s, H, P = x.shape
    N = B.shape[-1]
    state = (
        jnp.zeros((b, H, P, N), jnp.float32)
        if initial_state is None
        else initial_state.astype(jnp.float32)
    )

    def step(state, inp):
        x_t, dt_t, B_t, C_t = inp
        y_t, state = ssd_step(x_t, dt_t, A_log, B_t, C_t, state)
        return state, y_t

    xs = (
        jnp.moveaxis(x, 1, 0),
        jnp.moveaxis(dt, 1, 0),
        jnp.moveaxis(B, 1, 0),
        jnp.moveaxis(C, 1, 0),
    )
    state, ys = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(ys, 0, 1), state


# ---------------------------------------------------------------------------
# full mixer (in_proj → conv → SSD → gated norm → out_proj)
# ---------------------------------------------------------------------------


def _split_proj(z_all: Array, d_in: int, N: int, H: int):
    z, xBC, dt = jnp.split(z_all, [d_in, d_in + d_in + 2 * N], axis=-1)
    return z, xBC, dt


def mamba_mixer(
    params: Dict[str, Array],
    x: Array,
    cfg: SSMConfig,
    d_model: int,
    *,
    initial_state: Optional[Array] = None,
    return_conv_tail: bool = False,
) -> Tuple[Array, Array] | Tuple[Array, Array, Array]:
    """Sequence forward. x: (B, S, d_model) -> (B, S, d_model), final SSD state.

    With ``return_conv_tail`` also returns the last (conv_width-1) raw
    xBC channels — the conv state a decode continuation needs.
    """
    b, s, _ = x.shape
    d_in = cfg.d_inner(d_model)
    H = cfg.num_heads(d_model)
    N = cfg.state_dim

    z_all = x @ params["in_proj"]  # (b, s, 2*d_in + 2N + H)
    z, xBC_raw, dt = _split_proj(z_all, d_in, N, H)
    xBC = jax.nn.silu(causal_conv(xBC_raw, params["conv_w"], params["conv_b"]))
    xs, B, C = jnp.split(xBC, [d_in, d_in + N], axis=-1)
    dt = jax.nn.softplus(dt + params["dt_bias"])  # (b, s, H)

    xh = xs.reshape(b, s, H, cfg.head_dim)
    pad = (-s) % cfg.chunk_len
    if pad:
        padder = lambda t: jnp.pad(t, [(0, 0), (0, pad)] + [(0, 0)] * (t.ndim - 2))
        xh, dt, B, C = padder(xh), padder(dt), padder(B), padder(C)
    y, final_state = ssd_chunked(
        xh, dt, params["A_log"], B, C, chunk=cfg.chunk_len,
        initial_state=initial_state,
    )
    if pad:
        y = y[:, :s]
        dt = dt[:, :s]
    y = y + params["D"][None, None, :, None] * xs.reshape(b, s, H, cfg.head_dim)
    y = y.reshape(b, s, d_in)
    y = rmsnorm(y * jax.nn.silu(z), params["norm"])
    out = y @ params["out_proj"]
    if return_conv_tail:
        w1 = cfg.conv_width - 1
        tail = xBC_raw[:, -w1:, :]
        if s < w1:  # left-pad with zeros (cold conv state)
            tail = jnp.pad(xBC_raw, ((0, 0), (w1 - s, 0), (0, 0)))
        return out, final_state, tail
    return out, final_state


def mamba_mixer_step(
    params: Dict[str, Array],
    x_t: Array,
    ssm_state: Array,
    conv_state: Array,
    cfg: SSMConfig,
    d_model: int,
) -> Tuple[Array, Array, Array]:
    """Single-token decode. x_t: (B, d_model). Returns (y, ssm_state, conv_state)."""
    b, _ = x_t.shape
    d_in = cfg.d_inner(d_model)
    H = cfg.num_heads(d_model)
    N = cfg.state_dim

    z_all = x_t @ params["in_proj"]
    z, xBC, dt = _split_proj(z_all, d_in, N, H)
    xBC, conv_state = causal_conv_step(xBC, conv_state, params["conv_w"], params["conv_b"])
    xBC = jax.nn.silu(xBC)
    xs, B, C = jnp.split(xBC, [d_in, d_in + N], axis=-1)
    dt = jax.nn.softplus(dt + params["dt_bias"])  # (b, H)

    xh = xs.reshape(b, H, cfg.head_dim)
    y, ssm_state = ssd_step(xh, dt, params["A_log"], B, C, ssm_state)
    y = y + params["D"][None, :, None] * xh
    y = y.reshape(b, d_in)
    y = rmsnorm(y * jax.nn.silu(z), params["norm"])
    return y @ params["out_proj"], ssm_state, conv_state


def mamba_flops(d_model: int, cfg: SSMConfig, tokens: int) -> int:
    """Model FLOPs per the SSD recurrence (matmul-dominated terms)."""
    d_in = cfg.d_inner(d_model)
    H = cfg.num_heads(d_model)
    N = cfg.state_dim
    proj = 2 * tokens * d_model * (2 * d_in + 2 * N + H) + 2 * tokens * d_in * d_model
    conv = 2 * tokens * (d_in + 2 * N) * cfg.conv_width
    # state update + readout per token: H·P·N MACs each
    ssd = 2 * tokens * H * cfg.head_dim * N * 2
    return proj + conv + ssd
