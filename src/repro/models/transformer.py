"""The unified backbone stack: dense / MoE / SSM / hybrid / VLM / audio.

A model is a list of :class:`BlockGroup`s; each group is ``repeat`` copies
of a block ``pattern`` executed under ONE ``jax.lax.scan`` with
layer-stacked parameters (bounded HLO size — critical when compiling a
48-layer model for 512 SPMD partitions on the CPU backend).

Three entry points per architecture (DESIGN.md §3):

- :func:`forward`       — full-sequence forward (train / prefill / stats).
- :func:`prefill`       — forward + KV/SSM cache build.
- :func:`decode_step`   — ONE token against a pre-filled cache.

Caches are plain nested dicts (pytrees) so `jax.jit` shardings and
`tree_map` apply without ceremony:

    cache = {
      "groups": [ { "p<i>": {"k","v"} | {"ssm","conv"} | {...,"xk","xv"} } ],
      "index":      ()        int32   — #valid tokens,
      "positions":  (S_c,)    int32   — absolute position held by each
                                        self-attn cache slot (ring-aware),
    }
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.ad_checkpoint
import jax.numpy as jnp

from repro.models import attention as attn_lib
from repro.models import ssm as ssm_lib
from repro.models.common import ParamSpec, init_params
from repro.models.config import BlockGroup, ModelConfig
from repro.models.mlp import GATED, mlp_apply, mlp_flops, mlp_specs, norm_spec, rmsnorm
from repro.models.moe import moe_apply, moe_flops, moe_specs
from repro.sharding import constrain

Array = jax.Array
PyTree = Any

_NEG_BIG = jnp.int32(1 << 30)  # sentinel "invalid slot" position (fails causal mask)

# Sequences at or above this switch to flash-style chunked attention
# (attend_chunked) so (S, S) logits are never materialized.
_CHUNKED_ATTN_THRESHOLD = 1024


# ===========================================================================
# parameter specs
# ===========================================================================


def _attn_specs(cfg: ModelConfig, *, prefix_layers: int = 0) -> Dict[str, ParamSpec]:
    d, dh = cfg.d_model, cfg.resolved_head_dim
    hq, hkv = cfg.num_heads, cfg.num_kv_heads
    L = (prefix_layers,) if prefix_layers else ()
    lx = ("layers",) if prefix_layers else ()
    return {
        "wq": ParamSpec(L + (d, hq * dh), lx + ("embed", "heads")),
        "wk": ParamSpec(L + (d, hkv * dh), lx + ("embed", "kv_heads")),
        "wv": ParamSpec(L + (d, hkv * dh), lx + ("embed", "kv_heads")),
        "wo": ParamSpec(L + (hq * dh, d), lx + ("heads", "embed")),
    }


def _block_specs(kind: str, cfg: ModelConfig, repeat: int) -> Dict[str, PyTree]:
    """Spec subtree for one pattern position, stacked over ``repeat``."""
    R = repeat
    if kind in ("dense", "enc"):
        return {
            "norm1": norm_spec(cfg.d_model, prefix_layers=R),
            "attn": _attn_specs(cfg, prefix_layers=R),
            "norm2": norm_spec(cfg.d_model, prefix_layers=R),
            "mlp": mlp_specs(cfg.d_model, cfg.d_ff, cfg.mlp_act, prefix_layers=R),
        }
    if kind == "moe":
        assert cfg.moe is not None
        return {
            "norm1": norm_spec(cfg.d_model, prefix_layers=R),
            "attn": _attn_specs(cfg, prefix_layers=R),
            "norm2": norm_spec(cfg.d_model, prefix_layers=R),
            "moe": moe_specs(cfg.d_model, cfg.moe, cfg.mlp_act, prefix_layers=R),
        }
    if kind == "mamba":
        assert cfg.ssm is not None
        return {
            "norm1": norm_spec(cfg.d_model, prefix_layers=R),
            "mixer": ssm_lib.mamba_specs(cfg.d_model, cfg.ssm, prefix_layers=R),
        }
    if kind == "shared_attn":
        # weight-TIED: params declared once at stack level; the group only
        # owns a per-invocation norm (cheap, keeps scan xs non-empty).
        return {"norm1": norm_spec(cfg.d_model, prefix_layers=R)}
    if kind == "encdec":
        return {
            "norm1": norm_spec(cfg.d_model, prefix_layers=R),
            "attn": _attn_specs(cfg, prefix_layers=R),
            "norm_x": norm_spec(cfg.d_model, prefix_layers=R),
            "xattn": _attn_specs(cfg, prefix_layers=R),
            "norm2": norm_spec(cfg.d_model, prefix_layers=R),
            "mlp": mlp_specs(cfg.d_model, cfg.d_ff, cfg.mlp_act, prefix_layers=R),
        }
    raise ValueError(f"unknown block kind {kind!r}")


def _group_specs(group: BlockGroup, cfg: ModelConfig) -> Dict[str, PyTree]:
    return {
        f"p{i}": _block_specs(kind, cfg, group.repeat)
        for i, kind in enumerate(group.pattern)
    }


def build_specs(cfg: ModelConfig) -> Dict[str, PyTree]:
    """The full parameter-spec tree for one architecture."""
    d, v = cfg.d_model, cfg.vocab_size
    specs: Dict[str, PyTree] = {
        "embed": ParamSpec((v, d), ("vocab", "embed"), init="embed", scale=0.02),
        "groups": [_group_specs(g, cfg) for g in cfg.groups],
        "final_norm": norm_spec(d),
    }
    if not cfg.tie_embeddings:
        specs["unembed"] = ParamSpec((d, v), ("embed", "vocab"), scale=0.02)
    if any("shared_attn" in g.pattern for g in cfg.groups):
        specs["shared_attn"] = {
            "attn": _attn_specs(cfg),
            "norm2": norm_spec(d),
            "mlp": mlp_specs(d, cfg.d_ff, cfg.mlp_act),
        }
    if cfg.is_encdec:
        enc_group = BlockGroup(("enc",), cfg.encoder_layers)
        specs["encoder"] = {
            "pos": ParamSpec(
                (cfg.encoder_seq_len, d), (None, "embed"), init="embed", scale=0.02
            ),
            "groups": [_group_specs(enc_group, cfg)],
            "final_norm": norm_spec(d),
        }
        specs["dec_pos"] = ParamSpec(
            (min(cfg.max_seq_len, 32768), d),
            (None, "embed"),
            init="embed",
            scale=0.02,
        )
    return specs


def init_model(cfg: ModelConfig, key: jax.Array) -> PyTree:
    return init_params(build_specs(cfg), key)


# ===========================================================================
# context threaded through block application
# ===========================================================================


@dataclasses.dataclass(frozen=True)
class Ctx:
    cfg: ModelConfig
    positions: Array  # (B, S) or (3, B, S) for mrope
    enc_out: Optional[Array] = None  # (B, S_enc, d) whisper encoder states
    moe_dispatch_shards: int = 1  # §Perf: per-shard MoE dispatch
    # decode-only fields
    index: Optional[Array] = None  # () — #tokens already in the cache
    cache_positions: Optional[Array] = None  # (S_c,) absolute slot positions


def _zero_aux() -> Dict[str, Array]:
    return {
        "aux_loss": jnp.zeros((), jnp.float32),
        "router_z_loss": jnp.zeros((), jnp.float32),
        "dropped_fraction": jnp.zeros((), jnp.float32),
    }


def _acc_aux(a: Dict[str, Array], b: Dict[str, Array]) -> Dict[str, Array]:
    return {k: a[k] + b[k] for k in a}


# ===========================================================================
# per-block sequence application (train / prefill / stats)
# ===========================================================================


def _attn_seq(
    p: Dict[str, Array], x: Array, ctx: Ctx, *, causal: bool
) -> Tuple[Array, Tuple[Array, Array]]:
    cfg = ctx.cfg
    b, s, d = x.shape
    dh, hq, hkv = cfg.resolved_head_dim, cfg.num_heads, cfg.num_kv_heads
    q = constrain((x @ p["wq"]).reshape(b, s, hq, dh), "act_batch", None, "act_heads", None)
    k = (x @ p["wk"]).reshape(b, s, hkv, dh)
    v = (x @ p["wv"]).reshape(b, s, hkv, dh)
    q, k = attn_lib.apply_rope(
        q, k, ctx.positions, mode=cfg.rope, theta=cfg.rope_theta
    )
    if s >= _CHUNKED_ATTN_THRESHOLD:
        out = attn_lib.attend_chunked(
            q, k, v,
            causal=causal,
            sliding_window=cfg.sliding_window,
            logit_softcap=cfg.attn_logit_softcap,
            q_chunk=cfg.attn_q_chunk,
            kv_chunk=cfg.attn_kv_chunk,
        )
    else:
        out = attn_lib.attend(
            q, k, v,
            causal=causal,
            sliding_window=cfg.sliding_window,
            logit_softcap=cfg.attn_logit_softcap,
        )
    out = out.reshape(b, s, hq * dh) @ p["wo"]
    return constrain(out, "act_batch", "act_seq", "act_embed"), (k, v)


def _xattn_seq(p: Dict[str, Array], x: Array, ctx: Ctx) -> Tuple[Array, Tuple[Array, Array]]:
    """Cross-attention onto the (stubbed) encoder output."""
    cfg = ctx.cfg
    b, s, d = x.shape
    dh, hq, hkv = cfg.resolved_head_dim, cfg.num_heads, cfg.num_kv_heads
    enc = ctx.enc_out
    q = (x @ p["wq"]).reshape(b, s, hq, dh)
    k = (enc @ p["wk"]).reshape(b, enc.shape[1], hkv, dh)
    v = (enc @ p["wv"]).reshape(b, enc.shape[1], hkv, dh)
    if s >= _CHUNKED_ATTN_THRESHOLD:
        out = attn_lib.attend_chunked(q, k, v, causal=False, kv_chunk=500)
    else:
        out = attn_lib.attend(q, k, v, causal=False)
    return out.reshape(b, s, hq * dh) @ p["wo"], (k, v)


def _apply_seq(
    kind: str,
    p: Dict[str, PyTree],
    shared: Optional[Dict[str, PyTree]],
    x: Array,
    ctx: Ctx,
) -> Tuple[Array, Dict[str, Array], Dict[str, Array]]:
    """Returns (x, cache_contrib, aux)."""
    cfg = ctx.cfg
    aux = _zero_aux()
    cache: Dict[str, Array] = {}
    if kind in ("dense", "moe", "enc"):
        h, (k, v) = _attn_seq(p["attn"], rmsnorm(x, p["norm1"], cfg.norm_eps), ctx,
                              causal=cfg.causal and kind != "enc")
        x = x + h
        hin = rmsnorm(x, p["norm2"], cfg.norm_eps)
        if kind == "moe":
            t = hin.reshape(-1, cfg.d_model)
            out, aux_m = moe_apply(
                p["moe"], t, cfg.moe, cfg.mlp_act,
                dispatch_shards=ctx.moe_dispatch_shards,
            )
            x = x + out.reshape(x.shape)
            aux = _acc_aux(aux, {k2: aux_m[k2] for k2 in aux})
        else:
            x = x + mlp_apply(p["mlp"], hin, cfg.mlp_act)
        cache = {"k": k, "v": v}
    elif kind == "mamba":
        h, state, conv_tail = ssm_lib.mamba_mixer(
            p["mixer"], rmsnorm(x, p["norm1"], cfg.norm_eps), cfg.ssm, cfg.d_model,
            return_conv_tail=True,
        )
        x = x + h
        cache = {"ssm": state, "conv": conv_tail}
    elif kind == "shared_attn":
        sp = shared
        h, (k, v) = _attn_seq(sp["attn"], rmsnorm(x, p["norm1"], cfg.norm_eps), ctx,
                              causal=cfg.causal)
        x = x + h
        x = x + mlp_apply(sp["mlp"], rmsnorm(x, sp["norm2"], cfg.norm_eps), cfg.mlp_act)
        cache = {"k": k, "v": v}
    elif kind == "encdec":
        h, (k, v) = _attn_seq(p["attn"], rmsnorm(x, p["norm1"], cfg.norm_eps), ctx,
                              causal=True)
        x = x + h
        hx, (xk, xv) = _xattn_seq(p["xattn"], rmsnorm(x, p["norm_x"], cfg.norm_eps), ctx)
        x = x + hx
        x = x + mlp_apply(p["mlp"], rmsnorm(x, p["norm2"], cfg.norm_eps), cfg.mlp_act)
        cache = {"k": k, "v": v, "xk": xk, "xv": xv}
    else:
        raise ValueError(kind)
    return x, cache, aux


def _group_forward(
    group: BlockGroup,
    gp: Dict[str, PyTree],
    shared: Optional[Dict[str, PyTree]],
    x: Array,
    ctx: Ctx,
    *,
    collect_cache: bool,
    remat: bool,
) -> Tuple[Array, Optional[Dict[str, PyTree]], Dict[str, Array]]:
    """Run ``repeat`` iterations of the pattern under one lax.scan."""

    def body(carry, layer_params):
        x = carry
        caches: Dict[str, PyTree] = {}
        aux = _zero_aux()
        for i, kind in enumerate(group.pattern):
            x, c, a = _apply_seq(kind, layer_params[f"p{i}"], shared, x, ctx)
            # layer-boundary residual sharding: "act_embed" defaults to
            # replicated; the §Perf act-shard knob remaps it to "model".
            # Skipped for hybrid stacks — the alternating mamba/attn
            # pattern re-shards across the constraint (+15% measured,
            # EXPERIMENTS.md §Perf full-table notes).
            if ctx.cfg.family != "hybrid":
                x = constrain(x, "act_batch", "act_seq", "act_embed")
            aux = _acc_aux(aux, a)
            if collect_cache:
                caches[f"p{i}"] = c
        outs = (caches, aux) if collect_cache else (None, aux)
        return x, outs

    if remat and remat != "none":
        from repro.models.common import remat_policy as _policy

        name = remat if isinstance(remat, str) else "full"
        body = jax.checkpoint(body, policy=_policy(name))
    x, (caches, aux_stack) = jax.lax.scan(body, x, gp)
    aux = jax.tree_util.tree_map(lambda a: jnp.sum(a, axis=0), aux_stack)
    return x, caches, aux


# ===========================================================================
# embeddings / full-sequence forward
# ===========================================================================


def _default_positions(cfg: ModelConfig, batch: int, seq: int) -> Array:
    pos = jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32)[None], (batch, seq))
    if cfg.rope == "mrope":
        return jnp.broadcast_to(pos[None], (3, batch, seq))
    return pos


def embed_tokens(
    params: PyTree,
    cfg: ModelConfig,
    tokens: Array,
    *,
    patches: Optional[Array] = None,
) -> Array:
    """Token embeddings (+ VLM patch splice, + whisper learned positions)."""
    x = jnp.take(params["embed"], tokens, axis=0)  # (B, S, d)
    if cfg.name.startswith("gemma"):
        x = x * jnp.asarray(cfg.d_model**0.5, x.dtype)
    if patches is not None and cfg.vision_tokens:
        # splice pre-computed patch embeddings over the first V positions
        x = jax.lax.dynamic_update_slice(x, patches.astype(x.dtype), (0, 0, 0))
    if cfg.is_encdec:
        s = tokens.shape[1]
        x = x + params["dec_pos"][:s][None]
    return x


def encode_frames(params: PyTree, cfg: ModelConfig, frames: Array) -> Array:
    """Whisper encoder over stubbed (B, S_enc, d) frame embeddings."""
    enc = params["encoder"]
    x = frames + enc["pos"][None, : frames.shape[1]]
    ctx = Ctx(cfg=cfg, positions=_default_positions(cfg, x.shape[0], x.shape[1]))
    group = BlockGroup(("enc",), cfg.encoder_layers)
    x, _, _ = _group_forward(
        group, enc["groups"][0], None, x, ctx, collect_cache=False, remat=False
    )
    return rmsnorm(x, enc["final_norm"], cfg.norm_eps)


def forward(
    params: PyTree,
    cfg: ModelConfig,
    tokens: Array,
    *,
    positions: Optional[Array] = None,
    patches: Optional[Array] = None,
    frames: Optional[Array] = None,
    remat: bool = False,
    moe_dispatch_shards: int = 1,
) -> Tuple[Array, Dict[str, Array]]:
    """Full-sequence forward to final-norm hidden states.

    Returns (hidden (B, S, d), aux-loss dict).
    """
    b, s = tokens.shape
    x = embed_tokens(params, cfg, tokens, patches=patches)
    x = constrain(x, "act_batch", None, None)
    enc_out = encode_frames(params, cfg, frames) if cfg.is_encdec else None
    ctx = Ctx(
        cfg=cfg,
        positions=positions if positions is not None else _default_positions(cfg, b, s),
        enc_out=enc_out,
        moe_dispatch_shards=moe_dispatch_shards,
    )
    aux = _zero_aux()
    shared = params.get("shared_attn")
    for group, gp in zip(cfg.groups, params["groups"]):
        x, _, a = _group_forward(
            group, gp, shared, x, ctx, collect_cache=False, remat=remat
        )
        aux = _acc_aux(aux, a)
    return rmsnorm(x, params["final_norm"], cfg.norm_eps), aux


def unembed(params: PyTree, cfg: ModelConfig, hidden: Array) -> Array:
    w = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    logits = hidden @ w.astype(hidden.dtype)
    return constrain(logits, "act_batch", None, "act_vocab")


# ===========================================================================
# feature extraction (the Extractor protocol's models-layer entry point)
# ===========================================================================

POOLINGS = ("mean", "last", "tokens")


def feature_dim(cfg: ModelConfig) -> int:
    """Feature dimension every pooling mode emits: the final hidden width."""
    return cfg.d_model


def features(
    params: PyTree,
    cfg: ModelConfig,
    tokens: Array,
    *,
    pooling: str = "mean",
    positions: Optional[Array] = None,
    patches: Optional[Array] = None,
    frames: Optional[Array] = None,
    remat: bool = False,
    moe_dispatch_shards: int = 1,
) -> Array:
    """Pooled final hidden states: ``R^tokens -> R^feature_dim`` rows.

    The one sanctioned feature surface for every zoo architecture —
    FedCGS consumers (`fl/extractors`, `launch/`, `serve/`) go through
    this rather than calling :func:`forward` directly (enforced by the
    ``extractor-protocol`` audit rule).

    - ``mean``   — mean over sequence positions, one row per sequence (B, d).
    - ``last``   — final-position hidden state, one row per sequence (B, d).
    - ``tokens`` — every position as its own row (B*S, d); the LM-stats
      pooling where class = next-token id.
    """
    if pooling not in POOLINGS:
        raise ValueError(f"pooling must be one of {POOLINGS}, got {pooling!r}")
    hidden, _ = forward(
        params, cfg, tokens,
        positions=positions,
        patches=patches,
        frames=frames,
        remat=remat,
        moe_dispatch_shards=moe_dispatch_shards,
    )
    if pooling == "mean":
        return jnp.mean(hidden, axis=1)
    if pooling == "last":
        return hidden[:, -1, :]
    return hidden.reshape(-1, hidden.shape[-1])


# ===========================================================================
# caches
# ===========================================================================


def cache_len_for(cfg: ModelConfig, seq_len: int) -> int:
    if cfg.sliding_window is not None:
        return min(seq_len, cfg.sliding_window)
    return seq_len


def init_cache(
    cfg: ModelConfig, batch: int, seq_len: int, dtype=jnp.bfloat16
) -> Dict[str, PyTree]:
    """Zero cache with capacity ``cache_len_for(cfg, seq_len)``."""
    s_c = cache_len_for(cfg, seq_len)
    dh, hkv = cfg.resolved_head_dim, cfg.num_kv_heads
    groups: List[Dict[str, PyTree]] = []
    for g in cfg.groups:
        gd: Dict[str, PyTree] = {}
        for i, kind in enumerate(g.pattern):
            R = g.repeat
            if kind in ("dense", "moe", "shared_attn", "enc"):
                gd[f"p{i}"] = {
                    "k": jnp.zeros((R, batch, s_c, hkv, dh), dtype),
                    "v": jnp.zeros((R, batch, s_c, hkv, dh), dtype),
                }
            elif kind == "mamba":
                ssm = cfg.ssm
                h = ssm.num_heads(cfg.d_model)
                conv_ch = ssm.d_inner(cfg.d_model) + 2 * ssm.state_dim
                gd[f"p{i}"] = {
                    "ssm": jnp.zeros((R, batch, h, ssm.head_dim, ssm.state_dim), jnp.float32),
                    "conv": jnp.zeros((R, batch, ssm.conv_width - 1, conv_ch), dtype),
                }
            elif kind == "encdec":
                gd[f"p{i}"] = {
                    "k": jnp.zeros((R, batch, s_c, hkv, dh), dtype),
                    "v": jnp.zeros((R, batch, s_c, hkv, dh), dtype),
                    "xk": jnp.zeros((R, batch, cfg.encoder_seq_len, hkv, dh), dtype),
                    "xv": jnp.zeros((R, batch, cfg.encoder_seq_len, hkv, dh), dtype),
                }
        groups.append(gd)
    return {
        "groups": groups,
        "index": jnp.zeros((), jnp.int32),
        "positions": jnp.full((s_c,), _NEG_BIG, jnp.int32),
    }


def cache_specs(cfg: ModelConfig, batch: int, seq_len: int, dtype=jnp.bfloat16):
    """ShapeDtypeStruct tree matching :func:`init_cache` (dry-run input)."""
    return jax.eval_shape(lambda: init_cache(cfg, batch, seq_len, dtype))


def cache_logical_axes(tree: PyTree) -> PyTree:
    """Logical axes for every cache leaf (for dry-run shardings)."""

    def leaf_axes(path, leaf) -> Tuple[Optional[str], ...]:
        names = [getattr(p, "key", None) for p in path]
        if leaf.ndim == 0 or "positions" in names:
            return (None,) * leaf.ndim
        if "ssm" in names:  # (R, B, H, P, N)
            return ("layers", "act_batch", "act_heads", None, None)
        if "conv" in names:  # (R, B, W-1, CH)
            return ("layers", "act_batch", None, "act_inner")
        # kv slabs: (R, B, S_c, Hkv, Dh)
        return ("layers", "act_batch", None, "act_heads", None)

    return jax.tree_util.tree_map_with_path(leaf_axes, tree)


# ===========================================================================
# prefill
# ===========================================================================


def prefill(
    params: PyTree,
    cfg: ModelConfig,
    tokens: Array,
    *,
    positions: Optional[Array] = None,
    patches: Optional[Array] = None,
    frames: Optional[Array] = None,
    cache_dtype=jnp.bfloat16,
    cache_len: Optional[int] = None,
    moe_dispatch_shards: int = 1,
) -> Tuple[Array, Dict[str, PyTree]]:
    """Forward + cache build. Returns (final hidden (B, S, d), cache).

    ``cache_len`` sets the cache capacity (default: just the prompt);
    pass ``s + max_new_tokens`` to leave head-room for decoding.
    """
    b, s = tokens.shape
    s_c = cache_len_for(cfg, cache_len if cache_len is not None else s)
    x = embed_tokens(params, cfg, tokens, patches=patches)
    enc_out = encode_frames(params, cfg, frames) if cfg.is_encdec else None
    ctx = Ctx(
        cfg=cfg,
        positions=positions if positions is not None else _default_positions(cfg, b, s),
        enc_out=enc_out,
        moe_dispatch_shards=moe_dispatch_shards,
    )
    shared = params.get("shared_attn")
    groups_cache: List[Dict[str, PyTree]] = []
    for group, gp in zip(cfg.groups, params["groups"]):
        x, caches, _ = _group_forward(
            group, gp, shared, x, ctx, collect_cache=True, remat=False
        )
        gd: Dict[str, PyTree] = {}
        for i, kind in enumerate(group.pattern):
            c = caches[f"p{i}"]
            if kind == "mamba":
                gd[f"p{i}"] = {
                    "ssm": c["ssm"],
                    "conv": c["conv"].astype(cache_dtype),
                }
            else:
                # keep the LAST s_c tokens, placed at slot p % s_c (ring)
                k, v = c["k"], c["v"]
                if s_c < s:
                    # keep the last s_c tokens; token p lands at slot p % s_c
                    k, v = k[:, :, s - s_c :], v[:, :, s - s_c :]
                    k = jnp.roll(k, s % s_c, axis=2)
                    v = jnp.roll(v, s % s_c, axis=2)
                elif s_c > s:  # head-room for decode: zero-pad the tail
                    padw = [(0, 0), (0, 0), (0, s_c - s), (0, 0), (0, 0)]
                    k, v = jnp.pad(k, padw), jnp.pad(v, padw)
                entry = {"k": k.astype(cache_dtype), "v": v.astype(cache_dtype)}
                if kind == "encdec":
                    entry["xk"] = c["xk"].astype(cache_dtype)
                    entry["xv"] = c["xv"].astype(cache_dtype)
                gd[f"p{i}"] = entry
        groups_cache.append(gd)

    n_keep = min(s, s_c)
    pos_abs = jnp.arange(s - n_keep, s, dtype=jnp.int32)
    slot_pos = jnp.full((s_c,), _NEG_BIG, jnp.int32).at[pos_abs % s_c].set(pos_abs)
    cache = {
        "groups": groups_cache,
        "index": jnp.asarray(s, jnp.int32),
        "positions": slot_pos,
    }
    return rmsnorm(x, params["final_norm"], cfg.norm_eps), cache


# ===========================================================================
# decode
# ===========================================================================


def _attn_decode(
    p: Dict[str, Array],
    x_t: Array,
    kv: Dict[str, Array],
    ctx: Ctx,
) -> Tuple[Array, Dict[str, Array]]:
    """One-token attention against a (B, S_c, Hkv, Dh) cache slice."""
    cfg = ctx.cfg
    b, d = x_t.shape
    dh, hq, hkv = cfg.resolved_head_dim, cfg.num_heads, cfg.num_kv_heads
    idx = ctx.index
    q = (x_t @ p["wq"]).reshape(b, 1, hq, dh)
    k = (x_t @ p["wk"]).reshape(b, 1, hkv, dh)
    v = (x_t @ p["wv"]).reshape(b, 1, hkv, dh)
    pos = ctx.positions
    q, k = attn_lib.apply_rope(q, k, pos, mode=cfg.rope, theta=cfg.rope_theta)
    s_c = kv["k"].shape[1]
    slot = idx % s_c
    ck = jax.lax.dynamic_update_slice(kv["k"], k.astype(kv["k"].dtype), (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(kv["v"], v.astype(kv["v"].dtype), (0, slot, 0, 0))
    kv_pos = ctx.cache_positions.at[slot].set(idx)
    # seq-sharded caches (kv_heads don't divide "model") need the
    # explicit flash-decode combine — GSPMD would all-gather the cache
    from repro.sharding import active_mesh

    mesh = active_mesh()
    model = mesh.shape.get("model", 1) if mesh is not None else 1
    if model > 1 and hkv % model != 0 and s_c % model == 0:
        out = attn_lib.attend_decode_seq_sharded(
            q, ck, cv, kv_pos, idx,
            mesh=mesh,
            sliding_window=cfg.sliding_window,
            logit_softcap=cfg.attn_logit_softcap,
        )
    else:
        out = attn_lib.attend(
            q, ck, cv,
            causal=True,
            q_offset=idx,
            kv_positions=kv_pos,
            sliding_window=cfg.sliding_window,
            logit_softcap=cfg.attn_logit_softcap,
        )
    out = out.reshape(b, hq * dh) @ p["wo"]
    return out, {"k": ck, "v": cv}


def _apply_decode(
    kind: str,
    p: Dict[str, PyTree],
    shared: Optional[Dict[str, PyTree]],
    x_t: Array,
    c: Dict[str, Array],
    ctx: Ctx,
) -> Tuple[Array, Dict[str, Array]]:
    cfg = ctx.cfg
    if kind in ("dense", "moe"):
        h, nc = _attn_decode(p["attn"], rmsnorm(x_t, p["norm1"], cfg.norm_eps), c, ctx)
        x_t = x_t + h
        hin = rmsnorm(x_t, p["norm2"], cfg.norm_eps)
        if kind == "moe":
            out, _ = moe_apply(p["moe"], hin, cfg.moe, cfg.mlp_act)
            x_t = x_t + out
        else:
            x_t = x_t + mlp_apply(p["mlp"], hin, cfg.mlp_act)
        return x_t, nc
    if kind == "mamba":
        h, ssm_state, conv_state = ssm_lib.mamba_mixer_step(
            p["mixer"], rmsnorm(x_t, p["norm1"], cfg.norm_eps),
            c["ssm"], c["conv"].astype(jnp.float32), cfg.ssm, cfg.d_model,
        )
        x_t = x_t + h.astype(x_t.dtype)  # f32 conv state must not promote the carry
        return x_t, {"ssm": ssm_state, "conv": conv_state.astype(c["conv"].dtype)}
    if kind == "shared_attn":
        sp = shared
        h, nc = _attn_decode(sp["attn"], rmsnorm(x_t, p["norm1"], cfg.norm_eps), c, ctx)
        x_t = x_t + h
        x_t = x_t + mlp_apply(sp["mlp"], rmsnorm(x_t, sp["norm2"], cfg.norm_eps), cfg.mlp_act)
        return x_t, nc
    if kind == "encdec":
        h, nc = _attn_decode(p["attn"], rmsnorm(x_t, p["norm1"], cfg.norm_eps), c, ctx)
        x_t = x_t + h
        # cross-attention against the cached encoder K/V (no causal mask)
        b, d = x_t.shape
        dh, hq = cfg.resolved_head_dim, cfg.num_heads
        hx = rmsnorm(x_t, p["norm_x"], cfg.norm_eps)
        q = (hx @ p["xattn"]["wq"]).reshape(b, 1, hq, dh)
        out = attn_lib.attend(q, c["xk"], c["xv"], causal=False)
        x_t = x_t + out.reshape(b, hq * dh) @ p["xattn"]["wo"]
        x_t = x_t + mlp_apply(p["mlp"], rmsnorm(x_t, p["norm2"], cfg.norm_eps), cfg.mlp_act)
        nc = dict(nc)
        nc["xk"], nc["xv"] = c["xk"], c["xv"]
        return x_t, nc
    raise ValueError(kind)


def decode_step(
    params: PyTree,
    cfg: ModelConfig,
    token: Array,
    cache: Dict[str, PyTree],
    *,
    positions: Optional[Array] = None,
) -> Tuple[Array, Dict[str, PyTree]]:
    """ONE new token. token: (B,) int32. Returns (hidden (B, d), new cache)."""
    b = token.shape[0]
    idx = cache["index"]
    x = jnp.take(params["embed"], token, axis=0)  # (B, d)
    if cfg.name.startswith("gemma"):
        x = x * jnp.asarray(cfg.d_model**0.5, x.dtype)
    if cfg.is_encdec:
        x = x + jnp.take(params["dec_pos"], jnp.minimum(idx, params["dec_pos"].shape[0] - 1), axis=0)[None]
    if positions is None:
        pos = jnp.broadcast_to(idx[None, None], (b, 1)).astype(jnp.int32)
        if cfg.rope == "mrope":
            pos = jnp.broadcast_to(pos[None], (3, b, 1))
    else:
        pos = positions
    ctx = Ctx(
        cfg=cfg, positions=pos, index=idx, cache_positions=cache["positions"],
    )
    shared = params.get("shared_attn")

    # NOTE: decode unrolls the layer loop instead of lax.scan. Decode
    # bodies are tiny (one token), so HLO size is a non-issue — and a
    # compiled scan over a sequence-sharded KV cache miscompiles on
    # XLA-CPU SPMD (verified: a LENGTH-1 scan whose body is correct
    # returns wrong values; the unrolled body is correct). Unrolling
    # also lets XLA pipeline per-layer collectives during serving.
    new_groups: List[Dict[str, PyTree]] = []
    for group, gp, gc in zip(cfg.groups, params["groups"], cache["groups"]):
        has_attn = any(k != "mamba" for k in group.pattern)
        if has_attn:
            # unrolled path (see note above): KV caches present
            layer_caches: List[Dict[str, PyTree]] = []
            for r in range(group.repeat):
                layer_params = jax.tree_util.tree_map(lambda a: a[r], gp)
                layer_cache = jax.tree_util.tree_map(lambda a: a[r], gc)
                ncs: Dict[str, PyTree] = {}
                for i, kind in enumerate(group.pattern):
                    x, nc = _apply_decode(
                        kind, layer_params[f"p{i}"], shared, x,
                        layer_cache[f"p{i}"], ctx,
                    )
                    ncs[f"p{i}"] = nc
                layer_caches.append(ncs)
            new_gc = jax.tree_util.tree_map(
                lambda *ls: jnp.stack(ls), *layer_caches
            )
        else:
            # attention-free (pure mamba) groups keep the scan: no KV
            # cache to trip the SPMD bug, and batch-1 SSM decode regresses
            # ~6x when unrolled (per-layer op overheads, EXPERIMENTS §Perf)

            def body(carry, xs):
                x_t = carry
                layer_params, layer_cache = xs
                ncs: Dict[str, PyTree] = {}
                for i, kind in enumerate(group.pattern):
                    x_t, nc = _apply_decode(
                        kind, layer_params[f"p{i}"], shared, x_t,
                        layer_cache[f"p{i}"], ctx,
                    )
                    ncs[f"p{i}"] = nc
                return x_t, ncs

            x, new_gc = jax.lax.scan(body, x, (gp, gc))
        new_groups.append(new_gc)

    s_c = cache["positions"].shape[0]
    new_cache = {
        "groups": new_groups,
        "index": idx + 1,
        "positions": cache["positions"].at[idx % s_c].set(idx),
    }
    hidden = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return hidden, new_cache


# ===========================================================================
# losses / steps (pure functions; the launcher jits them with shardings)
# ===========================================================================


def lm_loss(
    params: PyTree,
    cfg: ModelConfig,
    tokens: Array,
    targets: Array,
    *,
    positions: Optional[Array] = None,
    patches: Optional[Array] = None,
    frames: Optional[Array] = None,
    remat: bool = True,
    prototypes: Optional[Array] = None,
    proto_lambda: float = 0.0,
    moe_dispatch_shards: int = 1,
) -> Tuple[Array, Dict[str, Array]]:
    """Next-token cross-entropy (+ optional FedCGS prototype regularizer).

    ``prototypes`` is the downloaded global μ (C, d): the personalized
    one-shot FL objective (paper Eq. 12) adds
    λ · mean_t ‖h_t − μ^{y_t}‖² over the batch.
    """
    hidden, aux = forward(
        params, cfg, tokens, positions=positions, patches=patches, frames=frames,
        remat=remat, moe_dispatch_shards=moe_dispatch_shards,
    )
    logits = unembed(params, cfg, hidden).astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = jnp.mean(logz - tgt)
    loss = nll + aux["aux_loss"] + aux["router_z_loss"]
    metrics = {"nll": nll, **aux}
    if prototypes is not None and proto_lambda > 0.0:
        mu_y = jnp.take(prototypes, targets, axis=0)  # (B, S, d)
        reg = jnp.mean(jnp.sum((hidden.astype(jnp.float32) - mu_y) ** 2, axis=-1))
        loss = loss + proto_lambda * reg
        metrics["proto_reg"] = reg
    return loss, metrics


# ===========================================================================
# model-FLOPs accounting (roofline's MODEL_FLOPS)
# ===========================================================================


def model_flops(cfg: ModelConfig, tokens: int, seq_len: int, *, decode: bool = False) -> int:
    """6·N·D-style accounting with per-block active parameters.

    For decode, attention score FLOPs use the cache length; matmul terms
    use the single new token.
    """
    d, dh = cfg.d_model, cfg.resolved_head_dim
    hq, hkv = cfg.num_heads, cfg.num_kv_heads
    total = 0
    # embeddings: lookup is bandwidth; unembed matmul counts
    total += 2 * tokens * d * cfg.vocab_size
    # decode attends over the cache; sliding windows bound the context
    attn_ctx = seq_len
    if cfg.sliding_window is not None:
        attn_ctx = min(attn_ctx, cfg.sliding_window)
    for g in cfg.groups:
        for kind in g.pattern:
            reps = g.repeat
            if kind in ("dense", "moe", "enc", "encdec", "shared_attn"):
                proj = 2 * tokens * d * (hq * dh + 2 * hkv * dh) + 2 * tokens * hq * dh * d
                scores = 2 * tokens * hq * dh * attn_ctx * 2  # qk + pv
                if not decode:
                    scores //= 2  # causal halves the realized score work
                total += reps * (proj + scores)
                if kind == "dense" or kind == "enc":
                    total += reps * mlp_flops(d, cfg.d_ff, cfg.mlp_act, tokens)
                elif kind == "shared_attn":
                    total += reps * mlp_flops(d, cfg.d_ff, cfg.mlp_act, tokens)
                elif kind == "encdec":
                    total += reps * mlp_flops(d, cfg.d_ff, cfg.mlp_act, tokens)
                    total += reps * (
                        2 * tokens * d * 2 * hkv * dh
                        + 2 * tokens * hq * dh * cfg.encoder_seq_len * 2
                    )
                elif kind == "moe":
                    total += reps * moe_flops(d, cfg.moe, cfg.mlp_act, tokens)
            elif kind == "mamba":
                total += reps * ssm_lib.mamba_flops(d, cfg.ssm, tokens)
    return total
