"""Mixture-of-Experts layer with sort-based capacity dispatch.

Design choice (DESIGN.md §5): we deliberately avoid the dense one-hot
dispatch einsum ``(T, E, C) x (T, d) -> (E, C, d)`` used by some JAX MoE
implementations — its FLOP count scales with TOTAL experts and would
poison the roofline's MODEL_FLOPS/HLO_FLOPs ratio.  Instead tokens are
ranked within their expert via an argsort + searchsorted pass (O(Tk log
Tk) scalar work, no matmul FLOPs) and scattered into an (E, capacity, d)
buffer, so the expert matmuls are batched matmuls over ACTIVE tokens
only — exactly the paper-style 6·N_active·D accounting.

Routing: softmax router, top-k, probabilities renormalized over the
selected k (llama4 top-1 degenerates to its raw gate).  Shared experts
(qwen2-moe) run as an always-on dense MLP fused over the shared group.
Aux losses (load-balance + router-z) are returned for the train step.
"""

from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ParamSpec
from repro.models.config import MoEConfig
from repro.models.mlp import GATED, mlp_apply, mlp_specs

Array = jax.Array


def moe_specs(
    d_model: int, cfg: MoEConfig, act: str, *, prefix_layers: int = 0
) -> Dict[str, ParamSpec]:
    L = (prefix_layers,) if prefix_layers else ()
    lax_ = ("layers",) if prefix_layers else ()
    E, ff = cfg.num_experts, cfg.expert_d_ff
    specs = {
        "router": ParamSpec(L + (d_model, E), lax_ + ("embed", None), scale=0.02),
        "w_up": ParamSpec(L + (E, d_model, ff), lax_ + ("expert", "embed", "expert_mlp")),
        "w_down": ParamSpec(L + (E, ff, d_model), lax_ + ("expert", "expert_mlp", "embed")),
    }
    if act in GATED:
        specs["w_gate"] = ParamSpec(
            L + (E, d_model, ff), lax_ + ("expert", "embed", "expert_mlp")
        )
    if cfg.num_shared_experts:
        shared_ff = cfg.shared_d_ff * cfg.num_shared_experts
        specs["shared"] = mlp_specs(d_model, shared_ff, act, prefix_layers=prefix_layers)
    return specs


def _zero_metrics() -> Dict[str, Array]:
    z = jnp.zeros((), jnp.float32)
    return {"aux_loss": z, "router_z_loss": z, "dropped_fraction": z}


def expert_capacity(tokens: int, cfg: MoEConfig) -> int:
    """Static per-expert capacity, rounded up to a multiple of 8."""
    cap = math.ceil(tokens * cfg.top_k * cfg.capacity_factor / cfg.num_experts)
    return max(8, (cap + 7) // 8 * 8)


def moe_apply(
    params: Dict[str, Array],
    x: Array,
    cfg: MoEConfig,
    act: str,
    *,
    dispatch_shards: int = 1,
) -> Tuple[Array, Dict[str, Array]]:
    """Apply the MoE block to flattened tokens.

    Args:
      x: (T, d) tokens (batch*seq already flattened by the caller).
      dispatch_shards: §Perf optimization — dispatch per data-shard
        instead of globally. The global-T capacity buffer (E, cap, d)
        is O(T·k·capacity_factor·d) and at train_4k shapes reaches
        tens of TB, forcing XLA into cross-mesh reshards; slicing the
        token stream into mesh-aligned shards makes ranking/scatter
        local and shrinks the live buffer by the shard count. 1 = the
        paper-faithful global dispatch (baseline).
    Returns:
      (T, d) output and {"aux_loss", "router_z_loss", "dropped_fraction"}.
    """
    T, d = x.shape
    if dispatch_shards > 1 and T % dispatch_shards == 0:
        return _moe_apply_sharded(params, x, cfg, act, dispatch_shards)

    E, k = cfg.num_experts, cfg.top_k
    cap = expert_capacity(T, cfg)

    router_logits = x.astype(jnp.float32) @ params["router"].astype(jnp.float32)
    probs = jax.nn.softmax(router_logits, axis=-1)  # (T, E)
    top_probs, top_idx = jax.lax.top_k(probs, k)  # (T, k)
    top_probs = top_probs / jnp.maximum(top_probs.sum(-1, keepdims=True), 1e-9)

    # ---- rank each (token, choice) within its expert (sort-based) ----
    flat_e = top_idx.reshape(-1)  # (T*k,)
    order = jnp.argsort(flat_e)  # stable
    sorted_e = flat_e[order]
    first = jnp.searchsorted(sorted_e, sorted_e, side="left")
    rank_sorted = jnp.arange(T * k) - first
    rank = jnp.zeros((T * k,), jnp.int32).at[order].set(rank_sorted.astype(jnp.int32))
    keep = rank < cap

    # ---- dispatch: scatter kept tokens into (E, cap, d) ----
    tok = jnp.arange(T * k) // k
    safe_e = jnp.where(keep, flat_e, 0)
    safe_r = jnp.where(keep, rank, 0)
    x_flat = x[tok] * keep[:, None].astype(x.dtype)
    buf = jnp.zeros((E, cap, d), x.dtype).at[safe_e, safe_r].add(
        x_flat, mode="drop"
    )

    # ---- expert computation: batched matmuls over ACTIVE tokens ----
    if "w_gate" in params:
        gate_act = jax.nn.gelu if act == "geglu" else jax.nn.silu
        h = gate_act(jnp.einsum("ecd,edf->ecf", buf, params["w_gate"]))
        h = h * jnp.einsum("ecd,edf->ecf", buf, params["w_up"])
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", buf, params["w_up"]))
    out_buf = jnp.einsum("ecf,efd->ecd", h, params["w_down"])  # (E, cap, d)

    # ---- combine: gather back, weight by router prob, sum over k ----
    gathered = out_buf[safe_e, safe_r]  # (T*k, d)
    weights = (top_probs.reshape(-1) * keep).astype(x.dtype)
    out = (gathered * weights[:, None]).reshape(T, k, d).sum(axis=1)

    if cfg.num_shared_experts:
        out = out + mlp_apply(params["shared"], x, act)

    # ---- aux losses ----
    mean_probs = probs.mean(axis=0)  # (E,)
    assign = jnp.zeros((E,)).at[flat_e].add(1.0) / (T * k)
    aux = E * jnp.sum(mean_probs * assign) * cfg.router_aux_weight
    zloss = jnp.mean(jax.scipy.special.logsumexp(router_logits, axis=-1) ** 2)
    metrics = {
        "aux_loss": aux,
        "router_z_loss": cfg.router_z_weight * zloss,
        "dropped_fraction": 1.0 - keep.mean(),
    }
    return out, metrics


# ---------------------------------------------------------------------------
# §Perf: per-shard dispatch (DESIGN.md §5 / EXPERIMENTS.md §Perf).
#
# The global sort-based dispatch above builds an (E, cap, d) buffer with
# cap ∝ GLOBAL tokens — tens of TB at train_4k — and GSPMD cannot shard a
# global scatter, so the buffer lands replicated. Here ONLY the
# token-local stages (ranking, scatter, combine-gather) run inside a
# shard_map over the batch axes; the expert matmuls run OUTSIDE on the
# capacity-sharded buffer, so the auto-sharded expert weights never cross
# the manual boundary (passing them through in_specs trips an XLA-CPU
# AllReducePromotion bug, and would defeat their model-axis sharding).
# ---------------------------------------------------------------------------


def _rank_within_expert(flat_e: Array, k_total: int) -> Array:
    """rank[i] = #earlier (token,choice) pairs routed to the same expert."""
    order = jnp.argsort(flat_e)
    sorted_e = flat_e[order]
    first = jnp.searchsorted(sorted_e, sorted_e, side="left")
    rank_sorted = jnp.arange(k_total) - first
    return jnp.zeros((k_total,), jnp.int32).at[order].set(rank_sorted.astype(jnp.int32))


def _moe_apply_sharded(
    params: Dict[str, Array],
    x: Array,
    cfg: MoEConfig,
    act: str,
    shards: int,
) -> Tuple[Array, Dict[str, Array]]:
    from jax.sharding import PartitionSpec as P

    from repro.sharding import active_mesh, constrain

    T, d = x.shape
    E, k = cfg.num_experts, cfg.top_k
    t_loc = T // shards
    cap = expert_capacity(t_loc, cfg)

    # ---- routing: global elementwise, shards trivially ----
    router_logits = x.astype(jnp.float32) @ params["router"].astype(jnp.float32)
    probs = jax.nn.softmax(router_logits, axis=-1)
    top_probs, top_idx = jax.lax.top_k(probs, k)
    top_probs = top_probs / jnp.maximum(top_probs.sum(-1, keepdims=True), 1e-9)

    # ---- local dispatch: scatter each shard's tokens into its own
    #      (E, cap, d) block; blocks concatenate along the cap dim ----
    def dispatch_local(x_l, idx_l):
        flat_e = idx_l.reshape(-1)
        rank = _rank_within_expert(flat_e, t_loc * k)
        keep = rank < cap
        tok = jnp.arange(t_loc * k) // k
        safe_e = jnp.where(keep, flat_e, 0)
        safe_r = jnp.where(keep, rank, 0)
        x_flat = x_l[tok] * keep[:, None].astype(x_l.dtype)
        buf = jnp.zeros((E, cap, d), x_l.dtype).at[safe_e, safe_r].add(
            x_flat, mode="drop"
        )
        return buf, safe_e, safe_r, keep

    def combine_local(out_buf_l, safe_e, safe_r, keep, w_flat):
        gathered = out_buf_l[safe_e, safe_r]  # (t_loc*k, d)
        w = (w_flat * keep).astype(gathered.dtype)
        return (gathered * w[:, None]).reshape(t_loc, k, d).sum(axis=1)

    mesh = active_mesh()
    axes = tuple(
        a for a in ("pod", "data")
        if mesh is not None and a in mesh.axis_names and mesh.shape[a] > 1
    )
    if axes:
        from repro.sharding import shard_map as _shard_map

        sm = lambda fn, ins, outs: _shard_map(
            fn, mesh=mesh, in_specs=ins, out_specs=outs, axis_names=set(axes)
        )
        buf, safe_e, safe_r, keep = sm(
            dispatch_local,
            (P(axes), P(axes)),
            (P(None, axes), P(axes), P(axes), P(axes)),
        )(x, top_idx)
    else:  # host tests: emulate the shard split with vmap
        xs = x.reshape(shards, t_loc, d)
        idxs = top_idx.reshape(shards, t_loc, k)
        buf, safe_e, safe_r, keep = jax.vmap(dispatch_local)(xs, idxs)
        buf = jnp.moveaxis(buf, 0, 1).reshape(E, shards * cap, d)
        safe_e, safe_r, keep = (
            safe_e.reshape(-1), safe_r.reshape(-1), keep.reshape(-1),
        )

    # ---- expert matmuls OUTSIDE the manual region: buf's cap dim is
    #      sharded over the batch axes, weights keep their auto layout ----
    buf = constrain(buf, None, "act_dispatch", None)
    if "w_gate" in params:
        gate_act = jax.nn.gelu if act == "geglu" else jax.nn.silu
        h = gate_act(jnp.einsum("ecd,edf->ecf", buf, params["w_gate"]))
        h = h * jnp.einsum("ecd,edf->ecf", buf, params["w_up"])
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", buf, params["w_up"]))
    out_buf = jnp.einsum("ecf,efd->ecd", h, params["w_down"])
    out_buf = constrain(out_buf, None, "act_dispatch", None)

    w_flat = top_probs.reshape(-1)
    if axes:
        out = sm(
            combine_local,
            (P(None, axes), P(axes), P(axes), P(axes), P(axes)),
            P(axes),
        )(out_buf, safe_e, safe_r, keep, w_flat)
    else:
        out = jax.vmap(combine_local)(
            jnp.moveaxis(out_buf.reshape(E, shards, cap, d), 1, 0),
            safe_e.reshape(shards, -1),
            safe_r.reshape(shards, -1),
            keep.reshape(shards, -1),
            w_flat.reshape(shards, -1),
        ).reshape(T, d)

    if cfg.num_shared_experts:
        out = out + mlp_apply(params["shared"], x, act)

    flat_e = top_idx.reshape(-1)
    mean_probs = probs.mean(axis=0)
    assign = jnp.zeros((E,)).at[flat_e].add(1.0) / (T * k)
    aux = E * jnp.sum(mean_probs * assign) * cfg.router_aux_weight
    zloss = jnp.mean(jax.scipy.special.logsumexp(router_logits, axis=-1) ** 2)
    metrics = {
        "aux_loss": aux,
        "router_z_loss": cfg.router_z_weight * zloss,
        "dropped_fraction": 1.0 - keep.astype(jnp.float32).mean(),
    }
    return out, metrics


def moe_flops(d_model: int, cfg: MoEConfig, act: str, tokens: int) -> int:
    """ACTIVE-parameter FLOPs (what the roofline's MODEL_FLOPS uses)."""
    mats = 3 if act in GATED else 2
    per_tok = 2 * mats * d_model * cfg.expert_d_ff * cfg.top_k
    per_tok += 2 * d_model * cfg.num_experts  # router
    if cfg.num_shared_experts:
        per_tok += 2 * mats * d_model * cfg.shared_d_ff * cfg.num_shared_experts
    return per_tok * tokens
