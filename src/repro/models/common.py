"""Spec-based parameter system.

Every parameter is declared as a :class:`ParamSpec` (shape + logical axis
names + initializer).  Declaring specs separately from materialization is
what lets the multi-pod dry-run build ``jax.ShapeDtypeStruct`` stand-ins for
a 400B-parameter model without ever allocating it, while smoke tests
materialize the same tree at reduced size.

Logical axis names are resolved to mesh axes by the rule engine in
``repro.launch.sharding``.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """Declaration of a single parameter tensor."""

    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]  # logical axis name per dim (None = replicated)
    init: str = "normal"  # normal | zeros | ones | scaled | embed | conv
    scale: Optional[float] = None  # stddev override for normal/scaled
    dtype: Any = jnp.float32

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(
                f"ParamSpec rank mismatch: shape={self.shape} axes={self.axes}"
            )


def _fan_in(shape: Tuple[int, ...]) -> int:
    # for stacked (layer-major) params the leading 'layers' dim is not a fan-in
    if len(shape) >= 3:
        return int(np.prod(shape[1:-1])) if len(shape) > 2 else shape[0]
    if len(shape) == 2:
        return shape[0]
    return max(1, shape[0] if shape else 1)


def init_param(key: jax.Array, spec: ParamSpec) -> jax.Array:
    """Materialize one parameter from its spec."""
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    if spec.init in ("normal", "scaled", "embed", "conv"):
        if spec.scale is not None:
            std = spec.scale
        elif spec.init == "embed":
            std = 1.0
        else:
            std = 1.0 / math.sqrt(_fan_in(spec.shape))
        return std * jax.random.normal(key, spec.shape, spec.dtype)
    raise ValueError(f"unknown init {spec.init}")


def init_params(specs: PyTree, key: jax.Array) -> PyTree:
    """Materialize a whole tree of ParamSpecs with independent keys."""
    leaves, treedef = jax.tree_util.tree_flatten(
        specs, is_leaf=lambda x: isinstance(x, ParamSpec)
    )
    keys = jax.random.split(key, len(leaves))
    vals = [init_param(k, s) for k, s in zip(keys, leaves)]
    return jax.tree_util.tree_unflatten(treedef, vals)


def spec_shapes(specs: PyTree, dtype=None) -> PyTree:
    """ShapeDtypeStruct tree for dry-run lowering (no allocation)."""
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype or s.dtype),
        specs,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def spec_axes(specs: PyTree) -> PyTree:
    """Tree of logical-axis tuples, same structure as the param tree."""
    return jax.tree_util.tree_map(
        lambda s: s.axes, specs, is_leaf=lambda x: isinstance(x, ParamSpec)
    )


def param_count(specs: PyTree) -> int:
    leaves = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, ParamSpec)
    )
    return sum(int(np.prod(s.shape)) for s in leaves)


def param_bytes(specs: PyTree, bytes_per_el: int = 2) -> int:
    return param_count(specs) * bytes_per_el


def cast_tree(tree: PyTree, dtype) -> PyTree:
    return jax.tree_util.tree_map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x,
        tree,
    )


# ---------------------------------------------------------------------------
# activation checkpoint policies used by the model stacks
# ---------------------------------------------------------------------------

REMAT_POLICIES: Dict[str, Optional[Callable]] = {
    "none": None,  # no remat
    "full": lambda *_, **__: False,  # save nothing; recompute everything
    "dots": None,  # filled lazily below (needs jax)
}


def remat_policy(name: str):
    import jax.ad_checkpoint as adc

    if name == "none":
        return "none"
    if name == "full":
        return adc.checkpoint_policies.nothing_saveable
    if name == "dots":
        return adc.checkpoint_policies.checkpoint_dots_with_no_batch_dims
    if name == "save_anything":
        return adc.checkpoint_policies.everything_saveable
    raise ValueError(f"unknown remat policy {name}")
