"""Grouped-query attention with RoPE variants, KV cache and sliding window.

Covers the attention needs of all assigned architectures:

- GQA with arbitrary ``num_kv_heads`` (incl. MQA kv=1 for gemma-2b).
- RoPE variants: ``standard`` (llama-style), ``2d`` (chatglm3: rotary on
  half of head_dim, the other half untouched), ``mrope`` (qwen2-vl:
  3-section temporal/height/width rotary driven by (3, B, S) position
  ids), ``none``/``learned`` (whisper uses learned absolute positions,
  added at embedding time, so attention sees ``none``).
- Sliding-window causal masking (the sub-quadratic long-context variant
  for dense archs; window W => decode cache is a W-slot ring buffer).
- Decode path: one new token against a pre-filled cache.

The (B, S, H, D) layout keeps heads in their own dim so the sharding
rule engine can shard heads over "model" with a single constraint.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float, dtype=jnp.float32) -> Array:
    """Inverse frequencies for rotary dims (head_dim must be even)."""
    exponents = jnp.arange(0, head_dim, 2, dtype=dtype) / head_dim
    return 1.0 / (theta ** exponents)  # (head_dim/2,)


def _rotate(x: Array, angles: Array) -> Array:
    """Apply rotation by ``angles`` to interleaved pairs of ``x``.

    x: (..., S, H, D) with D even; angles: broadcastable to (..., S, 1, D/2).
    """
    x1, x2 = jnp.split(x, 2, axis=-1)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def apply_rope(
    q: Array,
    k: Array,
    positions: Array,
    *,
    mode: str,
    theta: float,
    mrope_sections: Tuple[int, int, int] = (16, 24, 24),
) -> Tuple[Array, Array]:
    """Rotate q, k by position-dependent angles.

    Args:
      q: (B, S, Hq, D); k: (B, S, Hkv, D).
      positions: (B, S) int for standard/2d; (3, B, S) for mrope.
      mode: standard | 2d | mrope | none.
    """
    if mode in ("none", "learned"):
        return q, k
    head_dim = q.shape[-1]
    compute = jnp.float32

    if mode == "standard":
        freqs = rope_frequencies(head_dim, theta)  # (D/2,)
        ang = positions[..., None, None].astype(compute) * freqs  # (B,S,1,D/2)
        return (
            _rotate(q.astype(compute), ang).astype(q.dtype),
            _rotate(k.astype(compute), ang).astype(k.dtype),
        )

    if mode == "2d":
        # chatglm-style: rotary on the first half of head_dim only.
        rot = head_dim // 2
        freqs = rope_frequencies(rot, theta)
        ang = positions[..., None, None].astype(compute) * freqs

        def half(x):
            xr, xp = x[..., :rot], x[..., rot:]
            xr = _rotate(xr.astype(compute), ang).astype(x.dtype)
            return jnp.concatenate([xr, xp], axis=-1)

        return half(q), half(k)

    if mode == "mrope":
        # qwen2-vl multimodal rope: the D/2 frequency slots are split into
        # (temporal, height, width) sections, each driven by its own
        # position stream. positions: (3, B, S).
        if positions.ndim == 2:  # text-only fallback: reuse 1d positions
            positions = jnp.broadcast_to(positions[None], (3,) + positions.shape)
        freqs = rope_frequencies(head_dim, theta)  # (D/2,)
        sec = _mrope_sections(head_dim // 2, mrope_sections)
        section_id = jnp.repeat(
            jnp.arange(3), jnp.array(sec), total_repeat_length=head_dim // 2
        )  # (D/2,) in {0,1,2}
        # pos_per_slot: (B, S, D/2) — pick the stream per frequency slot.
        pos = jnp.moveaxis(positions, 0, -1).astype(compute)  # (B,S,3)
        pos_slot = jnp.take_along_axis(
            pos, jnp.broadcast_to(section_id, pos.shape[:-1] + section_id.shape)[
                ..., : head_dim // 2
            ].astype(jnp.int32),
            axis=-1,
        )  # (B,S,D/2)
        ang = pos_slot[..., None, :] * freqs  # (B,S,1,D/2)
        return (
            _rotate(q.astype(compute), ang).astype(q.dtype),
            _rotate(k.astype(compute), ang).astype(k.dtype),
        )

    raise ValueError(f"unknown rope mode {mode!r}")


def _mrope_sections(half_dim: int, sections: Tuple[int, int, int]) -> Tuple[int, int, int]:
    """Scale the canonical (16,24,24) section split to this head_dim."""
    total = sum(sections)
    a = max(1, half_dim * sections[0] // total)
    b = max(1, half_dim * sections[1] // total)
    c = half_dim - a - b
    return (a, b, max(1, c)) if c > 0 else (a, max(1, half_dim - a - 1), 1)


# ---------------------------------------------------------------------------
# KV cache
# ---------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class KVCache:
    """Per-group stacked KV cache.

    k, v: (L, B, S_cache, Hkv, D) — L = attention layers in the scan group.
    index: () int32 — number of tokens already written (same for all
    layers of a group).  For sliding-window caches S_cache == window and
    writes wrap (ring buffer).
    """

    k: Array
    v: Array
    index: Array  # scalar int32

    @property
    def cache_len(self) -> int:
        return self.k.shape[2]

    @staticmethod
    def zeros(
        layers: int, batch: int, cache_len: int, kv_heads: int, head_dim: int,
        dtype=jnp.bfloat16,
    ) -> "KVCache":
        shape = (layers, batch, cache_len, kv_heads, head_dim)
        return KVCache(
            k=jnp.zeros(shape, dtype),
            v=jnp.zeros(shape, dtype),
            index=jnp.zeros((), jnp.int32),
        )


def cache_update_prefill(cache_k: Array, cache_v: Array, k: Array, v: Array) -> Tuple[Array, Array]:
    """Write a full prefill segment at the start of (B, S_cache, H, D) slabs."""
    ck = jax.lax.dynamic_update_slice(cache_k, k.astype(cache_k.dtype), (0, 0, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache_v, v.astype(cache_v.dtype), (0, 0, 0, 0))
    return ck, cv


def cache_update_decode(
    cache_k: Array, cache_v: Array, k: Array, v: Array, index: Array
) -> Tuple[Array, Array]:
    """Write one token at position ``index % cache_len`` (ring for windows)."""
    slot = index % cache_k.shape[1]
    ck = jax.lax.dynamic_update_slice(cache_k, k.astype(cache_k.dtype), (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache_v, v.astype(cache_v.dtype), (0, slot, 0, 0))
    return ck, cv


# ---------------------------------------------------------------------------
# Core attention math
# ---------------------------------------------------------------------------


def _repeat_kv(k: Array, num_q_heads: int) -> Array:
    """(B, S, Hkv, D) -> (B, S, Hq, D) by repetition (GQA broadcast)."""
    hkv = k.shape[2]
    if hkv == num_q_heads:
        return k
    return jnp.repeat(k, num_q_heads // hkv, axis=2)


def attend(
    q: Array,
    k: Array,
    v: Array,
    *,
    causal: bool,
    q_offset: Optional[Array] = None,
    kv_valid_len: Optional[Array] = None,
    kv_positions: Optional[Array] = None,
    sliding_window: Optional[int] = None,
    logit_softcap: Optional[float] = None,
) -> Array:
    """Scaled-dot-product attention with GQA + masking.

    q: (B, Sq, Hq, D); k, v: (B, Skv, Hkv, D).
    q_offset: scalar — absolute position of q[:, 0] (decode: index).
    kv_valid_len: scalar — #valid cache slots (decode against a
      partially-filled cache).
    kv_positions: (Skv,) absolute positions of cache slots (ring buffers
      have out-of-order slots); defaults to arange.
    """
    b, sq, hq, d = q.shape
    skv = k.shape[1]
    k = _repeat_kv(k, hq)
    v = _repeat_kv(v, hq)
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    logits = jnp.einsum(
        "bqhd,bkhd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale
    if logit_softcap:
        logits = logit_softcap * jnp.tanh(logits / logit_softcap)

    q_pos = jnp.arange(sq)
    if q_offset is not None:
        q_pos = q_pos + q_offset
    k_pos = kv_positions if kv_positions is not None else jnp.arange(skv)
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= k_pos[None, :] <= q_pos[:, None]
    if sliding_window is not None:
        mask &= k_pos[None, :] > q_pos[:, None] - sliding_window
    if kv_valid_len is not None:
        mask &= (jnp.arange(skv) < kv_valid_len)[None, :]
    logits = jnp.where(mask[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# Sequence-parallel decode attention ("flash-decode").
#
# When kv_heads don't divide the model axis (GQA kv=8 on a 16-way mesh)
# the KV cache is SEQUENCE-sharded. GSPMD cannot see that softmax over
# the sharded seq dim is a partial reduction and ALL-GATHERS the whole
# cache every token (measured: 2 x 34 GB x 32 layers/chip/token on
# minitron-8b decode_32k). This shard_map computes local (m, l, acc)
# per seq shard and combines with one pmax + two psums of
# (B, H, 1[, D])-sized tensors — the textbook TPU flash-decode.
# ---------------------------------------------------------------------------


def attend_decode_seq_sharded(
    q: Array,  # (B, 1, Hq, D) — replicated over the model axis
    ck: Array,  # (B, S_c, Hkv, D) — sharded over S_c on "model"
    cv: Array,
    kv_positions: Array,  # (S_c,) absolute slot positions (sharded)
    q_offset: Array,  # () — the decoded token's position
    *,
    mesh,
    sliding_window: Optional[int] = None,
    logit_softcap: Optional[float] = None,
    axis: str = "model",
) -> Array:
    from jax.sharding import PartitionSpec as P

    hq = q.shape[2]

    def local(q, k, v, pos, q_offset):
        # GQA WITHOUT materializing repeated KV heads: fold the q-head
        # group dim into the einsum (k/v are read once at their native
        # head count — repeating 8->32 heads would 4x the cache traffic)
        b, _, hq_, d = q.shape
        hkv = k.shape[2]
        g = hq_ // hkv
        qg = q.astype(jnp.float32).reshape(b, 1, hkv, g, d)
        scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
        logits = jnp.einsum(
            "bqhgd,bkhd->bhgqk", qg, k.astype(jnp.float32)
        ) * scale  # (b, hkv, g, 1, S_loc)
        if logit_softcap:
            logits = logit_softcap * jnp.tanh(logits / logit_softcap)
        mask = pos[None, :] <= q_offset  # causal (+ invalid-slot sentinel)
        if sliding_window is not None:
            mask &= pos[None, :] > q_offset - sliding_window
        logits = jnp.where(mask[None, None, None], logits, -1e30)
        m = jnp.max(logits, axis=-1)  # (b, hkv, g, 1)
        gm = jax.lax.pmax(m, axis)
        p = jnp.exp(logits - gm[..., None])
        l = jax.lax.psum(jnp.sum(p, axis=-1), axis)
        acc = jax.lax.psum(
            jnp.einsum("bhgqk,bkhd->bhgqd", p, v.astype(jnp.float32)), axis
        )
        out = acc / jnp.maximum(l, 1e-30)[..., None]  # (b, hkv, g, 1, d)
        return jnp.einsum("bhgqd->bqhgd", out).reshape(b, 1, hq_, d)

    from repro.sharding import shard_map as _shard_map

    fn = _shard_map(
        local,
        mesh=mesh,
        # q_offset is an explicit replicated arg: a traced scalar must
        # not be CLOSED OVER by shard_map (silent mis-broadcast)
        in_specs=(P(), P(None, axis), P(None, axis), P(axis), P()),
        out_specs=P(),
        axis_names={axis},
    )
    return fn(q, ck, cv, kv_positions, jnp.asarray(q_offset)).astype(q.dtype)


# ---------------------------------------------------------------------------
# Chunked (flash-style) attention for long sequences.
#
# The naive path materializes (B, H, Sq, Skv) logits — 32k×32k is ~4 GB
# *per head*, so prefill_32k / train_4k would never fit.  This version
# scans over KV chunks with an online-softmax accumulator (running max m,
# normalizer l, weighted sum acc), exactly the FlashAttention recurrence,
# expressed in pure jnp so it lowers on any backend.  A Pallas TPU kernel
# with the same math lives in repro/kernels/flash_kernel.py; this is the
# portable oracle the dry-run compiles.
# ---------------------------------------------------------------------------


def attend_chunked(
    q: Array,
    k: Array,
    v: Array,
    *,
    causal: bool,
    sliding_window: Optional[int] = None,
    logit_softcap: Optional[float] = None,
    q_chunk: int = 512,
    kv_chunk: int = 1024,
) -> Array:
    """Memory-bounded attention: O(Sq·kv_chunk) live logits.

    Same semantics as :func:`attend` for the full-sequence (no-cache)
    case.  Ragged lengths are zero-padded internally (padded KV rows are
    masked out; padded Q rows are sliced off).
    """
    b, sq_in, hq, d = q.shape
    skv_in = k.shape[1]
    k = _repeat_kv(k, hq)
    v = _repeat_kv(v, hq)
    q_chunk = min(q_chunk, sq_in)
    kv_chunk = min(kv_chunk, skv_in)
    pad_q = (-sq_in) % q_chunk
    pad_k = (-skv_in) % kv_chunk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    sq, skv = sq_in + pad_q, skv_in + pad_k
    nq, nk = sq // q_chunk, skv // kv_chunk
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))

    # Pre-transpose ONCE into dot-friendly (b, h, ...) layouts.  Leaving
    # the (b, s, h, d) layout to per-block einsums makes XLA re-transpose
    # every K/V block per (q-chunk x kv-chunk) pair — measured at 57% of
    # the stats-step HBM traffic (EXPERIMENTS.md §Perf iteration 3).
    qc = jnp.einsum("bqhd->bhqd", q.astype(jnp.float32)) * scale
    qc = qc.reshape(b, hq, nq, q_chunk, d)
    kT = jnp.einsum("bkhd->bhdk", k.astype(jnp.float32))  # (b, h, d, skv)
    kc = kT.reshape(b, hq, d, nk, kv_chunk)
    vc = jnp.einsum("bkhd->bhkd", v.astype(jnp.float32)).reshape(
        b, hq, nk, kv_chunk, d
    )

    def per_q_chunk(qi, q_blk):
        # q_blk: (b, h, q_chunk, d)
        q_pos = qi * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, blk):
            m, l, acc = carry
            kj, k_blk, v_blk = blk  # (b,h,d,kv_chunk), (b,h,kv_chunk,d)
            s = jnp.einsum("bhqd,bhdk->bhqk", q_blk, k_blk)
            if logit_softcap:
                s = logit_softcap * jnp.tanh(s / logit_softcap)
            k_pos = kj * kv_chunk + jnp.arange(kv_chunk)
            mask = jnp.broadcast_to(
                (k_pos < skv_in)[None, :], (q_chunk, kv_chunk)
            )  # padded KV rows never attend
            if causal:
                mask &= k_pos[None, :] <= q_pos[:, None]
            if sliding_window is not None:
                mask &= k_pos[None, :] > q_pos[:, None] - sliding_window
            s = jnp.where(mask[None, None], s, -1e30)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = alpha * l + jnp.sum(p, axis=-1)
            acc_new = alpha[..., None] * acc + jnp.einsum(
                "bhqk,bhkd->bhqd", p, v_blk
            )
            return (m_new, l_new, acc_new), None

        init = (
            jnp.full((b, hq, q_chunk), -jnp.inf, jnp.float32),
            jnp.zeros((b, hq, q_chunk), jnp.float32),
            jnp.zeros((b, hq, q_chunk, d), jnp.float32),
        )
        kv_idx = jnp.arange(nk)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, init,
            (kv_idx, jnp.moveaxis(kc, 3, 0), jnp.moveaxis(vc, 2, 0)),
        )
        return acc / jnp.maximum(l, 1e-30)[..., None]  # (b, h, q_chunk, d)

    outs = jax.lax.map(
        lambda args: per_q_chunk(*args),
        (jnp.arange(nq), jnp.moveaxis(qc, 2, 0)),
    )  # (nq, b, h, q_chunk, d)
    out = jnp.einsum("nbhqd->bnqhd", outs).reshape(b, sq, hq, d)
    return out[:, :sq_in].astype(q.dtype)
