"""Dense MLP variants + RMSNorm.

- ``silu``  : SwiGLU   out = (silu(x Wg) * (x Wu)) Wd     (llama family)
- ``geglu`` : GeGLU    out = (gelu(x Wg) * (x Wu)) Wd     (gemma)
- ``gelu``  : plain    out = gelu(x Wu) Wd                (whisper)
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.models.common import ParamSpec

Array = jax.Array

GATED = {"silu", "geglu"}


def mlp_specs(d_model: int, d_ff: int, act: str, *, prefix_layers: int = 0) -> Dict[str, ParamSpec]:
    """Parameter specs for one (possibly layer-stacked) MLP.

    prefix_layers > 0 prepends a stacked 'layers' dim (for lax.scan).
    """
    L = (prefix_layers,) if prefix_layers else ()
    lax_ = ("layers",) if prefix_layers else ()
    specs = {
        "w_up": ParamSpec(L + (d_model, d_ff), lax_ + ("embed", "mlp")),
        "w_down": ParamSpec(L + (d_ff, d_model), lax_ + ("mlp", "embed")),
    }
    if act in GATED:
        specs["w_gate"] = ParamSpec(L + (d_model, d_ff), lax_ + ("embed", "mlp"))
    return specs


def mlp_apply(params: Dict[str, Array], x: Array, act: str) -> Array:
    if act == "silu":
        h = jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])
    elif act == "geglu":
        h = jax.nn.gelu(x @ params["w_gate"]) * (x @ params["w_up"])
    elif act == "gelu":
        h = jax.nn.gelu(x @ params["w_up"])
    else:
        raise ValueError(f"unknown mlp act {act!r}")
    return h @ params["w_down"]


def mlp_flops(d_model: int, d_ff: int, act: str, tokens: int) -> int:
    mats = 3 if act in GATED else 2
    return 2 * mats * tokens * d_model * d_ff


def rmsnorm(x: Array, scale: Array, eps: float = 1e-5) -> Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))).astype(dtype)


def norm_spec(d_model: int, *, prefix_layers: int = 0) -> ParamSpec:
    L = (prefix_layers,) if prefix_layers else ()
    lax_ = ("layers",) if prefix_layers else ()
    return ParamSpec(L + (d_model,), lax_ + ("embed",), init="zeros")
