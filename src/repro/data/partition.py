"""Client partitioners — the paper's three data-heterogeneity settings.

- :func:`dirichlet_partition` — label shift (Table 1): per-class Dirichlet(α)
  proportions over clients; lower α = more heterogeneous.
- :func:`domain_partition` — feature shift (Table 2): each training domain's
  data is split uniformly over ``clients_per_domain`` clients.
- :func:`dominant_class_partition` — the personalized-FL setting (Table 3):
  every client owns s% uniform data + (100−s)% from its dominant classes,
  all clients equal-sized.
"""

from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np

Partition = List[np.ndarray]  # per-client index arrays into the dataset


def dirichlet_partition(
    labels: np.ndarray,
    num_clients: int,
    alpha: float,
    *,
    seed: int = 0,
    min_size: int = 1,
) -> Partition:
    """Per-class Dirichlet split (the standard non-IID FL benchmark split)."""
    labels = np.asarray(labels)
    rng = np.random.default_rng(seed)
    classes = np.unique(labels)
    for _ in range(100):  # retry until every client has >= min_size samples
        buckets: List[List[int]] = [[] for _ in range(num_clients)]
        for c in classes:
            idx = np.flatnonzero(labels == c)
            rng.shuffle(idx)
            props = rng.dirichlet(np.full(num_clients, alpha))
            cuts = (np.cumsum(props)[:-1] * len(idx)).astype(int)
            for b, part in zip(buckets, np.split(idx, cuts)):
                b.extend(part.tolist())
        if min(len(b) for b in buckets) >= min_size:
            break
    return [np.array(sorted(b), dtype=np.int64) for b in buckets]


def uniform_partition(n: int, num_clients: int, *, seed: int = 0) -> Partition:
    rng = np.random.default_rng(seed)
    idx = rng.permutation(n)
    return [np.sort(part).astype(np.int64) for part in np.array_split(idx, num_clients)]


def domain_partition(
    domain_sizes: Sequence[int], clients_per_domain: int, *, seed: int = 0
) -> List[Tuple[int, np.ndarray]]:
    """Feature-shift split: returns [(domain_id, indices-into-that-domain)].

    Data from a single domain may spread over several clients, but each
    client belongs to exactly one domain (paper §Experiments).
    """
    out: List[Tuple[int, np.ndarray]] = []
    for dom, n in enumerate(domain_sizes):
        for part in uniform_partition(n, clients_per_domain, seed=seed + dom):
            out.append((dom, part))
    return out


def dominant_class_partition(
    labels: np.ndarray,
    num_clients: int,
    *,
    uniform_fraction: float = 0.2,
    dominant_classes_per_client: int = 2,
    seed: int = 0,
) -> Partition:
    """Personalized-FL split: s% uniform + (1−s)% from dominant classes.

    All clients end up the same size (paper: 20% uniform by default).
    """
    labels = np.asarray(labels)
    n = len(labels)
    rng = np.random.default_rng(seed)
    per_client = n // num_clients
    n_uni = int(per_client * uniform_fraction)
    n_dom = per_client - n_uni

    classes = np.unique(labels)
    by_class = {c: list(rng.permutation(np.flatnonzero(labels == c))) for c in classes}
    pool = list(rng.permutation(n))
    taken = np.zeros(n, bool)

    parts: Partition = []
    for i in range(num_clients):
        dom_classes = classes[
            (i * dominant_classes_per_client + np.arange(dominant_classes_per_client))
            % len(classes)
        ]
        mine: List[int] = []
        # dominant part — round-robin over this client's dominant classes
        for j in range(n_dom):
            c = dom_classes[j % len(dom_classes)]
            while by_class[c] and taken[by_class[c][-1]]:
                by_class[c].pop()
            if by_class[c]:
                k = by_class[c].pop()
                taken[k] = True
                mine.append(int(k))
        # uniform part — anything untaken
        while len(mine) < per_client and pool:
            k = pool.pop()
            if not taken[k]:
                taken[k] = True
                mine.append(int(k))
        parts.append(np.array(sorted(mine), dtype=np.int64))
    return parts
