"""Synthetic class-conditional dataset family (DESIGN.md §2).

The paper's datasets (CIFAR/SVHN/PACS/OfficeHome) are not available
offline, so the reproduction uses a controllable stand-in:

- each class ``j`` is a mixture of ``modes_per_class`` Gaussians in an
  ``input_dim``-dimensional input space (multi-modality is what makes
  single-Gaussian-per-class methods like a *local* GNB fit poorly, and
  is the regime where FedPFT's GMMs matter — so we keep it);
- classes are separated by mean vectors drawn at controlled radius
  (``class_sep`` = the difficulty dial);
- the *feature-shift* variant applies a per-domain affine map +
  nonlinearity-breaking rotation to the inputs, mimicking PACS-style
  domain gaps while keeping labels semantic.

Everything is generated with explicit PRNG keys — datasets are
reproducible functions of (spec, seed), never files.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class SyntheticSpec:
    num_classes: int = 10
    input_dim: int = 64
    modes_per_class: int = 3
    class_sep: float = 3.0
    mode_spread: float = 1.5  # distance of intra-class modes from class mean
    noise: float = 1.0  # within-mode stddev
    samples_per_class: int = 500
    seed: int = 0

    @property
    def total(self) -> int:
        return self.num_classes * self.samples_per_class


def _class_means(spec: SyntheticSpec, key: Array) -> Array:
    """(C, D) class centers on a radius-``class_sep`` sphere."""
    raw = jax.random.normal(key, (spec.num_classes, spec.input_dim))
    return spec.class_sep * raw / jnp.linalg.norm(raw, axis=1, keepdims=True)


def class_modes(spec: SyntheticSpec) -> Array:
    """(C, G, D) class-mode centers — the dataset's semantic STRUCTURE.

    Depends only on ``spec.seed``, so train/test splits and all domains
    share the same class meanings.
    """
    key = jax.random.key(spec.seed)
    k_mean, k_mode = jax.random.split(key)
    means = _class_means(spec, k_mean)  # (C, D)
    return means[:, None, :] + spec.mode_spread * jax.random.normal(
        k_mode, (spec.num_classes, spec.modes_per_class, spec.input_dim)
    )


def make_classification_data(
    spec: SyntheticSpec, *, seed: int | None = None
) -> Tuple[Array, Array]:
    """Generate (x (N, D), y (N,)).

    ``seed`` controls the SAMPLES only; the class structure always comes
    from ``spec.seed`` (so different seeds = fresh draws from the same
    distribution — train vs. test, or one draw per domain).
    """
    modes = class_modes(spec)  # (C, G, D)
    skey = jax.random.key(spec.seed + 1 if seed is None else seed)
    k_pick, k_noise, k_perm = jax.random.split(skey, 3)

    n = spec.samples_per_class
    y = jnp.repeat(jnp.arange(spec.num_classes), n)  # (N,)
    which = jax.random.randint(k_pick, (spec.total,), 0, spec.modes_per_class)
    centers = modes[y, which]  # (N, D)
    x = centers + spec.noise * jax.random.normal(k_noise, centers.shape)
    perm = jax.random.permutation(k_perm, spec.total)
    return x[perm], y[perm]


def make_domain_shift_data(
    spec: SyntheticSpec,
    num_domains: int = 4,
    *,
    domain_strength: float = 1.0,
    seed: int | None = None,
) -> List[Tuple[Array, Array]]:
    """PACS-style feature shift: same semantic classes, per-domain affine map.

    Returns one (x, y) pair per domain. Domain 0's map is the identity
    (the "photo" anchor); others get a random rotation + scaling + bias
    whose magnitude grows with ``domain_strength``.
    """
    base_seed = spec.seed if seed is None else seed
    out: List[Tuple[Array, Array]] = []
    for dom in range(num_domains):
        x, y = make_classification_data(spec, seed=base_seed + 104729 * (dom + 1))
        if dom > 0:
            kd = jax.random.key(base_seed + 15485863 * dom)
            k_rot, k_scale, k_bias = jax.random.split(kd, 3)
            # random near-orthogonal mixing matrix
            m = jax.random.normal(k_rot, (spec.input_dim, spec.input_dim))
            q, _ = jnp.linalg.qr(m)
            blend = domain_strength * 0.5
            mix = (1 - blend) * jnp.eye(spec.input_dim) + blend * q
            scale = 1.0 + domain_strength * 0.3 * jax.random.normal(
                k_scale, (spec.input_dim,)
            )
            bias = domain_strength * jax.random.normal(k_bias, (spec.input_dim,))
            x = (x @ mix) * scale + bias
        out.append((x, y))
    return out
