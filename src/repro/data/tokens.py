"""Synthetic token corpus + batch iterator for the LM training driver.

A first-order Markov chain with a skewed (Zipf-ish) transition structure
gives the model non-trivial statistics to learn without any external
data.  ``TokenStream`` yields fixed-shape (batch, seq+1) windows so the
jitted train step never retraces.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Tuple

import numpy as np


def synthetic_corpus(
    vocab_size: int,
    length: int,
    *,
    seed: int = 0,
    branching: int = 32,
) -> np.ndarray:
    """Markov corpus: each token has ``branching`` likely successors."""
    rng = np.random.default_rng(seed)
    # successor table: (V, branching) with Zipf-weighted choice
    succ = rng.integers(0, vocab_size, size=(vocab_size, branching))
    weights = 1.0 / np.arange(1, branching + 1)
    weights /= weights.sum()
    out = np.empty(length, dtype=np.int32)
    tok = rng.integers(0, vocab_size)
    ranks = rng.choice(branching, size=length, p=weights)
    jumps = rng.random(length) < 0.05  # occasional uniform jump
    jump_toks = rng.integers(0, vocab_size, size=length)
    for i in range(length):
        tok = jump_toks[i] if jumps[i] else succ[tok, ranks[i]]
        out[i] = tok
    return out


@dataclasses.dataclass
class TokenStream:
    corpus: np.ndarray
    batch: int
    seq_len: int
    seed: int = 0

    def __iter__(self) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        rng = np.random.default_rng(self.seed)
        max_start = len(self.corpus) - self.seq_len - 1
        while True:
            starts = rng.integers(0, max_start, size=self.batch)
            window = np.stack(
                [self.corpus[s : s + self.seq_len + 1] for s in starts]
            )  # (B, S+1)
            yield window[:, :-1], window[:, 1:]
