from repro.data.synthetic import (
    SyntheticSpec,
    make_classification_data,
    make_domain_shift_data,
)
from repro.data.partition import (
    dirichlet_partition,
    dominant_class_partition,
    domain_partition,
    uniform_partition,
)
from repro.data.tokens import TokenStream, synthetic_corpus

__all__ = [
    "SyntheticSpec",
    "make_classification_data",
    "make_domain_shift_data",
    "dirichlet_partition",
    "dominant_class_partition",
    "domain_partition",
    "uniform_partition",
    "TokenStream",
    "synthetic_corpus",
]
