"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import optional_hypothesis

# bare env (no dev extra): property tests skip, deterministic tests run
given, settings, st = optional_hypothesis()

from repro.kernels import client_stats, expand_features, gnb_logits
from repro.kernels import ref


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "n,d,c",
    [(64, 16, 4), (128, 128, 128), (513, 100, 10), (1000, 257, 37), (256, 512, 3)],
)
def test_client_stats_sweep(n, d, c, dtype):
    k1, k2 = jax.random.split(jax.random.key(n * d + c))
    f = jax.random.normal(k1, (n, d), dtype)
    y = jax.random.randint(k2, (n,), 0, c)
    A, B, N = client_stats(f, y, c)
    A0, B0, N0 = ref.client_stats_ref(f, y, c)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(A), np.asarray(A0), rtol=tol, atol=tol * 10)
    np.testing.assert_allclose(np.asarray(B), np.asarray(B0), rtol=tol, atol=tol * 10)
    np.testing.assert_allclose(np.asarray(N), np.asarray(N0))


@settings(max_examples=10, deadline=None)
@given(
    n=st.integers(1, 400),
    d=st.integers(1, 300),
    c=st.integers(1, 50),
    seed=st.integers(0, 1000),
)
def test_client_stats_property(n, d, c, seed):
    k1, k2 = jax.random.split(jax.random.key(seed))
    f = jax.random.normal(k1, (n, d))
    y = jax.random.randint(k2, (n,), 0, c)
    A, B, N = client_stats(f, y, c)
    A0, B0, N0 = ref.client_stats_ref(f, y, c)
    np.testing.assert_allclose(np.asarray(A), np.asarray(A0), rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(np.asarray(B), np.asarray(B0), rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(np.asarray(N), np.asarray(N0))
    # invariants: B symmetric PSD-ish, N sums to n
    np.testing.assert_allclose(np.asarray(B), np.asarray(B).T, atol=1e-3)
    assert float(jnp.sum(N)) == n


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("n,d,c", [(100, 64, 10), (300, 130, 101), (64, 512, 7)])
def test_gnb_logits_sweep(n, d, c, dtype):
    keys = jax.random.split(jax.random.key(7), 3)
    f = jax.random.normal(keys[0], (n, d), dtype)
    w = jax.random.normal(keys[1], (c, d), dtype)
    b = jax.random.normal(keys[2], (c,), dtype)
    out = gnb_logits(f, w, b)
    out0 = ref.gnb_logits_ref(f, w, b)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(out0), rtol=tol, atol=tol * 20)


@pytest.mark.parametrize("act", ["relu", "gelu", "tanh", "identity"])
@pytest.mark.parametrize("n,d,o", [(100, 60, 96), (257, 128, 130)])
def test_expansion_sweep(n, d, o, act):
    keys = jax.random.split(jax.random.key(11), 2)
    f = jax.random.normal(keys[0], (n, d))
    r = jax.random.normal(keys[1], (d, o))
    out = expand_features(f, r, activation=act)
    out0 = ref.expand_features_ref(f, r, act)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out0), rtol=1e-4, atol=1e-4)


def test_kernel_stats_feed_the_full_pipeline():
    """Kernel stats → derive_global → GNB head == jnp-path head."""
    from repro.core.classifier import gnb_head
    from repro.core.statistics import FeatureStats, client_statistics, derive_global

    k1, k2 = jax.random.split(jax.random.key(3))
    f = jax.random.normal(k1, (500, 96))
    y = jax.random.randint(k2, (500,), 0, 10)
    A, B, N = client_stats(f, y, 10)
    g_kernel = derive_global(FeatureStats(A=A, B=B, N=N))
    g_jnp = derive_global(client_statistics(f, y, 10))
    h1, h2 = gnb_head(g_kernel), gnb_head(g_jnp)
    np.testing.assert_allclose(np.asarray(h1.W), np.asarray(h2.W), atol=1e-3)
    np.testing.assert_allclose(np.asarray(h1.b), np.asarray(h2.b), atol=1e-3)


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize(
    "b,sq,skv,hq,hkv,d",
    [(2, 512, 512, 4, 2, 64), (1, 300, 300, 2, 2, 32), (2, 256, 700, 2, 1, 64)],
)
def test_flash_attention_sweep(b, sq, skv, hq, hkv, d, causal):
    from repro.kernels import flash_attention
    from repro.models import attention as A

    keys = jax.random.split(jax.random.key(b * sq + skv), 3)
    q = jax.random.normal(keys[0], (b, sq, hq, d))
    k = jax.random.normal(keys[1], (b, skv, hkv, d))
    v = jax.random.normal(keys[2], (b, skv, hkv, d))
    ref = A.attend(q, k, v, causal=causal)
    out = flash_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5)


def test_flash_attention_bf16():
    from repro.kernels import flash_attention
    from repro.models import attention as A

    keys = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(keys[0], (1, 256, 2, 64), jnp.bfloat16)
    k = jax.random.normal(keys[1], (1, 256, 2, 64), jnp.bfloat16)
    v = jax.random.normal(keys[2], (1, 256, 2, 64), jnp.bfloat16)
    ref = A.attend(q, k, v, causal=True)
    out = flash_attention(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=3e-2
    )
