"""StatsPipeline: the knob matrix (backend × placement × privacy ×
ingest shape) must land on the SAME statistics as the materialized
one-shot sweep, and the streaming sharded path must cost exactly one
collective per cohort.

- hypothesis property: any batch split (ragged tails included), kernel
  on/off, secure on/off — streaming cohorts equal ``client_statistics``
  on the concatenated data;
- deterministic matrix sweep for the bare-env (no hypothesis) case;
- collective-count check: the streaming fold's jaxpr contains ZERO
  psums, the finalize exactly ONE — so batch count never changes the
  communication bill;
- multi-shard streaming-equals-materialized equivalence runs in a
  subprocess with 8 simulated devices (the dry-run flag must not leak).
"""

import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import optional_hypothesis, subprocess_env

given, settings, st = optional_hypothesis()

from repro.core.statistics import client_statistics
from repro.core.stats_pipeline import (
    StatsPipeline,
    class_conditional_moments,
)


def _random_data(n, d, c, seed):
    rng = np.random.default_rng(seed)
    return (
        rng.standard_normal((n, d)).astype(np.float32),
        rng.integers(0, c, n).astype(np.int32),
    )


def _split_batches(x, y, cuts):
    parts = np.split(np.arange(len(y)), cuts)
    return [(x[p], y[p]) for p in parts if len(p)]


def _assert_stats_close(got, want, atol=1e-3, n_atol=0.0):
    """Plain-summation N is exact; SecureAgg cancellation leaves float
    dust on every leaf, so secure cells pass n_atol > 0."""
    np.testing.assert_allclose(np.asarray(got.A), np.asarray(want.A),
                               rtol=1e-4, atol=atol)
    np.testing.assert_allclose(np.asarray(got.B), np.asarray(want.B),
                               rtol=1e-4, atol=atol)
    np.testing.assert_allclose(np.asarray(got.N), np.asarray(want.N),
                               atol=n_atol)


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(30, 180),
    d=st.integers(3, 24),
    c=st.integers(2, 6),
    m=st.integers(1, 5),
    use_kernel=st.booleans(),
    secure=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_streaming_cohort_equals_materialized(n, d, c, m, use_kernel, secure, seed):
    """Streaming (any split, ragged tail, kernel on/off, secure on/off)
    == client_statistics on the concatenated data."""
    x, y = _random_data(n, d, c, seed)
    want = client_statistics(jnp.asarray(x), jnp.asarray(y), c)

    rng = np.random.default_rng(seed + 1)
    cuts = np.sort(rng.choice(np.arange(1, n), size=min(m - 1, n - 1),
                              replace=False))
    batches = _split_batches(x, y, cuts)
    pipeline = StatsPipeline(
        c,
        backend="fused" if use_kernel else "jnp",
        privacy="secure" if secure else "plain",
        mask_scale=10.0,
    )
    # each split piece doubles as one client's batch iterator: client i
    # streams its rows in two ragged sub-batches
    clients = [
        iter([(f[: len(f) // 2 + 1], lbl[: len(f) // 2 + 1]),
              (f[len(f) // 2 + 1 :], lbl[len(f) // 2 + 1 :])])
        for f, lbl in batches
    ]
    got = pipeline.from_cohort(clients, feature_dim=d)
    # secure aggregation cancels masks only up to float associativity
    atol = 5e-2 if secure else 1e-3
    _assert_stats_close(got, want, atol=atol, n_atol=atol if secure else 0.0)


KNOB_MATRIX = [
    (backend, placement, privacy)
    for backend in ("jnp", "fused")
    for placement in ("local", "sharded")
    for privacy in ("plain", "secure")
]


@pytest.mark.parametrize("backend,placement,privacy", KNOB_MATRIX)
def test_knob_matrix_cell_matches_materialized(backend, placement, privacy):
    """Every cell of the matrix, cohort + streaming ingest, equals the
    materialized one-shot reference sweep."""
    n, d, c = 210, 18, 5
    x, y = _random_data(n, d, c, seed=7)
    want = client_statistics(jnp.asarray(x), jnp.asarray(y), c)
    pipeline = StatsPipeline(
        c, backend=backend, placement=placement, privacy=privacy,
        mask_scale=10.0,
    )
    secure = privacy == "secure"
    atol = 5e-2 if secure else 1e-3
    n_atol = atol if secure else 0.0

    got_arrays = pipeline.from_arrays(jnp.asarray(x), jnp.asarray(y))
    if not secure or placement == "sharded":
        # local from_arrays has a single party: secure is aggregation-time
        _assert_stats_close(got_arrays, want, atol=atol, n_atol=n_atol)

    clients = _split_batches(x, y, [60, 140])
    got_cohort = pipeline.from_cohort(clients)
    _assert_stats_close(got_cohort, want, atol=atol, n_atol=n_atol)

    streams = [iter([(f[:37], lbl[:37]), (f[37:], lbl[37:])])
               for f, lbl in clients]
    got_stream = pipeline.from_cohort(streams, feature_dim=d)
    _assert_stats_close(got_stream, want, atol=atol, n_atol=n_atol)


def test_from_batches_single_trace_per_shape():
    """Ragged tails are padded to the first batch shape: the whole
    stream costs ONE fold trace (trace-count check on the jit cache)."""
    from repro.core.stats_pipeline import _fold_jnp

    # shape chosen to be unique in the suite: the check counts NEW cache
    # entries on the shared jitted fold, so a colliding (batch, d, C)
    # elsewhere would make it vacuous
    n, d, c = 300, 13, 9
    x, y = _random_data(n, d, c, seed=3)
    misses_before = _fold_jnp._cache_size()
    out = StatsPipeline(c).from_batches(
        (x[i : i + 64], y[i : i + 64]) for i in range(0, n, 64)
    )
    new_traces = _fold_jnp._cache_size() - misses_before
    assert new_traces == 1, f"expected 1 fold trace, got {new_traces}"
    want = client_statistics(jnp.asarray(x), jnp.asarray(y), c)
    _assert_stats_close(out, want)


@pytest.mark.parametrize("use_kernel", [False, True])
@pytest.mark.parametrize("secure", [False, True])
def test_streaming_sharded_is_one_psum_per_cohort(secure, use_kernel):
    """The fold trace holds zero collectives (both carry layouts: the
    jnp FeatureStats fold AND the fused in-place (M, N) fold); finalize
    holds exactly one — so the communication bill is independent of the
    batch count.  Counted by the SHARED audit rule
    (``repro.analysis.jaxpr_audit``): the test, the 8-device subprocess
    check, and the CI gate all call one implementation, so the
    collective-counting logic cannot drift between them."""
    from repro.analysis.jaxpr_audit import check_collective_budget
    from repro.launch.mesh import make_host_mesh
    from repro.launch.stats_engine import make_streaming_engine

    mesh = make_host_mesh(1)
    carry, fold, finalize = make_streaming_engine(
        5, 16, mesh, use_kernel=use_kernel, secure=secure, mask_scale=10.0
    )
    f = jnp.zeros((8, 16))
    y = jnp.zeros((8,), jnp.int32)
    assert check_collective_budget(
        "fold", jax.make_jaxpr(fold)(carry, f, y), 0
    ) == []
    assert check_collective_budget(
        "finalize", jax.make_jaxpr(finalize)(carry), 1
    ) == []


DROPOUT_MATRIX = [
    (backend, placement)
    for backend in ("jnp", "fused")
    for placement in ("local", "sharded")
]


@pytest.mark.parametrize("backend,placement", DROPOUT_MATRIX)
def test_knob_matrix_dropout_axis(backend, placement):
    """The acceptance scenario: K=16 clients, t=9, any 4 dropped — the
    secure round's Shamir recovery equals the plain sum over survivors
    to ≤ 1e-5 relative, in every backend × placement cell."""
    from repro.core.statistics import aggregate

    k, t, c, d = 16, 9, 5, 12
    rng = np.random.default_rng(23)
    clients = [
        (
            rng.standard_normal((30, d)).astype(np.float32),
            rng.integers(0, c, 30).astype(np.int32),
        )
        for _ in range(k)
    ]
    dropped = [1, 4, 10, 15]
    survivors = [i for i in range(k) if i not in set(dropped)]
    want = aggregate(
        [
            client_statistics(jnp.asarray(x), jnp.asarray(y), c)
            for x, y in (clients[i] for i in survivors)
        ]
    )
    secure = StatsPipeline(
        c, backend=backend, placement=placement, privacy="secure",
        dropout=dropped, min_survivors=t, mask_scale=10.0,
    )
    got = secure.from_cohort(clients)
    for leaf in ("A", "B", "N"):
        g = np.asarray(getattr(got, leaf))
        w = np.asarray(getattr(want, leaf))
        rel = np.linalg.norm(g - w) / (np.linalg.norm(w) + 1e-12)
        assert rel < 1e-5, f"{backend}/{placement} {leaf}: rel={rel}"
    # the plain cell simply sums the survivors — same answer, no masks
    plain = secure.replace(privacy="plain")
    _assert_stats_close(plain.from_cohort(clients), want)


def test_dropout_validation():
    p = StatsPipeline(5, privacy="secure", dropout=[9], mask_scale=10.0)
    with pytest.raises(ValueError, match="9"):
        p.from_cohort([(np.zeros((4, 3), np.float32), np.zeros(4, np.int32))
                       for _ in range(4)])
    with pytest.raises(ValueError, match="survivors"):
        StatsPipeline(
            5, privacy="secure", dropout=[0, 1], min_survivors=3,
        ).from_cohort([(np.zeros((4, 3), np.float32), np.zeros(4, np.int32))
                       for _ in range(4)])
    with pytest.raises(ValueError, match="parties"):
        StatsPipeline(5, dropout=[0]).from_arrays(
            jnp.zeros((4, 3)), jnp.zeros((4,), jnp.int32)
        )
    # shard-level dropout ids are validated too — a bogus id must raise,
    # not silently report full-cohort statistics as recovered
    with pytest.raises(ValueError, match="out of range"):
        StatsPipeline(
            5, placement="sharded", privacy="secure", dropout=[64],
        ).from_arrays(jnp.zeros((8, 3)), jnp.zeros((8,), jnp.int32))
    # plain rounds honor an explicit min_survivors (no silent degrade)
    with pytest.raises(ValueError, match="survivors"):
        StatsPipeline(
            5, dropout=[0, 1], min_survivors=3,
        ).from_cohort([(np.zeros((4, 3), np.float32), np.zeros(4, np.int32))
                       for _ in range(4)])


def test_class_conditional_moments_match_numpy():
    n, d, c = 160, 9, 4
    x, y = _random_data(n, d, c, seed=11)
    y[y == 3] = 0  # leave class 3 empty
    mu, cov, counts = class_conditional_moments(
        StatsPipeline(c), jnp.asarray(x), y
    )
    for cls in range(c):
        sel = x[y == cls]
        assert counts[cls] == len(sel)
        if len(sel) >= 1:
            np.testing.assert_allclose(mu[cls], sel.mean(axis=0),
                                       rtol=1e-4, atol=1e-4)
        if len(sel) >= 2:
            np.testing.assert_allclose(cov[cls], np.cov(sel, rowvar=False),
                                       rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(mu[3], 0.0)
    np.testing.assert_allclose(cov[3], 0.0)


_SUBPROCESS_BODY = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.core.statistics import client_statistics
    from repro.core.stats_pipeline import StatsPipeline
    from repro.launch.mesh import make_host_mesh
    from repro.launch.stats_engine import streaming_sharded_stats

    assert len(jax.devices()) == 8
    mesh = make_host_mesh(2)  # (data=4, model=2): a real >1-shard layout
    rng = np.random.default_rng(0)
    n, d, c = 250, 20, 6
    x = rng.standard_normal((n, d)).astype(np.float32)
    y = rng.integers(0, c, n).astype(np.int32)
    want = client_statistics(jnp.asarray(x), jnp.asarray(y), c)

    # streaming == materialized on a 4-shard mesh, plain and secure
    for secure in (False, True):
        out = streaming_sharded_stats(
            ((x[i:i+64], y[i:i+64]) for i in range(0, n, 64)),
            c, mesh=mesh, use_kernel=False, secure=secure, mask_scale=10.0,
        )
        atol = 5e-2 if secure else 1e-3
        np.testing.assert_allclose(np.asarray(out.A), np.asarray(want.A), atol=atol)
        np.testing.assert_allclose(np.asarray(out.B), np.asarray(want.B), atol=atol)
        np.testing.assert_allclose(np.asarray(out.N), np.asarray(want.N), atol=1e-3)

    # and via the pipeline's sharded streaming cell
    out = StatsPipeline(c, placement="sharded", mesh=mesh).from_batches(
        (x[i:i+64], y[i:i+64]) for i in range(0, n, 64)
    )
    np.testing.assert_allclose(np.asarray(out.A), np.asarray(want.A), atol=1e-3)
    np.testing.assert_allclose(np.asarray(out.N), np.asarray(want.N), atol=1e-5)

    # collective budget via the SHARED audit rule, on the real 8-device mesh
    from repro.analysis.jaxpr_audit import check_collective_budget
    from repro.launch.stats_engine import make_streaming_engine
    carry, fold, finalize = make_streaming_engine(
        c, d, mesh, use_kernel=False, secure=False, mask_scale=10.0
    )
    fb = jnp.zeros((8, d)); yb = jnp.zeros((8,), jnp.int32)
    assert check_collective_budget("fold", jax.make_jaxpr(fold)(carry, fb, yb), 0) == []
    assert check_collective_budget("finalize", jax.make_jaxpr(finalize)(carry), 1) == []
    print("STREAMING_MULTIDEVICE_OK")
    """
)


def test_streaming_sharded_multidevice_subprocess():
    proc = subprocess.run(
        [sys.executable, "-c", _SUBPROCESS_BODY],
        capture_output=True, text=True, timeout=300,
        env=subprocess_env(),
        cwd="/root/repo",
    )
    assert "STREAMING_MULTIDEVICE_OK" in proc.stdout, proc.stderr[-2000:]


_DROPOUT_SUBPROCESS_BODY = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.core.statistics import aggregate, client_statistics
    from repro.core.stats_pipeline import StatsPipeline
    from repro.launch.mesh import make_host_mesh
    from repro.launch.stats_engine import (
        sharded_client_stats, streaming_sharded_stats,
    )

    def rel_close(got, want, tol=1e-5):
        for leaf in ("A", "B", "N"):
            g = np.asarray(getattr(got, leaf))
            w = np.asarray(getattr(want, leaf))
            rel = np.linalg.norm(g - w) / (np.linalg.norm(w) + 1e-12)
            assert rel < tol, (leaf, rel)

    assert len(jax.devices()) == 8
    mesh = make_host_mesh(2)  # (data=4, model=2): 4 client shards
    rng = np.random.default_rng(1)
    n, d, c = 256, 16, 5
    x = rng.standard_normal((n, d)).astype(np.float32)
    y = rng.integers(0, c, n).astype(np.int32)

    # shard 0 goes dark in the one-shot secure sweep: exact stats of the
    # surviving shards' rows (shard s owns the s-th quarter of the rows)
    per = n // 4
    want = client_statistics(jnp.asarray(x[per:]), jnp.asarray(y[per:]), c)
    got = sharded_client_stats(
        x, y, c, mesh=mesh, use_kernel=False, secure=True,
        mask_scale=10.0, dropped_shards=(0,), min_survivors=2,
    )
    rel_close(got, want)

    # streaming: shard 0 loses its slice of EVERY batch
    bs = 64
    surv = np.concatenate(
        [np.arange(b + bs // 4, b + bs) for b in range(0, n, bs)]
    )
    want_s = client_statistics(jnp.asarray(x[surv]), jnp.asarray(y[surv]), c)
    got_s = streaming_sharded_stats(
        ((x[i:i+bs], y[i:i+bs]) for i in range(0, n, bs)),
        c, mesh=mesh, use_kernel=False, secure=True, mask_scale=10.0,
        dropped_shards=(0,), min_survivors=2,
    )
    rel_close(got_s, want_s)

    # cohort on the mesh where one shard's clients ALL drop: 8 clients,
    # two per shard; clients 0 and 1 (shard 0's cohort) disconnect
    clients = [
        (x[i * 32 : (i + 1) * 32], y[i * 32 : (i + 1) * 32])
        for i in range(8)
    ]
    dropped = [0, 1]
    survivors = [i for i in range(8) if i not in dropped]
    want_c = aggregate(
        [client_statistics(jnp.asarray(f), jnp.asarray(l), c)
         for f, l in (clients[i] for i in survivors)]
    )
    got_c = StatsPipeline(
        c, placement="sharded", privacy="secure", mesh=mesh,
        dropout=dropped, min_survivors=4, mask_scale=10.0,
    ).from_cohort(clients)
    rel_close(got_c, want_c)
    print("DROPOUT_MULTIDEVICE_OK")
    """
)


def test_dropout_sharded_multidevice_subprocess():
    """Lost-shard + lost-client recovery on a real >1-shard mesh: the
    dropped parties' masks are reconstructed from Shamir shares and the
    result is the exact survivor statistics."""
    proc = subprocess.run(
        [sys.executable, "-c", _DROPOUT_SUBPROCESS_BODY],
        capture_output=True, text=True, timeout=300,
        env=subprocess_env(),
        cwd="/root/repo",
    )
    assert "DROPOUT_MULTIDEVICE_OK" in proc.stdout, proc.stderr[-2000:]


# ---------------------------------------------------------------------------
# canonical_batch_stream edge cases (the pad-to-first-seen contract)
# ---------------------------------------------------------------------------


def test_canonical_stream_empty_iterator():
    """An empty stream yields nothing; the pipeline turns it into the
    zero statistic only when told its shape."""
    from repro.core.stats_pipeline import canonical_batch_stream

    assert list(canonical_batch_stream(iter([]))) == []
    p = StatsPipeline(4)
    z = p.from_batches(iter([]), feature_dim=6)
    assert z.A.shape == (4, 6) and z.B.shape == (6, 6)
    assert float(np.asarray(z.N).sum()) == 0.0
    with pytest.raises(ValueError, match="feature_dim"):
        p.from_batches(iter([]))


def test_canonical_stream_single_ragged_tail():
    """One ragged tail: padded UP to the first-seen row count with zero
    features and label −1; oversized batches pass through untouched."""
    from repro.core.stats_pipeline import canonical_batch_stream

    x, y = _random_data(10, 5, 3, seed=0)
    out = list(canonical_batch_stream(iter([(x[:8], y[:8]), (x[8:], y[8:])])))
    assert [f.shape for f, _ in out] == [(8, 5), (8, 5)]
    tail_f, tail_y = np.asarray(out[1][0]), np.asarray(out[1][1])
    np.testing.assert_array_equal(tail_f[:2], x[8:])
    assert (tail_f[2:] == 0).all()
    np.testing.assert_array_equal(tail_y[2:], -1)
    # an oversized batch keeps its own shape (its own cached trace)
    big = list(canonical_batch_stream(iter([(x[:2], y[:2]), (x[2:], y[2:])])))
    assert big[1][0].shape == (8, 5)


@pytest.mark.parametrize("backend", ["jnp", "fused"])
def test_ragged_tail_label_padding_contributes_nothing(backend):
    """The −1 padding discipline under both backends: a ragged stream's
    statistics equal the materialized sweep, and N proves the padded
    rows fell out of every statistic."""
    x, y = _random_data(11, 6, 4, seed=1)
    batches = _split_batches(x, y, [4, 8])  # 4 + 4 + 3-row ragged tail
    got = StatsPipeline(4, backend=backend).from_batches(iter(batches))
    want = client_statistics(jnp.asarray(x), jnp.asarray(y), 4)
    _assert_stats_close(got, want)
    assert float(np.asarray(got.N).sum()) == 11.0
