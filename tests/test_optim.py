"""Optimizers: convergence on a quadratic + state/step semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import adamw, apply_updates, global_norm, sgd


def _minimize(opt, steps=200):
    target = jnp.asarray([3.0, -2.0, 0.5])
    params = {"w": jnp.zeros(3)}
    state = opt.init(params)

    @jax.jit
    def step(params, state):
        grads = jax.grad(lambda p: jnp.sum((p["w"] - target) ** 2))(params)
        upd, state = opt.update(grads, state, params)
        return apply_updates(params, upd), state

    for _ in range(steps):
        params, state = step(params, state)
    return float(jnp.max(jnp.abs(params["w"] - target)))


@pytest.mark.parametrize(
    "opt",
    [
        sgd(0.1),
        sgd(0.05, momentum=0.9),
        sgd(0.05, momentum=0.9, nesterov=True),
        adamw(0.1),
        adamw(0.1, grad_clip=1.0),
    ],
    ids=["sgd", "sgd-mom", "sgd-nesterov", "adamw", "adamw-clip"],
)
def test_converges_on_quadratic(opt):
    assert _minimize(opt) < 1e-2


def test_weight_decay_shrinks_params():
    opt = sgd(0.1, weight_decay=0.5)
    params = {"w": jnp.ones(4)}
    state = opt.init(params)
    grads = {"w": jnp.zeros(4)}
    upd, _ = opt.update(grads, state, params)
    new = apply_updates(params, upd)
    assert float(new["w"][0]) < 1.0


def test_grad_clip_bounds_update():
    opt = adamw(1.0, grad_clip=0.001)
    params = {"w": jnp.zeros(3)}
    state = opt.init(params)
    grads = {"w": 1e6 * jnp.ones(3)}
    upd, _ = opt.update(grads, state, params)
    assert float(global_norm(upd)) < 10.0


def test_adamw_state_counts_steps():
    opt = adamw(0.1)
    params = {"w": jnp.zeros(2)}
    state = opt.init(params)
    for i in range(3):
        _, state = opt.update({"w": jnp.ones(2)}, state, params)
    assert int(state.count) == 3
