"""repro.serve.front + repro.serve.replicate: the multi-worker tier.

- fan-out exactness: ragged traffic through N workers behind the front
  is bit-identical per request to direct ``score_features`` scoring
  (hypothesis over ragged mixes crossing shape buckets, plus a
  deterministic sweep);
- routing: join-shortest-queue sends work to the least-loaded worker;
- admission control + load shedding: the front-wide row bound and the
  all-workers-full case both shed (QueueFull) and count into
  ``FrontMetrics.shed_ratio`` instead of growing latency unboundedly;
- the asyncio JSON-lines socket shim end-to-end on localhost,
  including the ``{"error": "shed"}`` degraded response;
- replication: publish → snapshot → replica ``sync_once`` restores an
  identical ``(version, head)`` and fires hot-swap subscribers; steps
  apply monotonically; the watch thread picks up new snapshots.
"""

import asyncio
import time

import jax.numpy as jnp
import numpy as np
import pytest

from conftest import optional_hypothesis

given, settings, st = optional_hypothesis()

from repro.core.classifier import LinearHead
from repro.kernels import gnb_logits
from repro.serve import (
    GNBServer,
    HeadRegistry,
    QueueFull,
    RegistryReplicator,
    ServeFront,
    publish_snapshot,
)
from repro.serve.front import request_scores, serve_socket
from repro.serve.scoring import score_features


def _head(d, c, seed=0):
    rng = np.random.default_rng(seed)
    return LinearHead(
        W=jnp.asarray(rng.standard_normal((c, d)), jnp.float32),
        b=jnp.asarray(rng.standard_normal(c), jnp.float32),
    )


def _requests(sizes, d, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.standard_normal((n, d)).astype(np.float32) for n in sizes]


def _direct(head, feats):
    return np.asarray(score_features(jnp.asarray(feats), head.W, head.b))


# ---------------------------------------------------------------------------
# fan-out exactness
# ---------------------------------------------------------------------------


def _assert_front_exact(sizes, d, c, seed, workers=3):
    head = _head(d, c, seed)
    reqs = _requests(sizes, d, seed)
    front = ServeFront.create(workers, head=head, max_delay_s=5e-4)
    with front:
        futures = [front.submit(r) for r in reqs]
        front.drain(timeout=120)
    for fut, req in zip(futures, reqs):
        res = fut.result(timeout=0)
        np.testing.assert_array_equal(res.logits, _direct(head, req))
    snap = front.snapshot()
    assert snap["front"]["accepted"] == len(reqs)
    assert snap["front"]["shed"] == 0
    assert snap["aggregate"]["requests"] == len(reqs)
    assert snap["aggregate"]["rows"] == sum(s for s in sizes)


@settings(max_examples=8, deadline=None)
@given(
    sizes=st.lists(st.integers(min_value=1, max_value=300), min_size=1,
                   max_size=10),
    seed=st.integers(min_value=0, max_value=4),
)
def test_front_exactness_ragged(sizes, seed):
    """Ragged mixes spanning several pow2 buckets, fanned across
    workers: per-request results are bit-identical to direct
    ``score_features``."""
    _assert_front_exact(sizes, d=8, c=5, seed=seed)


def test_front_exactness_deterministic():
    _assert_front_exact([1, 33, 7, 300, 2, 64, 129], d=16, c=7, seed=3)


def test_front_single_worker_matches_server():
    d, c = 8, 4
    head = _head(d, c, 1)
    reqs = _requests([5, 17, 40], d, 1)
    with ServeFront.create(1, head=head, max_delay_s=5e-4) as front:
        got = [front.score(r, timeout=120) for r in reqs]
    with GNBServer(head, max_delay_s=5e-4) as server:
        want = [server.score(r, timeout=120) for r in reqs]
    for g, w in zip(got, want):
        np.testing.assert_array_equal(g.logits, w.logits)


# ---------------------------------------------------------------------------
# routing + shedding
# ---------------------------------------------------------------------------


def test_front_routes_to_least_loaded_worker():
    d, c = 4, 3
    # workers never tick (not started, huge delay): queues only fill
    front = ServeFront.create(2, head=_head(d, c), max_delay_s=60.0,
                              max_batch_rows=64, max_queue_rows=64)
    front.submit(np.zeros((10, d), np.float32))
    assert [w.batcher.queued_rows for w in front.workers] == [10, 0]
    front.submit(np.zeros((4, d), np.float32))  # worker 1 is emptier
    assert [w.batcher.queued_rows for w in front.workers] == [10, 4]
    front.submit(np.zeros((2, d), np.float32))
    assert [w.batcher.queued_rows for w in front.workers] == [10, 6]
    for w in front.workers:
        w.batcher.drain_pending()


def test_front_sheds_when_all_workers_full():
    d, c = 4, 3
    front = ServeFront.create(2, head=_head(d, c), max_delay_s=60.0,
                              max_batch_rows=16, max_queue_rows=16)
    front.submit(np.zeros((16, d), np.float32))
    front.submit(np.zeros((16, d), np.float32))  # fills the second worker
    with pytest.raises(QueueFull, match="shed"):
        front.submit(np.zeros((1, d), np.float32))
    snap = front.metrics.snapshot()
    assert snap == {"accepted": 2, "shed": 1, "shed_ratio": pytest.approx(1 / 3)}
    for w in front.workers:
        w.batcher.drain_pending()


def test_front_wide_admission_bound():
    d, c = 4, 3
    front = ServeFront.create(2, head=_head(d, c), max_delay_s=60.0,
                              max_batch_rows=64, max_queue_rows=64,
                              max_queued_rows=20)
    front.submit(np.zeros((12, d), np.float32))
    with pytest.raises(QueueFull, match="shed"):
        # workers have room (2×64) but the FRONT bound says no
        front.submit(np.zeros((12, d), np.float32))
    front.submit(np.zeros((8, d), np.float32))  # exactly at the bound
    assert front.metrics.snapshot()["shed"] == 1
    for w in front.workers:
        w.batcher.drain_pending()


def test_front_rejects_mismatched_workers():
    reg = HeadRegistry(_head(4, 3))
    reg2 = HeadRegistry(_head(8, 3))
    with pytest.raises(ValueError, match="feature_dim"):
        ServeFront([GNBServer(registry=reg), GNBServer(registry=reg2)])
    with pytest.raises(ValueError):
        ServeFront([])
    with pytest.raises(ValueError):
        ServeFront.create(0, head=_head(4, 3))


def test_front_shared_registry_hot_swaps_every_worker():
    d, c = 8, 4
    head0 = _head(d, c, 0)
    front = ServeFront.create(3, head=head0, max_delay_s=5e-4)
    with front:
        r0 = front.score(np.ones((5, d), np.float32), timeout=120)
        head1 = _head(d, c, 1)
        front.workers[0].registry.publish(head1)  # ONE registry: all see it
        front.drain(timeout=120)
        futs = [w.submit(np.ones((5, d), np.float32)) for w in front.workers]
        results = [f.result(timeout=120) for f in futs]
    assert r0.head_version == 0
    assert [r.head_version for r in results] == [1, 1, 1]
    assert all(w.metrics.snapshot()["head_swaps"] == 1 for w in front.workers)


# ---------------------------------------------------------------------------
# the asyncio socket shim
# ---------------------------------------------------------------------------


def test_socket_front_end_to_end():
    d, c = 8, 5
    head = _head(d, c, 2)
    reqs = _requests([3, 50, 7, 129, 1], d, 2)

    async def drive(front):
        server = await serve_socket(front)
        host, port = server.sockets[0].getsockname()[:2]
        try:
            return await request_scores(host, port, reqs)
        finally:
            server.close()
            await server.wait_closed()

    with ServeFront.create(2, head=head, max_delay_s=5e-4) as front:
        responses = asyncio.run(drive(front))
    assert len(responses) == len(reqs)
    for resp, req in zip(responses, reqs):
        assert resp["head_version"] == 0
        np.testing.assert_array_equal(
            np.asarray(resp["logits"], np.float32), _direct(head, req)
        )
        want_pred = np.argmax(_direct(head, req), axis=-1)
        np.testing.assert_array_equal(np.asarray(resp["predictions"]),
                                      want_pred)


def test_socket_front_sheds_gracefully():
    d, c = 4, 3
    # an unstarted worker with a tiny queue, pre-filled out-of-band:
    # every socket request must come back as a shed ERROR (a degraded
    # response), never hang the connection
    front = ServeFront.create(1, head=_head(d, c), max_delay_s=60.0,
                              max_batch_rows=8, max_queue_rows=8)
    front.submit(np.zeros((8, d), np.float32))  # fills the only queue
    reqs = _requests([8, 4], d, 0)

    async def drive():
        server = await serve_socket(front)
        host, port = server.sockets[0].getsockname()[:2]
        try:
            return await request_scores(host, port, reqs)
        finally:
            server.close()
            await server.wait_closed()

    responses = asyncio.run(drive())
    assert [r.get("error") for r in responses] == ["shed", "shed"]
    assert front.metrics.snapshot()["shed"] == 2
    for w in front.workers:
        w.batcher.drain_pending()


def test_socket_front_reports_bad_requests():
    front = ServeFront.create(1, head=_head(4, 3), max_delay_s=60.0)

    async def drive():
        server = await serve_socket(front)
        host, port = server.sockets[0].getsockname()[:2]
        try:
            reader, writer = await asyncio.open_connection(host, port)
            writer.write(b'{"no_features": 1}\n')
            writer.write(b"not json\n")
            await writer.drain()
            import json as _json

            out = [_json.loads(await reader.readline()) for _ in range(2)]
            writer.close()
            return out
        finally:
            server.close()
            await server.wait_closed()

    responses = asyncio.run(drive())
    assert all(r["error"].startswith("bad request") for r in responses)


# ---------------------------------------------------------------------------
# replication off shared snapshots
# ---------------------------------------------------------------------------


def test_replication_round_trip(tmp_path):
    """publish → snapshot → replica restore: the replica serves the
    identical (version, head) and counts the restore as a hot swap."""
    d, c = 8, 4
    source = HeadRegistry(keep=8)
    head = _head(d, c, 0)
    path = publish_snapshot(source, str(tmp_path), head)
    assert path.endswith("step_00000000.npz")

    replica_reg = HeadRegistry(_head(d, c, 99))  # stale replica head
    replicator = RegistryReplicator(replica_reg, str(tmp_path))
    assert replicator.sync_once() == 0
    assert replicator.last_step == 0

    src_v, src_head = source.current()
    rep_v, rep_head = replica_reg.current()
    assert rep_v == src_v
    np.testing.assert_array_equal(np.asarray(rep_head.W),
                                  np.asarray(src_head.W))
    np.testing.assert_array_equal(np.asarray(rep_head.b),
                                  np.asarray(src_head.b))

    # nothing new → no restore (monotonic steps, no churn under traffic)
    assert replicator.sync_once() is None
    assert replicator.last_step == 0

    # a NEW round published on the source lands on the next poll and
    # fires the replica's hot-swap subscribers
    fired = []
    replica_reg.subscribe(fired.append)
    publish_snapshot(source, str(tmp_path), _head(d, c, 1))
    assert replicator.sync_once() == 1
    assert replicator.last_step == 1
    assert fired == [1]
    np.testing.assert_array_equal(
        np.asarray(replica_reg.current()[1].W),
        np.asarray(source.current()[1].W),
    )


def test_replication_empty_directory(tmp_path):
    replica = HeadRegistry(_head(4, 2))
    replicator = RegistryReplicator(replica, str(tmp_path / "empty"))
    assert replicator.sync_once() is None  # nothing there yet: no-op
    assert replica.latest_version == 0  # replica state untouched


def test_replicated_serving_end_to_end(tmp_path):
    """The full multi-host story on one box: an FL-side registry
    publishes + snapshots; a replica server under a watch thread picks
    the new head up and serves bit-identical logits under the same
    version number."""
    d, c = 8, 4
    source = HeadRegistry(keep=8)
    head0 = _head(d, c, 0)
    publish_snapshot(source, str(tmp_path), head0)

    replica_reg = HeadRegistry()
    RegistryReplicator(replica_reg, str(tmp_path)).sync_once()  # seed it
    server = GNBServer(registry=replica_reg, max_delay_s=5e-4)
    replicator = RegistryReplicator(replica_reg, str(tmp_path),
                                    poll_interval_s=5e-3)
    req = _requests([13], d, 7)[0]
    with server, replicator:
        r0 = server.score(req, timeout=120)
        publish_snapshot(source, str(tmp_path), _head(d, c, 1))
        deadline = time.perf_counter() + 60
        while replicator.last_step != 1:
            assert time.perf_counter() < deadline, "replicator never synced"
            time.sleep(2e-3)
        server.drain(timeout=120)
        r1 = server.score(req, timeout=120)
    assert (r0.head_version, r1.head_version) == (0, 1)
    np.testing.assert_array_equal(r0.logits, _direct(head0, req))
    np.testing.assert_array_equal(
        r1.logits, _direct(source.current()[1], req)
    )
    assert server.metrics.snapshot()["head_swaps"] == 1
    assert not replicator.running


def test_replicator_thread_lifecycle(tmp_path):
    replicator = RegistryReplicator(HeadRegistry(_head(4, 2)),
                                    str(tmp_path), poll_interval_s=1e-3)
    assert not replicator.running
    with replicator:
        assert replicator.running
        with pytest.raises(RuntimeError, match="already started"):
            replicator.start()
    assert not replicator.running
