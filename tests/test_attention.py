"""Attention: chunked-vs-dense equivalence, RoPE variants, GQA, ring cache."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import attention as A


def _qkv(b, sq, skv, hq, hkv, d, seed=0):
    k1, k2, k3 = jax.random.split(jax.random.key(seed), 3)
    return (
        jax.random.normal(k1, (b, sq, hq, d)),
        jax.random.normal(k2, (b, skv, hkv, d)),
        jax.random.normal(k3, (b, skv, hkv, d)),
    )


@pytest.mark.parametrize("hkv", [1, 2, 4])
@pytest.mark.parametrize("causal", [True, False])
def test_chunked_matches_dense(hkv, causal):
    q, k, v = _qkv(2, 512, 512, 4, hkv, 32)
    ref = A.attend(q, k, v, causal=causal)
    out = A.attend_chunked(q, k, v, causal=causal, q_chunk=128, kv_chunk=128)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_chunked_sliding_window():
    q, k, v = _qkv(1, 1024, 1024, 2, 2, 16, seed=1)
    ref = A.attend(q, k, v, causal=True, sliding_window=100)
    out = A.attend_chunked(
        q, k, v, causal=True, sliding_window=100, q_chunk=256, kv_chunk=256
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_softcap():
    q, k, v = _qkv(1, 256, 256, 2, 2, 16, seed=2)
    ref = A.attend(q, k, v, causal=True, logit_softcap=20.0)
    out = A.attend_chunked(q, k, v, causal=True, logit_softcap=20.0, q_chunk=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@pytest.mark.parametrize("mode", ["standard", "2d", "mrope"])
def test_rope_preserves_norm_and_relativity(mode):
    b, s, h, d = 2, 16, 2, 32
    key = jax.random.key(0)
    q = jax.random.normal(key, (b, s, h, d))
    k = q + 0.0
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    if mode == "mrope":
        pos = jnp.broadcast_to(pos[None], (3, b, s))
    q1, k1 = A.apply_rope(q, k, pos, mode=mode, theta=1e4)
    # rotations preserve vector norms
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(q1), axis=-1),
        np.linalg.norm(np.asarray(q), axis=-1),
        rtol=1e-5,
    )
    # shifting all positions by a constant leaves q·k (same offset) invariant
    q2, k2 = A.apply_rope(
        q, k, pos + 7, mode=mode, theta=1e4
    )
    dot1 = np.einsum("bshd,bshd->bsh", np.asarray(q1), np.asarray(k1))
    dot2 = np.einsum("bshd,bshd->bsh", np.asarray(q2), np.asarray(k2))
    np.testing.assert_allclose(dot1, dot2, atol=1e-4)


def test_decode_against_ring_cache_positions():
    """attend() with explicit kv_positions handles out-of-order ring slots."""
    b, h, d, w = 1, 2, 16, 8
    key = jax.random.key(3)
    ks = jax.random.normal(key, (b, 16, h, d))
    vs = jax.random.normal(jax.random.key(4), (b, 16, h, d))
    q = jax.random.normal(jax.random.key(5), (b, 1, h, d))
    # tokens 8..15 in a ring of 8: slot s holds position 8 + ((s - 0) % 8)…
    ring_k = jnp.zeros((b, w, h, d)).at[:, jnp.arange(8, 16) % w].set(ks[:, 8:16])
    ring_v = jnp.zeros((b, w, h, d)).at[:, jnp.arange(8, 16) % w].set(vs[:, 8:16])
    kv_pos = jnp.zeros((w,), jnp.int32).at[jnp.arange(8, 16) % w].set(
        jnp.arange(8, 16)
    )
    out_ring = A.attend(
        q, ring_k, ring_v, causal=True, q_offset=jnp.asarray(16),
        kv_positions=kv_pos, sliding_window=w + 1,
    )
    out_ref = A.attend(
        q, ks[:, 8:16], vs[:, 8:16], causal=True, q_offset=jnp.asarray(16),
        kv_positions=jnp.arange(8, 16), sliding_window=w + 1,
    )
    np.testing.assert_allclose(np.asarray(out_ring), np.asarray(out_ref), atol=1e-5)


def test_chunked_ragged_lengths():
    """Non-divisible lengths (whisper's 1500 frames) pad internally."""
    q, k, v = _qkv(1, 1500, 1500, 2, 2, 32, seed=9)
    ref = A.attend(q, k, v, causal=False)
    out = A.attend_chunked(q, k, v, causal=False, q_chunk=512, kv_chunk=512)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5)
    q, k, v = _qkv(1, 1100, 700, 2, 1, 16, seed=10)
    ref = A.attend(q, k, v, causal=False)
    out = A.attend_chunked(q, k, v, causal=False, q_chunk=512, kv_chunk=512)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5)
