"""repro.tune: cache round-trip, miss-path defaults, dispatch equivalence.

The load-bearing contracts:

- ``backend="auto"`` is a DISPATCHER, not a third numeric path — its
  output must be bitwise identical to whichever concrete backend it
  selects, at every shape (property test straddling the cache's bucket
  boundaries).
- A corrupt, absent, or foreign cache file degrades to the empty cache:
  every accessor answers with today's compiled-in defaults, never an
  error — an untuned deployment is exactly the pre-tuning deployment.
- The serve batcher's pad-to multiple comes from the same cache verdict
  that picks the scoring backend, so tuning can't desync padding from
  the kernel's block expectations.
"""

import json

import jax.numpy as jnp
import numpy as np
import pytest

from conftest import optional_hypothesis

given, settings, st = optional_hypothesis()

from repro import tune
from repro.core.stats_pipeline import StatsPipeline
from repro.kernels import gnb_logits
from repro.kernels.ops import gnb_logits_jnp
from repro.serve.batcher import DynamicBatcher
from repro.serve.scoring import score_features


def _decision(kernel="gnb", n=512, d=512, c=100, winner="jnp", **blocks):
    defaults = {
        "gnb": {"block_n": 128, "block_c": 128, "block_k": 512},
        "stats": {"block_n": 256, "block_d": 128},
        "stats_acc": {"block_n": 256, "block_d": 128},
    }[kernel]
    defaults.update(blocks)
    return tune.Decision(kernel=kernel, n=n, d=d, c=c, winner=winner,
                         blocks=defaults)


# -- bucketing + cache mechanics --------------------------------------------


def test_bucket_powers_of_two():
    assert [tune.bucket(x) for x in (1, 2, 3, 17, 48, 512, 513)] == [
        1, 2, 4, 32, 64, 512, 1024,
    ]


def test_record_validates_kernel_and_winner():
    cache = tune.TuneCache()
    with pytest.raises(ValueError):
        cache.record(_decision(kernel="stats").__class__(
            kernel="nope", n=1, d=1, c=1, winner="jnp", blocks={}))
    with pytest.raises(ValueError):
        cache.record(tune.Decision(kernel="gnb", n=1, d=1, c=1,
                                   winner="fastest", blocks={}))


def test_cache_roundtrip_preserves_decisions(tmp_path):
    cache = tune.TuneCache()
    cache.record(_decision(kernel="gnb", winner="jnp"))
    cache.record(_decision(kernel="stats", n=4096, winner="fused"))
    path = str(tmp_path / "tune.json")
    cache.save(path)
    reloaded = tune.TuneCache.load(path)
    assert len(reloaded) == len(cache) == 2
    assert sorted(map(repr, reloaded.decisions())) == sorted(
        map(repr, cache.decisions())
    )
    # the reloaded cache drives every dispatch decision identically
    assert tune.stats_backend(4096, 512, 100, cache=reloaded) == \
        tune.stats_backend(4096, 512, 100, cache=cache) == "fused"
    assert tune.gnb_blocks(512, 512, 100, cache=reloaded) == \
        tune.gnb_blocks(512, 512, 100, cache=cache) == (128, 128, 512)
    assert tune.serve_row_multiple(512, 100, cache=reloaded) == \
        tune.serve_row_multiple(512, 100, cache=cache)


@pytest.mark.parametrize("payload", [
    "not json at all {",
    json.dumps({"version": 999, "entries": {}}),          # foreign version
    json.dumps({"version": 1, "entries": {"k": {"bad": 1}}}),  # bad schema
    json.dumps([1, 2, 3]),                                 # wrong shape
])
def test_corrupt_cache_degrades_to_defaults(tmp_path, payload):
    path = tmp_path / "tune.json"
    path.write_text(payload)
    cache = tune.TuneCache.load(str(path))
    assert len(cache) == 0
    assert tune.stats_blocks(4096, 512, 100, cache=cache) == (
        tune.DEFAULT_STATS_BLOCK_N, tune.DEFAULT_STATS_BLOCK_D,
    )
    assert tune.serve_row_multiple(512, 100, cache=cache) == \
        tune.DEFAULT_GNB_BLOCK_N


def test_absent_cache_degrades_to_defaults(tmp_path):
    cache = tune.TuneCache.load(str(tmp_path / "never_written.json"))
    assert len(cache) == 0
    assert tune.gnb_blocks(64, 64, 10, cache=cache) == (
        tune.DEFAULT_GNB_BLOCK_N, tune.DEFAULT_GNB_BLOCK_C,
        tune.DEFAULT_GNB_BLOCK_K,
    )


def test_lookup_nearest_n_falls_back_within_d_c_bucket():
    cache = tune.TuneCache()
    cache.record(_decision(kernel="stats", n=4096, d=512, c=100,
                           winner="fused"))
    # other n, same d/C family → the 4096 verdict informs it
    assert cache.lookup("stats", 512, 512, 100).winner == "fused"
    # n unknown entirely (batcher construction time) → largest-n entry
    assert cache.lookup("stats", None, 512, 100).winner == "fused"
    # different d bucket → genuine miss
    assert cache.lookup("stats", 4096, 64, 100) is None


def test_using_cache_scopes_and_restores():
    cache = tune.TuneCache()
    cache.record(_decision(kernel="gnb", winner="jnp"))
    assert tune.serve_row_multiple(512, 100) == tune.DEFAULT_GNB_BLOCK_N
    with tune.using_cache(cache):
        assert tune.serve_row_multiple(512, 100) == tune.JNP_ROW_MULTIPLE
        with tune.using_cache(tune.TuneCache()):
            assert tune.serve_row_multiple(512, 100) == \
                tune.DEFAULT_GNB_BLOCK_N
        assert tune.serve_row_multiple(512, 100) == tune.JNP_ROW_MULTIPLE
    assert tune.serve_row_multiple(512, 100) == tune.DEFAULT_GNB_BLOCK_N


# -- heuristics (the untuned miss path, on this CPU host) -------------------


def test_cpu_heuristics_without_cache():
    # interpret-mode Pallas never beats compiled XLA → stats goes jnp…
    assert tune.stats_backend(65536, 512, 100, cache=tune.TuneCache()) == "jnp"
    # …but GNB stays fused: the serve tests pin bit-exactness against
    # the kernel path, and only a MEASURED jnp win may flip it
    assert tune.gnb_backend(48, 17, 7, cache=tune.TuneCache()) == "fused"


# -- batcher coupling -------------------------------------------------------


def test_serve_pad_target_follows_tuned_verdict():
    """Per-batch pad targets couple to the tuner per BUCKET: the pow2
    row bucket rounds up to the winning backend's quantum — the tuned
    fused block_n, or the sublane alignment on a jnp verdict."""
    d, c = 512, 100
    fused = tune.TuneCache()
    fused.record(_decision(kernel="gnb", n=512, d=d, c=c, winner="fused",
                           block_n=128))
    jnp_win = tune.TuneCache()
    jnp_win.record(_decision(kernel="gnb", n=512, d=d, c=c, winner="jnp"))
    # fused verdict: bucket 512 rounds to the tuned 128-row blocks
    assert tune.serve_pad_target(400, d, c, cache=fused) == 512
    assert tune.serve_pad_target(513, d, c, cache=fused) == 1024
    # jnp verdict: no kernel block constraint — just the row alignment
    assert tune.serve_pad_target(400, d, c, cache=jnp_win) == 512
    assert tune.serve_pad_target(390, d, c, align=100, cache=jnp_win) == 600
    # untuned: the heuristic pin (fused on CPU) with the default block
    assert tune.serve_pad_target(5, d, c, cache=tune.TuneCache()) == \
        tune.DEFAULT_GNB_BLOCK_N
    # caller alignment (mesh shards) always divides the target
    assert tune.serve_pad_target(400, d, c, align=3, cache=fused) % 3 == 0


def test_batcher_pad_targets_follow_tuned_verdict():
    d, c = 512, 100
    fused = tune.TuneCache()
    fused.record(_decision(kernel="gnb", n=64, d=d, c=c, winner="fused",
                           block_n=32))
    with tune.using_cache(fused):
        batcher = DynamicBatcher(d, num_classes=c, max_batch_rows=256,
                                 max_queue_rows=4096)
        # the tuned 32-row blocks shape the small buckets: 64-row bucket
        # pads to 64 (2 blocks), not to the 256-row default block
        assert 64 in batcher.pad_targets()
        assert batcher._pad_target(40) == 64
    # row_multiple is the pad ALIGNMENT now, not the pad target — an
    # explicit value constrains every target without dictating it
    batcher = DynamicBatcher(d, num_classes=c, row_multiple=24,
                             max_batch_rows=256, max_queue_rows=4096)
    assert batcher.row_multiple == 24
    assert all(t % 24 == 0 for t in batcher.pad_targets())


# -- auto dispatch ≡ selected concrete backend ------------------------------


def _crossover_cache(d, c):
    """jnp wins the small-n bucket, fused the large one — auto must
    straddle the boundary."""
    cache = tune.TuneCache()
    cache.record(_decision(kernel="stats", n=64, d=d, c=c, winner="jnp"))
    cache.record(_decision(kernel="stats", n=256, d=d, c=c, winner="fused",
                           block_n=128))
    return cache


@settings(max_examples=12, deadline=None)
@given(n=st.integers(min_value=4, max_value=320))
def test_auto_stats_bitwise_matches_selected_backend(n):
    d, c = 24, 5
    cache = _crossover_cache(d, c)
    rng = np.random.default_rng(n)
    f = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
    y = jnp.asarray(rng.integers(0, c, n), jnp.int32)
    with tune.using_cache(cache):
        verdict = tune.stats_backend(n, d, c)
        assert verdict in ("jnp", "fused")
        auto = StatsPipeline(c, backend="auto").from_arrays(f, y)
        concrete = StatsPipeline(c, backend=verdict).from_arrays(f, y)
    np.testing.assert_array_equal(np.asarray(auto.A), np.asarray(concrete.A))
    np.testing.assert_array_equal(np.asarray(auto.B), np.asarray(concrete.B))
    np.testing.assert_array_equal(np.asarray(auto.N), np.asarray(concrete.N))


def test_auto_stats_resolves_before_use_kernel():
    pipe = StatsPipeline(3)  # default backend is now auto
    assert pipe.backend == "auto"
    with pytest.raises(RuntimeError):
        pipe.use_kernel  # unresolved auto must never reach a kernel choice


def test_auto_scoring_bitwise_matches_selected_backend():
    d, c, n = 24, 5, 48
    rng = np.random.default_rng(0)
    f = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((c, d)), jnp.float32)
    b = jnp.asarray(rng.standard_normal(c), jnp.float32)
    for winner, reference in (
        ("jnp", gnb_logits_jnp(f, w, b)),
        ("fused", gnb_logits(f, w, b, interpret=True)),
    ):
        cache = tune.TuneCache()
        cache.record(_decision(kernel="gnb", n=n, d=d, c=c, winner=winner))
        with tune.using_cache(cache):
            auto = score_features(f, w, b, interpret=True, backend="auto")
        np.testing.assert_array_equal(np.asarray(auto), np.asarray(reference))


def test_scoring_rejects_unknown_backend():
    f = jnp.zeros((4, 8))
    w = jnp.zeros((3, 8))
    b = jnp.zeros((3,))
    with pytest.raises(ValueError):
        score_features(f, w, b, interpret=True, backend="pallas")


# -- the tuner itself (tiny smoke: grid → decision → cache) -----------------


def test_tune_stats_smoke_records_decision():
    cache = tune.TuneCache()
    dec = tune.tune_stats(64, 16, 4, cache=cache, iters=1, interpret=True,
                          candidates=[(128, 128)])
    assert dec.winner in ("jnp", "fused")
    assert dec.blocks == {"block_n": 128, "block_d": 128}
    assert dec.jnp_ms > 0 and dec.fused_ms > 0 and dec.default_ms > 0
    assert cache.lookup("stats", 64, 16, 4) is dec


def test_tune_gnb_smoke_records_decision():
    cache = tune.TuneCache()
    dec = tune.tune_gnb(64, 16, 4, cache=cache, iters=1, interpret=True,
                        candidates=[(64, 128, 128)])
    assert dec.kernel == "gnb"
    assert dec.blocks["block_n"] == 64
    assert cache.lookup("gnb", 64, 16, 4) is dec


def test_tune_stats_acc_smoke_records_decision():
    cache = tune.TuneCache()
    dec = tune.tune_stats_acc(64, 16, 4, cache=cache, iters=1,
                              interpret=True, candidates=[(128, 128)])
    assert dec.kernel == "stats_acc"
    assert tune.stats_acc_blocks(4, 16, rows=64, cache=cache) == (128, 128)
