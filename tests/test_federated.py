"""Distributed FedCGS aggregation: shard_map psum == centralized oracle.

Multi-device coverage runs in a SUBPROCESS with
--xla_force_host_platform_device_count=8 so the main test process keeps
seeing 1 CPU device (the dry-run flag must never leak globally).
"""

import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np

from conftest import subprocess_env

from repro.core.federated import distributed_client_stats, masked_distributed_stats
from repro.core.statistics import client_statistics
from repro.launch.mesh import make_host_mesh


def test_single_device_mesh_matches_oracle():
    mesh = make_host_mesh(1)
    k1, k2 = jax.random.split(jax.random.key(0))
    f = jax.random.normal(k1, (64, 16))
    y = jax.random.randint(k2, (64,), 0, 5)
    out = distributed_client_stats(f, y, 5, mesh)
    ref = client_statistics(f, y, 5)
    np.testing.assert_allclose(np.asarray(out.A), np.asarray(ref.A), atol=1e-4)
    np.testing.assert_allclose(np.asarray(out.B), np.asarray(ref.B), atol=1e-4)


_SUBPROCESS_BODY = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.core.federated import distributed_client_stats, masked_distributed_stats
    from repro.core.statistics import client_statistics
    from repro.launch.mesh import make_host_mesh

    assert len(jax.devices()) == 8
    mesh = make_host_mesh(2)  # (data=4, model=2)
    k1, k2 = jax.random.split(jax.random.key(0))
    f = jax.random.normal(k1, (128, 24))
    y = jax.random.randint(k2, (128,), 0, 6)
    ref = client_statistics(f, y, 6)

    out = distributed_client_stats(f, y, 6, mesh)
    np.testing.assert_allclose(np.asarray(out.A), np.asarray(ref.A), atol=1e-3)
    np.testing.assert_allclose(np.asarray(out.B), np.asarray(ref.B), atol=1e-3)
    np.testing.assert_allclose(np.asarray(out.N), np.asarray(ref.N), atol=1e-5)

    masked = masked_distributed_stats(f, y, 6, mesh, mask_scale=100.0)
    np.testing.assert_allclose(np.asarray(masked.A), np.asarray(ref.A), atol=2e-2)
    np.testing.assert_allclose(np.asarray(masked.B), np.asarray(ref.B), atol=2e-2)
    print("MULTIDEVICE_OK")
    """
)


def test_multidevice_psum_aggregation_subprocess():
    proc = subprocess.run(
        [sys.executable, "-c", _SUBPROCESS_BODY],
        capture_output=True, text=True, timeout=300,
        env=subprocess_env(),
        cwd="/root/repo",
    )
    assert "MULTIDEVICE_OK" in proc.stdout, proc.stderr[-2000:]
