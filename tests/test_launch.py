"""Launch-layer units: input specs, support matrix, roofline math, serve."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import PUBLIC_IDS, get_config
from repro.launch import io_specs
from repro.launch.hlo_analysis import HBM_BW, ICI_BW, PEAK_FLOPS, Roofline
from repro.models.config import INPUT_SHAPES


def test_support_matrix_is_39_of_40():
    supported = sum(
        io_specs.supported(get_config(a), s)
        for a in PUBLIC_IDS
        for s in INPUT_SHAPES.values()
    )
    assert supported == 39  # whisper-tiny x long_500k is the one skip


@pytest.mark.parametrize("arch", PUBLIC_IDS)
def test_train_inputs_cover_model_needs(arch):
    cfg = get_config(arch)
    batch = io_specs.train_inputs(cfg, INPUT_SHAPES["train_4k"])
    assert batch["tokens"].shape == (256, 4096)
    if cfg.rope == "mrope":
        assert batch["positions"].shape == (3, 256, 4096)
    if cfg.vision_tokens:
        assert batch["patches"].shape[1] == cfg.vision_tokens
    if cfg.is_encdec:
        assert batch["frames"].shape[1] == cfg.encoder_seq_len


@pytest.mark.parametrize("arch", ["llama4-maverick-400b-a17b", "mamba2-2.7b", "whisper-tiny"])
def test_decode_inputs_have_cache_tree(arch):
    cfg = get_config(arch)
    inputs = io_specs.decode_inputs(cfg, INPUT_SHAPES["decode_32k"])
    assert inputs["token"].shape == (128,)
    cache = inputs["cache"]
    assert cache["index"].shape == ()
    assert cache["positions"].shape[0] == 32768
    leaves = jax.tree_util.tree_leaves(cache)
    assert all(isinstance(l, jax.ShapeDtypeStruct) for l in leaves)


def test_long500k_gets_sliding_window_for_dense():
    cfg = get_config("starcoder2-15b")
    out = io_specs.config_for_shape(cfg, INPUT_SHAPES["long_500k"])
    assert out.sliding_window == io_specs.LONG_CONTEXT_WINDOW
    ssm = get_config("mamba2-2.7b")
    assert io_specs.config_for_shape(ssm, INPUT_SHAPES["long_500k"]).sliding_window is None


def test_roofline_terms_and_dominance():
    r = Roofline(
        hlo_flops=PEAK_FLOPS,  # exactly 1 s of compute
        hlo_bytes=HBM_BW * 2.0,  # 2 s of memory
        collective_bytes_per_chip=ICI_BW * 0.5,  # 0.5 s
        chips=256,
        model_flops=PEAK_FLOPS * 256 * 0.5,
    )
    assert r.compute_s == pytest.approx(1.0)
    assert r.memory_s == pytest.approx(2.0)
    assert r.collective_s == pytest.approx(0.5)
    assert r.dominant == "memory"
    assert r.useful_flops_ratio == pytest.approx(0.5)
    d = r.as_dict()
    assert d["dominant"] == "memory"


def test_serve_driver_generates():
    from repro.launch.serve import serve

    gen, stats = serve("gemma-2b", batch=2, prompt_len=16, gen_tokens=4)
    assert gen.shape == (2, 4)
    assert (gen >= 0).all()
    assert stats["tokens_per_s"] > 0


def test_batch_axes_replicate_batch1():
    class FakeMesh:
        axis_names = ("pod", "data", "model")
        shape = {"pod": 2, "data": 16, "model": 16}

    # batch=1 isn't divisible by pod*data -> replicated
    assert io_specs._batch_axes(FakeMesh(), 1) is None
    # batch=256 is -> joint (pod, data)
    assert io_specs._batch_axes(FakeMesh(), 256) == ("pod", "data")
