"""repro.serve: the dynamic-batching GNB serving subsystem.

- batcher coalescing + block padding is EXACT: every request's rows,
  scored as part of any coalesced padded batch, are bit-identical to
  scoring that request alone through ``kernels.gnb_logits``
  (hypothesis over ragged request sizes, plus a deterministic sweep);
- hot-swap atomicity: under concurrent submits and repeated publishes,
  every response is bit-identical to the head version it REPORTS —
  no request is ever scored by a half-written or mixed head;
- backpressure (QueueFull past the queue bound) and graceful
  drain/shutdown semantics;
- the acceptance end-to-end: ragged concurrent traffic, a secure +
  dropout StatsPipeline cohort round hot-swapping the head mid-stream,
  every response bit-identical to its recorded head version;
- mesh-sharded smoke on 8 simulated devices via subprocess.
"""

import subprocess
import sys
import textwrap
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from conftest import optional_hypothesis, subprocess_env

given, settings, st = optional_hypothesis()

from repro.core.classifier import LinearHead, gnb_head
from repro.core.statistics import derive_global
from repro.core.stats_pipeline import StatsPipeline
from repro.kernels import gnb_logits
from repro.serve import DynamicBatcher, GNBServer, HeadRegistry, QueueFull
from repro.serve.metrics import ServeMetrics, percentile


def _head(d, c, seed=0):
    rng = np.random.default_rng(seed)
    return LinearHead(
        W=jnp.asarray(rng.standard_normal((c, d)), jnp.float32),
        b=jnp.asarray(rng.standard_normal(c), jnp.float32),
    )


def _requests(sizes, d, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.standard_normal((n, d)).astype(np.float32) for n in sizes]


def _direct(head, feats):
    return np.asarray(gnb_logits(jnp.asarray(feats), head.W, head.b))


def _drive_batcher(batcher, head):
    """Score everything queued exactly the way the server loop does."""
    while batcher.pending_requests:
        pendings, padded, rows = batcher.form_batch()
        logits = _direct(head, padded)[:rows]
        batcher.complete(pendings, logits, 0, batch_rows=rows)


# ---------------------------------------------------------------------------
# batcher: coalescing + padding exactness
# ---------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(
    sizes=st.lists(st.integers(min_value=1, max_value=40), min_size=1,
                   max_size=8),
    seed=st.integers(min_value=0, max_value=5),
)
def test_batcher_exactness_ragged(sizes, seed):
    """Any ragged mix of request sizes: coalesced+padded scoring per
    request is bit-identical to scoring the request alone."""
    d, c = 8, 5
    head = _head(d, c, seed)
    reqs = _requests(sizes, d, seed)
    batcher = DynamicBatcher(d, max_batch_rows=64, max_queue_rows=4096)
    futures = [batcher.submit(r) for r in reqs]
    _drive_batcher(batcher, head)
    for fut, req in zip(futures, reqs):
        res = fut.result(timeout=0)
        np.testing.assert_array_equal(res.logits, _direct(head, req))
        np.testing.assert_array_equal(
            res.predictions, np.argmax(_direct(head, req), axis=-1)
        )


def test_batcher_exactness_deterministic():
    """Bare-env (no hypothesis) version: ragged sizes incl. one request
    larger than max_batch_rows (admitted whole, its own batch)."""
    d, c = 16, 7
    head = _head(d, c, 3)
    sizes = [1, 33, 7, 300, 2, 64]
    reqs = _requests(sizes, d, 3)
    batcher = DynamicBatcher(d, max_batch_rows=128, max_queue_rows=4096)
    futures = [batcher.submit(r) for r in reqs]
    batches = 0
    while batcher.pending_requests:
        pendings, padded, rows = batcher.form_batch()
        assert padded.shape[0] % batcher.row_multiple == 0
        assert padded.shape[0] >= rows
        logits = _direct(head, padded)[:rows]
        batcher.complete(pendings, logits, 0, batch_rows=rows)
        batches += 1
    assert batches > 1  # the 300-row request forced a split
    for fut, req in zip(futures, reqs):
        np.testing.assert_array_equal(
            fut.result(timeout=0).logits, _direct(head, req)
        )


def test_batcher_pads_to_bucket_not_capacity():
    """The tentpole behaviour: a small batch pads to ITS pow2 bucket
    target, not to one global shape — a 5-row request in an otherwise
    idle queue must not burn max_batch_rows-5 padding lanes."""
    from repro import tune

    d = 16
    batcher = DynamicBatcher(d, max_batch_rows=1024, max_queue_rows=16384)
    batcher.submit(np.zeros((5, d), np.float32))
    pendings, padded, rows = batcher.form_batch()
    assert rows == 5
    assert padded.shape[0] == tune.serve_pad_target(5, d, None)
    assert padded.shape[0] < 1024  # NOT pad-to-capacity
    assert padded.shape[0] % batcher.row_multiple == 0
    batcher.complete(pendings, np.zeros((rows, 3)), 0, batch_rows=rows)
    # every normal-traffic pad shape is enumerable (the trace-warm set)
    targets = batcher.pad_targets()
    assert padded.shape[0] in targets
    assert all(t % batcher.row_multiple == 0 for t in targets)
    assert targets == sorted(set(targets))


def test_batcher_buckets_by_request_size():
    from repro import tune

    d = 4
    batcher = DynamicBatcher(d, max_batch_rows=64, max_queue_rows=4096,
                             max_delay_s=60.0)
    for n in (3, 4, 17, 30, 200):
        batcher.submit(np.zeros((n, d), np.float32))
    assert batcher.queued_buckets() == {
        tune.bucket(3): 2,  # 3 and 4 share the pow2-4 bucket
        tune.bucket(17): 2,  # 17 and 30 share the pow2-32 bucket
        tune.bucket(200): 1,
    }
    batcher.drain_pending()
    assert batcher.queued_buckets() == {}


def test_batcher_top_up_fills_padding_lanes():
    """Padding lanes of the primary batch are converted into real rows
    from other buckets when they fit — occupancy for free."""
    d = 8
    head = _head(d, 3, 0)
    batcher = DynamicBatcher(d, max_batch_rows=1024, max_queue_rows=16384)
    target = batcher._pad_target(100)
    assert target >= 128  # the top-up below must fit the padding gap
    reqs = _requests([100, 5, 5], d, 0)
    futures = [batcher.submit(r) for r in reqs]
    pendings, padded, rows = batcher.form_batch()
    # one batch took all three: the two 5-row requests rode the padding
    assert rows == 110 and len(pendings) == 3
    assert padded.shape[0] == target  # top-up never grows the target
    assert batcher.pending_requests == 0
    logits = _direct(head, padded)[:rows]
    batcher.complete(pendings, logits, 0, batch_rows=rows)
    for fut, req in zip(futures, reqs):  # exactness across the seams
        np.testing.assert_array_equal(
            fut.result(timeout=0).logits, _direct(head, req)
        )


def test_batcher_primary_bucket_is_oldest_head():
    d = 4
    batcher = DynamicBatcher(d, max_batch_rows=64, max_queue_rows=4096,
                             max_delay_s=60.0)
    first = batcher.submit(np.zeros((40, d), np.float32))  # pow2-64 bucket
    batcher.submit(np.zeros((2, d), np.float32))  # pow2-2 bucket, younger
    pendings, _, _ = batcher.form_batch()
    # the 40-row request is oldest, so ITS bucket is primary (the 2-row
    # request still rides along as top-up into the same batch)
    assert pendings[0].future is first
    batcher.drain_pending()


def test_batcher_admission_policy():
    d = 4
    batcher = DynamicBatcher(
        d, max_batch_rows=32, max_delay_s=10.0, max_queue_rows=64
    )
    assert not batcher.ready()
    batcher.submit(np.zeros((8, d), np.float32))
    now = time.perf_counter()
    assert not batcher.ready(now)  # 8 rows < 32, no delay elapsed
    assert batcher.ready(now + 11.0)  # oldest waited past max_delay_s
    batcher.submit(np.zeros((24, d), np.float32))
    assert batcher.ready(now)  # 32 rows reach max_batch_rows
    batcher.drain_pending()


def test_batcher_rejects_malformed():
    batcher = DynamicBatcher(8)
    with pytest.raises(ValueError):
        batcher.submit(np.zeros((3, 9), np.float32))  # wrong feature dim
    with pytest.raises(ValueError):
        batcher.submit(np.zeros((0, 8), np.float32))  # empty request


# ---------------------------------------------------------------------------
# backpressure + drain/shutdown
# ---------------------------------------------------------------------------


def test_backpressure_queue_full():
    d = 4
    batcher = DynamicBatcher(d, max_batch_rows=16, max_queue_rows=16)
    batcher.submit(np.zeros((10, d), np.float32))
    with pytest.raises(QueueFull):
        batcher.submit(np.zeros((7, d), np.float32))  # 17 > 16
    batcher.submit(np.zeros((6, d), np.float32))  # exactly at the bound


def test_server_backpressure_counts_rejections():
    d, c = 8, 3
    server = GNBServer(
        _head(d, c), max_batch_rows=16, max_queue_rows=16,
        max_delay_s=60.0,  # the worker never fires on its own
    )
    # not started: the queue only fills
    server.submit(np.zeros((12, d), np.float32))
    with pytest.raises(QueueFull):
        server.submit(np.zeros((12, d), np.float32))
    assert server.metrics.snapshot()["rejected"] == 1
    server.shutdown(drain=False)


def test_server_drain_and_shutdown():
    d, c = 8, 3
    head = _head(d, c)
    server = GNBServer(head, max_delay_s=1e-3).start()
    futures = [server.submit(r) for r in _requests([3, 50, 7, 129], d, 1)]
    server.drain(timeout=60)
    assert all(f.done() for f in futures)
    server.shutdown()
    with pytest.raises(RuntimeError):
        server.submit(np.zeros((1, d), np.float32))
    assert not server.running


def test_server_drain_raises_without_running_worker():
    """Regression: ``drain()`` with work queued but no worker alive used
    to spin forever (the queue can only empty inside the worker tick).
    Both the never-started and the already-stopped cases must raise."""
    d, c = 8, 3
    server = GNBServer(_head(d, c), max_delay_s=60.0)
    server.submit(np.zeros((2, d), np.float32))
    with pytest.raises(RuntimeError, match="no running worker"):
        server.drain(timeout=5)

    # an empty queue with no worker is fine — nothing to wait for
    GNBServer(_head(d, c)).drain(timeout=5)

    # dead-worker case: stop the thread, leave work queued
    server2 = GNBServer(_head(d, c), max_delay_s=60.0, max_batch_rows=1 << 14)
    server2.start()
    server2._stop.set()
    server2._thread.join(timeout=10)
    assert not server2.running
    server2.submit(np.zeros((2, d), np.float32))
    with pytest.raises(RuntimeError, match="no running worker"):
        server2.drain(timeout=5)
    server2.shutdown(drain=False)


def test_server_shutdown_without_drain_fails_pending():
    d, c = 8, 3
    server = GNBServer(
        _head(d, c), max_delay_s=60.0, max_batch_rows=1 << 14,
    ).start()
    fut = server.submit(np.zeros((2, d), np.float32))
    server.shutdown(drain=False)
    with pytest.raises(RuntimeError, match="shut down"):
        fut.result(timeout=0)


# ---------------------------------------------------------------------------
# registry: versioning + refit
# ---------------------------------------------------------------------------


def test_registry_versions_and_eviction():
    d, c = 4, 3
    reg = HeadRegistry(keep=2)
    assert reg.latest_version is None
    with pytest.raises(LookupError):
        reg.current()
    v0 = reg.publish(_head(d, c, 0))
    v1 = reg.publish(_head(d, c, 1))
    v2 = reg.publish(_head(d, c, 2))
    assert (v0, v1, v2) == (0, 1, 2)
    assert reg.versions() == [1, 2]  # keep=2 evicted v0
    with pytest.raises(LookupError):
        reg.head(v0)
    ver, live = reg.current()
    assert ver == v2
    np.testing.assert_array_equal(np.asarray(live.W), np.asarray(reg.head(v2).W))


def test_registry_refit_matches_direct_head():
    rng = np.random.default_rng(7)
    n, d, c = 160, 8, 4
    feats = rng.standard_normal((n, d)).astype(np.float32)
    labels = rng.integers(0, c, n).astype(np.int32)
    clients = [(feats[:80], labels[:80]), (feats[80:], labels[80:])]
    pipe = StatsPipeline(c)
    reg = HeadRegistry()
    version = reg.refit_from_round(pipe, clients)
    want = gnb_head(derive_global(pipe.from_cohort(clients)))
    got = reg.head(version)
    np.testing.assert_array_equal(np.asarray(got.W), np.asarray(want.W))
    np.testing.assert_array_equal(np.asarray(got.b), np.asarray(want.b))


def test_registry_snapshot_restore_round_trip(tmp_path):
    """Durable snapshots via checkpoint.store: every retained head, the
    live pointer, AND the version counter survive the round trip, so a
    replica restored off shared storage serves bit-identical logits and
    never reuses a persisted version number."""
    d, c = 6, 3
    reg = HeadRegistry(keep=4)
    for seed in range(3):
        reg.publish(_head(d, c, seed))
    path = reg.snapshot(str(tmp_path))
    assert path.endswith("step_00000000.npz")

    replica = HeadRegistry()
    live = replica.restore(str(tmp_path))
    assert live == reg.latest_version == 2
    assert replica.versions() == reg.versions() == [0, 1, 2]
    for v in reg.versions():
        np.testing.assert_array_equal(
            np.asarray(replica.head(v).W), np.asarray(reg.head(v).W)
        )
        np.testing.assert_array_equal(
            np.asarray(replica.head(v).b), np.asarray(reg.head(v).b)
        )
    ver, head = replica.current()
    assert ver == 2
    np.testing.assert_array_equal(np.asarray(head.W), np.asarray(reg.head(2).W))
    # numbering continues past the snapshot's counter
    assert replica.publish(_head(d, c, 9)) == 3

    # step defaults to one past the latest snapshot in the directory
    assert reg.snapshot(str(tmp_path)).endswith("step_00000001.npz")


def test_registry_restore_notifies_subscribers(tmp_path):
    """Regression: ``restore()`` used to swap the live head WITHOUT
    firing subscribers — a replica restoring a newer round off shared
    storage silently skipped its swap metric (and any watcher hook)."""
    d, c = 6, 3
    source = HeadRegistry()
    source.publish(_head(d, c, 0))
    source.publish(_head(d, c, 1))
    source.snapshot(str(tmp_path))

    replica = HeadRegistry(_head(d, c, 9))
    fired = []
    replica.subscribe(fired.append)
    assert replica.restore(str(tmp_path)) == 1
    assert fired == [1]  # live version changed 0 -> 1: one notification

    # idempotent restore: same live version again -> NO spurious swap
    assert replica.restore(str(tmp_path)) == 1
    assert fired == [1]

    # the server-level consequence: a replica GNBServer counts the
    # restore as a head swap exactly like a local publish
    server = GNBServer(registry=HeadRegistry(_head(d, c, 9)))
    assert server.metrics.snapshot()["head_swaps"] == 0
    server.registry.restore(str(tmp_path))
    assert server.metrics.snapshot()["head_swaps"] == 1


def test_registry_snapshot_empty_and_missing(tmp_path):
    empty = HeadRegistry()
    empty.snapshot(str(tmp_path / "empty"))
    replica = HeadRegistry(_head(4, 2, 0))
    assert replica.restore(str(tmp_path / "empty")) is None
    assert replica.latest_version is None and len(replica) == 0
    with pytest.raises(LookupError):
        replica.current()
    assert replica.publish(_head(4, 2, 1)) == 0  # counter restored to 0

    with pytest.raises(FileNotFoundError):
        HeadRegistry().restore(str(tmp_path / "nowhere"))


# ---------------------------------------------------------------------------
# hot-swap atomicity under concurrent submits
# ---------------------------------------------------------------------------


def test_hot_swap_atomicity_under_concurrent_submits():
    """Producers hammer the queue while heads are republished; every
    response must be bit-identical to a direct score under the exact
    version it reports — a torn/mixed head would match neither."""
    d, c = 8, 4
    heads = {0: _head(d, c, 0)}
    registry = HeadRegistry(heads[0], keep=64)
    server = GNBServer(
        registry=registry, max_delay_s=2e-4, poll_interval_s=5e-5,
    ).start()

    results, errors = [], []
    stop = threading.Event()

    def producer(seed):
        rng = np.random.default_rng(seed)
        try:
            for _ in range(25):
                req = rng.standard_normal(
                    (int(rng.integers(1, 24)), d)
                ).astype(np.float32)
                results.append((req, server.submit(req)))
                time.sleep(float(rng.uniform(0, 1e-3)))
        except Exception as e:  # noqa: BLE001
            errors.append(e)

    threads = [threading.Thread(target=producer, args=(s,)) for s in range(4)]
    for t in threads:
        t.start()
    # swap heads mid-traffic
    for v in range(1, 6):
        time.sleep(5e-3)
        heads[v] = _head(d, c, seed=100 + v)
        assert registry.publish(heads[v]) == v
    for t in threads:
        t.join()
    server.drain(timeout=120)
    server.shutdown()
    assert not errors, errors

    seen_versions = set()
    for req, fut in results:
        res = fut.result(timeout=0)
        seen_versions.add(res.head_version)
        np.testing.assert_array_equal(
            res.logits, _direct(heads[res.head_version], req)
        )
    assert len(seen_versions) > 1, "traffic never crossed a swap"
    assert server.metrics.snapshot()["head_swaps"] == 5


# ---------------------------------------------------------------------------
# acceptance end-to-end: FL round (secure + dropout) hot-swaps mid-traffic
# ---------------------------------------------------------------------------


def test_end_to_end_fl_round_hot_swap():
    """Initial head → ragged concurrent traffic → a secure+dropout
    StatsPipeline cohort round refits and hot-swaps mid-traffic → more
    traffic.  Every response is bit-identical to directly scoring its
    rows with the head version that was live when it was batched, and
    both versions actually served."""
    rng = np.random.default_rng(11)
    n, d, c = 480, 16, 5
    feats = rng.standard_normal((n, d)).astype(np.float32)
    labels = rng.integers(0, c, n).astype(np.int32)

    # initial head: plain round over the first half of the data
    pipe0 = StatsPipeline(c)
    registry = HeadRegistry(keep=8)
    v0 = registry.refit_from_stats(pipe0.from_arrays(feats[: n // 2],
                                                     labels[: n // 2]))
    server = GNBServer(registry=registry, max_delay_s=5e-4).start()

    reqs = _requests([3, 61, 7, 150, 1, 40], d, seed=21)
    first = [(r, server.submit(r)) for r in reqs[:3]]

    # the one-shot FL round, secure aggregation + dropout recovery on:
    # 6 clients, two drop, Shamir threshold 3 — then the atomic swap
    clients = [
        (feats[i * 80 : (i + 1) * 80], labels[i * 80 : (i + 1) * 80])
        for i in range(6)
    ]
    round_pipe = StatsPipeline(
        c, privacy="secure", dropout=[1, 4], min_survivors=3,
        mask_scale=10.0,
    )
    v1 = registry.refit_from_round(round_pipe, clients)
    assert v1 == v0 + 1

    second = [(r, server.submit(r)) for r in reqs[3:]]
    server.drain(timeout=120)
    server.shutdown()

    versions = set()
    for req, fut in first + second:
        res = fut.result(timeout=0)
        versions.add(res.head_version)
        np.testing.assert_array_equal(
            res.logits, _direct(registry.head(res.head_version), req)
        )
    # the swap landed mid-traffic: the late requests saw the new head
    late = [f.result(timeout=0).head_version for _, f in second]
    assert set(late) == {v1}
    assert versions == {v0, v1}

    snap = server.metrics.snapshot()
    assert snap["requests"] == len(reqs)
    assert snap["head_swaps"] == 1
    assert snap["rows"] == sum(r.shape[0] for r in reqs)
    assert 0.0 <= snap["pad_waste_frac"] < 1.0


# ---------------------------------------------------------------------------
# metrics unit behaviour
# ---------------------------------------------------------------------------


def test_percentile_nearest_rank():
    assert percentile([], 0.5) != percentile([], 0.5)  # NaN
    assert percentile([1.0], 0.99) == 1.0
    vals = sorted(range(1, 101))
    # true nearest rank is ceil(q*N): p50 of 100 samples is the 50th
    # value, not the 51st (the old round() impl overshot by one here)
    assert percentile(vals, 0.5) == 50
    assert percentile(vals, 0.95) == 95
    assert percentile(vals, 0.0) == 1
    assert percentile(vals, 1.0) == 100
    # regression: round() banker's-rounds ranks landing on .5 — the old
    # impl returned 3 for p50 of [1,2,3,4] (round(1.5)=2, zero-based)
    assert percentile([1.0, 2.0, 3.0, 4.0], 0.5) == 2.0
    # and q*N need not land on an integer: ceil, never floor
    assert percentile([1.0, 2.0, 3.0], 0.5) == 2.0
    assert percentile([1.0, 2.0, 3.0], 0.34) == 2.0


def test_metrics_accounting():
    m = ServeMetrics(capacity_rows=100)
    m.record_batch(requests=2, rows=50, padded_rows=100, score_s=0.0)
    m.record_batch(requests=1, rows=50, padded_rows=100, score_s=0.0)
    m.record_latency(0.010)
    m.record_latency(0.020)
    snap = m.snapshot()
    assert snap["requests"] == 3 and snap["batches"] == 2
    assert snap["batch_occupancy"] == pytest.approx(0.5)
    assert snap["pad_waste_frac"] == pytest.approx(0.5)
    assert snap["latency_p50_ms"] == pytest.approx(10.0)
    assert snap["latency_p99_ms"] == pytest.approx(20.0)


def test_metrics_occupancy_capped_for_oversized_batches():
    # regression: an oversized single request (admitted whole by the
    # batcher's first-request rule) used to be divided by the nominal
    # capacity, reporting occupancy > 1.0
    m = ServeMetrics(capacity_rows=100)
    m.record_batch(requests=1, rows=150, padded_rows=160, score_s=0.0)
    snap = m.snapshot()
    assert snap["batch_occupancy"] == pytest.approx(150 / 160)
    assert snap["batch_occupancy"] <= 1.0
    # mixed with a normal batch: each accounted at its own capacity
    m.record_batch(requests=1, rows=50, padded_rows=64, score_s=0.0)
    snap = m.snapshot()
    assert snap["batch_occupancy"] == pytest.approx(200 / 260)
    assert snap["batch_occupancy"] <= 1.0


# ---------------------------------------------------------------------------
# mesh-sharded smoke (8 simulated devices, subprocess)
# ---------------------------------------------------------------------------

_MESH_SUBPROCESS_BODY = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, numpy as np, jax.numpy as jnp
    from repro.core.classifier import LinearHead
    from repro.kernels import gnb_logits
    from repro.launch.mesh import make_host_mesh
    from repro.serve import GNBServer
    from repro.serve.server import serve_requests

    assert len(jax.devices()) == 8
    mesh = make_host_mesh(2)  # (data=4, model=2): 4 row shards
    rng = np.random.default_rng(5)
    d, c = 16, 5
    head = LinearHead(
        W=jnp.asarray(rng.standard_normal((c, d)), jnp.float32),
        b=jnp.asarray(rng.standard_normal(c), jnp.float32),
    )
    # ragged sizes, none divisible by the 4-shard data axis
    reqs = [rng.standard_normal((n, d)).astype(np.float32)
            for n in (3, 61, 7, 259, 1)]
    with GNBServer(head, mesh=mesh, max_delay_s=1e-3) as server:
        assert server.batcher.row_multiple % 4 == 0
        results = serve_requests(server, reqs, timeout=120)
    for res, req in zip(results, reqs):
        want = np.asarray(gnb_logits(jnp.asarray(req), head.W, head.b))
        np.testing.assert_allclose(res.logits, want, rtol=1e-5, atol=1e-4)
        assert res.logits.shape == (req.shape[0], c)
    print("SERVE_MESH_OK")
    """
)


def test_serve_mesh_sharded_subprocess():
    proc = subprocess.run(
        [sys.executable, "-c", _MESH_SUBPROCESS_BODY],
        capture_output=True, text=True, timeout=300,
        env=subprocess_env(),
        cwd="/root/repo",
    )
    assert "SERVE_MESH_OK" in proc.stdout, proc.stderr[-2000:]


_SHARD_BACKEND_SUBPROCESS_BODY = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np, jax.numpy as jnp
    from repro import tune
    from repro.kernels import gnb_logits
    from repro.launch.mesh import make_host_mesh
    from repro.serve.scoring import resolve_backend, score_features

    mesh = make_host_mesh(2)  # (data=4, model=2): 4 row shards
    d, c = 16, 5
    # a cache where the GLOBAL batch bucket (512) and the PER-SHARD
    # bucket (512/4 = 128) disagree on the winning backend
    cache = tune.TuneCache()
    cache.record(tune.Decision(kernel="gnb", n=512, d=d, c=c,
                               winner="fused", blocks={"block_n": 128}))
    cache.record(tune.Decision(kernel="gnb", n=128, d=d, c=c,
                               winner="jnp", blocks={}))
    tune.set_cache(cache)
    assert resolve_backend("auto", 512, d, c) == "fused"
    assert resolve_backend("auto", 128, d, c) == "jnp"

    rng = np.random.default_rng(0)
    feats = jnp.asarray(rng.standard_normal((512, d)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((c, d)), jnp.float32)
    b = jnp.asarray(rng.standard_normal(c), jnp.float32)
    # spy on the rows the dispatcher asks the tuner about (jit-cache
    # counting can't see calls staged under shard_map tracing)
    resolved = []
    real_gnb_backend = tune.gnb_backend
    def spy(n, d_, c_, **kw):
        resolved.append(int(n))
        return real_gnb_backend(n, d_, c_, **kw)
    tune.gnb_backend = spy
    out = score_features(feats, w, b, mesh=mesh, backend="auto")
    # regression: auto used to resolve on the global 512-row batch
    # (fused) even though each shard's kernel call sees 128 rows — the
    # tuner's verdict only holds at the bucket it was measured on
    assert resolved == [128], (
        "mesh auto dispatch resolved on rows %r, not the 128-row shard"
        % (resolved,)
    )
    want = np.asarray(gnb_logits(feats, w, b))
    np.testing.assert_allclose(np.asarray(out), want, rtol=1e-5, atol=1e-4)
    print("SHARD_BACKEND_OK")
    """
)


def test_mesh_auto_backend_resolves_per_shard_subprocess():
    proc = subprocess.run(
        [sys.executable, "-c", _SHARD_BACKEND_SUBPROCESS_BODY],
        capture_output=True, text=True, timeout=300,
        env=subprocess_env(),
        cwd="/root/repo",
    )
    assert "SHARD_BACKEND_OK" in proc.stdout, proc.stderr[-2000:]


# ---------------------------------------------------------------------------
# serve_bench smoke: the CI artifact is well-formed
# ---------------------------------------------------------------------------


def test_serve_bench_smoke_emits_json(tmp_path):
    sys.path.insert(0, "/root/repo")
    try:
        from benchmarks.common import Reporter
        from benchmarks.serve_bench import run as bench_run
    finally:
        sys.path.pop(0)
    out = tmp_path / "serve_bench.json"
    bench_run(Reporter(), smoke=True, json_path=str(out))
    import json

    data = json.loads(out.read_text())
    assert data["config"]["mode"] == "smoke"
    poisson, burst = data["traffic"]
    assert poisson["workload"] == "poisson"
    for key in ("latency_p50_ms", "latency_p95_ms", "latency_p99_ms",
                "throughput_rps", "batch_occupancy", "pad_waste_frac"):
        assert np.isfinite(poisson[key]), (key, poisson)
    assert poisson["rejected"] == 0
    # the bucketed-batching acceptance: the mixed-size burst coalesces
    # toward full batches instead of padding every request to one shape
    assert burst["workload"] == "burst"
    assert burst["pad_waste_frac"] < 0.15, burst
    assert burst["batch_occupancy"] > 0.5, burst
    # the front degrades into shedding with bounded p99, measurably
    curve = data["shed_curve"]
    assert [p["offered_rows_s"] for p in curve] == [1e4, 1e5, 1e6]
    assert curve[-1]["shed_ratio"] > 0.0, curve[-1]
    for p in curve:
        assert 0.0 <= p["shed_ratio"] <= 1.0
        assert np.isfinite(p["latency_p99_ms"]), p
