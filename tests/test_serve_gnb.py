"""GNB serving path smoke: kernel logits == jnp logits, local and meshed,
and the end-to-end FedCGS head actually classifies through it."""

import jax.numpy as jnp
import numpy as np

from repro.core.classifier import LinearHead
from repro.launch.mesh import make_host_mesh
from repro.launch.serve_gnb import gnb_serve


def _head_and_feats(n=101, d=33, c=7, seed=0):
    rng = np.random.default_rng(seed)
    head = LinearHead(
        W=jnp.asarray(rng.standard_normal((c, d)), jnp.float32),
        b=jnp.asarray(rng.standard_normal(c), jnp.float32),
    )
    feats = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
    return head, feats


def test_serve_matches_jnp_logits():
    head, feats = _head_and_feats()
    logits, pred = gnb_serve(head, feats)
    want = feats @ head.W.T + head.b
    np.testing.assert_allclose(np.asarray(logits), np.asarray(want),
                               rtol=1e-5, atol=1e-4)
    np.testing.assert_array_equal(
        np.asarray(pred), np.asarray(jnp.argmax(want, axis=-1))
    )


def test_serve_sharded_matches_local():
    head, feats = _head_and_feats(n=97)  # ragged vs the shard count
    local, _ = gnb_serve(head, feats)
    meshed, pred = gnb_serve(head, feats, mesh=make_host_mesh(1))
    np.testing.assert_allclose(np.asarray(meshed), np.asarray(local),
                               rtol=1e-5, atol=1e-4)
    assert meshed.shape == local.shape
    assert pred.shape == (97,)


def test_serve_fedcgs_head_end_to_end():
    """Statistics -> derive_global -> gnb_head -> serving path: the served
    predictions equal the head's own predict()."""
    from repro.core.classifier import gnb_head
    from repro.core.statistics import derive_global
    from repro.core.stats_pipeline import StatsPipeline

    rng = np.random.default_rng(3)
    n, d, c = 240, 16, 5
    feats = rng.standard_normal((n, d)).astype(np.float32)
    labels = rng.integers(0, c, n).astype(np.int32)
    stats = StatsPipeline(c).from_arrays(jnp.asarray(feats), jnp.asarray(labels))
    head = gnb_head(derive_global(stats))
    _, pred = gnb_serve(head, jnp.asarray(feats))
    np.testing.assert_array_equal(
        np.asarray(pred), np.asarray(head.predict(jnp.asarray(feats)))
    )
