"""End-to-end FL behaviour: the paper's qualitative claims on synthetic data.

Kept cheap (few epochs) — benchmarks/ run the full-strength versions.
"""

import numpy as np
import pytest

from repro.core.expansion import FeatureExpansion
from repro.data import (
    SyntheticSpec,
    dirichlet_partition,
    make_classification_data,
)
from repro.fl.backbone import make_backbone
from repro.fl.fedcgs import run_fedcgs, run_fedcgs_personalized


@pytest.fixture(scope="module")
def setup():
    spec = SyntheticSpec(
        num_classes=10, input_dim=32, samples_per_class=60, class_sep=2.0, seed=1
    )
    x, y = make_classification_data(spec)
    xt, yt = make_classification_data(spec, seed=999)
    # smallest backbone in the ladder: this file tests PIPELINE claims
    # (invariances, wiring), not representation power
    bb = make_backbone("mobilenet-like", spec.input_dim)
    return np.asarray(x), np.asarray(y), np.asarray(xt), np.asarray(yt), bb


def _clients(x, y, alpha, m=10, seed=0):
    """Dirichlet label skew with EQUAL client sizes: the α-skew lives in
    the label composition, while uniform sizes keep the number of
    distinct jit shapes (CPU trace cost) at ~2 instead of m."""
    parts = dirichlet_partition(y, m, alpha, seed=seed)
    order = np.concatenate([p for p in parts if len(p)])
    return [(x[p], y[p]) for p in np.array_split(order, m)]


def test_alpha_invariance(setup):
    """The paper's central claim: accuracy is EXACTLY constant in α.

    Plain summation isolates the algebraic claim — SecureAgg's mask
    cancellation (float-level, not exact) has its own tests.
    """
    x, y, xt, yt, bb = setup
    accs = []
    # every distinct client size is a fresh jit trace on CPU — keep the
    # sweep small (extreme vs mild skew is what the claim is about)
    for alpha in (0.05, 0.5):
        r = run_fedcgs(
            bb, _clients(x, y, alpha, m=6), 10, test_data=(xt, yt),
            use_secure_agg=False,
        )
        accs.append(r.accuracy)
    assert max(accs) - min(accs) < 1e-6, accs


def test_client_count_invariance(setup):
    x, y, xt, yt, bb = setup
    a4 = run_fedcgs(
        bb, _clients(x, y, 0.1, m=4), 10, test_data=(xt, yt), use_secure_agg=False
    ).accuracy
    a12 = run_fedcgs(
        bb, _clients(x, y, 0.1, m=12), 10, test_data=(xt, yt), use_secure_agg=False
    ).accuracy
    assert abs(a4 - a12) < 5e-3


def test_secure_agg_does_not_change_result(setup):
    x, y, xt, yt, bb = setup
    clients = _clients(x, y, 0.1)
    a_sec = run_fedcgs(bb, clients, 10, test_data=(xt, yt), use_secure_agg=True)
    a_raw = run_fedcgs(bb, clients, 10, test_data=(xt, yt), use_secure_agg=False)
    assert abs(a_sec.accuracy - a_raw.accuracy) < 2e-2


def test_beats_chance_substantially(setup):
    x, y, xt, yt, bb = setup
    r = run_fedcgs(bb, _clients(x, y, 0.05), 10, test_data=(xt, yt))
    assert r.accuracy > 0.5


def test_fused_kernel_path_matches_jnp_path(setup):
    """run_fedcgs(use_kernel=True) — the fused Pallas sweep — must land on
    the same head as the jnp statistics path."""
    x, y, xt, yt, bb = setup
    clients = _clients(x, y, 0.1, m=4)
    a_jnp = run_fedcgs(
        bb, clients, 10, test_data=(xt, yt), use_secure_agg=False
    ).accuracy
    a_kern = run_fedcgs(
        bb, clients, 10, test_data=(xt, yt), use_secure_agg=False, use_kernel=True
    ).accuracy
    assert abs(a_jnp - a_kern) < 1e-3


def test_feature_expansion_helps_or_holds(setup):
    """Paper Fig. 3: random-projection expansion should not hurt."""
    x, y, xt, yt, bb = setup
    clients = _clients(x, y, 0.1)
    base = run_fedcgs(bb, clients, 10, test_data=(xt, yt)).accuracy
    exp = FeatureExpansion(in_dim=bb.feature_dim, out_dim=256, seed=0)
    expanded = run_fedcgs(bb, clients, 10, test_data=(xt, yt), expansion=exp).accuracy
    assert expanded > base - 0.05


def test_upload_size_matches_formula(setup):
    x, y, xt, yt, bb = setup
    r = run_fedcgs(bb, _clients(x, y, 0.5), 10, test_data=None)
    d = bb.feature_dim
    assert r.uploaded_floats_per_client == (10 + d) * d + 10


def test_personalized_runs_and_learns(setup):
    x, y, xt, yt, bb = setup
    m = 3
    parts = dirichlet_partition(y, m, 0.5, seed=5)
    train_c = [(x[p], y[p]) for p in parts]
    test_c = [(xt, yt)] * m  # shared test set; dominant-class split is in benches
    accs, gstats = run_fedcgs_personalized(
        bb, train_c, test_c, 10, epochs=10, lr=0.05, proto_lambda=0.5
    )
    assert np.mean(accs) > 0.4  # way beyond 0.1 chance
    assert gstats.mu.shape == (10, bb.feature_dim)


def test_fedcgs_dropout_equals_survivor_run(setup):
    """Mid-round disconnects (paper's connection-drop risk): run_fedcgs
    with dropout + Shamir recovery derives the SAME global statistics as
    a plain run over only the surviving clients."""
    x, y, xt, yt, bb = setup
    clients = _clients(x, y, 0.5, m=6)
    dropped = [1, 4]
    res = run_fedcgs(
        bb, clients, 10, test_data=(xt, yt),
        dropout=dropped, min_survivors=3,
    )
    ref = run_fedcgs(
        bb, [c for i, c in enumerate(clients) if i not in dropped], 10,
        test_data=(xt, yt), use_secure_agg=False,
    )
    np.testing.assert_allclose(
        np.asarray(res.stats.mu), np.asarray(ref.stats.mu),
        rtol=1e-4, atol=1e-4,
    )
    assert res.accuracy == pytest.approx(ref.accuracy, abs=1e-6)
