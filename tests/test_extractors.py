"""The Extractor protocol, end to end.

- every implementation (random-feature Backbone, zoo ModelExtractor,
  expansion-composed) satisfies ONE structural protocol;
- the streamed raw-input path (`StatsPipeline(extractor=)`) is pinned
  BIT-IDENTICAL to materializing the forward pass first and folding the
  features through the identical pipeline (hypothesis over batch
  splits) — same fold traces on same inputs, so equality is exact, not
  allclose.  The single-batch case additionally pins the streamed path
  against the one-shot ``from_arrays`` reference.  (A multi-split fold
  vs one concatenated ``from_arrays`` matmul is NOT bitwise on every
  backend — f32 matmul reduction order differs with shape — which is
  why the bit-exactness contract is stated per-split and the cross-
  split check is allclose.)
- `fedcgs-extract`'s driver, the registry refit, and serve scoring all
  consume the same object: config → features → global head → served.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import optional_hypothesis

given, settings, st = optional_hypothesis()

from repro.configs import get_config
from repro.core.expansion import FeatureExpansion
from repro.core.statistics import aggregate
from repro.core.stats_pipeline import StatsPipeline
from repro.fl.backbone import make_backbone
from repro.fl.extractors import (
    ComposedExtractor,
    Extractor,
    ModelExtractor,
    as_extractor,
    synthetic_token_clients,
    token_labels,
)

# one tiny dense config for the property tests (fast forward), one real
# reduced zoo config (whisper = enc-dec, exercises the frames stub)
TINY = get_config("gemma-2b", reduced=True).reduced(d_model=64, vocab_size=64)


@pytest.fixture(scope="module")
def tiny_ext():
    return ModelExtractor(TINY, pooling="tokens", seed=3)


def _token_batches(cfg, *, batches, batch, seq_len, seed=0):
    return synthetic_token_clients(
        cfg, clients=1, batches_per_client=batches,
        batch=batch, seq_len=seq_len, seed=seed,
    )[0]


def _assert_stats_equal(got, want):
    np.testing.assert_array_equal(np.asarray(got.A), np.asarray(want.A))
    np.testing.assert_array_equal(np.asarray(got.B), np.asarray(want.B))
    np.testing.assert_array_equal(np.asarray(got.N), np.asarray(want.N))


# ---------------------------------------------------------------------------
# protocol conformance
# ---------------------------------------------------------------------------


def test_every_implementation_satisfies_protocol(tiny_ext):
    bb = make_backbone("mobilenet-like", 8)
    exp = FeatureExpansion(in_dim=bb.feature_dim, out_dim=16, seed=0)
    for impl in (bb, tiny_ext, as_extractor(bb, exp)):
        assert isinstance(impl, Extractor)
        assert isinstance(impl.feature_dim, int)


def test_pooling_shapes_and_determinism():
    toks = _token_batches(TINY, batches=1, batch=3, seq_len=8)[0][0]
    d = TINY.d_model
    for pooling, rows in (("mean", 3), ("last", 3), ("tokens", 24)):
        ext = ModelExtractor(TINY, pooling=pooling, seed=7)
        f = ext.features(toks)
        assert f.shape == (rows, d)
        assert bool(jnp.isfinite(f).all())
        # frozen + seeded: a second call AND a fresh instance are bitwise
        np.testing.assert_array_equal(np.asarray(f), np.asarray(ext.features(toks)))
        twin = ModelExtractor(TINY, pooling=pooling, seed=7)
        np.testing.assert_array_equal(np.asarray(f), np.asarray(twin.features(toks)))


def test_whisper_side_input_stub_is_deterministic():
    ext = ModelExtractor("whisper_tiny", pooling="mean", seed=1)
    assert ext.cfg.is_encdec
    toks = _token_batches(ext.cfg, batches=1, batch=2, seq_len=8)[0][0]
    f1, f2 = ext.features(toks), ext.features(toks)
    assert f1.shape == (2, ext.feature_dim)
    np.testing.assert_array_equal(np.asarray(f1), np.asarray(f2))


def test_validation_errors(tiny_ext):
    with pytest.raises(ValueError, match="pooling"):
        ModelExtractor(TINY, pooling="max")
    with pytest.raises(TypeError, match="Extractor protocol"):
        StatsPipeline(4, extractor=object())
    toks, tgts = _token_batches(TINY, batches=1, batch=2, seq_len=8)[0]
    pipe = StatsPipeline(TINY.vocab_size, extractor=tiny_ext)
    with pytest.raises(ValueError, match="labels"):
        pipe.from_arrays(toks, tgts[:, :4])  # 8 rows of labels missing
    with pytest.raises(ValueError, match="tokens"):
        tiny_ext.features(np.zeros((2, 3, 4)))


def test_composed_extractor_matches_manual_stack():
    bb = make_backbone("mobilenet-like", 8)
    exp = FeatureExpansion(in_dim=bb.feature_dim, out_dim=16, seed=5)
    comp = as_extractor(bb, exp)
    assert isinstance(comp, ComposedExtractor)
    assert comp.feature_dim == exp.expanded_dim
    x = jnp.asarray(np.random.default_rng(0).standard_normal((6, 8)), jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(comp.features(x)), np.asarray(exp(bb.features(x)))
    )
    assert as_extractor(bb) is bb


# ---------------------------------------------------------------------------
# the bit-exactness contract (acceptance criterion)
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(
    batches=st.integers(1, 4),
    batch=st.integers(1, 4),
    seq_len=st.integers(2, 10),
    ragged_tail=st.booleans(),
    backend=st.sampled_from(["jnp", "fused"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_streamed_extractor_fold_bit_identical(
    batches, batch, seq_len, ragged_tail, backend, seed
):
    """Streamed extractor+fold over raw tokens == materialize the
    forward pass, then fold the SAME features — bitwise, every split,
    both backends; plus the from_arrays reference for one batch."""
    ext = ModelExtractor(TINY, pooling="tokens", seed=3)
    raw = _token_batches(
        TINY, batches=batches, batch=batch, seq_len=seq_len, seed=seed % 997
    )
    if ragged_tail and batch > 1:
        toks, tgts = raw[-1]
        raw[-1] = (toks[: batch - 1], tgts[: batch - 1])

    streamed = StatsPipeline(
        TINY.vocab_size, backend=backend, extractor=ext
    ).from_batches(iter(raw))

    feats = [(ext.features(t), token_labels(y)) for t, y in raw]
    ref = StatsPipeline(TINY.vocab_size, backend=backend).from_batches(iter(feats))
    _assert_stats_equal(streamed, ref)

    # cross-split sanity vs the one-shot materialized reference
    f_all = jnp.concatenate([f for f, _ in feats])
    y_all = jnp.concatenate([y for _, y in feats])
    one_shot = StatsPipeline(TINY.vocab_size, backend=backend).from_arrays(
        f_all, y_all
    )
    np.testing.assert_allclose(
        np.asarray(streamed.B), np.asarray(one_shot.B), rtol=1e-4, atol=1e-3
    )
    np.testing.assert_array_equal(np.asarray(streamed.N), np.asarray(one_shot.N))

    if len(raw) == 1:
        # single batch: the streamed raw-ingest from_arrays IS the
        # materialized forward-pass-then-from_arrays, bit for bit
        direct = StatsPipeline(
            TINY.vocab_size, backend=backend, extractor=ext
        ).from_arrays(raw[0][0], raw[0][1])
        _assert_stats_equal(
            direct,
            StatsPipeline(TINY.vocab_size, backend=backend).from_arrays(
                feats[0][0], feats[0][1]
            ),
        )


def test_cohort_extractor_matches_materialized(tiny_ext):
    clients = synthetic_token_clients(
        TINY, clients=3, batches_per_client=2, batch=2, seq_len=8, seed=4
    )
    got = StatsPipeline(TINY.vocab_size, extractor=tiny_ext).from_cohort(clients)
    feat_clients = [
        [(tiny_ext.features(t), token_labels(y)) for t, y in c] for c in clients
    ]
    want = aggregate([
        StatsPipeline(TINY.vocab_size).from_batches(iter(c)) for c in feat_clients
    ])
    _assert_stats_equal(got, want)


def test_cohort_extractor_secure_matches_plain(tiny_ext):
    clients = synthetic_token_clients(
        TINY, clients=4, batches_per_client=1, batch=2, seq_len=6, seed=9
    )
    plain = StatsPipeline(TINY.vocab_size, extractor=tiny_ext).from_cohort(clients)
    secure = StatsPipeline(
        TINY.vocab_size, extractor=tiny_ext, privacy="secure", mask_scale=10.0,
    ).from_cohort(clients)
    np.testing.assert_allclose(
        np.asarray(secure.A), np.asarray(plain.A), rtol=1e-4, atol=5e-2
    )
    np.testing.assert_allclose(
        np.asarray(secure.N), np.asarray(plain.N), atol=5e-2
    )


# ---------------------------------------------------------------------------
# config → features → global head → served (one pipeline)
# ---------------------------------------------------------------------------


def test_run_extract_one_command():
    from repro.launch.extract import run_extract

    report = run_extract(
        "whisper_tiny", clients=2, batches_per_client=1, batch=2, seq_len=8,
    )
    assert report["rows_folded"] == 2 * 1 * 2 * 8
    assert report["feature_dim"] == 256
    assert report["head_shape"] == [512, 256]
    assert 0.0 <= report["holdout_accuracy"] <= 1.0
    assert report["round_seconds"] > 0


def test_registry_refit_and_scoring_through_extractor(tiny_ext):
    from repro.serve.registry import HeadRegistry
    from repro.serve.scoring import score_features

    clients = synthetic_token_clients(
        TINY, clients=2, batches_per_client=1, batch=2, seq_len=8, seed=2
    )
    reg = HeadRegistry()
    version = reg.refit_from_round(
        StatsPipeline(TINY.vocab_size), clients,
        extractor=tiny_ext, ridge=1e-3,
    )
    _, head = reg.current()
    assert version == 0 and head.W.shape == (TINY.vocab_size, TINY.d_model)

    toks = clients[0][0][0]
    logits = score_features(toks, head.W, head.b, extractor=tiny_ext)
    want = score_features(tiny_ext.features(toks), head.W, head.b)
    np.testing.assert_array_equal(np.asarray(logits), np.asarray(want))


def test_fedcgs_round_through_model_extractor(tiny_ext):
    """run_fedcgs accepts ANY Extractor: a zoo config drives the paper's
    one-shot protocol end to end (raw tokens in, GNB head out)."""
    from repro.fl.fedcgs import run_fedcgs

    rng = np.random.default_rng(0)
    clients = [
        tuple(
            np.asarray(a)
            for a in synthetic_token_clients(
                TINY, clients=1, batches_per_client=1, batch=2, seq_len=8,
                seed=11 + i,
            )[0][0]
        )
        for i in range(2)
    ]
    clients = [(t, np.asarray(y).reshape(-1)) for t, y in clients]
    del rng
    result = run_fedcgs(
        tiny_ext, clients, TINY.vocab_size, use_secure_agg=False, ridge=1e-3,
    )
    assert result.head.W.shape == (TINY.vocab_size, TINY.d_model)
    assert result.uploaded_floats_per_client > 0
