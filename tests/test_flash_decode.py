"""Sequence-parallel flash-decode (§Perf pair 4): the shard_map combine
must equal the dense single-device decode, end-to-end through a real
model with a GQA cache whose kv_heads don't divide the model axis."""

import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import attention as A


def test_combine_math_single_device_mesh():
    """On a model=1 mesh the sharded path must be exactly the dense one."""
    from repro.launch.mesh import make_host_mesh

    mesh = make_host_mesh(1)
    keys = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(keys[0], (2, 1, 4, 8))
    ck = jax.random.normal(keys[1], (2, 16, 2, 8))
    cv = jax.random.normal(keys[2], (2, 16, 2, 8))
    kv_pos = jnp.arange(16, dtype=jnp.int32).at[12:].set(1 << 30)
    idx = jnp.asarray(11)
    ref = A.attend(q, ck, cv, causal=True, q_offset=idx, kv_positions=kv_pos)
    out = A.attend_decode_seq_sharded(q, ck, cv, kv_pos, idx, mesh=mesh)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


def test_sliding_window_mask():
    from repro.launch.mesh import make_host_mesh

    mesh = make_host_mesh(1)
    keys = jax.random.split(jax.random.key(1), 3)
    q = jax.random.normal(keys[0], (1, 1, 2, 8))
    ck = jax.random.normal(keys[1], (1, 16, 2, 8))
    cv = jax.random.normal(keys[2], (1, 16, 2, 8))
    kv_pos = jnp.arange(16, dtype=jnp.int32)
    idx = jnp.asarray(15)
    ref = A.attend(
        q, ck, cv, causal=True, q_offset=idx, kv_positions=kv_pos,
        sliding_window=5,
    )
    out = A.attend_decode_seq_sharded(
        q, ck, cv, kv_pos, idx, mesh=mesh, sliding_window=5
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


_E2E = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config
    from repro.models import transformer as T
    from repro.models.common import init_params
    from repro.launch.mesh import make_host_mesh
    from repro.sharding import use_mesh

    # chatglm3 reduced: kv=1 heads vs model axis 4 -> 1 % 4 != 0 and the
    # reduced cache len divides 4 => the flash-decode path triggers.
    cfg = get_config("chatglm3-6b", reduced=True)
    assert cfg.num_kv_heads % 4 != 0
    params = init_params(T.build_specs(cfg), jax.random.key(0))
    B, S = 2, 16
    toks = jax.random.randint(jax.random.key(1), (B, S + 1), 0, cfg.vocab_size)

    # reference: no mesh (dense decode path)
    h_pre, cache = T.prefill(params, cfg, toks[:, :S],
                             cache_dtype=jnp.float32, cache_len=S + 4)
    h_ref, _ = T.decode_step(params, cfg, toks[:, S], cache)

    # sharded: model=4 mesh -> seq-sharded cache -> shard_map flash-decode
    mesh = make_host_mesh(4)
    with use_mesh(mesh):
        h_pre2, cache2 = jax.jit(
            lambda p, t: T.prefill(p, cfg, t, cache_dtype=jnp.float32,
                                   cache_len=S + 4)
        )(params, toks[:, :S])
        h_sp, _ = jax.jit(
            lambda p, t, c: T.decode_step(p, cfg, t, c)
        )(params, toks[:, S], cache2)
    err = float(jnp.max(jnp.abs(h_sp - h_ref)))
    assert err < 1e-3, err
    print("FLASH_DECODE_E2E_OK", err)
    """
)


def test_end_to_end_model_decode_subprocess():
    proc = subprocess.run(
        [sys.executable, "-c", _E2E], capture_output=True, text=True,
        timeout=420, env=__import__("conftest").subprocess_env(),
        cwd="/root/repo",
    )
    assert "FLASH_DECODE_E2E_OK" in proc.stdout, (
        proc.stdout[-800:] + proc.stderr[-1500:]
    )
