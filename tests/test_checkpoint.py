import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, load_pytree, save_pytree


def _tree(seed=0):
    k = jax.random.key(seed)
    return {
        "params": {"w": jax.random.normal(k, (4, 8)), "b": jnp.zeros(8)},
        "layers": [jnp.ones((2, 2)), jnp.arange(5)],
    }


def test_roundtrip(tmp_path):
    tree = _tree()
    save_pytree(tree, str(tmp_path), 7)
    loaded = load_pytree(tree, str(tmp_path), 7)
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_allclose(np.asarray(a), np.asarray(b)),
        tree, loaded,
    )


def test_latest_step(tmp_path):
    tree = _tree()
    for s in (1, 5, 3):
        save_pytree(tree, str(tmp_path), s)
    assert latest_step(str(tmp_path)) == 5
    load_pytree(tree, str(tmp_path))  # loads latest without error


def test_shape_mismatch_raises(tmp_path):
    save_pytree(_tree(), str(tmp_path), 0)
    bad = _tree()
    bad["params"]["w"] = jnp.zeros((3, 3))
    with pytest.raises(ValueError):
        load_pytree(bad, str(tmp_path), 0)


def test_missing_checkpoint_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        load_pytree(_tree(), str(tmp_path))
