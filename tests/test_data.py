"""Data pipeline: partitions are exact partitions; heterogeneity behaves."""

import numpy as np
import pytest

from repro.data import (
    SyntheticSpec,
    dirichlet_partition,
    domain_partition,
    dominant_class_partition,
    make_classification_data,
    make_domain_shift_data,
    synthetic_corpus,
    TokenStream,
)


def test_classification_data_shapes_and_balance():
    spec = SyntheticSpec(num_classes=7, input_dim=16, samples_per_class=50)
    x, y = make_classification_data(spec)
    assert x.shape == (350, 16)
    counts = np.bincount(np.asarray(y), minlength=7)
    assert (counts == 50).all()


def test_same_structure_different_samples():
    spec = SyntheticSpec(num_classes=4, input_dim=8, samples_per_class=200)
    x1, y1 = make_classification_data(spec, seed=1)
    x2, y2 = make_classification_data(spec, seed=2)
    assert not np.allclose(np.asarray(x1), np.asarray(x2))
    # but per-class means agree (same class structure)
    for c in range(4):
        m1 = np.asarray(x1)[np.asarray(y1) == c].mean(0)
        m2 = np.asarray(x2)[np.asarray(y2) == c].mean(0)
        assert np.linalg.norm(m1 - m2) < 1.5


@pytest.mark.parametrize("alpha", [0.05, 0.5, 100.0])
def test_dirichlet_partition_is_partition(alpha):
    labels = np.random.default_rng(0).integers(0, 10, 2000)
    parts = dirichlet_partition(labels, 10, alpha, seed=1)
    all_idx = np.sort(np.concatenate(parts))
    np.testing.assert_array_equal(all_idx, np.arange(2000))
    assert min(len(p) for p in parts) >= 1


def test_dirichlet_alpha_controls_skew():
    labels = np.random.default_rng(0).integers(0, 10, 5000)

    def skew(alpha):
        parts = dirichlet_partition(labels, 10, alpha, seed=2)
        # mean per-client label entropy (lower = more skewed)
        ents = []
        for p in parts:
            c = np.bincount(labels[p], minlength=10) + 1e-9
            q = c / c.sum()
            ents.append(-(q * np.log(q)).sum())
        return np.mean(ents)

    assert skew(0.05) < skew(100.0)


def test_dominant_class_partition_sizes_equal():
    labels = np.random.default_rng(1).integers(0, 10, 3000)
    parts = dominant_class_partition(labels, 10, uniform_fraction=0.2, seed=3)
    sizes = {len(p) for p in parts}
    assert len(sizes) == 1
    # each client's dominant classes over-represented
    p0 = labels[parts[0]]
    top2 = np.sort(np.bincount(p0, minlength=10))[-2:].sum()
    assert top2 / len(p0) > 0.5


def test_domain_partition_structure():
    parts = domain_partition([100, 120, 90], clients_per_domain=5)
    assert len(parts) == 15
    for dom in range(3):
        doms = [idx for d, idx in parts if d == dom]
        total = np.concatenate(doms)
        assert len(np.unique(total)) == [100, 120, 90][dom]


def test_domain_shift_changes_inputs_not_labels():
    spec = SyntheticSpec(num_classes=5, input_dim=12, samples_per_class=40)
    domains = make_domain_shift_data(spec, num_domains=3)
    x0, y0 = domains[0]
    x1, y1 = domains[1]
    assert x0.shape == x1.shape
    assert not np.allclose(np.asarray(x0).mean(0), np.asarray(x1).mean(0), atol=0.1)


def test_token_stream_shapes_and_range():
    corpus = synthetic_corpus(100, 5000, seed=0)
    assert corpus.min() >= 0 and corpus.max() < 100
    it = iter(TokenStream(corpus, batch=4, seq_len=16))
    tokens, targets = next(it)
    assert tokens.shape == (4, 16) and targets.shape == (4, 16)
    np.testing.assert_array_equal(tokens[:, 1:], targets[:, :-1])


def test_corpus_is_learnable_markov():
    """Bigram structure exists: successor entropy << unigram entropy."""
    corpus = synthetic_corpus(50, 20000, seed=1)
    uni = np.bincount(corpus, minlength=50) + 1e-9
    h_uni = -(uni / uni.sum() * np.log(uni / uni.sum())).sum()
    # conditional entropy via bigram counts
    big = np.zeros((50, 50)) + 1e-9
    np.add.at(big, (corpus[:-1], corpus[1:]), 1)
    pj = big / big.sum()
    h_joint = -(pj * np.log(pj)).sum()
    h_cond = h_joint - h_uni
    assert h_cond < h_uni * 0.9
