"""Shared test helpers.

``subprocess_env`` builds the environment for tests that re-exec python
with simulated devices (``--xla_force_host_platform_device_count``).
The env is deliberately minimal, BUT the parent's backend selection
(``JAX_PLATFORMS``) must survive: on hosts where libtpu is installed and
no TPU is reachable, a child process without it hangs for minutes inside
TPU backend discovery instead of falling back to CPU.  ``JAX_ENABLE_X64``
is propagated for the same reason: children must run under the parent's
dtype regime or cross-process bit-identity checks compare different
programs.
"""

import os

import pytest


def optional_hypothesis():
    """(given, settings, st) — real hypothesis, or stand-ins that turn
    each property test into a single SKIPPED test.

    Lets modules mixing property-based and deterministic tests collect
    everywhere: a bare environment (no dev extra) skips only the
    ``@given`` tests instead of erroring at collection or skipping the
    whole module.
    """
    try:
        from hypothesis import given, settings, strategies as st

        return given, settings, st
    except ImportError:
        class _AnyStrategy:
            def __getattr__(self, name):
                return lambda *a, **k: None

        def settings(**kwargs):
            return lambda fn: fn

        def given(*args, **kwargs):
            def deco(fn):
                @pytest.mark.skip(reason="hypothesis not installed (dev extra)")
                def skipped():
                    pass

                skipped.__name__ = fn.__name__
                return skipped

            return deco

        return given, settings, _AnyStrategy()


def subprocess_env(**extra):
    env = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"}
    # JAX_ENABLE_X64 must survive too: the Shamir field arithmetic
    # (core.shamir) scopes x64 locally, but a parent suite running with
    # the flag set must see identical child semantics (seed-determinism
    # tests hash masked views across processes).
    for key in ("JAX_PLATFORMS", "JAX_PLATFORM_NAME", "JAX_ENABLE_X64"):
        if key in os.environ:
            env[key] = os.environ[key]
    env.update(extra)
    return env
