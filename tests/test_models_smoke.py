"""Per-architecture smoke tests: REDUCED variant of each family, one
forward + one train step on CPU, shape + finiteness assertions, and
prefill→decode consistency."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import PUBLIC_IDS, get_config
from repro.launch import io_specs, steps
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as T
from repro.models.common import init_params, param_count
from repro.models.config import InputShape
from repro.optim import adamw
from repro.sharding import tree_shardings


def _extras(cfg, b, seed=7):
    rng = np.random.default_rng(seed)
    kw = {}
    if cfg.vision_tokens:
        kw["patches"] = jnp.asarray(
            rng.standard_normal((b, cfg.vision_tokens, cfg.d_model)) * 0.02,
            jnp.float32,
        )
    if cfg.is_encdec:
        kw["frames"] = jnp.asarray(
            rng.standard_normal((b, cfg.encoder_seq_len, cfg.d_model)) * 0.02,
            jnp.float32,
        )
    return kw


@pytest.mark.parametrize("arch", PUBLIC_IDS)
def test_reduced_constraints(arch):
    cfg = get_config(arch, reduced=True)
    assert cfg.d_model <= 512
    assert cfg.num_layers <= 8
    if cfg.moe:
        assert cfg.moe.num_experts <= 4


@pytest.mark.parametrize("arch", PUBLIC_IDS)
def test_forward_shapes_and_finiteness(arch):
    cfg = get_config(arch, reduced=True)
    params = init_params(T.build_specs(cfg), jax.random.key(0))
    B, S = 2, 32
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    h, aux = T.forward(params, cfg, toks, **_extras(cfg, B))
    logits = T.unembed(params, cfg, h)
    assert h.shape == (B, S, cfg.d_model)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux["aux_loss"]))


@pytest.mark.parametrize("arch", PUBLIC_IDS)
def test_one_train_step(arch):
    cfg = get_config(arch, reduced=True)
    if cfg.moe is not None:  # avoid train/decode drop noise in smoke
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=4.0)
        )
    mesh = make_host_mesh(1)
    specs = T.build_specs(cfg)
    params = init_params(specs, jax.random.key(0))
    opt = adamw(1e-3)
    opt_state = opt.init(params)
    B, S = 2, 32
    shape = InputShape("smoke", S, B, "train")
    batch = {
        "tokens": jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size),
        "targets": jax.random.randint(jax.random.key(2), (B, S), 0, cfg.vocab_size),
    }
    if cfg.rope == "mrope":
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        batch["positions"] = jnp.broadcast_to(pos[None], (3, B, S))
    batch.update(_extras(cfg, B))
    step = steps.jit_step(
        steps.make_train_step(cfg, opt),
        mesh,
        (tree_shardings(specs, mesh),
         steps.opt_state_shardings(opt, specs, tree_shardings(specs, mesh), mesh),
         io_specs.batch_shardings(batch, mesh)),
    )
    new_params, _, metrics = step(params, opt_state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    # parameters actually moved
    moved = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), params, new_params
    )
    assert max(jax.tree_util.tree_leaves(moved)) > 0.0


@pytest.mark.parametrize("arch", PUBLIC_IDS)
def test_prefill_decode_consistency(arch):
    cfg = get_config(arch, reduced=True)
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0)
        )
    params = init_params(T.build_specs(cfg), jax.random.key(0))
    B, S = 2, 17
    toks = jax.random.randint(jax.random.key(1), (B, S + 1), 0, cfg.vocab_size)
    kw = _extras(cfg, B)
    h_full, _ = T.forward(params, cfg, toks, **kw)
    h_pre, cache = T.prefill(
        params, cfg, toks[:, :S], cache_dtype=jnp.float32, cache_len=S + 4, **kw
    )
    h_dec, cache2 = T.decode_step(params, cfg, toks[:, S], cache)
    np.testing.assert_allclose(
        np.asarray(h_dec), np.asarray(h_full[:, S]), atol=2e-4
    )
    assert int(cache2["index"]) == S + 1


@pytest.mark.parametrize("arch", ["gemma-2b", "starcoder2-15b"])
def test_sliding_window_decode_consistency(arch):
    """The long_500k dense variant: ring cache == windowed full forward."""
    cfg = get_config(arch, reduced=True).with_sliding_window(8)
    params = init_params(T.build_specs(cfg), jax.random.key(0))
    B, S = 1, 21
    toks = jax.random.randint(jax.random.key(1), (B, S + 1), 0, cfg.vocab_size)
    h_full, _ = T.forward(params, cfg, toks)
    _, cache = T.prefill(params, cfg, toks[:, :S], cache_dtype=jnp.float32)
    h_dec, _ = T.decode_step(params, cfg, toks[:, S], cache)
    np.testing.assert_allclose(
        np.asarray(h_dec), np.asarray(h_full[:, S]), atol=2e-4
    )


def test_full_config_param_counts():
    """Full-size spec trees match the advertised scales (no allocation)."""
    expected = {
        "llama4-maverick-400b-a17b": (350e9, 480e9),
        "minitron-8b": (6e9, 10e9),
        "starcoder2-15b": (13e9, 18e9),
        "gemma-2b": (2e9, 3.2e9),
        "mamba2-2.7b": (2.2e9, 3.2e9),
        "chatglm3-6b": (5.5e9, 7.5e9),
        "qwen2-moe-a2.7b": (12e9, 16e9),
        "zamba2-1.2b": (1.0e9, 1.8e9),
        "qwen2-vl-2b": (1.3e9, 2.4e9),
        "whisper-tiny": (25e6, 80e6),
    }
    for arch, (lo, hi) in expected.items():
        cfg = get_config(arch)
        n = param_count(T.build_specs(cfg))
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B params not in [{lo/1e9}, {hi/1e9}]B"
