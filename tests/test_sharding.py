"""Logical-axis rule engine: divisibility fallbacks, joint axes, constrain."""

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import make_host_mesh
from repro.sharding import DEFAULT_RULES, merge_rules, resolve_spec, use_mesh, constrain


class FakeMesh:
    """Duck-typed mesh: just axis_names + shape mapping."""

    def __init__(self, **axes):
        self.axis_names = tuple(axes)
        self.shape = axes


MESH = FakeMesh(data=16, model=16)
POD_MESH = FakeMesh(pod=2, data=16, model=16)


def test_basic_resolution():
    spec = resolve_spec(("embed", "mlp"), (4096, 16384), MESH)
    assert spec == P("data", "model")


def test_divisibility_fallback_to_second_candidate():
    # 60 experts: model(16) fails, data(16) fails -> replicated
    spec = resolve_spec(("expert", "embed", "expert_mlp"), (60, 2048, 1408), MESH)
    assert spec == P(None, "data", "model")


def test_axis_not_reused_within_tensor():
    # both dims want model; second falls back (to data here)
    spec = resolve_spec(("mlp", "expert_mlp"), (1024, 1024), MESH)
    assert spec[0] == "model"
    assert spec[1] != "model"


def test_joint_axes_for_batch():
    spec = resolve_spec(("act_batch", None, None), (256, 4096, 1024), POD_MESH)
    assert spec == P(("pod", "data"), None, None)
    # batch=1 long-context: not divisible -> replicated
    spec = resolve_spec(("act_batch", None, None), (1, 4096, 1024), POD_MESH)
    assert spec == P(None, None, None)


def test_missing_rule_raises():
    with pytest.raises(KeyError):
        resolve_spec(("nonexistent",), (64,), MESH)


def test_merge_rules_overrides():
    rules = merge_rules(DEFAULT_RULES, embed=("model",))
    spec = resolve_spec(("embed",), (4096,), MESH, rules)
    assert spec == P("model")


def test_vocab_fallback_replicated():
    # whisper vocab 51865 doesn't divide 16 -> replicated
    spec = resolve_spec(("vocab", "embed"), (51865, 384), MESH)
    assert spec == P(None, "data")


def test_constrain_is_noop_without_mesh():
    x = jnp.zeros((8, 4))
    y = constrain(x, "act_batch", None)
    assert y.shape == x.shape


def test_constrain_under_real_mesh():
    mesh = make_host_mesh(1)
    x = jnp.zeros((8, 4))
    with use_mesh(mesh):
        y = jax.jit(lambda t: constrain(t, "act_batch", None))(x)
    assert y.shape == x.shape
