"""Self-tests for the static-analysis subsystem (``repro.analysis``).

Every rule is exercised twice: once against its planted violation
(``repro.analysis.plants`` — the finding MUST fire) and once against a
clean fixture (the finding must NOT fire).  The CLI gate is driven as a
subprocess the same way CI drives it, including ``--plant`` injections
proving the gate can actually go non-zero.
"""

import json
import subprocess
import sys
import textwrap

import pytest

from conftest import subprocess_env as _subprocess_env

from repro.analysis import hlo_audit, lint, lockcheck
from repro.analysis.findings import Baseline, Finding, as_json
from repro.analysis.plants import PLANTS

REPO = "/root/repo"


# ---------------------------------------------------------------------------
# Finding / Baseline model
# ---------------------------------------------------------------------------


def test_finding_key_is_line_insensitive():
    a = Finding(rule="r", path="p.py", message="m", line=10)
    b = Finding(rule="r", path="p.py", message="m", line=99)
    assert a.key == b.key
    assert a.key != Finding(rule="r", path="p.py", message="other").key


def test_finding_rejects_unknown_severity():
    with pytest.raises(ValueError):
        Finding(rule="r", path="p", message="m", severity="fatal")


def test_finding_format_omits_zero_line():
    assert Finding(rule="r", path="p.py", message="m").format() == "p.py: [r] m"
    assert "p.py:7:" in Finding(rule="r", path="p.py", message="m", line=7).format()


def test_baseline_missing_file_is_empty(tmp_path):
    b = Baseline.load(str(tmp_path / "nope.json"))
    assert b.entries == {}
    assert b.validate() == []


def test_baseline_unjustified_entry_is_itself_a_finding(tmp_path):
    path = tmp_path / "baseline.json"
    path.write_text(json.dumps({"findings": [
        {"rule": "time-time", "path": "x.py", "message": "m",
         "justification": "measured host wall time on purpose"},
        {"rule": "time-time", "path": "y.py", "message": "m"},
    ]}))
    bad = Baseline.load(str(path)).validate()
    assert len(bad) == 1
    assert bad[0].rule == "baseline-justification"
    assert "y.py" in bad[0].message


def test_baseline_split_matches_on_key_not_line(tmp_path):
    path = tmp_path / "baseline.json"
    path.write_text(json.dumps({"findings": [
        {"rule": "r", "path": "p.py", "message": "m", "justification": "ok"},
    ]}))
    b = Baseline.load(str(path))
    grandfathered = Finding(rule="r", path="p.py", message="m", line=123)
    fresh = Finding(rule="r", path="p.py", message="new violation")
    new, old = b.split([grandfathered, fresh])
    assert old == [grandfathered]
    assert new == [fresh]


def test_as_json_roundtrips():
    f = Finding(rule="r", path="p.py", message="m", line=3)
    data = json.loads(as_json([f]))
    assert data["findings"][0]["rule"] == "r"
    assert data["findings"][0]["line"] == 3


# ---------------------------------------------------------------------------
# Every plant fires; clean fixtures stay silent
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", sorted(PLANTS))
def test_plant_fires(name):
    findings = PLANTS[name]()
    assert findings, f"plant {name!r} produced no findings — vacuous rule"
    assert all(f.rule == name for f in findings), [f.rule for f in findings]


_CLEAN_SRC = textwrap.dedent(
    """
    import numpy as np

    from repro.serve.metrics import timed
    from repro.sharding import shard_map


    def cov_centred(x):
        mu = x.mean(axis=0)
        xc = x - mu  # centre FIRST, then sweep: no catastrophic cancel
        return xc.T @ xc / (len(x) - 1)


    def bench(fn):
        rng = np.random.default_rng(0)
        _, dt = timed(fn, rng.standard_normal(8))
        return dt
    """
)


def test_lint_clean_source_is_silent():
    assert lint.check_source(_CLEAN_SRC, "clean.py") == []


_CLEAN_LOCK_SRC = textwrap.dedent(
    """
    import threading


    class Counter:
        def __init__(self):
            self._lock = threading.Lock()
            self._total = 0

        def add(self, k):
            with self._lock:
                self._total += k

        def peek(self):
            with self._lock:
                return self._total
    """
)


def test_lockcheck_clean_source_is_silent():
    assert lockcheck.check_source(_CLEAN_LOCK_SRC, "clean_lock.py") == []


def test_real_repo_static_rules_are_silent():
    """The committed tree must hold zero static findings (empty baseline)."""
    assert lockcheck.check_tree(f"{REPO}/src/repro/serve", rel_to=REPO) == []
    assert lint.check_paths(
        [f"{REPO}/src", f"{REPO}/benchmarks"], rel_to=REPO
    ) == []


# ---------------------------------------------------------------------------
# HLO-level rules on text fixtures
# ---------------------------------------------------------------------------

_ALIASED_STABLEHLO = "func.func @main(%arg0: tensor<4xf32> {tf.aliasing_output = 0 : i32})"
_ALIASED_COMPILED = "HloModule m, input_output_alias={ {0}: (0, {}, must-alias) }"
_PLAIN = "HloModule m\nENTRY %main () -> f32[] {\n}\n"

_ONE_ALLREDUCE_HLO = textwrap.dedent(
    """
    HloModule onepsum

    %sum (a: f32[], b: f32[]) -> f32[] {
      %a = f32[] parameter(0)
      %b = f32[] parameter(1)
      ROOT %add = f32[] add(%a, %b)
    }

    ENTRY %main (p0: f32[128]) -> f32[128] {
      %p0 = f32[128]{0} parameter(0)
      ROOT %ar = f32[128]{0} all-reduce(%p0), to_apply=%sum
    }
    """
)


def test_donated_aliasing_passes_when_markers_present():
    assert hlo_audit.check_donated_aliasing(
        "ok", lowered_text=_ALIASED_STABLEHLO, compiled_text=_ALIASED_COMPILED
    ) == []


def test_donated_aliasing_flags_each_missing_stage():
    out = hlo_audit.check_donated_aliasing(
        "bad", lowered_text=_PLAIN, compiled_text=_PLAIN
    )
    assert len(out) == 2
    assert all(f.rule == "donated-aliasing" for f in out)


def test_hlo_collective_budget_exact():
    assert hlo_audit.check_hlo_collective_budget("m", _ONE_ALLREDUCE_HLO, 1) == []
    over = hlo_audit.check_hlo_collective_budget("m", _ONE_ALLREDUCE_HLO, 0)
    assert len(over) == 1 and over[0].rule == "collective-budget"
    assert "all-reduce=1" in over[0].message


# ---------------------------------------------------------------------------
# jaxpr-level helpers
# ---------------------------------------------------------------------------


def test_count_collectives_zero_on_pure_math():
    import jax
    import jax.numpy as jnp

    from repro.analysis.jaxpr_audit import count_collectives

    jx = jax.make_jaxpr(lambda x: jnp.tanh(x) @ x.T)(jnp.zeros((4, 4)))
    assert count_collectives(jx) == 0


def test_dtype_discipline_flags_weak_outputs():
    import jax

    from repro.analysis.jaxpr_audit import check_dtype_discipline

    jx = jax.make_jaxpr(lambda x: x + x)(2.0)  # python float: weak f32
    out = check_dtype_discipline("weak", jx)
    assert any("weak-typed" in f.message for f in out)


def test_measure_new_traces_counts_cache_misses():
    import jax
    import jax.numpy as jnp

    from repro.analysis.jaxpr_audit import measure_new_traces

    jitted = jax.jit(lambda x: x * 2)
    same = lambda: [jitted(jnp.zeros((5,))) for _ in range(3)]
    assert measure_new_traces(jitted, same) == 1
    assert measure_new_traces(jitted, same) == 0  # cache warm now


# ---------------------------------------------------------------------------
# The CLI gate, driven exactly the way CI drives it
# ---------------------------------------------------------------------------


def _run_cli(*flags):
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *flags],
        capture_output=True, text=True, timeout=600,
        env=_subprocess_env(), cwd=REPO,
    )


def test_cli_static_only_exits_zero_on_repo():
    proc = _run_cli("--static-only")
    assert proc.returncode == 0, proc.stdout + proc.stderr[-2000:]
    assert "0 finding(s)" in proc.stdout


def test_cli_json_output_is_parseable():
    proc = _run_cli("--static-only", "--json")
    assert proc.returncode == 0, proc.stdout + proc.stderr[-2000:]
    assert json.loads(proc.stdout) == {"findings": []}


@pytest.mark.parametrize("plant", [
    "collective-budget", "donated-aliasing",
    "lock-discipline", "shard-map-import", "extractor-protocol",
    "block-constants", "metric-funnel",
])
def test_cli_plant_exits_nonzero(plant):
    """Acceptance: the gate must be able to FAIL, one subprocess per
    planted violation class (static-only keeps the jax plants from
    paying the full dynamic-audit bill on top of the plant)."""
    proc = _run_cli("--check", "--static-only", "--plant", plant)
    assert proc.returncode == 1, (
        f"plant {plant} exit={proc.returncode}\n"
        + proc.stdout + proc.stderr[-2000:]
    )
    assert f"[{plant}]" in proc.stdout
