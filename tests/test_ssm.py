"""Mamba2 SSD: chunked scan vs naive recurrence, decode continuation."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ssm as S
from repro.models.config import SSMConfig


def _inputs(b, s, h, p, n, seed=0):
    keys = jax.random.split(jax.random.key(seed), 5)
    x = jax.random.normal(keys[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(keys[1], (b, s, h)))
    A_log = 0.5 * jax.random.normal(keys[2], (h,))
    B = jax.random.normal(keys[3], (b, s, n))
    C = jax.random.normal(keys[4], (b, s, n))
    return x, dt, A_log, B, C


@pytest.mark.parametrize("chunk", [4, 16, 64])
@pytest.mark.parametrize("s", [64, 128])
def test_chunked_matches_reference(chunk, s):
    x, dt, A_log, B, C = _inputs(2, s, 3, 8, 16)
    y_ref, state_ref = S.ssd_reference(x, dt, A_log, B, C)
    y, state = S.ssd_chunked(x, dt, A_log, B, C, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=2e-4)
    np.testing.assert_allclose(np.asarray(state), np.asarray(state_ref), atol=2e-4)


def test_initial_state_continuation():
    """chunked(x[:half]) then chunked(x[half:], init=state) == full scan."""
    x, dt, A_log, B, C = _inputs(1, 128, 2, 8, 8, seed=1)
    y_full, state_full = S.ssd_chunked(x, dt, A_log, B, C, chunk=16)
    h = 64
    y1, s1 = S.ssd_chunked(x[:, :h], dt[:, :h], A_log, B[:, :h], C[:, :h], chunk=16)
    y2, s2 = S.ssd_chunked(
        x[:, h:], dt[:, h:], A_log, B[:, h:], C[:, h:], chunk=16, initial_state=s1
    )
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y_full[:, h:]), atol=2e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(state_full), atol=2e-4)


def test_step_matches_scan_tail():
    """ssd_step from the prefix state reproduces the next scan output."""
    x, dt, A_log, B, C = _inputs(2, 33, 2, 4, 8, seed=2)
    _, state = S.ssd_reference(
        x[:, :32], dt[:, :32], A_log, B[:, :32], C[:, :32]
    )
    y_t, _ = S.ssd_step(x[:, 32], dt[:, 32], A_log, B[:, 32], C[:, 32], state)
    y_full, _ = S.ssd_reference(x, dt, A_log, B, C)
    np.testing.assert_allclose(np.asarray(y_t), np.asarray(y_full[:, 32]), atol=2e-4)


def test_mixer_decode_matches_sequence():
    """Full mamba mixer: token-by-token decode == sequence forward."""
    cfg = SSMConfig(state_dim=8, head_dim=16, expand=2, conv_width=4, chunk_len=8)
    d_model = 32
    params = {
        k: jnp.asarray(v)
        for k, v in jax.tree_util.tree_map(
            lambda s: None, {}
        ).items()
    }
    from repro.models.common import init_params

    specs = S.mamba_specs(d_model, cfg)
    params = init_params(specs, jax.random.key(0))
    x = 0.5 * jax.random.normal(jax.random.key(1), (2, 24, d_model))

    y_seq, _, _ = S.mamba_mixer(params, x, cfg, d_model, return_conv_tail=True)

    d_in = cfg.d_inner(d_model)
    conv_ch = d_in + 2 * cfg.state_dim
    ssm_state = jnp.zeros((2, cfg.num_heads(d_model), cfg.head_dim, cfg.state_dim))
    conv_state = jnp.zeros((2, cfg.conv_width - 1, conv_ch))
    outs = []
    for t in range(24):
        y_t, ssm_state, conv_state = S.mamba_mixer_step(
            params, x[:, t], ssm_state, conv_state, cfg, d_model
        )
        outs.append(y_t)
    y_dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_seq), atol=2e-4)


def test_conv_tail_continuation():
    """prefill's conv tail feeds decode correctly across the boundary."""
    cfg = SSMConfig(state_dim=4, head_dim=8, expand=2, conv_width=4, chunk_len=8)
    d_model = 16
    from repro.models.common import init_params

    params = init_params(S.mamba_specs(d_model, cfg), jax.random.key(0))
    x = 0.5 * jax.random.normal(jax.random.key(2), (1, 17, d_model))

    y_all, _, _ = S.mamba_mixer(params, x, cfg, d_model, return_conv_tail=True)
    y_pre, state, tail = S.mamba_mixer(
        params, x[:, :16], cfg, d_model, return_conv_tail=True
    )
    y_t, _, _ = S.mamba_mixer_step(params, x[:, 16], state, tail, cfg, d_model)
    np.testing.assert_allclose(np.asarray(y_t), np.asarray(y_all[:, 16]), atol=2e-4)
