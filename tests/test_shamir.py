"""Shamir secret sharing over GF(2³¹−1): share→reconstruct round-trip for
any t ≤ K ≤ 32 and ANY t-subset of shares, (t−1)-subset secrecy (the
share distribution is independent of the secret — smoke-checked), exact
serialization round-trip, DH pair-seed symmetry, and jnp↔numpy modexp
parity (the engines use the numpy path inside traces).
"""

import itertools

import jax
import numpy as np
import pytest

from conftest import optional_hypothesis

given, settings, st = optional_hypothesis()

from repro.core import shamir


def _rand_secrets(rng, n):
    return rng.integers(0, shamir.PRIME, size=n, dtype=np.uint64).astype(
        np.uint32
    )


# --- deterministic core (runs in bare envs without hypothesis) -------------


@pytest.mark.parametrize("t,k", [(1, 1), (1, 4), (2, 3), (3, 5), (9, 16),
                                 (32, 32)])
def test_roundtrip_random_subsets(t, k):
    rng = np.random.default_rng(t * 100 + k)
    secrets = _rand_secrets(rng, 6)
    xs, ys = shamir.split_secret(secrets, t, k, key=jax.random.key(0))
    for trial in range(4):
        idx = rng.choice(k, size=t, replace=False)
        rec = shamir.reconstruct_secret(xs[idx], ys[idx])
        np.testing.assert_array_equal(rec, secrets)
    # over-determined: every share at once still lands on the secret
    np.testing.assert_array_equal(shamir.reconstruct_secret(xs, ys), secrets)


def test_every_t_subset_of_small_round():
    """Exhaustive: ALL C(6,3) share subsets of a 3-of-6 round reconstruct."""
    rng = np.random.default_rng(7)
    secrets = _rand_secrets(rng, 3)
    xs, ys = shamir.split_secret(secrets, 3, 6, key=jax.random.key(1))
    for idx in itertools.combinations(range(6), 3):
        rec = shamir.reconstruct_secret(xs[list(idx)], ys[list(idx)])
        np.testing.assert_array_equal(rec, secrets)


def test_scalar_secret_roundtrip():
    xs, ys = shamir.split_secret(np.uint32(123456789), 4, 9,
                                 key=jax.random.key(2))
    assert ys.shape == (9,)
    assert int(shamir.reconstruct_secret(xs[2:6], ys[2:6])) == 123456789


def test_validation_errors():
    with pytest.raises(ValueError):
        shamir.split_secret(np.uint32(1), 5, 4, key=jax.random.key(0))
    with pytest.raises(ValueError):
        shamir.split_secret(np.uint32(1), 0, 4, key=jax.random.key(0))
    xs, ys = shamir.split_secret(np.uint32(1), 2, 4, key=jax.random.key(0))
    with pytest.raises(ValueError):  # duplicate abscissae
        shamir.reconstruct_secret(np.uint32([1, 1]), ys[[0, 0]])
    with pytest.raises(ValueError):
        shamir.reconstruct_secret(np.uint32([]), np.uint32([]))
    with pytest.raises(ValueError):
        shamir.deserialize_shares(b"NOTSHAM" + b"\x00" * 16)


def test_dh_pair_seed_symmetry_and_powmod_parity():
    """pk_j^{u_i} == pk_i^{u_j} for every pair, and the trace-immune
    numpy modexp agrees with the jnp field path bit-for-bit."""
    rng = np.random.default_rng(11)
    u = rng.integers(1, shamir.PRIME - 1, size=8, dtype=np.uint64)
    pk = shamir.dh_public(u)
    s_ij = shamir.dh_shared(u[:, None], pk[None, :])
    np.testing.assert_array_equal(s_ij, s_ij.T)
    assert np.all(s_ij != 0)
    # parity: jnp square-and-multiply == numpy square-and-multiply
    from jax.experimental import enable_x64

    with enable_x64():
        got = np.asarray(shamir._powmod(u, u[::-1].copy()), np.uint64)
    np.testing.assert_array_equal(got, shamir._powmod_host(u, u[::-1].copy()))


def test_serialization_roundtrip_deterministic():
    rng = np.random.default_rng(3)
    secrets = _rand_secrets(rng, 5)
    xs, ys = shamir.split_secret(secrets, 3, 7, key=jax.random.key(4))
    blob = shamir.serialize_shares(xs, ys)
    xs2, ys2 = shamir.deserialize_shares(blob)
    np.testing.assert_array_equal(xs, xs2)
    np.testing.assert_array_equal(ys, ys2)
    # scalar-secret bundles round-trip too
    xs1, ys1 = shamir.split_secret(np.uint32(42), 2, 3, key=jax.random.key(5))
    xs3, ys3 = shamir.deserialize_shares(shamir.serialize_shares(xs1, ys1))
    np.testing.assert_array_equal(ys1, ys3)
    assert int(shamir.reconstruct_secret(xs3[:2], ys3[:2])) == 42


# --- hypothesis properties --------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    k=st.integers(1, 32),
    t_frac=st.floats(0.0, 1.0),
    n_secrets=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_roundtrip_any_t_subset(k, t_frac, n_secrets, seed):
    """share→reconstruct is exact for any t ≤ K ≤ 32 and any t-subset."""
    t = max(1, min(k, int(round(t_frac * k))))
    rng = np.random.default_rng(seed)
    secrets = _rand_secrets(rng, n_secrets)
    xs, ys = shamir.split_secret(secrets, t, k, key=jax.random.key(seed))
    idx = rng.choice(k, size=t, replace=False)
    np.testing.assert_array_equal(
        shamir.reconstruct_secret(xs[idx], ys[idx]), secrets
    )


@settings(max_examples=15, deadline=None)
@given(
    k=st.integers(2, 16),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_serialization_roundtrip(k, seed):
    rng = np.random.default_rng(seed)
    t = int(rng.integers(1, k + 1))
    secrets = _rand_secrets(rng, int(rng.integers(1, 5)))
    xs, ys = shamir.split_secret(secrets, t, k, key=jax.random.key(seed))
    xs2, ys2 = shamir.deserialize_shares(shamir.serialize_shares(xs, ys))
    np.testing.assert_array_equal(xs, xs2)
    np.testing.assert_array_equal(ys, ys2)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_property_below_threshold_is_secret_independent(seed):
    """Distribution smoke check: a fixed (t−1)-subset of shares has the
    same first moments whether the secret is 0 or p−1 — any sub-threshold
    view is (statistically) independent of the secret."""
    t, k, rounds = 4, 8, 64
    rng = np.random.default_rng(seed)
    subset = rng.choice(k, size=t - 1, replace=False)
    views = {}
    for secret in (0, shamir.PRIME - 1):
        vals = []
        for r in range(rounds):
            key = jax.random.fold_in(jax.random.key(seed), r)
            _, ys = shamir.split_secret(np.uint32(secret), t, k, key=key)
            vals.append(ys[subset].astype(np.float64))
        views[secret] = np.asarray(vals) / shamir.PRIME  # in [0, 1)
    m0 = views[0].mean()
    m1 = views[shamir.PRIME - 1].mean()
    # uniform[0,1) mean 0.5, sd of the mean ≈ 1/sqrt(12·rounds·(t−1)) ≈ 0.021
    assert abs(m0 - 0.5) < 0.12 and abs(m1 - 0.5) < 0.12
    assert abs(m0 - m1) < 0.17  # same distribution up to sampling noise
