"""End-to-end system tests: the full FedCGS story on one synthetic world,
plus the LM-stats-head generalization and a short training run."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.classifier import gnb_head
from repro.core.statistics import (
    FeatureStats,
    centralized_statistics,
    derive_global,
    statistics_deviation,
)
from repro.core.secure_agg import secure_sum
from repro.data import SyntheticSpec, dirichlet_partition, make_classification_data
from repro.fl.backbone import make_backbone
from repro.fl.fedcgs import client_stats_pass, run_fedcgs


def test_full_protocol_matches_centralized_head():
    """FedCGS over 10 skewed clients == head built on pooled features."""
    spec = SyntheticSpec(num_classes=6, input_dim=24, samples_per_class=150, seed=4)
    x, y = make_classification_data(spec)
    x, y = np.asarray(x), np.asarray(y)
    bb = make_backbone("resnet18-like", spec.input_dim)

    parts = dirichlet_partition(y, 10, alpha=0.05, seed=0)
    stats = secure_sum(
        [client_stats_pass(bb, x[p], y[p], 6) for p in parts]
    )
    g_fed = derive_global(stats)

    feats = bb.features(jnp.asarray(x))
    g_central = centralized_statistics(feats, jnp.asarray(y), 6)
    dmu, dsig = statistics_deviation(g_fed, g_central)
    # paper Table 4 magnitudes (float32, masked aggregation)
    assert float(dmu) < 1e-2
    assert float(dsig) < 1e-1

    h_fed, h_central = gnb_head(g_fed), gnb_head(g_central)
    pred_f = h_fed.predict(feats)
    pred_c = h_central.predict(feats)
    agreement = float(jnp.mean((pred_f == pred_c).astype(jnp.float32)))
    assert agreement > 0.999


def test_lm_stats_head_beats_uniform():
    """Beyond-paper: class = next token. The training-free GNB head over
    backbone features must beat the uniform-random LM baseline."""
    from repro.configs import get_config
    from repro.core.statistics import client_statistics
    from repro.data.tokens import TokenStream, synthetic_corpus
    from repro.models import transformer as T
    from repro.models.common import init_params

    cfg = get_config("gemma-2b", reduced=True)
    V = cfg.vocab_size
    params = init_params(T.build_specs(cfg), jax.random.key(0))
    corpus = synthetic_corpus(V, 60_000, seed=0, branching=8)
    stream = iter(TokenStream(corpus, batch=8, seq_len=64, seed=0))

    stats = FeatureStats.zeros(V, cfg.d_model)
    for _ in range(6):
        tokens, targets = next(stream)
        hidden, _ = T.forward(params, cfg, jnp.asarray(tokens))
        feats = hidden.reshape(-1, cfg.d_model)
        stats = stats + client_statistics(feats, jnp.asarray(targets).reshape(-1), V)

    head = gnb_head(derive_global(stats))
    tokens, targets = next(stream)
    hidden, _ = T.forward(params, cfg, jnp.asarray(tokens))
    feats = hidden.reshape(-1, cfg.d_model)
    acc = float(head.accuracy(feats, jnp.asarray(targets).reshape(-1)))
    assert acc > 5.0 / V, f"stats-head acc {acc} vs uniform {1.0 / V}"


def test_short_training_run_decreases_loss():
    from repro.launch.train import train

    _, losses = train("qwen2-vl-2b", num_steps=15, batch=4, seq=128, lr=1e-3)
    assert losses[-1] < losses[0]


def test_serve_roundtrip_consistency():
    """serve(): first generated token == argmax of full-forward logits."""
    from repro.configs import get_config
    from repro.models import transformer as T
    from repro.models.common import init_params

    cfg = get_config("chatglm3-6b", reduced=True)
    params = init_params(T.build_specs(cfg), jax.random.key(0))
    B, S = 2, 24
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    hidden, _ = T.forward(params, cfg, toks)
    ref_next = jnp.argmax(T.unembed(params, cfg, hidden[:, -1:]), axis=-1)[:, 0]
    h_pre, _ = T.prefill(params, cfg, toks, cache_dtype=jnp.float32, cache_len=S + 4)
    got_next = jnp.argmax(T.unembed(params, cfg, h_pre[:, -1:]), axis=-1)[:, 0]
    np.testing.assert_array_equal(np.asarray(ref_next), np.asarray(got_next))
