"""Dry-run machinery smoke tests (subprocess, 8 fake devices).

The full 256/512-chip campaign runs via benchmarks; these assert the
machinery — lowering, compiling, roofline extraction, the whisper skip —
works end-to-end for representative archs at reduced scale.
"""

import json
import subprocess
import sys

import pytest

ARCHS = ["gemma-2b", "qwen2-moe-a2.7b", "mamba2-2.7b", "whisper-tiny"]


def _run(args, timeout=540):
    from conftest import subprocess_env

    return subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", *args],
        capture_output=True, text=True, timeout=timeout,
        env=subprocess_env(XLA_FLAGS="--xla_force_host_platform_device_count=8"),
        cwd="/root/repo",
    )


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_dryrun_all_shapes(arch, tmp_path):
    proc = _run(
        ["--arch", arch, "--shape", "all", "--mesh", "single", "--reduced",
         "--out", str(tmp_path)]
    )
    assert proc.returncode == 0, proc.stdout[-1500:] + proc.stderr[-1500:]
    assert "all dry-runs passed" in proc.stdout
    # artifacts exist and have roofline terms
    files = list(tmp_path.glob("*.json"))
    assert len(files) == 4
    for f in files:
        d = json.loads(f.read_text())
        if d.get("skipped"):
            assert d["arch"] == "whisper-tiny"
            continue
        r = d["roofline"]
        assert r["compute_s"] >= 0 and r["memory_s"] > 0
        assert r["dominant"] in ("compute", "memory", "collective")
        assert d["memory"]["temp_size_in_bytes"] >= 0


def test_whisper_long500k_skipped(tmp_path):
    proc = _run(
        ["--arch", "whisper-tiny", "--shape", "long_500k", "--mesh", "single",
         "--reduced", "--out", str(tmp_path)]
    )
    assert proc.returncode == 0
    assert "SKIP" in proc.stdout


def test_stats_step_lowers(tmp_path):
    """The paper's contribution as a distributed step must lower too."""
    proc = _run(
        ["--arch", "gemma-2b", "--shape", "train_4k", "--mesh", "single",
         "--reduced", "--step", "stats", "--out", str(tmp_path)]
    )
    assert proc.returncode == 0, proc.stdout[-1500:] + proc.stderr[-1500:]
