"""FedCGS statistics: partition-invariance (the paper's central claim),
exactness vs. centralized (Table 4), and edge cases."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import optional_hypothesis

# bare env (no dev extra): property tests skip, deterministic tests run
given, settings, st = optional_hypothesis()

from repro.core.statistics import (
    FeatureStats,
    aggregate,
    centralized_statistics,
    client_statistics,
    derive_global,
    statistics_deviation,
)

jax.config.update("jax_enable_x64", False)


def _random_data(n, d, c, seed):
    rng = np.random.default_rng(seed)
    return (
        rng.standard_normal((n, d)).astype(np.float32),
        rng.integers(0, c, n).astype(np.int32),
    )


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(20, 200),
    d=st.integers(2, 32),
    c=st.integers(2, 8),
    m=st.integers(1, 7),
    seed=st.integers(0, 2**31 - 1),
)
def test_partition_invariance(n, d, c, m, seed):
    """Σ_i ClientStats(D_i) is independent of how D is partitioned."""
    x, y = _random_data(n, d, c, seed)
    pooled = client_statistics(jnp.asarray(x), jnp.asarray(y), c)

    rng = np.random.default_rng(seed + 1)
    cuts = np.sort(rng.choice(np.arange(1, n), size=min(m - 1, n - 1), replace=False))
    parts = np.split(np.arange(n), cuts)
    shards = [
        client_statistics(jnp.asarray(x[p]), jnp.asarray(y[p]), c)
        for p in parts
        if len(p)
    ]
    agg = aggregate(shards)

    np.testing.assert_allclose(agg.A, pooled.A, rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(agg.B, pooled.B, rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(agg.N, pooled.N)


@pytest.mark.parametrize("num_clients", [1, 5, 25])
@pytest.mark.parametrize("alpha_like_skew", [False, True])
def test_exactness_vs_centralized(num_clients, alpha_like_skew):
    """Paper Table 4: aggregated (μ, Σ) ≈ centralized ground truth
    (deviation 1e-7…1e-4 float32 regardless of partition skew)."""
    n, d, c = 600, 24, 10
    x, y = _random_data(n, d, c, seed=3)
    if alpha_like_skew:
        order = np.argsort(y)  # clients get near-single-class shards
        x, y = x[order], y[order]
    parts = np.array_split(np.arange(n), num_clients)
    agg = aggregate(
        client_statistics(jnp.asarray(x[p]), jnp.asarray(y[p]), c) for p in parts
    )
    ours = derive_global(agg)
    ref = centralized_statistics(jnp.asarray(x), jnp.asarray(y), c)
    dmu, dsigma = statistics_deviation(ours, ref)
    assert float(dmu) < 1e-3, f"Δμ={float(dmu)}"
    assert float(dsigma) < 1e-2, f"ΔΣ={float(dsigma)}"
    np.testing.assert_allclose(ours.pi, ref.pi, atol=1e-6)


def test_empty_class_handling():
    x, y = _random_data(50, 8, 4, seed=0)
    y = np.where(y == 3, 0, y)  # class 3 never observed
    stats = client_statistics(jnp.asarray(x), jnp.asarray(y), 4)
    g = derive_global(stats)
    assert float(g.pi[3]) == 0.0
    np.testing.assert_allclose(g.mu[3], 0.0)
    assert np.isfinite(np.asarray(g.sigma)).all()


def test_upload_accounting():
    """(C+d)·d + C — the paper's §Communication Overhead formula."""
    stats = FeatureStats.zeros(10, 512)
    assert stats.num_elements() == (10 + 512) * 512 + 10


def test_streaming_accumulation_matches_single_pass():
    from repro.core.statistics import client_statistics_batched

    x, y = _random_data(300, 16, 5, seed=9)
    whole = client_statistics(jnp.asarray(x), jnp.asarray(y), 5)
    batched = client_statistics_batched(
        [jnp.asarray(x[i : i + 64]) for i in range(0, 300, 64)],
        [jnp.asarray(y[i : i + 64]) for i in range(0, 300, 64)],
        5,
    )
    np.testing.assert_allclose(batched.A, whole.A, rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(batched.B, whole.B, rtol=1e-5, atol=1e-4)
