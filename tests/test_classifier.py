"""GNB head: closed form (Eq. 11/14) vs explicit Gaussian posterior."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.classifier import (
    gaussian_posterior_reference,
    gnb_head,
    gnb_log_posterior,
)
from repro.core.statistics import centralized_statistics


def _stats(n=400, d=12, c=5, seed=0):
    rng = np.random.default_rng(seed)
    mu = 3.0 * rng.standard_normal((c, d))
    y = rng.integers(0, c, n)
    x = mu[y] + rng.standard_normal((n, d))
    return centralized_statistics(jnp.asarray(x, jnp.float32), jnp.asarray(y), c), x, y


def test_closed_form_matches_gaussian_posterior():
    stats, x, _ = _stats()
    ridge = 1e-4 * float(jnp.mean(jnp.diag(stats.sigma)))
    ours = gnb_log_posterior(stats, jnp.asarray(x, jnp.float32), ridge=ridge)
    ref = gaussian_posterior_reference(stats, jnp.asarray(x, jnp.float32), ridge)
    np.testing.assert_allclose(np.asarray(ours), np.asarray(ref), atol=2e-3)


def test_head_accuracy_beats_chance_and_matches_bayes():
    stats, x, y = _stats(n=2000, d=8, c=4, seed=1)
    head = gnb_head(stats)
    acc = float(head.accuracy(jnp.asarray(x, jnp.float32), jnp.asarray(y)))
    assert acc > 0.8  # well-separated Gaussians => near-Bayes accuracy


def test_prior_affects_bias_only():
    stats, _, _ = _stats(seed=2)
    head = gnb_head(stats)
    # doubling one class's prior should only move its bias, not weights
    import dataclasses

    skewed = dataclasses.replace(
        stats, pi=stats.pi.at[0].set(stats.pi[0] * 2.0)
    )
    head2 = gnb_head(skewed)
    np.testing.assert_allclose(head.W, head2.W, rtol=1e-6)
    assert not np.allclose(head.b[0], head2.b[0])
    np.testing.assert_allclose(head.b[1:], head2.b[1:], rtol=1e-6)


def test_w_solves_sigma_inverse_mu():
    stats, _, _ = _stats(seed=3)
    ridge = 1e-4 * float(jnp.mean(jnp.diag(stats.sigma)))
    head = gnb_head(stats, ridge=ridge)
    d = stats.feature_dim
    sigma = 0.5 * (stats.sigma + stats.sigma.T) + ridge * jnp.eye(d)
    np.testing.assert_allclose(
        np.asarray(sigma @ head.W.T), np.asarray(stats.mu.T), atol=1e-3
    )
