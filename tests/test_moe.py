"""MoE sort-based capacity dispatch vs a dense one-hot reference."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.common import init_params
from repro.models.config import MoEConfig
from repro.models.moe import expert_capacity, moe_apply, moe_specs


def _dense_reference(params, x, cfg: MoEConfig, act: str):
    """No-capacity dense dispatch: every token to its top-k, no drops."""
    T, d = x.shape
    logits = x.astype(jnp.float32) @ params["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, cfg.top_k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    out = jnp.zeros_like(x)
    for e in range(cfg.num_experts):
        if "w_gate" in params:
            gate_act = jax.nn.gelu if act == "geglu" else jax.nn.silu
            h = gate_act(x @ params["w_gate"][e]) * (x @ params["w_up"][e])
        else:
            h = jax.nn.gelu(x @ params["w_up"][e])
        y_e = h @ params["w_down"][e]
        w_e = jnp.sum(jnp.where(top_i == e, top_p, 0.0), axis=-1)
        out = out + y_e * w_e[:, None].astype(x.dtype)
    return out


@pytest.mark.parametrize("top_k", [1, 2, 4])
def test_matches_dense_reference_when_capacity_ample(top_k):
    cfg = MoEConfig(num_experts=8, top_k=top_k, expert_d_ff=32, capacity_factor=16.0)
    specs = moe_specs(16, cfg, "silu")
    params = init_params(specs, jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (64, 16))
    out, metrics = moe_apply(params, x, cfg, "silu")
    ref = _dense_reference(params, x, cfg, "silu")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-4)
    assert float(metrics["dropped_fraction"]) == 0.0


def test_capacity_drops_are_reported():
    cfg = MoEConfig(num_experts=4, top_k=1, expert_d_ff=16, capacity_factor=0.25)
    specs = moe_specs(8, cfg, "silu")
    params = init_params(specs, jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (64, 8))
    _, metrics = moe_apply(params, x, cfg, "silu")
    assert float(metrics["dropped_fraction"]) > 0.0


def test_shared_experts_add_dense_path():
    cfg = MoEConfig(
        num_experts=4, top_k=1, expert_d_ff=16,
        num_shared_experts=2, shared_d_ff=16, capacity_factor=8.0,
    )
    specs = moe_specs(8, cfg, "silu")
    params = init_params(specs, jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (32, 8))
    out_with, _ = moe_apply(params, x, cfg, "silu")
    params_no = {k: v for k, v in params.items() if k != "shared"}
    import dataclasses

    cfg_no = dataclasses.replace(cfg, num_shared_experts=0)
    out_without, _ = moe_apply(params_no, x, cfg_no, "silu")
    assert not np.allclose(np.asarray(out_with), np.asarray(out_without))


def test_capacity_is_static_and_padded():
    cfg = MoEConfig(num_experts=60, top_k=4, expert_d_ff=8)
    cap = expert_capacity(1000, cfg)
    assert cap % 8 == 0 and cap >= 1000 * 4 * 1.25 / 60


def test_aux_losses_finite_and_balanced_router_lowers_aux():
    cfg = MoEConfig(num_experts=8, top_k=2, expert_d_ff=16, capacity_factor=4.0)
    specs = moe_specs(16, cfg, "silu")
    params = init_params(specs, jax.random.key(2))
    x = jax.random.normal(jax.random.key(3), (128, 16))
    _, m = moe_apply(params, x, cfg, "silu")
    assert np.isfinite(float(m["aux_loss"]))
    assert np.isfinite(float(m["router_z_loss"]))
    # uniform router => aux close to its minimum cfg.router_aux_weight
    params_uniform = dict(params)
    params_uniform["router"] = jnp.zeros_like(params["router"])
    _, mu = moe_apply(params_uniform, x, cfg, "silu")
    assert float(mu["aux_loss"]) <= float(m["aux_loss"]) + 1e-4


@pytest.mark.parametrize("shards", [2, 4])
def test_sharded_dispatch_matches_global(shards):
    """§Perf per-shard dispatch == global dispatch when capacity is ample."""
    cfg = MoEConfig(
        num_experts=8, top_k=2, expert_d_ff=32, capacity_factor=16.0,
        num_shared_experts=1, shared_d_ff=32,
    )
    specs = moe_specs(16, cfg, "silu")
    params = init_params(specs, jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (64, 16))
    out1, m1 = moe_apply(params, x, cfg, "silu")
    out2, m2 = moe_apply(params, x, cfg, "silu", dispatch_shards=shards)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), atol=2e-5)
    np.testing.assert_allclose(
        float(m1["aux_loss"]), float(m2["aux_loss"]), rtol=1e-5
    )


def test_sharded_dispatch_local_capacity_drops():
    """Per-shard capacity binds per shard (locality is real, not cosmetic)."""
    cfg = MoEConfig(num_experts=4, top_k=1, expert_d_ff=16, capacity_factor=0.3)
    specs = moe_specs(8, cfg, "silu")
    params = init_params(specs, jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (128, 8))
    _, m = moe_apply(params, x, cfg, "silu", dispatch_shards=4)
    assert float(m["dropped_fraction"]) > 0.0


def test_sharded_dispatch_under_mesh_shard_map():
    """shard_map path on a multi-device mesh (subprocess, 8 devices)."""
    import subprocess, sys, textwrap

    body = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from repro.models.common import init_params
        from repro.models.config import MoEConfig
        from repro.models.moe import moe_apply, moe_specs
        from repro.launch.mesh import make_host_mesh
        from repro.sharding import use_mesh

        cfg = MoEConfig(num_experts=8, top_k=2, expert_d_ff=32,
                        capacity_factor=16.0)
        params = init_params(moe_specs(16, cfg, "silu"), jax.random.key(0))
        x = jax.random.normal(jax.random.key(1), (64, 16))
        out1, _ = moe_apply(params, x, cfg, "silu")
        with use_mesh(make_host_mesh(2)):
            out4, _ = jax.jit(
                lambda p, x: moe_apply(p, x, cfg, "silu", dispatch_shards=4)
            )(params, x)
        np.testing.assert_allclose(np.asarray(out1), np.asarray(out4), atol=2e-5)
        print("SHARDMAP_OK")
        """
    )
    proc = subprocess.run(
        [sys.executable, "-c", body], capture_output=True, text=True,
        timeout=300, env=__import__("conftest").subprocess_env(),
        cwd="/root/repo",
    )
    assert "SHARDMAP_OK" in proc.stdout, proc.stderr[-1500:]
