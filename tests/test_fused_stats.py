"""Fused single-pass statistics engine: deterministic Table-4 exactness.

No hypothesis here on purpose — these are the tier-1 guarantees for the
fused Pallas kernel and the sharded layer on a bare environment:

- fused kernel == two-kernel path == jnp oracle on ragged n/d/C that
  exercise the block padding (label −1 pad rows must contribute zero to
  A, B, AND N);
- fused client_stats → aggregate → derive_global == centralized_statistics
  under several partition layouts (the paper's partition-invariance);
- single-device vs shard_map sharded engine equivalence.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.statistics import (
    FeatureStats,
    aggregate,
    centralized_statistics,
    client_statistics_fused,
    derive_global,
    statistics_deviation,
)
from repro.kernels import client_stats
from repro.kernels import ref
from repro.kernels import stats_kernel


def _data(n, d, c, seed):
    k1, k2 = jax.random.split(jax.random.key(seed))
    f = jax.random.normal(k1, (n, d))
    y = jax.random.randint(k2, (n,), 0, c)
    return f, y


# ragged shapes straddling the (block_n=512, block_d=128) boundaries
RAGGED = [
    (65, 16, 4),        # everything below one block
    (512, 128, 128),    # exact block multiples (no padding at all)
    (513, 129, 129),    # one past every block boundary
    (1000, 257, 37),    # ragged everywhere
    (100, 640, 3),      # d > n, tiny C
]


@pytest.mark.parametrize("n,d,c", RAGGED)
def test_fused_matches_oracle_and_unfused(n, d, c):
    f, y = _data(n, d, c, seed=n + d + c)
    A, B, N = client_stats(f, y, c)
    A0, B0, N0 = ref.client_stats_ref(f, y, c)
    np.testing.assert_allclose(np.asarray(A), np.asarray(A0), rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(np.asarray(B), np.asarray(B0), rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(np.asarray(N), np.asarray(N0))
    Au, Bu, Nu = client_stats(f, y, c, fused=False)
    np.testing.assert_allclose(np.asarray(A), np.asarray(Au), rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(np.asarray(B), np.asarray(Bu), rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(np.asarray(N), np.asarray(Nu))
    # B must be exactly symmetric (mirrored upper triangle, not recomputed)
    np.testing.assert_array_equal(np.asarray(B), np.asarray(B).T)
    # pad rows contributed zero to N: total count == real row count
    assert float(jnp.sum(N)) == n


def test_pad_rows_contribute_zero_to_everything():
    """Feed the raw kernel explicitly padded input: the −1-labelled zero
    rows must leave A, B, and N identical to the unpadded sweep."""
    n, d, c = 300, 96, 7
    f, y = _data(n, d, c, seed=0)
    c_pad = 128
    fp = jnp.pad(f, ((0, 512 - n), (0, 128 - d)))
    yp = jnp.pad(y.astype(jnp.int32)[:, None], ((0, 512 - n), (0, 0)),
                 constant_values=-1)
    A, B, N = stats_kernel.fused_stats(fp, yp, c_pad, interpret=True)
    A0, B0, N0 = ref.client_stats_ref(f, y, c)
    np.testing.assert_allclose(np.asarray(A[:c, :d]), np.asarray(A0),
                               rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(np.asarray(B[:d, :d]), np.asarray(B0),
                               rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(np.asarray(N[:c]), np.asarray(N0))
    # the padded tail of every statistic is exactly zero
    assert float(jnp.abs(A[c:]).max()) == 0.0
    assert float(jnp.abs(B[d:, :]).max()) == 0.0
    assert float(jnp.abs(B[:, d:]).max()) == 0.0
    assert float(jnp.abs(N[c:]).max()) == 0.0


# three partition layouts: even split, skewed sizes, sorted-by-label
# (near-single-class clients — the paper's pathological heterogeneity)
def _partitions(n, seed):
    rng = np.random.default_rng(seed)
    even = np.array_split(np.arange(n), 5)
    cuts = np.sort(rng.choice(np.arange(1, n), size=3, replace=False))
    skewed = np.split(np.arange(n), cuts)
    return {"even": even, "skewed": skewed}


@pytest.mark.parametrize("layout", ["even", "skewed", "sorted_by_label"])
def test_fused_partition_invariance_vs_centralized(layout):
    """Table 4: fused client_stats → aggregate → derive_global equals the
    centralized ground truth for every partition layout."""
    n, d, c = 700, 130, 11  # ragged vs both block sizes
    f, y = _data(n, d, c, seed=42)
    fx, yx = np.asarray(f), np.asarray(y)
    if layout == "sorted_by_label":
        order = np.argsort(yx)
        fx, yx = fx[order], yx[order]
        parts = np.array_split(np.arange(n), 6)
    else:
        parts = _partitions(n, seed=1)[layout]
    shards = [
        client_statistics_fused(jnp.asarray(fx[p]), jnp.asarray(yx[p]), c)
        for p in parts
        if len(p)
    ]
    agg = aggregate(shards)
    ours = derive_global(agg)
    centr = centralized_statistics(jnp.asarray(fx), jnp.asarray(yx), c)
    dmu, dsigma = statistics_deviation(ours, centr)
    assert float(dmu) < 1e-4, f"Δμ={float(dmu)}"
    assert float(dsigma) < 1e-4, f"ΔΣ={float(dsigma)}"


def test_fused_feeds_derive_global_like_jnp_path():
    from repro.core.statistics import client_statistics

    f, y = _data(400, 80, 9, seed=5)
    g_fused = derive_global(client_statistics_fused(f, y, 9))
    g_jnp = derive_global(client_statistics(f, y, 9))
    np.testing.assert_allclose(np.asarray(g_fused.mu), np.asarray(g_jnp.mu),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(g_fused.sigma), np.asarray(g_jnp.sigma),
                               rtol=1e-4, atol=1e-4)


def test_sharded_engine_matches_single_device():
    """shard_map engine == plain fused sweep on the host's devices (1 on a
    CPU runner; the multi-device layout runs in test_federated's
    subprocess with 8 simulated devices)."""
    from repro.launch.stats_engine import sharded_client_stats

    n, d, c = 530, 48, 6  # ragged => exercises the shard-count padding too
    f, y = _data(n, d, c, seed=11)
    out = sharded_client_stats(f, y, c)
    A0, B0, N0 = ref.client_stats_ref(f, y, c)
    np.testing.assert_allclose(np.asarray(out.A), np.asarray(A0), rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(np.asarray(out.B), np.asarray(B0), rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(np.asarray(out.N), np.asarray(N0))


def test_carry_kernel_streams_to_materialized_result():
    """The accumulate-in variant (client_stats_acc): folding ragged
    batches through the padded carry equals the one-shot fused sweep,
    including B's exact symmetry after the single finalize mirror."""
    from repro.kernels import client_stats_acc, stats_carry_finalize, stats_carry_init

    n, d, c = 700, 130, 11
    f, y = _data(n, d, c, seed=8)
    m, cnt = stats_carry_init(c, d)
    for s in range(0, n, 256):  # 256, 256, 188 — ragged tail
        m, cnt = client_stats_acc(m, cnt, f[s : s + 256], y[s : s + 256])
    A, B, N = stats_carry_finalize(m, cnt, c, d)
    A0, B0, N0 = client_stats(f, y, c)
    np.testing.assert_allclose(np.asarray(A), np.asarray(A0), rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(np.asarray(B), np.asarray(B0), rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(np.asarray(N), np.asarray(N0))
    np.testing.assert_array_equal(np.asarray(B), np.asarray(B).T)
    assert float(jnp.sum(N)) == n


def test_sharded_cohort_equals_per_client_sum():
    from repro.launch.stats_engine import sharded_cohort_stats

    c = 5
    batches = []
    for i, n in enumerate((120, 77, 301)):
        f, y = _data(n, 32, c, seed=20 + i)
        batches.append((np.asarray(f), np.asarray(y)))
    out = sharded_cohort_stats(batches, c)
    per_client = aggregate(
        FeatureStats(*client_stats(jnp.asarray(f), jnp.asarray(y), c))
        for f, y in batches
    )
    np.testing.assert_allclose(np.asarray(out.A), np.asarray(per_client.A),
                               rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(np.asarray(out.B), np.asarray(per_client.B),
                               rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(np.asarray(out.N), np.asarray(per_client.N))
