"""Pairwise-mask SecureAgg: exact cancellation + per-client privacy."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.secure_agg import mask_client_update, masked_views, secure_sum
from repro.core.statistics import FeatureStats, client_statistics


def _clients(m=6, n=40, d=10, c=4, seed=0):
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(m):
        x = rng.standard_normal((n, d)).astype(np.float32)
        y = rng.integers(0, c, n)
        out.append(client_statistics(jnp.asarray(x), jnp.asarray(y), c))
    return out


@pytest.mark.parametrize("m", [2, 5, 11])
def test_masks_cancel_exactly(m):
    clients = _clients(m=m)
    unmasked = clients[0]
    for s in clients[1:]:
        unmasked = unmasked + s
    masked = secure_sum(clients, mask_scale=1e3)
    np.testing.assert_allclose(masked.A, unmasked.A, rtol=1e-4, atol=2e-2)
    np.testing.assert_allclose(masked.B, unmasked.B, rtol=1e-4, atol=2e-2)
    np.testing.assert_allclose(masked.N, unmasked.N, atol=2e-2)


def test_masked_views_hide_individual_statistics():
    clients = _clients(m=4)
    views = masked_views(clients, mask_scale=1e3)
    for true, seen in zip(clients, views):
        # the served view must be dominated by the mask, not the data
        rel = float(jnp.linalg.norm(seen.A - true.A) / (jnp.linalg.norm(true.A) + 1e-9))
        assert rel > 10.0, f"mask too weak: rel={rel}"


def test_single_client_no_masks():
    (c0,) = _clients(m=1)
    masked = mask_client_update(c0, 0, 1)
    np.testing.assert_allclose(masked.A, c0.A)


def test_mask_deterministic_between_parties():
    """Both sides of a pair derive the same mask (seed agreement)."""
    clients = _clients(m=2)
    m0 = mask_client_update(clients[0], 0, 2, base_seed=7)
    m1 = mask_client_update(clients[1], 1, 2, base_seed=7)
    total = FeatureStats(
        A=m0.A + m1.A, B=m0.B + m1.B, N=m0.N + m1.N
    )
    ref = clients[0] + clients[1]
    np.testing.assert_allclose(total.A, ref.A, rtol=1e-4, atol=2e-2)
