"""Pairwise-mask SecureAgg: exact cancellation, per-client privacy, and
Shamir dropout recovery (mask reconstruction from t-of-K shares)."""

import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

from conftest import subprocess_env

from repro.core import shamir
from repro.core.secure_agg import (
    mask_client_update,
    masked_round,
    masked_survivor_views,
    masked_views,
    recover_round,
    secure_sum,
    setup_round,
)
from repro.core.statistics import FeatureStats, aggregate, client_statistics


def _clients(m=6, n=40, d=10, c=4, seed=0):
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(m):
        x = rng.standard_normal((n, d)).astype(np.float32)
        y = rng.integers(0, c, n)
        out.append(client_statistics(jnp.asarray(x), jnp.asarray(y), c))
    return out


@pytest.mark.parametrize("m", [2, 5, 11])
def test_masks_cancel_exactly(m):
    clients = _clients(m=m)
    unmasked = clients[0]
    for s in clients[1:]:
        unmasked = unmasked + s
    masked = secure_sum(clients, mask_scale=1e3)
    np.testing.assert_allclose(masked.A, unmasked.A, rtol=1e-4, atol=2e-2)
    np.testing.assert_allclose(masked.B, unmasked.B, rtol=1e-4, atol=2e-2)
    np.testing.assert_allclose(masked.N, unmasked.N, atol=2e-2)


def test_masked_views_hide_individual_statistics():
    clients = _clients(m=4)
    views = masked_views(clients, mask_scale=1e3)
    for true, seen in zip(clients, views):
        # the served view must be dominated by the mask, not the data
        rel = float(jnp.linalg.norm(seen.A - true.A) / (jnp.linalg.norm(true.A) + 1e-9))
        assert rel > 10.0, f"mask too weak: rel={rel}"


def test_single_client_no_masks():
    (c0,) = _clients(m=1)
    masked = mask_client_update(c0, 0, 1)
    np.testing.assert_allclose(masked.A, c0.A)


def test_mask_deterministic_between_parties():
    """Both sides of a pair derive the same mask (seed agreement)."""
    clients = _clients(m=2)
    m0 = mask_client_update(clients[0], 0, 2, base_seed=7)
    m1 = mask_client_update(clients[1], 1, 2, base_seed=7)
    total = FeatureStats(
        A=m0.A + m1.A, B=m0.B + m1.B, N=m0.N + m1.N
    )
    ref = clients[0] + clients[1]
    np.testing.assert_allclose(total.A, ref.A, rtol=1e-4, atol=2e-2)


def test_masked_round_matches_per_client_masking():
    """The single-derivation round must produce the EXACT views the
    per-client protocol step produces (same pair seeds, same masks)."""
    clients = _clients(m=5)
    views, total = masked_round(clients, base_seed=3)
    for i, v in enumerate(views):
        per_client = mask_client_update(clients[i], i, 5, base_seed=3)
        np.testing.assert_allclose(np.asarray(v.A), np.asarray(per_client.A),
                                   rtol=1e-5, atol=1e-3)
        np.testing.assert_allclose(np.asarray(v.B), np.asarray(per_client.B),
                                   rtol=1e-5, atol=1e-3)
    summed = views[0]
    for v in views[1:]:
        summed = summed + v
    np.testing.assert_allclose(np.asarray(total.A), np.asarray(summed.A))


def test_secure_sum_over_fused_kernel_stats():
    """Regression: secure_sum over FUSED-kernel FeatureStats matches the
    plain sum to 1e-5 relative (mask cancellation is independent of how
    the statistics were computed)."""
    from repro.core.statistics import client_statistics_fused

    rng = np.random.default_rng(4)
    clients = []
    for _ in range(4):
        x = rng.standard_normal((150, 40)).astype(np.float32)
        y = rng.integers(0, 6, 150)
        clients.append(
            client_statistics_fused(jnp.asarray(x), jnp.asarray(y), 6)
        )
    plain = clients[0]
    for s in clients[1:]:
        plain = plain + s
    # mask_scale 1e2 still dominates every statistic by orders of
    # magnitude; 1e3 would put the f32 cancellation residual itself at
    # ~1e-5 relative on the small-normed N leaf.
    masked = secure_sum(clients, mask_scale=1e2)
    for a, b in [(masked.A, plain.A), (masked.B, plain.B), (masked.N, plain.N)]:
        denom = float(jnp.linalg.norm(b)) + 1e-12
        rel = float(jnp.linalg.norm(a - b)) / denom
        assert rel < 1e-5, f"relative deviation {rel}"


# ---------------------------------------------------------------------------
# Dropout recovery.
# ---------------------------------------------------------------------------


def _assert_rel_close(got, want, tol=1e-5):
    for leaf in ("A", "B", "N"):
        a, b = np.asarray(getattr(got, leaf)), np.asarray(getattr(want, leaf))
        rel = np.linalg.norm(a - b) / (np.linalg.norm(b) + 1e-12)
        assert rel < tol, f"{leaf}: relative deviation {rel}"


@pytest.mark.parametrize("dropped", [[], [0], [2, 5], [0, 1, 7]])
def test_recover_round_equals_survivor_sum(dropped):
    """Server-side Shamir recovery lands on the EXACT plain sum over the
    surviving clients, for several dropout patterns (incl. none)."""
    k, t = 8, 5
    clients = _clients(m=k)
    survivors = [i for i in range(k) if i not in set(dropped)]
    setup = setup_round(k, t, base_seed=3)
    views = masked_survivor_views(
        clients, survivors, k, base_seed=3, mask_scale=10.0
    )
    got = recover_round(views, survivors, setup, mask_scale=10.0)
    _assert_rel_close(got, aggregate([clients[i] for i in survivors]))


def test_recover_round_below_threshold_raises():
    k, t = 8, 5
    clients = _clients(m=k)
    survivors = [0, 1, 2, 3]  # 4 < t
    setup = setup_round(k, t, base_seed=0)
    views = masked_survivor_views(clients, survivors, k, mask_scale=10.0)
    with pytest.raises(ValueError, match="survivors"):
        recover_round(views, survivors, setup, mask_scale=10.0)


def test_setup_round_shares_reconstruct_to_published_keys():
    """Any t survivor shares of client i's secret reconstruct a value
    whose public key is the published pk_i — the recovery math's
    load-bearing invariant (and the secrets never live in the setup)."""
    k, t = 9, 4
    setup = setup_round(k, t, base_seed=17)
    assert not hasattr(setup, "secrets")
    rng = np.random.default_rng(0)
    for i in range(k):
        donors = np.sort(rng.choice(k, size=t, replace=False))
        u_i = shamir.reconstruct_secret(
            setup.share_xs[donors], setup.share_ys[donors, i]
        )
        assert int(shamir.dh_public(u_i)) == int(setup.pubkeys[i])


def test_masked_survivor_views_match_full_round():
    """A survivor's masked view is the same whether or not OTHER clients
    drop — dropping only removes views, never changes them."""
    k = 6
    clients = _clients(m=k)
    full, _ = masked_round(clients, base_seed=5, mask_scale=10.0)
    survivors = [0, 2, 3, 5]
    part = masked_survivor_views(
        clients, survivors, k, base_seed=5, mask_scale=10.0
    )
    for s, view in zip(survivors, part):
        np.testing.assert_array_equal(
            np.asarray(view.A), np.asarray(full[s].A)
        )
        np.testing.assert_array_equal(
            np.asarray(view.B), np.asarray(full[s].B)
        )


_DETERMINISM_BODY = textwrap.dedent(
    """
    import hashlib
    import jax.numpy as jnp
    import numpy as np
    from repro.core.secure_agg import (
        masked_round, masked_survivor_views, pair_seed_matrix,
        recover_round, setup_round,
    )
    from repro.core.statistics import FeatureStats

    k, t, seed = 6, 4, 123
    rng = np.random.default_rng(0)
    clients = [
        FeatureStats(
            A=jnp.asarray(rng.standard_normal((3, 5)).astype(np.float32)),
            B=jnp.asarray(rng.standard_normal((5, 5)).astype(np.float32)),
            N=jnp.asarray(rng.standard_normal((3,)).astype(np.float32)),
        )
        for _ in range(k)
    ]
    h = hashlib.sha256()
    h.update(pair_seed_matrix(seed, k).tobytes())
    setup = setup_round(k, t, base_seed=seed)
    h.update(setup.pubkeys.tobytes())
    h.update(setup.share_ys.tobytes())
    views, total = masked_round(clients, base_seed=seed, mask_scale=10.0)
    for v in views + [total]:
        h.update(np.asarray(v.A).tobytes())
        h.update(np.asarray(v.B).tobytes())
        h.update(np.asarray(v.N).tobytes())
    survivors = [0, 2, 3, 5]
    sv = masked_survivor_views(
        clients, survivors, k, base_seed=seed, mask_scale=10.0
    )
    rec = recover_round(sv, survivors, setup, mask_scale=10.0)
    h.update(np.asarray(rec.A).tobytes())
    print("DIGEST", h.hexdigest())
    """
)


def test_masked_round_bit_identical_across_processes():
    """The PRG/fold_in contract the recovery math depends on: a fixed
    base_seed yields bit-identical masked views, setup transcripts, and
    recoveries in two separate processes."""
    digests = []
    for _ in range(2):
        proc = subprocess.run(
            [sys.executable, "-c", _DETERMINISM_BODY],
            capture_output=True, text=True, timeout=300,
            env=subprocess_env(),
            cwd="/root/repo",
        )
        lines = [l for l in proc.stdout.splitlines() if l.startswith("DIGEST")]
        assert lines, proc.stderr[-2000:]
        digests.append(lines[0])
    assert digests[0] == digests[1]
