"""Pairwise-mask SecureAgg: exact cancellation + per-client privacy."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.secure_agg import (
    mask_client_update,
    masked_round,
    masked_views,
    secure_sum,
)
from repro.core.statistics import FeatureStats, client_statistics


def _clients(m=6, n=40, d=10, c=4, seed=0):
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(m):
        x = rng.standard_normal((n, d)).astype(np.float32)
        y = rng.integers(0, c, n)
        out.append(client_statistics(jnp.asarray(x), jnp.asarray(y), c))
    return out


@pytest.mark.parametrize("m", [2, 5, 11])
def test_masks_cancel_exactly(m):
    clients = _clients(m=m)
    unmasked = clients[0]
    for s in clients[1:]:
        unmasked = unmasked + s
    masked = secure_sum(clients, mask_scale=1e3)
    np.testing.assert_allclose(masked.A, unmasked.A, rtol=1e-4, atol=2e-2)
    np.testing.assert_allclose(masked.B, unmasked.B, rtol=1e-4, atol=2e-2)
    np.testing.assert_allclose(masked.N, unmasked.N, atol=2e-2)


def test_masked_views_hide_individual_statistics():
    clients = _clients(m=4)
    views = masked_views(clients, mask_scale=1e3)
    for true, seen in zip(clients, views):
        # the served view must be dominated by the mask, not the data
        rel = float(jnp.linalg.norm(seen.A - true.A) / (jnp.linalg.norm(true.A) + 1e-9))
        assert rel > 10.0, f"mask too weak: rel={rel}"


def test_single_client_no_masks():
    (c0,) = _clients(m=1)
    masked = mask_client_update(c0, 0, 1)
    np.testing.assert_allclose(masked.A, c0.A)


def test_mask_deterministic_between_parties():
    """Both sides of a pair derive the same mask (seed agreement)."""
    clients = _clients(m=2)
    m0 = mask_client_update(clients[0], 0, 2, base_seed=7)
    m1 = mask_client_update(clients[1], 1, 2, base_seed=7)
    total = FeatureStats(
        A=m0.A + m1.A, B=m0.B + m1.B, N=m0.N + m1.N
    )
    ref = clients[0] + clients[1]
    np.testing.assert_allclose(total.A, ref.A, rtol=1e-4, atol=2e-2)


def test_masked_round_matches_per_client_masking():
    """The single-derivation round must produce the EXACT views the
    per-client protocol step produces (same pair seeds, same masks)."""
    clients = _clients(m=5)
    views, total = masked_round(clients, base_seed=3)
    for i, v in enumerate(views):
        per_client = mask_client_update(clients[i], i, 5, base_seed=3)
        np.testing.assert_allclose(np.asarray(v.A), np.asarray(per_client.A),
                                   rtol=1e-5, atol=1e-3)
        np.testing.assert_allclose(np.asarray(v.B), np.asarray(per_client.B),
                                   rtol=1e-5, atol=1e-3)
    summed = views[0]
    for v in views[1:]:
        summed = summed + v
    np.testing.assert_allclose(np.asarray(total.A), np.asarray(summed.A))


def test_secure_sum_over_fused_kernel_stats():
    """Regression: secure_sum over FUSED-kernel FeatureStats matches the
    plain sum to 1e-5 relative (mask cancellation is independent of how
    the statistics were computed)."""
    from repro.core.statistics import client_statistics_fused

    rng = np.random.default_rng(4)
    clients = []
    for _ in range(4):
        x = rng.standard_normal((150, 40)).astype(np.float32)
        y = rng.integers(0, 6, 150)
        clients.append(
            client_statistics_fused(jnp.asarray(x), jnp.asarray(y), 6)
        )
    plain = clients[0]
    for s in clients[1:]:
        plain = plain + s
    # mask_scale 1e2 still dominates every statistic by orders of
    # magnitude; 1e3 would put the f32 cancellation residual itself at
    # ~1e-5 relative on the small-normed N leaf.
    masked = secure_sum(clients, mask_scale=1e2)
    for a, b in [(masked.A, plain.A), (masked.B, plain.B), (masked.N, plain.N)]:
        denom = float(jnp.linalg.norm(b)) + 1e-12
        rel = float(jnp.linalg.norm(a - b)) / denom
        assert rel < 1e-5, f"relative deviation {rel}"
