"""repro.obs: tracing, the metrics registry, and exposition.

- trace: enable/disable switching, the no-op disabled path, ring-buffer
  bounding, nested-span trace-ID inheritance, cross-thread pinning via
  ``trace_id=``, error stamping, JSONL export;
- registry: counter/gauge/histogram semantics, label children,
  get-or-create with type/label mismatch errors, exact-vs-bucket
  percentile paths;
- expo: Prometheus text render + parse round-trip, JSON twin,
  histogram bucket series;
- ServeMetrics rebase satellites: the throughput-anchor regression
  (queue wait must count), NaN-guarded snapshot, and the full-window
  snapshot cost budget;
- end-to-end span chains: every request in an in-process front run —
  including cross-bucket top-ups and sheds — leaves a complete
  submit→complete chain, and the same holds for the subprocess
  ``fedcgs-front --smoke`` run with exported JSONL.
"""

import json
import math
import os
import subprocess
import sys
import threading
import time

import numpy as np
import pytest

from repro.obs import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    parse_prometheus,
    render_json,
    render_prometheus,
    trace,
)
from repro.obs.registry import EXACT_WINDOW, latency_buckets
from repro.serve.metrics import ServeMetrics, percentile


@pytest.fixture(autouse=True)
def _clean_tracer():
    """Every test starts and ends with tracing off, an empty buffer,
    and the default ring capacity (capacity resizes are sticky)."""
    trace.enable(capacity=trace.DEFAULT_CAPACITY)
    trace.disable()
    trace.reset()
    yield
    trace.enable(capacity=trace.DEFAULT_CAPACITY)
    trace.disable()
    trace.reset()


# ---------------------------------------------------------------------------
# trace
# ---------------------------------------------------------------------------


def test_span_disabled_is_shared_noop():
    s1 = trace.span("a", rows=1)
    s2 = trace.span("b")
    assert s1 is s2  # one shared stateless no-op, no allocation
    with s1 as sp:
        sp.set(x=1)
        sp.fail("nope")
    assert trace.spans() == []


def test_span_records_when_enabled():
    trace.enable()
    with trace.span("work", rows=3) as sp:
        sp.set(extra="y")
    (rec,) = trace.spans()
    assert rec["name"] == "work"
    assert rec["attrs"] == {"rows": 3, "extra": "y"}
    assert rec["duration_s"] >= 0
    assert rec["trace_id"] and rec["parent_id"] is None
    assert "error" not in rec


def test_nested_spans_inherit_trace_id():
    trace.enable()
    with trace.span("outer") as outer:
        with trace.span("inner") as inner:
            assert inner.trace_id == outer.trace_id
            assert trace.current_trace_id() == outer.trace_id
    inner_rec, outer_rec = trace.spans()
    assert inner_rec["name"] == "inner"
    assert inner_rec["parent_id"] == outer_rec["span_id"]
    assert inner_rec["trace_id"] == outer_rec["trace_id"]


def test_explicit_trace_id_pins_across_threads():
    trace.enable()
    with trace.span("submit") as sp:
        tid = sp.trace_id

    def worker():
        with trace.span("complete", trace_id=tid):
            pass

    t = threading.Thread(target=worker)
    t.start()
    t.join()
    names = {s["name"]: s["trace_id"] for s in trace.spans()}
    assert names["complete"] == names["submit"] == tid


def test_span_error_stamping():
    trace.enable()
    with trace.span("shedding") as sp:
        sp.fail("shed")
    with pytest.raises(RuntimeError):
        with trace.span("boom"):
            raise RuntimeError("kernel died")
    shed, boom = trace.spans()
    assert shed["error"] == "shed"
    assert "kernel died" in boom["error"]


def test_ring_buffer_bounds_memory():
    trace.enable(capacity=8)
    for i in range(50):
        with trace.span("s", i=i):
            pass
    kept = trace.spans()
    assert len(kept) == 8
    assert [s["attrs"]["i"] for s in kept] == list(range(42, 50))


def test_export_jsonl_round_trip(tmp_path):
    trace.enable()
    with trace.span("a"):
        pass
    with trace.span("b") as sp:
        sp.fail("x")
    path = str(tmp_path / "trace.jsonl")
    assert trace.export_jsonl(path) == 2
    lines = [json.loads(l) for l in open(path)]
    assert [l["name"] for l in lines] == ["a", "b"]
    assert lines[1]["error"] == "x"


def test_disable_then_reenable_keeps_buffer():
    trace.enable()
    with trace.span("kept"):
        pass
    trace.disable()
    with trace.span("dropped"):
        pass
    trace.enable()
    assert [s["name"] for s in trace.spans()] == ["kept"]


def test_annotate_is_noop_without_device_flag():
    trace.enable()  # host-only: no TraceAnnotation cost
    cm = trace.annotate("serve.scoring.gnb_logits")
    with cm:
        pass
    assert trace.spans() == []  # annotations never enter the span buffer


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


def test_counter_monotone():
    reg = MetricsRegistry()
    c = reg.counter("c_total", "help")
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    with pytest.raises(ValueError):
        c.inc(-1)


def test_gauge_set_and_inc():
    reg = MetricsRegistry()
    g = reg.gauge("g", "help")
    g.set(5)
    g.inc(-2)
    assert g.value == 3.0


def test_labels_create_independent_children():
    reg = MetricsRegistry()
    fam = reg.counter("reqs_total", "help", ("worker",))
    fam.labels(worker="w0").inc(3)
    fam.labels(worker="w1").inc(4)
    assert fam.labels(worker="w0").value == 3
    assert fam.labels(worker="w1").value == 4
    assert dict(
        (vals, child.value) for vals, child in fam.children()
    ) == {("w0",): 3, ("w1",): 4}
    with pytest.raises(ValueError):
        fam.labels(wrong="x")


def test_get_or_create_is_shared_and_type_checked():
    reg = MetricsRegistry()
    a = reg.counter("shared_total", "help")
    b = reg.counter("shared_total")
    assert a is b
    with pytest.raises(ValueError):
        reg.gauge("shared_total")
    with pytest.raises(ValueError):
        reg.counter("shared_total", label_names=("worker",))


def test_histogram_exact_window_matches_nearest_rank():
    reg = MetricsRegistry()
    h = reg.histogram("lat", "help", window=64)
    rng = np.random.default_rng(0)
    vals = rng.exponential(0.01, 50)
    for v in vals:
        h.observe(v)
    ordered = sorted(vals)
    for q in (0.5, 0.95, 0.99):
        assert h.percentile(q) == percentile(ordered, q)


def test_histogram_bucket_path_beyond_window():
    reg = MetricsRegistry()
    h = reg.histogram("lat", "help", window=16)
    rng = np.random.default_rng(1)
    vals = rng.exponential(0.01, 500)
    for v in vals:
        h.observe(v)
    ordered = sorted(vals)
    for q in (0.5, 0.95, 0.99):
        est, true = h.percentile(q), percentile(ordered, q)
        # bucket interpolation: within one log-spaced bucket (x1.33)
        assert true / 1.34 <= est <= true * 1.34, (q, est, true)
    assert h.count == 500
    assert h.sum == pytest.approx(vals.sum())


def test_histogram_empty_and_overflow_bucket():
    reg = MetricsRegistry()
    h = reg.histogram("lat", "help", window=4)
    assert math.isnan(h.percentile(0.5))
    for _ in range(10):
        h.observe(1e6)  # beyond the highest finite bound
    # +Inf bucket: report the highest finite bound as a monotone floor
    assert h.percentile(0.99) == latency_buckets()[-1]


def test_histogram_bucket_counts_cumulative():
    reg = MetricsRegistry()
    h = reg.histogram("lat", "help", buckets=(0.1, 1.0), window=4)
    for v in (0.05, 0.5, 0.5, 5.0):
        h.observe(v)
    assert h.bucket_counts() == [(0.1, 1), (1.0, 3), (math.inf, 4)]


# ---------------------------------------------------------------------------
# exposition
# ---------------------------------------------------------------------------


def test_prometheus_render_parse_round_trip():
    reg = MetricsRegistry()
    reg.counter("app_requests_total", "reqs", ("worker",)).labels(
        worker="w0"
    ).inc(7)
    reg.gauge("app_depth", "queue depth").set(3)
    h = reg.histogram("app_latency_seconds", "lat", buckets=(0.1, 1.0))
    h.observe(0.05)
    h.observe(0.5)
    text = render_prometheus(reg)
    assert "# TYPE app_requests_total counter" in text
    assert "# TYPE app_latency_seconds histogram" in text
    parsed = parse_prometheus(text)
    assert parsed["app_requests_total"]['{worker="w0"}'] == 7
    assert parsed["app_depth"][""] == 3
    assert parsed["app_latency_seconds_bucket"]['{le="0.1"}'] == 1
    assert parsed["app_latency_seconds_bucket"]['{le="+Inf"}'] == 2
    assert parsed["app_latency_seconds_count"][""] == 2
    assert parsed["app_latency_seconds_sum"][""] == pytest.approx(0.55)


def test_render_json_structure():
    reg = MetricsRegistry()
    reg.counter("x_total", "help").inc(2)
    out = render_json(reg)
    (fam,) = out["families"]
    assert fam["name"] == "x_total" and fam["kind"] == "counter"
    assert fam["series"] == [{"labels": {}, "value": 2.0}]
    json.dumps(out)  # JSON-ready, no numpy leakage


def test_parse_prometheus_rejects_malformed():
    with pytest.raises(ValueError):
        parse_prometheus("== not a sample ==\n")


# ---------------------------------------------------------------------------
# ServeMetrics rebase satellites
# ---------------------------------------------------------------------------


def _metrics(**kw):
    return ServeMetrics(registry=MetricsRegistry(), **kw)


def test_throughput_anchor_includes_queue_wait():
    """Regression: the first batch's span must start at request submit,
    not ``now - score_s`` — a queued first batch used to backdate only
    by the kernel time and overstate throughput."""
    m = _metrics(capacity_rows=64)
    enqueue_t = time.perf_counter()
    time.sleep(0.05)  # the queue wait the old anchor dropped
    m.record_batch(requests=4, rows=8, padded_rows=8, score_s=1e-4,
                   enqueued_t=enqueue_t)
    time.sleep(0.01)
    m.record_batch(requests=4, rows=8, padded_rows=8, score_s=1e-4)
    rps = m.snapshot()["throughput_rps"]
    # 8 requests over >= 60ms: the old anchor (span ~= 10ms) reported
    # several hundred rps here — the fix caps it near 8/0.06 ~ 133
    assert rps < 8 / 0.055, rps


def test_snapshot_nan_guards_empty_metrics():
    snap = _metrics().snapshot()
    assert math.isnan(snap["throughput_rps"])
    assert math.isnan(snap["throughput_rows_s"])
    assert math.isnan(snap["latency_p50_ms"])
    assert math.isnan(snap["pad_waste_frac"])
    assert snap["requests"] == 0


def test_snapshot_of_full_latency_window_is_cheap():
    """Satellite: a 65536-observation history must snapshot in bounded
    time — the bucket path is O(#buckets), never a sort of the raw
    samples (the old deque sorted 65536 floats under the lock)."""
    m = _metrics(capacity_rows=64)
    rng = np.random.default_rng(0)
    for v in rng.exponential(0.01, 65536):
        m.record_latency(v)
    t0 = time.perf_counter()
    for _ in range(20):
        snap = m.snapshot()
    per_snap = (time.perf_counter() - t0) / 20
    assert per_snap < 0.02, f"snapshot cost {per_snap * 1e3:.1f}ms"
    assert snap["latency_p50_ms"] > 0


def test_serve_metrics_snapshot_keys_are_prom_backed():
    m = _metrics(capacity_rows=32)
    m.record_batch(requests=2, rows=10, padded_rows=16, score_s=0.01)
    m.record_latency(0.002)
    m.record_swap()
    m.record_rejected()
    snap = m.snapshot()
    assert snap["requests"] == 2 and snap["rows"] == 10
    assert snap["head_swaps"] == 1 and snap["rejected"] == 1
    assert snap["batch_occupancy"] == pytest.approx(10 / 32)
    assert snap["pad_waste_frac"] == pytest.approx(1 - 10 / 16)


# ---------------------------------------------------------------------------
# end-to-end span chains through the serving tier
# ---------------------------------------------------------------------------


def test_span_chain_in_process_with_topup_and_shed():
    from repro.serve import ServeFront
    from repro.serve.batcher import QueueFull
    from repro.serve.front import verify_span_chains
    from tests.test_serve_front import _head  # reuse the fixture helper

    trace.enable()
    d = 8
    front = ServeFront.create(
        1, head=_head(d, 4), max_batch_rows=64, max_delay_s=5e-3,
        max_queued_rows=96,
    )
    rng = np.random.default_rng(0)
    served = shed = 0
    with front:
        futures = []
        # ragged mix across buckets: small probes ride big batches as
        # top-ups; the tight front bound forces at least one shed
        for n in (40, 3, 2, 60, 5, 50, 33, 7):
            try:
                futures.append(
                    front.submit(rng.standard_normal((n, d)).astype(np.float32))
                )
            except QueueFull:
                shed += 1
        for f in futures:
            f.result(timeout=30)
            served += 1
    assert shed >= 1, "fixture meant to shed at least once"
    verify_span_chains(trace.spans(), served=served, shed=shed)
    # cross-bucket top-ups keep their own trace IDs through complete
    complete = [s for s in trace.spans() if s["name"] == "serve.complete"]
    assert any(s["attrs"].get("topup") for s in complete)


@pytest.mark.slow
def test_front_smoke_subprocess_exports_complete_chains(tmp_path):
    """Satellite: the CI smoke run — every request in a --workers 2 run
    has a complete span chain in the exported JSONL, and the metrics
    exposition file parses with matching totals."""
    trace_out = str(tmp_path / "trace.jsonl")
    metrics_out = str(tmp_path / "metrics.prom")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")]
    )
    env.setdefault("JAX_PLATFORMS", "cpu")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.serve.front", "--smoke",
         "--workers", "2", "--requests", "16",
         "--trace-out", trace_out, "--metrics-out", metrics_out],
        capture_output=True, text=True, timeout=600, env=env,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr[-2000:]
    # the smoke process already self-verifies chains; re-verify from
    # the exported artifact so the JSONL itself is proven complete
    from repro.serve.front import verify_span_chains

    spans = [json.loads(l) for l in open(trace_out)]
    served = sum(
        1 for s in spans if s["name"] == "serve.submit" and "error" not in s
    )
    shed = sum(
        1 for s in spans if s["name"] == "serve.submit"
        and s.get("error") == "shed"
    )
    assert served + shed == 16
    verify_span_chains(spans, served=served, shed=shed)
    parsed = parse_prometheus(open(metrics_out).read())
    total = sum(parsed["fedcgs_front_accepted_total"].values())
    assert total == served


# ---------------------------------------------------------------------------
# round-lifecycle spans
# ---------------------------------------------------------------------------


def test_pipeline_and_registry_spans():
    import jax.numpy as jnp

    from repro.core.stats_pipeline import StatsPipeline
    from repro.serve.registry import HeadRegistry

    trace.enable()
    rng = np.random.default_rng(0)
    f = jnp.asarray(rng.standard_normal((32, 8)), jnp.float32)
    y = jnp.asarray(rng.integers(0, 4, 32), jnp.int32)
    pipe = StatsPipeline(4, backend="jnp")
    stats = pipe.from_batches([(f[:16], y[:16]), (f[16:], y[16:])])
    reg = HeadRegistry()
    reg.refit_from_stats(stats)
    names = [s["name"] for s in trace.spans()]
    assert "pipeline.fold" in names
    assert "registry.publish" in names
    fold = next(s for s in trace.spans() if s["name"] == "pipeline.fold")
    assert fold["attrs"]["batches"] == 2
    pub = next(s for s in trace.spans() if s["name"] == "registry.publish")
    assert pub["attrs"]["version"] == 0
