"""Loop-aware HLO cost parser: trip-count multiplication, grads, collectives."""

import jax
import jax.ad_checkpoint as adc
import jax.numpy as jnp
import pytest

from conftest import subprocess_env as _subprocess_env

from repro.launch.hlo_parse import analyze


def _compile(fn, *shapes):
    args = [jax.ShapeDtypeStruct(s, jnp.float32) for s in shapes]
    return jax.jit(fn).lower(*args).compile()


def test_scan_flops_multiplied_by_trip_count():
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y.sum()

    c = _compile(f, (256, 256), (256, 256))
    flops = analyze(c.as_text()).flops
    assert flops == pytest.approx(10 * 2 * 256**3, rel=0.05)


def test_grad_and_remat_flops():
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=6)
        return y.sum()

    def f_remat(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        body = jax.checkpoint(body, policy=adc.checkpoint_policies.nothing_saveable)
        y, _ = jax.lax.scan(body, x, None, length=6)
        return y.sum()

    one = 2 * 256**3
    g = analyze(_compile(jax.grad(f), (256, 256), (256, 256)).as_text()).flops
    gr = analyze(_compile(jax.grad(f_remat), (256, 256), (256, 256)).as_text()).flops
    assert g == pytest.approx(6 * 2 * one, rel=0.05)  # fwd + dx
    assert gr == pytest.approx(6 * 3 * one, rel=0.05)  # fwd + recompute + dx


def test_nested_scan_multiplies():
    def f(x, w):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None
            ci, _ = jax.lax.scan(inner, c, None, length=3)
            return ci, None
        y, _ = jax.lax.scan(outer, x, None, length=4)
        return y.sum()

    c = _compile(f, (128, 128), (128, 128))
    flops = analyze(c.as_text()).flops
    assert flops == pytest.approx(12 * 2 * 128**3, rel=0.05)


def test_dus_fusion_bytes_not_full_buffer():
    """In-place cache write must cost ~update bytes, not cache bytes.

    The cache must be DONATED — otherwise XLA inserts a defensive
    full-buffer copy, which is real traffic and correctly counted.
    """

    def f(cache, upd):
        return jax.lax.dynamic_update_slice(cache, upd, (0, 0))

    args = [
        jax.ShapeDtypeStruct((4096, 1024), jnp.float32),
        jax.ShapeDtypeStruct((1, 1024), jnp.float32),
    ]
    c = jax.jit(f, donate_argnums=(0,)).lower(*args).compile()
    costs = analyze(c.as_text())
    # full buffer is 16 MB; the update is 4 KB
    assert costs.bytes < 1e6, f"bytes={costs.bytes}"


def test_collectives_counted_with_loops():
    import subprocess, sys, textwrap

    body = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.hlo_parse import analyze

        mesh = jax.make_mesh((4,), ("data",))  # Auto axes (the default)
        sh = NamedSharding(mesh, P("data"))
        rep = NamedSharding(mesh, P())

        def f(x, w):
            def body(c, _):
                # contraction over the sharded dim forces an all-reduce
                return jnp.tanh(c @ w), None
            y, _ = jax.lax.scan(body, x, None, length=5)
            return y

        x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
        w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
        c = jax.jit(f, in_shardings=(rep, sh), out_shardings=rep).lower(x, w).compile()
        costs = analyze(c.as_text())
        total = costs.total_collective_bytes
        print("COLL", total)
        """
    )
    proc = subprocess.run(
        [sys.executable, "-c", body], capture_output=True, text=True, timeout=300,
        env=_subprocess_env(), cwd="/root/repo",
    )
    assert "COLL" in proc.stdout, proc.stderr[-2000:]
    total = float(proc.stdout.split("COLL")[1].strip())
    # 5 iterations x (128x128 f32) ~ 320 KB; loop multiplication must show
    assert total >= 5 * 128 * 128 * 4 * 0.5, total


# ---------------------------------------------------------------------------
# Hand-written-module edge cases (no jax compile needed)
# ---------------------------------------------------------------------------

import textwrap as _textwrap

_TYPED_OPERAND_HLO = _textwrap.dedent(
    """
    HloModule typed_operands

    ENTRY %main (lhs: f32[256,256], rhs: f32[256,256]) -> f32[256,256] {
      %lhs = f32[256,256]{1,0} parameter(0)
      %rhs = f32[256,256]{1,0} parameter(1)
      ROOT %dot.1 = f32[256,256]{1,0} dot(f32[256,256]{1,0} %lhs, f32[256,256]{1,0} %rhs), lhs_contracting_dims={1}, rhs_contracting_dims={0}
    }
    """
)


def test_typed_operands_old_dump_style():
    """Older XLA dumps print operands WITH their types; the operand name
    is the trailing %name and the contraction dim must still resolve."""
    costs = analyze(_TYPED_OPERAND_HLO)
    assert costs.flops == pytest.approx(2 * 256**3)


_FUSION_HLO = _textwrap.dedent(
    """
    HloModule fusion_body

    %fused_computation (p0: f32[128,128], p1: f32[128,128]) -> f32[128,128] {
      %p0 = f32[128,128]{1,0} parameter(0)
      %p1 = f32[128,128]{1,0} parameter(1)
      ROOT %dot.2 = f32[128,128]{1,0} dot(%p0, %p1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
    }

    ENTRY %main (a: f32[128,128], b: f32[128,128]) -> f32[128,128] {
      %a = f32[128,128]{1,0} parameter(0)
      %b = f32[128,128]{1,0} parameter(1)
      ROOT %fusion = f32[128,128]{1,0} fusion(%a, %b), kind=kOutput, calls=%fused_computation
    }
    """
)


def test_fusion_body_flops_counted_bytes_fused():
    """FLOPs recurse into the fusion body; HBM bytes count the fusion's
    operands + result ONCE (internals are fused, not re-read)."""
    costs = analyze(_FUSION_HLO)
    assert costs.flops == pytest.approx(2 * 128**3)
    # operands (2) + result (1), each 128*128*4 bytes — nothing more
    assert costs.bytes == pytest.approx(3 * 128 * 128 * 4)


def test_empty_module_is_all_zero():
    costs = analyze("")
    assert costs.flops == 0.0
    assert costs.bytes == 0.0
    assert costs.total_collective_bytes == 0.0
    assert all(v == 0.0 for v in costs.collective_count.values())


def test_no_entry_falls_back_to_largest_computation():
    text = _textwrap.dedent(
        """
        HloModule no_entry

        %small (x: f32[4]) -> f32[4] {
          %x = f32[4]{0} parameter(0)
          ROOT %neg = f32[4]{0} negate(%x)
        }

        %big (p0: f32[64,64], p1: f32[64,64]) -> f32[64,64] {
          %p0 = f32[64,64]{1,0} parameter(0)
          %p1 = f32[64,64]{1,0} parameter(1)
          %t = f32[64,64]{1,0} tanh(%p0)
          ROOT %dot.3 = f32[64,64]{1,0} dot(%t, %p1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
        }
        """
    )
    costs = analyze(text)
    assert costs.flops == pytest.approx(2 * 64**3)
