"""Quickstart: FedCGS in ~30 lines.

10 clients with highly skewed (Dirichlet α=0.05) data, one upload round,
a training-free global classifier.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.data import SyntheticSpec, dirichlet_partition, make_classification_data
from repro.fl.backbone import make_backbone
from repro.fl.fedcgs import run_fedcgs

# --- a synthetic 10-class world + a frozen "pre-trained" backbone -------
spec = SyntheticSpec(num_classes=10, input_dim=64, samples_per_class=400)
x, y = map(np.asarray, make_classification_data(spec))
x_test, y_test = map(np.asarray, make_classification_data(spec, seed=123))
backbone = make_backbone("resnet18-like", spec.input_dim)

# --- extreme label shift: α = 0.05 over 10 clients ----------------------
parts = dirichlet_partition(y, num_clients=10, alpha=0.05)
clients = [(x[p], y[p]) for p in parts]
print("client sizes:", [len(p) for p in parts])
print("client label skew (client 0):", np.bincount(y[parts[0]], minlength=10))

# --- ONE communication round: upload (A_i, B_i, N_i), SecureAgg, done ---
result = run_fedcgs(backbone, clients, num_classes=10, test_data=(x_test, y_test))

print(f"\nFedCGS accuracy     : {result.accuracy:.4f}")
print(f"uploaded floats     : {result.uploaded_floats_per_client:,} per client")
print(f"  (vs full model    : a ResNet18 upload is 11,181,642 floats)")
print(f"global prototypes μ : {result.stats.mu.shape}")
print(f"shared covariance Σ : {result.stats.sigma.shape}")
