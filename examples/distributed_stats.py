"""FedCGS aggregation as a mesh collective (DESIGN.md §3).

Spawns itself with 8 simulated devices, assigns client cohorts to mesh
shards, computes the statistics per shard — with the FUSED single-pass
Pallas engine — and realizes the "server" as a single psum over the
FeatureStats tree, with and without SecureAgg masks folded into the
reduction. Shows the exactness claim surviving the distributed path.

    PYTHONPATH=src python examples/distributed_stats.py
"""

import os
import subprocess
import sys

BODY = """
import os
import jax, jax.numpy as jnp, numpy as np
from repro.core.statistics import centralized_statistics, derive_global, statistics_deviation
from repro.core.classifier import gnb_head
from repro.data import SyntheticSpec, make_classification_data
from repro.fl.backbone import make_backbone
from repro.launch.mesh import make_host_mesh
from repro.launch.stats_engine import sharded_client_stats, sharded_cohort_stats

print(f"devices: {len(jax.devices())}")
mesh = make_host_mesh(2)  # ("data"=4, "model"=2)
print(f"mesh: {dict(mesh.shape)} — clients live on the data axis")

spec = SyntheticSpec(num_classes=10, input_dim=64, samples_per_class=200)
x, y = make_classification_data(spec)
bb = make_backbone("resnet18-like", spec.input_dim)
feats = bb.features(jnp.asarray(x))
ref = centralized_statistics(feats, jnp.asarray(y), 10)

# ---- the server aggregation IS a psum over ("data",) -------------------
# each shard sweeps its rows ONCE with the fused Pallas kernel (A, B, N
# in a single k-sweep), then one collective sums the tree.
stats = sharded_client_stats(feats, jnp.asarray(y), 10, mesh=mesh)
g = derive_global(stats)
dmu, dsig = statistics_deviation(g, ref)
print(f"fused + psum:        delta_mu={float(dmu):.2e} delta_sigma={float(dsig):.2e}")

# ---- many simulated clients, one collective ----------------------------
parts = np.array_split(np.arange(feats.shape[0]), 16)
cohort = [(np.asarray(feats)[p], np.asarray(y)[p]) for p in parts]
stats_c = sharded_cohort_stats(cohort, 10, mesh=mesh)
gc = derive_global(stats_c)
dmu, dsig = statistics_deviation(gc, ref)
print(f"16-client cohort:    delta_mu={float(dmu):.2e} delta_sigma={float(dsig):.2e}")

# ---- SecureAgg masks cancel INSIDE the same psum -----------------------
masked = sharded_client_stats(feats, jnp.asarray(y), 10, mesh=mesh, secure=True)
gm = derive_global(masked)
dmu, dsig = statistics_deviation(gm, ref)
print(f"masked aggregation:  delta_mu={float(dmu):.2e} delta_sigma={float(dsig):.2e}")

head = gnb_head(gm)
acc = float(head.accuracy(feats, jnp.asarray(y)))
print(f"GNB head from the masked distributed statistics: train-set acc {acc:.4f}")
"""

if __name__ == "__main__":
    env = dict(os.environ)  # keeps JAX_PLATFORMS: TPU probing must not hang
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env.setdefault("PYTHONPATH", "src")
    raise SystemExit(subprocess.call([sys.executable, "-c", BODY], env=env))
