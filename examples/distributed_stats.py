"""FedCGS aggregation as a mesh collective (DESIGN.md §3).

Spawns itself with 8 simulated devices, assigns client cohorts to mesh
shards, computes the statistics per shard, and realizes the "server"
as a single psum — with and without SecureAgg masks folded into the
reduction. Shows the exactness claim surviving the distributed path.

    PYTHONPATH=src python examples/distributed_stats.py
"""

import os
import subprocess
import sys

BODY = """
import os
import jax, jax.numpy as jnp, numpy as np
from repro.core.federated import distributed_client_stats, masked_distributed_stats
from repro.core.statistics import centralized_statistics, derive_global, statistics_deviation
from repro.core.classifier import gnb_head
from repro.data import SyntheticSpec, make_classification_data
from repro.fl.backbone import make_backbone
from repro.launch.mesh import make_host_mesh

print(f"devices: {len(jax.devices())}")
mesh = make_host_mesh(2)  # ("data"=4, "model"=2)
print(f"mesh: {dict(mesh.shape)} — clients live on the data axis")

spec = SyntheticSpec(num_classes=10, input_dim=64, samples_per_class=200)
x, y = make_classification_data(spec)
bb = make_backbone("resnet18-like", spec.input_dim)
feats = bb.features(jnp.asarray(x))

# ---- the server aggregation IS a psum over ("data",) ----
stats = distributed_client_stats(feats, jnp.asarray(y), 10, mesh)
g = derive_global(stats)
ref = centralized_statistics(feats, jnp.asarray(y), 10)
dmu, dsig = statistics_deviation(g, ref)
print(f"psum aggregation:    delta_mu={float(dmu):.2e} delta_sigma={float(dsig):.2e}")

# ---- SecureAgg masks cancel INSIDE the same psum ----
masked = masked_distributed_stats(feats, jnp.asarray(y), 10, mesh, mask_scale=1e3)
gm = derive_global(masked)
dmu, dsig = statistics_deviation(gm, ref)
print(f"masked aggregation:  delta_mu={float(dmu):.2e} delta_sigma={float(dsig):.2e}")

head = gnb_head(gm)
acc = float(head.accuracy(feats, jnp.asarray(y)))
print(f"GNB head from the masked distributed statistics: train-set acc {acc:.4f}")
"""

if __name__ == "__main__":
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env.setdefault("PYTHONPATH", "src")
    raise SystemExit(subprocess.call([sys.executable, "-c", BODY], env=env))
