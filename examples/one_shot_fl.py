"""End-to-end one-shot FL comparison — the paper's Table 1 in miniature.

Runs FedCGS against FedAvg(one-shot), Ensemble and FedPFT at two
heterogeneity levels and prints the comparison. Note how every baseline
degrades as α drops while FedCGS is bit-identical.

    PYTHONPATH=src python examples/one_shot_fl.py
"""

import numpy as np

from repro.data import SyntheticSpec, dirichlet_partition, make_classification_data
from repro.fl.backbone import make_backbone
from repro.fl.baselines import run_ensemble, run_fedavg_oneshot, run_fedpft
from repro.fl.fedcgs import run_fedcgs

spec = SyntheticSpec(
    num_classes=10, input_dim=64, samples_per_class=300, class_sep=1.6
)
x, y = map(np.asarray, make_classification_data(spec))
test = tuple(map(np.asarray, make_classification_data(spec, seed=321)))
backbone = make_backbone("resnet18-like", spec.input_dim)

print(f"{'alpha':>6} | {'FedAvg':>8} | {'Ensemble':>8} | {'FedPFT':>8} | {'FedCGS':>8}")
print("-" * 52)
for alpha in (0.05, 0.5):
    parts = dirichlet_partition(y, 10, alpha, seed=0)
    clients = [(x[p], y[p]) for p in parts]
    a_avg = run_fedavg_oneshot(backbone, clients, 10, test, epochs=15)
    a_ens = run_ensemble(backbone, clients, 10, test, epochs=15)
    a_pft = run_fedpft(backbone, clients, 10, test, epochs=15)
    a_cgs = run_fedcgs(backbone, clients, 10, test_data=test).accuracy
    print(
        f"{alpha:>6} | {a_avg:>8.4f} | {a_ens:>8.4f} | {a_pft:>8.4f} | {a_cgs:>8.4f}"
    )

print("\nFedCGS is exactly α-invariant: the aggregated (A, B, N) are")
print("partition-independent sums, so heterogeneity cannot affect them.")
