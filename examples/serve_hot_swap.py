"""Serve the FedCGS head under traffic, hot-swap it from a new round.

The deployment story in one script: fit an initial GNB head from a
plain one-shot round, stand the dynamic-batching server up, push
ragged requests through it, then run a SECOND round — secure
aggregation on, two clients dropping mid-round (Shamir recovery) — and
hot-swap the refit head in while requests are still flowing.  Every
response records the head version that scored it, so the swap boundary
is visible in the output.

    PYTHONPATH=src python examples/serve_hot_swap.py
"""

import numpy as np

from repro.core.stats_pipeline import StatsPipeline
from repro.data import SyntheticSpec, dirichlet_partition, make_classification_data
from repro.fl.backbone import make_backbone
from repro.serve import GNBServer, HeadRegistry

# --- a synthetic world + frozen backbone features -----------------------
spec = SyntheticSpec(num_classes=10, input_dim=64, samples_per_class=200)
x, y = map(np.asarray, make_classification_data(spec))
backbone = make_backbone("resnet18-like", spec.input_dim)
feats = np.asarray(backbone.features(x))
d, c = feats.shape[1], spec.num_classes

# --- round 1 (plain, half the clients seen) → initial head --------------
parts = dirichlet_partition(y, num_clients=8, alpha=0.3)
clients = [(feats[p], y[p]) for p in parts]
registry = HeadRegistry()
v0 = registry.refit_from_round(StatsPipeline(c), clients[:4])
print(f"initial head: version {v0} from 4 clients (plain round)")

# --- serve ragged traffic, swap mid-stream ------------------------------
rng = np.random.default_rng(0)
requests = [feats[rng.integers(0, len(feats), n)] for n in (3, 40, 17, 96, 5, 64)]

with GNBServer(registry=registry, max_delay_s=1e-3) as server:
    early = [server.submit(r) for r in requests[:3]]

    # round 2: all 8 clients, SecureAgg on, clients 2 and 5 drop
    # mid-round — Shamir mask recovery, then the atomic hot-swap
    v1 = registry.refit_from_round(
        StatsPipeline(c, privacy="secure", dropout=[2, 5], min_survivors=4),
        clients,
    )
    print(f"hot-swapped: version {v1} (secure round, 2 dropped, recovered)")

    late = [server.submit(r) for r in requests[3:]]
    for i, fut in enumerate(early + late):
        res = fut.result(timeout=120)
        print(
            f"request {i}: {res.logits.shape[0]:3d} rows  "
            f"head v{res.head_version}  latency {res.latency_s*1e3:6.2f} ms"
        )
    server.drain()
    snap = server.metrics.snapshot()

print(
    f"\nserved {snap['requests']} requests / {snap['rows']} rows in "
    f"{snap['batches']} batches  (p95 {snap['latency_p95_ms']:.2f} ms, "
    f"occupancy {snap['batch_occupancy']*100:.0f}%, "
    f"pad waste {snap['pad_waste_frac']*100:.0f}%, "
    f"head swaps {snap['head_swaps']})"
)
