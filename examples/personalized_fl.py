"""Personalized one-shot FL (paper Eq. 12): clients download the global
prototypes once and fine-tune locally with the feature-alignment
regularizer. Compared against Local-only training.

    PYTHONPATH=src python examples/personalized_fl.py
"""

import numpy as np

from repro.data import (
    SyntheticSpec,
    dominant_class_partition,
    make_classification_data,
)
from repro.fl.backbone import make_backbone
from repro.fl.baselines import run_local_only
from repro.fl.fedcgs import run_fedcgs_personalized

spec = SyntheticSpec(num_classes=10, input_dim=64, samples_per_class=200)
x, y = map(np.asarray, make_classification_data(spec))
xt, yt = map(np.asarray, make_classification_data(spec, seed=55))
backbone = make_backbone("resnet18-like", spec.input_dim)

# every client: 20% uniform data + 80% from 2 dominant classes
parts = dominant_class_partition(y, num_clients=5, uniform_fraction=0.2)
clients = [(x[p], y[p]) for p in parts]

# per-client test sets matching each client's label distribution
rng = np.random.default_rng(0)
tests = []
for p in parts:
    probs = np.bincount(y[p], minlength=10).astype(float)
    probs /= probs.sum()
    w = probs[yt] / probs[yt].sum()
    idx = rng.choice(len(yt), size=400, p=w, replace=False)
    tests.append((xt[idx], yt[idx]))

local = run_local_only(backbone, clients, tests, 10, epochs=60)
print(f"Local-only        : {np.mean(local):.4f} (per-client {np.round(local, 3)})")

accs, gstats = run_fedcgs_personalized(
    backbone, clients, tests, 10, proto_lambda=1.0, epochs=60, lr=0.05
)
print(f"FedCGS-personal.  : {np.mean(accs):.4f} (per-client {np.round(accs, 3)})")
print(
    "\nOne extra DOWNLOAD round delivered fixed global prototypes "
    f"μ {tuple(gstats.mu.shape)}; the regularizer pulls each client's "
    "features toward them (Eq. 12)."
)
