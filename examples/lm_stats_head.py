"""Beyond-paper: FedCGS statistics over an LLM backbone (class = next token).

Trains a reduced gemma-2b for a few hundred steps on a synthetic Markov
corpus, then builds the TRAINING-FREE GNB language-model head from
federated (A, B, N) statistics captured across 4 simulated clients, and
compares its next-token accuracy against the model's own trained head.

This is the end-to-end driver exercising the launch/train substrate:
~100M-param-class reduced model, a few hundred steps.

    PYTHONPATH=src python examples/lm_stats_head.py [--steps 200]
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.classifier import gnb_head
from repro.core.secure_agg import secure_sum
from repro.core.statistics import FeatureStats, client_statistics, derive_global
from repro.data.tokens import TokenStream, synthetic_corpus
from repro.launch.train import train
from repro.models import transformer as T

p = argparse.ArgumentParser()
p.add_argument("--steps", type=int, default=200)
p.add_argument("--batch", type=int, default=8)
p.add_argument("--seq", type=int, default=256)
args = p.parse_args()

# --- 1. pre-train the backbone (this is the "pre-trained model") --------
print(f"pre-training reduced gemma-2b for {args.steps} steps ...")
params, losses = train(
    "gemma-2b", num_steps=args.steps, batch=args.batch, seq=args.seq, lr=1e-3,
    log_every=max(1, args.steps // 5),
)
cfg = get_config("gemma-2b", reduced=True)
V, d = cfg.vocab_size, cfg.d_model
print(f"loss {losses[0]:.3f} -> {losses[-1]:.3f}\n")

# --- 2. four "clients", each with its own shard of the corpus -----------
num_clients = 4
corpus = synthetic_corpus(V, 200_000, seed=1)
shards = np.array_split(corpus, num_clients)

client_stats = []
for i, shard in enumerate(shards):
    stream = iter(TokenStream(shard, batch=8, seq_len=args.seq, seed=i))
    stats = FeatureStats.zeros(V, d)
    for _ in range(4):
        tokens, targets = next(stream)
        hidden, _ = T.forward(params, cfg, jnp.asarray(tokens))
        stats = stats + client_statistics(
            hidden.reshape(-1, d), jnp.asarray(targets).reshape(-1), V
        )
    client_stats.append(stats)
    print(f"client {i}: {int(jnp.sum(stats.N))} token statistics captured")

# --- 3. SecureAgg + training-free LM head --------------------------------
agg = secure_sum(client_stats)
head = gnb_head(derive_global(agg))

# --- 4. evaluate both heads on held-out text ----------------------------
stream = iter(TokenStream(corpus, batch=16, seq_len=args.seq, seed=999))
tokens, targets = next(stream)
hidden, _ = T.forward(params, cfg, jnp.asarray(tokens))
feats = hidden.reshape(-1, d)
tgt = jnp.asarray(targets).reshape(-1)

stats_acc = float(head.accuracy(feats, tgt))
logits = T.unembed(params, cfg, hidden)
trained_acc = float(jnp.mean((jnp.argmax(logits, -1).reshape(-1) == tgt)))
print(f"\ntrained unembedding head : next-token acc {trained_acc:.4f}")
print(f"FedCGS stats head        : next-token acc {stats_acc:.4f}")
print(f"uniform-random baseline  : {1.0 / V:.6f}")
print("\nThe stats head was configured WITHOUT any training — one secure")
print("aggregation of (A, B, N) over clients, then w_j = Σ⁻¹μ_j.")
