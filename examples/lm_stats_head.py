"""Beyond-paper: FedCGS statistics over an LLM backbone (class = next token).

Trains a reduced gemma-2b for a few hundred steps on a synthetic Markov
corpus, wraps it as an **Extractor** (`repro.fl.extractors`), then builds
the TRAINING-FREE GNB language-model head in one streamed pass: the
`StatsPipeline(extractor=...)` round consumes RAW token batches and does
extractor-forward → fold per batch, so no client ever materializes its
feature matrix.  The result is compared against the model's own trained
unembedding head.

This is the same config → features → global head pipeline the
`fedcgs-extract` console script drives end to end over an untrained zoo
config:

    fedcgs-extract --config gemma_2b --smoke

Here the backbone is first trained, which is the one thing the
one-command driver doesn't do:

    PYTHONPATH=src python examples/lm_stats_head.py [--steps 200]
"""

import argparse

import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.classifier import gnb_head
from repro.core.statistics import derive_global
from repro.core.stats_pipeline import StatsPipeline
from repro.data.tokens import TokenStream, synthetic_corpus
from repro.fl.extractors import ModelExtractor, token_labels
from repro.launch.train import train
from repro.models import transformer as T

p = argparse.ArgumentParser()
p.add_argument("--steps", type=int, default=200)
p.add_argument("--batch", type=int, default=8)
p.add_argument("--seq", type=int, default=256)
args = p.parse_args()

# --- 1. pre-train the backbone (this is the "pre-trained model") --------
print(f"pre-training reduced gemma-2b for {args.steps} steps ...")
params, losses = train(
    "gemma-2b", num_steps=args.steps, batch=args.batch, seq=args.seq, lr=1e-3,
    log_every=max(1, args.steps // 5),
)
cfg = get_config("gemma-2b", reduced=True)
V, d = cfg.vocab_size, cfg.d_model
print(f"loss {losses[0]:.3f} -> {losses[-1]:.3f}\n")

# --- 2. the trained model, behind the one Extractor protocol ------------
# pooling="tokens": one feature row per position, class = next-token id
ext = ModelExtractor(cfg, pooling="tokens", params=params)

# --- 3. four "clients", each a stream of RAW token batches --------------
num_clients = 4
corpus = synthetic_corpus(V, 200_000, seed=1)
shards = np.array_split(corpus, num_clients)
clients = []
for i, shard in enumerate(shards):
    stream = iter(TokenStream(shard, batch=8, seq_len=args.seq, seed=i))
    clients.append([next(stream) for _ in range(4)])

# --- 4. one secure FedCGS round: stream extractor-forward → fold --------
pipe = StatsPipeline(V, extractor=ext, privacy="secure")
agg = pipe.from_cohort(clients)
print(f"{int(jnp.sum(agg.N))} token statistics captured across {num_clients} clients")
head = gnb_head(derive_global(agg))

# --- 5. evaluate both heads on held-out text ----------------------------
stream = iter(TokenStream(corpus, batch=16, seq_len=args.seq, seed=999))
tokens, targets = next(stream)
feats = ext.features(jnp.asarray(tokens))
tgt = token_labels(jnp.asarray(targets))

stats_acc = float(head.accuracy(feats, tgt))
hidden, _ = T.forward(params, cfg, jnp.asarray(tokens))
logits = T.unembed(params, cfg, hidden)
trained_acc = float(jnp.mean((jnp.argmax(logits, -1).reshape(-1) == tgt)))
print(f"\ntrained unembedding head : next-token acc {trained_acc:.4f}")
print(f"FedCGS stats head        : next-token acc {stats_acc:.4f}")
print(f"uniform-random baseline  : {1.0 / V:.6f}")
print("\nThe stats head was configured WITHOUT any training — one secure")
print("aggregation of (A, B, N) over clients, then w_j = Σ⁻¹μ_j.")
