"""Paper Fig. 2 analogue: classifier-head configurations.

- Centralized : linear head trained on ALL raw features (upper bound)
- Linear      : linear head trained on features SAMPLED from the global
                statistics (the "upper bound of FedPFT")
- GNB (ours)  : the training-free Naive-Bayes head from the same stats
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import Reporter, make_world
from repro.core.classifier import gnb_head
from repro.core.statistics import centralized_statistics
from repro.fl.baselines.fedpft import _train_linear_head


def run(reporter: Reporter, *, quick: bool = False, seed: int = 0) -> None:
    datasets = ["synth10"] if quick else ["synth10", "synth100", "synth-svhn"]
    epochs = 10 if quick else 50
    rng = np.random.default_rng(seed)
    for ds in datasets:
        world = make_world(ds, quick=quick)
        x, y = world.train
        c = world.spec.num_classes
        feats = np.asarray(world.backbone.features(jnp.asarray(x)))
        test_feats = world.backbone.features(jnp.asarray(world.test[0]))
        yt = jnp.asarray(world.test[1])

        # --- Centralized: linear head on raw features
        w, b = _train_linear_head(feats, y, c, epochs=epochs, seed=seed)
        acc = float(jnp.mean((jnp.argmax(test_feats @ w + b, -1) == yt).astype(jnp.float32)))
        reporter.add("fig2", ds, "Centralized-linear", acc)

        # --- global statistics (exact, as FedCGS captures them)
        stats = centralized_statistics(jnp.asarray(feats), jnp.asarray(y), c)

        # --- Linear: head trained on stats-sampled synthetic features
        cov = np.asarray(stats.sigma) + 1e-4 * np.eye(stats.feature_dim)
        chol = np.linalg.cholesky(cov)
        synth_x, synth_y = [], []
        for cls in range(c):
            n_cls = int(stats.counts[cls])
            if n_cls < 1:
                continue
            z = rng.standard_normal((n_cls, stats.feature_dim))
            synth_x.append(np.asarray(stats.mu[cls]) + z @ chol.T)
            synth_y.append(np.full(n_cls, cls, dtype=np.int64))
        w, b = _train_linear_head(
            np.concatenate(synth_x), np.concatenate(synth_y), c,
            epochs=epochs, seed=seed,
        )
        acc = float(jnp.mean((jnp.argmax(test_feats @ w + b, -1) == yt).astype(jnp.float32)))
        reporter.add("fig2", ds, "Linear-on-sampled", acc)

        # --- GNB head (ours): training-free
        head = gnb_head(stats)
        acc = float(head.accuracy(test_feats, yt))
        reporter.add("fig2", ds, "GNB-head", acc)
