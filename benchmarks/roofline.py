"""§Roofline aggregation: read the dry-run JSON artifacts and emit the
per-(arch × shape × mesh) roofline table (CSV rows + a markdown file).

The dry-run campaign itself is launched by ``benchmarks/run_dryruns.sh``
(hours of CPU compile time); this module only aggregates what exists.
"""

from __future__ import annotations

import json
import os
from typing import Dict, List

from benchmarks.common import Reporter

ARTIFACT_DIR = os.environ.get("DRYRUN_DIR", "/root/repo/experiments/dryrun")


def kernel_roofline(
    flops: float, hbm_bytes: float, *, collective_bytes: float = 0.0,
    chips: int = 1,
) -> Dict:
    """Roofline position of one kernel measurement (modelled numbers).

    Reuses :class:`repro.launch.hlo_analysis.Roofline` — the same
    machine classification the dry-run artifacts get — so the kernel
    microbenchmarks (``kernel_bench.py``) and the full-model dry-runs
    quote positions on the SAME roofline instead of two drifting ones.
    Adds the arithmetic-intensity view (AI vs the ridge point) the
    kernel table reasons in.
    """
    from repro.launch.hlo_analysis import HBM_BW, PEAK_FLOPS, Roofline

    roof = Roofline(
        hlo_flops=flops, hlo_bytes=hbm_bytes,
        collective_bytes_per_chip=collective_bytes, chips=chips,
    )
    out = roof.as_dict()
    ridge = PEAK_FLOPS / HBM_BW
    ai = flops / hbm_bytes if hbm_bytes else float("inf")
    out["arith_intensity"] = ai
    out["ridge_intensity"] = ridge
    out["compute_bound"] = bool(ai > ridge)
    return out


def load_artifacts(directory: str = ARTIFACT_DIR) -> List[Dict]:
    out = []
    if not os.path.isdir(directory):
        return out
    for fname in sorted(os.listdir(directory)):
        if fname.endswith(".json"):
            with open(os.path.join(directory, fname)) as f:
                out.append(json.load(f))
    return out


def markdown_table(arts: List[Dict]) -> str:
    lines = [
        "| arch | shape | mesh | compute_s | memory_s | collective_s | "
        "dominant | useful_flops | HBM/chip GB |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for a in arts:
        if a.get("skipped"):
            lines.append(
                f"| {a['arch']} | {a['shape']} | - | - | - | - | SKIP | - | - |"
            )
            continue
        r = a["roofline"]
        mem_gb = (
            a["memory"].get("argument_size_in_bytes", 0)
            + a["memory"].get("temp_size_in_bytes", 0)
        ) / 1e9
        uf = r.get("useful_flops_ratio")
        lines.append(
            f"| {a['arch']} | {a['shape']} | {a['mesh']} "
            f"| {r['compute_s']:.3g} | {r['memory_s']:.3g} "
            f"| {r['collective_s']:.3g} | {r['dominant']} "
            f"| {uf:.2f} | {mem_gb:.1f} |"
            if uf is not None
            else f"| {a['arch']} | {a['shape']} | {a['mesh']} | - | - | - | ? | - | - |"
        )
    return "\n".join(lines)


def run(reporter: Reporter, *, quick: bool = False, seed: int = 0) -> None:
    arts = load_artifacts()
    if not arts:
        reporter.add("roofline", "artifacts", "count", 0)
        print("# roofline: no dry-run artifacts found "
              f"(run benchmarks/run_dryruns.sh first; looked in {ARTIFACT_DIR})")
        return
    reporter.add("roofline", "artifacts", "count", len(arts))
    for a in arts:
        if a.get("skipped"):
            continue
        r = a["roofline"]
        tag = f"{a['arch']}|{a['shape']}|{a['mesh']}"
        reporter.add("roofline", tag, "compute_s", r["compute_s"])
        reporter.add("roofline", tag, "memory_s", r["memory_s"])
        reporter.add("roofline", tag, "collective_s", r["collective_s"])
        dom = {"compute": 0, "memory": 1, "collective": 2}[r["dominant"]]
        reporter.add("roofline", tag, "dominant_code", dom)
        if r.get("useful_flops_ratio") is not None:
            reporter.add("roofline", tag, "useful_flops", r["useful_flops_ratio"])
    md = markdown_table(arts)
    out_path = os.path.join(os.path.dirname(ARTIFACT_DIR), "roofline_table.md")
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        f.write(md + "\n")
    print(f"# roofline table -> {out_path}")

    # --- baseline vs beyond-paper optimized (if the opt campaign ran) ---
    opt_dir = ARTIFACT_DIR.rstrip("/") + "_opt"
    opts = {(a["arch"], a["shape"], a.get("kind")): a for a in load_artifacts(opt_dir)}
    if opts:
        lines = [
            "| arch | shape | step | base max-term (s) | opt max-term (s) | delta |",
            "|---|---|---|---|---|---|",
        ]
        for a in arts:
            key = (a["arch"], a["shape"], a.get("kind"))
            o = opts.get(key)
            if a.get("skipped") or o is None or o.get("skipped"):
                continue
            rb, ro = a["roofline"], o["roofline"]
            mb = max(rb["compute_s"], rb["memory_s"], rb["collective_s"])
            mo = max(ro["compute_s"], ro["memory_s"], ro["collective_s"])
            delta = (mo - mb) / mb * 100.0
            lines.append(
                f"| {a['arch']} | {a['shape']} | {a.get('kind')} "
                f"| {mb:.3g} | {mo:.3g} | {delta:+.0f}% |"
            )
            reporter.add(
                "roofline_opt", f"{a['arch']}|{a['shape']}|{a.get('kind')}",
                "max_term_delta_pct", delta,
            )
        cmp_path = os.path.join(os.path.dirname(ARTIFACT_DIR), "roofline_opt_compare.md")
        with open(cmp_path, "w") as f:
            f.write("\n".join(lines) + "\n")
        print(f"# baseline-vs-optimized -> {cmp_path}")
