"""Serving benchmark: open-loop Poisson traffic against the GNBServer.

Synthetic clients fire ragged scoring requests at the dynamic-batching
server (``repro.serve``) with exponential inter-arrival gaps — OPEN
loop, arrivals don't wait for completions, which is what exposes the
batcher's latency/throughput trade-off: at low rates ticks fire on the
``max_delay_s`` clock with near-empty batches (latency ≈ the delay
bound, pad waste high), at high rates batches fill to
``max_batch_rows`` and throughput climbs while queueing delay takes
over.  Each rate emits p50/p95/p99 latency, achieved throughput,
batch occupancy, pad waste, and the rejected-request count
(backpressure) — the curve lands in ``serve_bench.json`` next to the
kernel numbers (CI uploads both).

The kernel traces for the padded shapes are warmed before traffic
starts, so the curve measures the steady-state serving loop rather
than jit compiles.

Standalone:  PYTHONPATH=src python -m benchmarks.serve_bench [--smoke]

``--smoke`` (what CI runs on every push) is one low rate with a
handful of requests — a regression tripwire for the subsystem plus the
JSON emission, not a measurement.
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np

from benchmarks.common import Reporter
from repro.core.classifier import LinearHead
from repro.launch.serve_gnb import standin_head
from repro.serve import GNBServer, QueueFull
from repro.serve.batcher import pad_rows_to


def _warm_traces(server: GNBServer, head: LinearHead) -> None:
    """Compile EVERY padded-shape trace the traffic can hit.

    Batches pad to multiples of ``row_multiple`` up to ``max_batch_rows``
    (requests here are far smaller than a batch, so no oversized
    batches occur); warming each multiple keeps first-hit jit compiles
    out of the measured latencies.
    """
    from repro.serve.scoring import score_features

    mult = server.batcher.row_multiple
    for r in range(mult, server.batcher.max_batch_rows + 1, mult):
        f = np.zeros((r, server.batcher.feature_dim), np.float32)
        np.asarray(score_features(
            pad_rows_to(f, mult), head.W, head.b,
            mesh=server.mesh, client_axes=server.client_axes,
            interpret=server.interpret,
        ))


def drive_rate(
    rate_rps: float,
    n_requests: int,
    *,
    mean_rows: int,
    feature_dim: int,
    classes: int,
    seed: int,
    max_batch_rows: int = 1024,
    max_delay_s: float = 2e-3,
    max_queue_rows: int = 16384,
    timeout_s: float = 120.0,
) -> dict:
    """One point of the curve: Poisson arrivals at ``rate_rps``."""
    rng = np.random.default_rng(seed)
    head = standin_head(classes, feature_dim, seed)
    sizes = np.clip(rng.poisson(mean_rows, n_requests), 1, None).astype(int)
    gaps = rng.exponential(1.0 / rate_rps, n_requests)
    requests = [
        rng.standard_normal((n, feature_dim)).astype(np.float32) for n in sizes
    ]
    server = GNBServer(
        head,
        max_batch_rows=max_batch_rows,
        max_delay_s=max_delay_s,
        max_queue_rows=max_queue_rows,
    )
    _warm_traces(server, head)
    rejected = 0
    with server:
        futures = []
        for req, gap in zip(requests, gaps):
            time.sleep(gap)
            try:
                futures.append(server.submit(req))
            except QueueFull:
                rejected += 1
        for f in futures:
            f.result(timeout=timeout_s)
        server.drain()
        snap = server.metrics.snapshot()
    return {
        "offered_rate_rps": rate_rps,
        "requests": n_requests,
        "mean_rows": mean_rows,
        "rejected": rejected,
        **{
            k: snap[k]
            for k in (
                "latency_p50_ms", "latency_p95_ms", "latency_p99_ms",
                "throughput_rps", "throughput_rows_s",
                "batch_occupancy", "pad_waste_frac", "batches",
            )
        },
    }


def run(
    reporter: Reporter,
    *,
    quick: bool = False,
    seed: int = 0,
    json_path: str | None = "serve_bench.json",
    smoke: bool = False,
) -> None:
    feature_dim, classes, mean_rows = 64, 10, 64
    if smoke:
        points = [(100.0, 24)]
    elif quick:
        points = [(100.0, 64), (400.0, 64)]
    else:
        points = [(50.0, 128), (200.0, 128), (800.0, 256)]
    results = []
    for rate, n_requests in points:
        row = drive_rate(
            rate, n_requests,
            mean_rows=mean_rows, feature_dim=feature_dim, classes=classes,
            seed=seed,
        )
        results.append(row)
        tag = f"rate{rate:g}|req{n_requests}|rows{mean_rows}"
        for metric in (
            "latency_p50_ms", "latency_p95_ms", "latency_p99_ms",
            "throughput_rps", "batch_occupancy", "pad_waste_frac",
        ):
            reporter.add("serve", tag, metric, row[metric])
        reporter.add("serve", tag, "rejected", row["rejected"])
    if json_path:
        with open(json_path, "w") as fh:
            json.dump(
                {
                    "config": {
                        "feature_dim": feature_dim,
                        "classes": classes,
                        "mean_rows": mean_rows,
                        "mode": "smoke" if smoke else ("quick" if quick else "full"),
                    },
                    "traffic": results,
                },
                fh,
                indent=2,
            )
        print(f"# wrote {json_path} ({len(results)} rates)")


if __name__ == "__main__":
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument(
        "--smoke", action="store_true",
        help="one rate, few requests — CI's regression tripwire",
    )
    p.add_argument("--quick", action="store_true", help="reduced rate sweep")
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args()
    run(Reporter(), quick=args.quick, seed=args.seed, smoke=args.smoke)
