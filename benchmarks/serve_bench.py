"""Serving benchmark: traffic curves against the bucketed serving tier.

Three workloads, one JSON artifact (CI uploads it):

- **poisson**: open-loop Poisson traffic against a single
  ``GNBServer`` — arrivals don't wait for completions, which exposes
  the batcher's latency/throughput trade-off (low rates tick on the
  ``max_delay_s`` clock, high rates fill batches);
- **burst**: the mixed-size efficiency point — a back-to-back ragged
  mix spanning several pow2 shape buckets.  This is the pad-waste /
  occupancy headline for shape-bucketed batching: requests coalesce
  toward full batches and pad only to their bucket target, where the
  old pad-to-one-shape batcher burned >70% of its kernel rows on
  zeros at the same mix;
- **shed curve**: offered load swept across decades of rows/s through
  a multi-worker :class:`~repro.serve.front.ServeFront` with tight
  queue bounds — past saturation the tier degrades into a measured
  shed ratio with bounded p99, not unbounded queueing delay.

Kernel traces for every padded shape normal traffic can produce
(``batcher.pad_targets()``) are warmed before measuring, so the curves
see the steady-state loop rather than jit compiles.  Pass
``--tune-cache`` to dispatch through a measured autotune cache (CI
feeds it the tune smoke's artifact); untuned runs use the built-in
heuristics.

Standalone:  PYTHONPATH=src python -m benchmarks.serve_bench [--smoke]

``--smoke`` (what CI runs on every push) shrinks every workload to a
regression tripwire plus the JSON emission, not a measurement.
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np

from benchmarks.common import Reporter
from repro.core.classifier import LinearHead
from repro.launch.serve_gnb import standin_head
from repro.serve import GNBServer, QueueFull, ServeFront

_CURVE_METRICS = (
    "latency_p50_ms", "latency_p95_ms", "latency_p99_ms",
    "throughput_rps", "throughput_rows_s",
    "batch_occupancy", "pad_waste_frac", "batches",
)


def _warm_traces(server: GNBServer, head: LinearHead) -> None:
    """Compile every padded-shape trace normal traffic can hit.

    The bucketed batcher's pad shapes are enumerable up front
    (``pad_targets()`` — O(log max_rows) of them), so first-hit jit
    compiles stay out of the measured latencies.
    """
    from repro.serve.scoring import score_features

    for rows in server.batcher.pad_targets():
        f = np.zeros((rows, server.batcher.feature_dim), np.float32)
        np.asarray(score_features(
            f, head.W, head.b,
            mesh=server.mesh, client_axes=server.client_axes,
            interpret=server.interpret,
        ))


def _ragged_sizes(rng, n_requests: int, mean_rows: int) -> np.ndarray:
    """A bucket-spanning ragged mix: geometric spread around the mean,
    clipped to [1, 4*mean] — tiny probes next to near-batch requests."""
    raw = rng.lognormal(np.log(mean_rows), 0.9, n_requests)
    return np.clip(raw, 1, 4 * mean_rows).astype(int)


def _paced_submit(submit, requests, gaps) -> int:
    """Open-loop pacing; returns the rejected/shed request count.

    Sub-millisecond gaps are accumulated instead of slept — at offered
    loads past ~10^5 rows/s the scheduler can't honour them and the
    sleep overhead itself would throttle the offered rate.
    """
    rejected = 0
    owed = 0.0
    for req, gap in zip(requests, gaps):
        owed += gap
        if owed >= 1e-3:
            time.sleep(owed)
            owed = 0.0
        try:
            submit(req)
        except QueueFull:
            rejected += 1
    return rejected


def drive_rate(
    rate_rps: float,
    n_requests: int,
    *,
    mean_rows: int,
    feature_dim: int,
    classes: int,
    seed: int,
    burst: bool = False,
    max_batch_rows: int = 1024,
    max_delay_s: float = 2e-3,
    max_queue_rows: int = 16384,
    timeout_s: float = 120.0,
) -> dict:
    """One single-server point: Poisson arrivals (or a burst) of a
    bucket-spanning ragged mix."""
    rng = np.random.default_rng(seed)
    head = standin_head(classes, feature_dim, seed)
    sizes = _ragged_sizes(rng, n_requests, mean_rows)
    gaps = (
        np.zeros(n_requests)
        if burst
        else rng.exponential(1.0 / rate_rps, n_requests)
    )
    requests = [
        rng.standard_normal((n, feature_dim)).astype(np.float32) for n in sizes
    ]
    server = GNBServer(
        head,
        max_batch_rows=max_batch_rows,
        max_delay_s=max_delay_s,
        max_queue_rows=max_queue_rows,
    )
    _warm_traces(server, head)
    futures = []
    with server:
        rejected = _paced_submit(
            lambda r: futures.append(server.submit(r)), requests, gaps
        )
        for f in futures:
            f.result(timeout=timeout_s)
        server.drain()
        snap = server.metrics.snapshot()
    return {
        "workload": "burst" if burst else "poisson",
        "offered_rate_rps": None if burst else rate_rps,
        "requests": n_requests,
        "mean_rows": mean_rows,
        "offered_rows": int(sizes.sum()),
        "rejected": rejected,
        **{k: snap[k] for k in _CURVE_METRICS},
    }


def drive_shed_point(
    offered_rows_s: float,
    n_requests: int,
    *,
    mean_rows: int,
    feature_dim: int,
    classes: int,
    seed: int,
    workers: int = 2,
    max_batch_rows: int = 1024,
    max_delay_s: float = 2e-3,
    max_queue_rows: int = 2048,
    timeout_s: float = 120.0,
) -> dict:
    """One front point: offered load in rows/s against N workers with
    TIGHT queue bounds, so saturation surfaces as shed ratio + p99."""
    rng = np.random.default_rng(seed)
    head = standin_head(classes, feature_dim, seed)
    sizes = _ragged_sizes(rng, n_requests, mean_rows)
    rate_rps = offered_rows_s / mean_rows
    gaps = rng.exponential(1.0 / rate_rps, n_requests)
    requests = [
        rng.standard_normal((n, feature_dim)).astype(np.float32) for n in sizes
    ]
    front = ServeFront.create(
        workers,
        head=head,
        max_batch_rows=max_batch_rows,
        max_delay_s=max_delay_s,
        max_queue_rows=max_queue_rows,
    )
    _warm_traces(front.workers[0], head)
    futures = []
    with front:
        _paced_submit(
            lambda r: futures.append(front.submit(r)), requests, gaps
        )
        for f in futures:
            f.result(timeout=timeout_s)
        front.drain(timeout=timeout_s)
        snap = front.snapshot()
    agg = snap["aggregate"]
    return {
        "offered_rows_s": offered_rows_s,
        "requests": n_requests,
        "mean_rows": mean_rows,
        "workers": workers,
        "accepted": snap["front"]["accepted"],
        "shed": snap["front"]["shed"],
        "shed_ratio": snap["front"]["shed_ratio"],
        "latency_p99_ms": agg["latency_p99_ms"],
        "throughput_rows_s": sum(
            w["throughput_rows_s"] for w in snap["workers"]
            if w["throughput_rows_s"] == w["throughput_rows_s"]
        ),
        "pad_waste_frac": agg["pad_waste_frac"],
    }


def obs_overhead_point(
    burst_requests: int,
    *,
    mean_rows: int,
    feature_dim: int,
    classes: int,
    seed: int,
) -> dict:
    """The tracing-overhead point: the same burst workload with tracing
    off (best of two, damping run-to-run noise) and with tracing ON.

    The off point is what the CI gate holds within 2% of the same run's
    burst baseline — a same-host, same-process comparison, so the
    assert doesn't encode one machine's absolute throughput.  The on
    point quantifies what full span collection costs and is reported,
    not gated (it pays for span objects, clock reads and ring-buffer
    appends on every request by design).
    """
    from repro.obs import trace

    was_enabled = trace.enabled()
    kw = dict(mean_rows=mean_rows, feature_dim=feature_dim,
              classes=classes, seed=seed, burst=True)
    trace.disable()
    off = max(
        drive_rate(0.0, burst_requests, **kw)["throughput_rows_s"]
        for _ in range(2)
    )
    trace.enable()
    on = drive_rate(0.0, burst_requests, **kw)["throughput_rows_s"]
    trace.reset()
    if not was_enabled:
        trace.disable()
    return {
        "tracing_off_rows_s": off,
        "tracing_on_rows_s": on,
        "enabled_overhead_frac": (
            1.0 - on / off if off else float("nan")
        ),
    }


def run(
    reporter: Reporter,
    *,
    quick: bool = False,
    seed: int = 0,
    json_path: str | None = "serve_bench.json",
    smoke: bool = False,
    tune_cache: str | None = None,
) -> None:
    if tune_cache:
        from repro import tune

        tune.set_cache(tune.TuneCache.load(tune_cache))
    feature_dim, classes, mean_rows = 64, 10, 64
    if smoke:
        poisson_points = [(100.0, 24)]
        burst_requests = 150
        shed_points = [(1e4, 60), (1e5, 90), (1e6, 120)]
    elif quick:
        poisson_points = [(100.0, 64), (400.0, 64)]
        burst_requests = 250
        shed_points = [(1e4, 120), (1e5, 180), (1e6, 240)]
    else:
        poisson_points = [(50.0, 128), (200.0, 128), (800.0, 256)]
        burst_requests = 600
        shed_points = [(1e4, 200), (3e4, 200), (1e5, 300), (3e5, 300),
                       (1e6, 400)]
    results = []
    for rate, n_requests in poisson_points:
        row = drive_rate(
            rate, n_requests,
            mean_rows=mean_rows, feature_dim=feature_dim, classes=classes,
            seed=seed,
        )
        results.append(row)
        tag = f"poisson|rate{rate:g}|req{n_requests}"
        for metric in _CURVE_METRICS[:7]:
            reporter.add("serve", tag, metric, row[metric])
        reporter.add("serve", tag, "rejected", row["rejected"])

    # the mixed-size efficiency headline for shape-bucketed batching
    burst = drive_rate(
        0.0, burst_requests,
        mean_rows=mean_rows, feature_dim=feature_dim, classes=classes,
        seed=seed, burst=True,
    )
    results.append(burst)
    for metric in ("batch_occupancy", "pad_waste_frac", "throughput_rows_s",
                   "latency_p99_ms"):
        reporter.add("serve", f"burst|req{burst_requests}", metric,
                     burst[metric])

    # tracing overhead: disabled must ride within 2% of the same-run
    # burst baseline (the CI gate reads these back out of the JSON)
    obs = obs_overhead_point(
        burst_requests,
        mean_rows=mean_rows, feature_dim=feature_dim, classes=classes,
        seed=seed,
    )
    obs["burst_rows_s"] = burst["throughput_rows_s"]
    obs["off_within_2pct"] = bool(
        obs["tracing_off_rows_s"] >= 0.98 * obs["burst_rows_s"]
    )
    for metric in ("tracing_off_rows_s", "tracing_on_rows_s",
                   "enabled_overhead_frac"):
        reporter.add("serve", "obs_overhead", metric, obs[metric])

    shed_curve = []
    for offered, n_requests in shed_points:
        point = drive_shed_point(
            offered, n_requests,
            mean_rows=mean_rows, feature_dim=feature_dim, classes=classes,
            seed=seed,
        )
        shed_curve.append(point)
        tag = f"front|offered{offered:g}rows_s"
        for metric in ("shed_ratio", "latency_p99_ms", "throughput_rows_s"):
            reporter.add("serve", tag, metric, point[metric])

    if json_path:
        with open(json_path, "w") as fh:
            json.dump(
                {
                    "config": {
                        "feature_dim": feature_dim,
                        "classes": classes,
                        "mean_rows": mean_rows,
                        "tune_cache": tune_cache,
                        "mode": "smoke" if smoke else ("quick" if quick else "full"),
                    },
                    "traffic": results,
                    "obs_overhead": obs,
                    "shed_curve": shed_curve,
                },
                fh,
                indent=2,
            )
        print(f"# wrote {json_path} "
              f"({len(results)} traffic points, {len(shed_curve)} shed points)")


if __name__ == "__main__":
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument(
        "--smoke", action="store_true",
        help="shrunken workloads — CI's regression tripwire",
    )
    p.add_argument("--quick", action="store_true", help="reduced sweep")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--tune-cache", default=None,
                   help="autotune cache JSON to dispatch through")
    args = p.parse_args()
    run(Reporter(), quick=args.quick, seed=args.seed, smoke=args.smoke,
        tune_cache=args.tune_cache)
