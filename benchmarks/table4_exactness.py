"""Paper Table 4 (+ appendix Tables 7-8): Δμ / ΔΣ between FedCGS output
and the centralized ground truth, vs M ∈ {10, 50} and α ∈ {0.05, 0.1, 0.5}.

This is the one experiment quantitatively comparable to the paper — it is
dataset-independent float algebra; the paper reports 1e-7…1e-5.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import Reporter, make_world
from repro.core.classifier import gnb_head
from repro.core.statistics import (
    aggregate,
    centralized_statistics,
    derive_global,
    statistics_deviation,
)
from repro.data import dirichlet_partition
from repro.fl.fedcgs import client_stats_pass


def run(reporter: Reporter, *, quick: bool = False, seed: int = 0) -> None:
    datasets = ["synth10"] if quick else ["synth10", "synth100", "synth-svhn"]
    for ds in datasets:
        world = make_world(ds, quick=quick)
        x, y = world.train
        c = world.spec.num_classes
        feats = world.backbone.features(jnp.asarray(x))
        ref = centralized_statistics(feats, jnp.asarray(y), c)
        ref_head = gnb_head(ref)
        test_feats = world.backbone.features(jnp.asarray(world.test[0]))
        ref_acc = float(ref_head.accuracy(test_feats, jnp.asarray(world.test[1])))

        for m in (10, 50):
            for alpha in (0.05, 0.1, 0.5):
                parts = dirichlet_partition(y, m, alpha, seed=seed)
                agg = aggregate(
                    client_stats_pass(world.backbone, x[p], y[p], c) for p in parts
                )
                ours = derive_global(agg)
                dmu, dsig = statistics_deviation(ours, ref)
                tag = f"{ds}|M{m}|a{alpha}"
                reporter.add("table4", tag, "delta_mu", float(dmu))
                reporter.add("table4", tag, "delta_sigma", float(dsig))
                head = gnb_head(ours)
                acc = float(head.accuracy(test_feats, jnp.asarray(world.test[1])))
                reporter.add("table4", tag, "acc", acc)
                reporter.add("table4", tag, "acc_drift_vs_central", abs(acc - ref_acc))
