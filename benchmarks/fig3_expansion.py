"""Paper Fig. 3 analogue: feature expansion vs accuracy + comm overhead."""

from __future__ import annotations

from benchmarks.common import Reporter, make_world
from repro.core.expansion import FeatureExpansion
from repro.data import dirichlet_partition
from repro.fl.fedcgs import run_fedcgs


def run(reporter: Reporter, *, quick: bool = False, seed: int = 0) -> None:
    datasets = ["synth10"] if quick else ["synth10", "synth100"]
    dims = (0, 128, 512) if quick else (0, 128, 256, 512, 1024)
    for ds in datasets:
        world = make_world(ds, quick=quick)
        x, y = world.train
        c = world.spec.num_classes
        parts = dirichlet_partition(y, 10, 0.1, seed=seed)
        clients = [(x[p], y[p]) for p in parts]
        for dim in dims:
            exp = (
                None
                if dim == 0
                else FeatureExpansion(
                    in_dim=world.backbone.feature_dim, out_dim=dim, seed=seed
                )
            )
            res = run_fedcgs(
                world.backbone, clients, c, test_data=world.test, expansion=exp
            )
            reporter.add("fig3", f"{ds}|d+{dim}", "acc", res.accuracy)
            reporter.add(
                "fig3", f"{ds}|d+{dim}", "upload_floats",
                res.uploaded_floats_per_client,
            )
