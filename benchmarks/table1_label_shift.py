"""Paper Table 1 analogue: global one-shot FL under label shift.

Methods: FedAvg (one-shot), Ensemble, DENSE, Co-Boosting, FedPFT, FedCGS
at α ∈ {0.05, 0.1, 0.5} on the synthetic CIFAR10/CIFAR100/SVHN stand-ins.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import Reporter, make_world
from repro.data import dirichlet_partition
from repro.fl.baselines import (
    run_dense,
    run_ensemble,
    run_fedavg_oneshot,
    run_fedpft,
)
from repro.fl.baselines.dense_kd import run_co_boosting
from repro.fl.fedcgs import run_fedcgs

ALPHAS = (0.05, 0.1, 0.5)


def run(reporter: Reporter, *, quick: bool = False, seed: int = 0) -> None:
    datasets = ["synth10"] if quick else ["synth10", "synth100", "synth-svhn"]
    epochs = 10 if quick else 30
    num_clients = 10
    for ds in datasets:
        world = make_world(ds, quick=quick)
        x, y = world.train
        c = world.spec.num_classes
        for alpha in ALPHAS:
            parts = dirichlet_partition(y, num_clients, alpha, seed=seed)
            clients = [(x[p], y[p]) for p in parts]
            tag = f"{ds}|a{alpha}"

            acc = run_fedcgs(
                world.backbone, clients, c, test_data=world.test
            ).accuracy
            reporter.add("table1", tag, "FedCGS", acc)

            acc = run_fedavg_oneshot(
                world.backbone, clients, c, world.test, epochs=epochs, seed=seed
            )
            reporter.add("table1", tag, "FedAvg-oneshot", acc)

            acc = run_ensemble(
                world.backbone, clients, c, world.test, epochs=epochs, seed=seed
            )
            reporter.add("table1", tag, "Ensemble", acc)

            acc = run_fedpft(
                world.backbone, clients, c, world.test,
                k_components=10, epochs=epochs, seed=seed,
            )
            reporter.add("table1", tag, "FedPFT", acc)

            if not quick:
                acc = run_dense(
                    world.backbone, clients, c, world.test,
                    local_epochs=epochs, gen_epochs=20, distill_epochs=30,
                    seed=seed,
                )
                reporter.add("table1", tag, "DENSE", acc)
                acc = run_co_boosting(
                    world.backbone, clients, c, world.test,
                    local_epochs=epochs, gen_epochs=20, distill_epochs=30,
                    seed=seed,
                )
                reporter.add("table1", tag, "Co-Boosting", acc)
