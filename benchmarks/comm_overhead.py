"""Paper §Communication Overhead: uploaded floats per client per method."""

from __future__ import annotations

from benchmarks.common import Reporter, make_world
from repro.fl.baselines.ccvr import ccvr_upload_floats
from repro.fl.baselines.fedpft import fedpft_upload_floats
from repro.fl.trainer import ClassifierModel


def run(reporter: Reporter, *, quick: bool = False, seed: int = 0) -> None:
    import jax

    world = make_world("synth10", quick=True)
    c = world.spec.num_classes
    d = world.backbone.feature_dim
    model = ClassifierModel(backbone=world.backbone, num_classes=c)
    theta = sum(
        x.size for x in jax.tree_util.tree_leaves(model.init(0))
    )
    reporter.add("comm", f"C{c}|d{d}", "FedAvg/DENSE/Co-Boosting(|theta|)", theta)
    reporter.add("comm", f"C{c}|d{d}", "FedPFT((2d+1)KgC)", fedpft_upload_floats(d, 10, c))
    reporter.add("comm", f"C{c}|d{d}", "CCVR(C(d^2+d+1))", ccvr_upload_floats(d, c))
    reporter.add("comm", f"C{c}|d{d}", "FedCGS((C+d)d+C)", (c + d) * d + c)

    # the paper's own example: ResNet18 (d=512) on CIFAR10
    d, c, theta_resnet18 = 512, 10, 11_181_642
    reporter.add("comm", "paper|resnet18|cifar10", "FedAvg(|theta|)", theta_resnet18)
    reporter.add(
        "comm", "paper|resnet18|cifar10", "FedPFT", fedpft_upload_floats(d, 10, c)
    )
    reporter.add("comm", "paper|resnet18|cifar10", "FedCGS", (c + d) * d + c)
    reporter.add("comm", "paper|resnet18|cifar10", "CCVR", ccvr_upload_floats(d, c))
