"""Benchmark driver — one module per paper table/figure.

Prints ``bench,config,metric,value`` CSV rows (captured by
``python -m benchmarks.run | tee bench_output.txt``).

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only table1,...]
"""

from __future__ import annotations

import argparse
import sys

from benchmarks.common import Reporter
from repro.serve.metrics import timed

MODULES = [
    "table1_label_shift",
    "table2_feature_shift",
    "table3_personalized",
    "table4_exactness",
    "fig2_head_configs",
    "fig3_expansion",
    "comm_overhead",
    "ablation_secureagg",
    "kernel_bench",
    "serve_bench",
    "extract_bench",
    "roofline",
]


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--quick", action="store_true", help="reduced sizes/epochs")
    p.add_argument("--only", default=None, help="comma-separated module subset")
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)

    mods = MODULES if args.only is None else [
        m for m in MODULES if any(m.startswith(o) for o in args.only.split(","))
    ]
    reporter = Reporter()
    print("bench,config,metric,value")
    failures = []
    for name in mods:
        print(f"# === {name} ===", flush=True)
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["run"])
            _, dt = timed(mod.run, reporter, quick=args.quick, seed=args.seed)
            print(f"# {name} done in {dt:.1f}s", flush=True)
        except Exception as e:  # noqa: BLE001
            failures.append((name, repr(e)))
            print(f"# FAILED {name}: {e!r}", flush=True)
    if failures:
        print(f"# {len(failures)} benchmark module(s) failed")
        return 1
    print("# all benchmarks completed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
