"""Paper Table 3 analogue: personalized one-shot FL.

Local-only / FedAvg / FedAvg-FT / FedProto / FedCGS-personalized on the
dominant-class split (20% uniform), per-client test sets drawn from each
client's own label distribution.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import Reporter, make_world
from repro.data import dominant_class_partition
from repro.fl.baselines import (
    run_fedavg_ft,
    run_fedavg_multiround,
    run_fedproto,
    run_local_only,
)
from repro.fl.fedcgs import run_fedcgs_personalized


def _client_testsets(xt, yt, parts_labels, seed=0):
    """Per-client test sets matching each client's label distribution."""
    rng = np.random.default_rng(seed)
    out = []
    for labels in parts_labels:
        probs = np.bincount(labels, minlength=yt.max() + 1).astype(float)
        probs /= probs.sum()
        weights = probs[yt]
        weights /= weights.sum()
        idx = rng.choice(len(yt), size=min(500, len(yt)), p=weights, replace=False)
        out.append((xt[idx], yt[idx]))
    return out


def run(reporter: Reporter, *, quick: bool = False, seed: int = 0) -> None:
    world = make_world("synth10", quick=quick)
    x, y = world.train
    xt, yt = world.test
    c = world.spec.num_classes
    m = 5 if quick else 10
    parts = dominant_class_partition(y, m, uniform_fraction=0.2, seed=seed)
    clients = [(x[p], y[p]) for p in parts]
    tests = _client_testsets(xt, yt, [y[p] for p in parts], seed=seed)

    rounds = 10 if quick else 50
    local_epochs = 30 if quick else 100

    accs = run_local_only(
        world.backbone, clients, tests, c, epochs=local_epochs, seed=seed
    )
    reporter.add("table3", "synth10", "Local-only", float(np.mean(accs)))

    acc_global, model, gparams = run_fedavg_multiround(
        world.backbone, clients, c, world.test, rounds=rounds, seed=seed,
        return_params=True,
    )
    import jax.numpy as jnp

    per_client = [
        model.accuracy(gparams, jnp.asarray(tx), jnp.asarray(ty))
        for tx, ty in tests
    ]
    reporter.add("table3", "synth10", "FedAvg", float(np.mean(per_client)))

    accs = run_fedavg_ft(
        world.backbone, clients, tests, c, rounds=rounds, ft_epochs=10, seed=seed
    )
    reporter.add("table3", "synth10", "FedAvg-FT", float(np.mean(accs)))

    accs = run_fedproto(
        world.backbone, clients, tests, c, rounds=rounds, proto_lambda=1.0,
        seed=seed,
    )
    reporter.add("table3", "synth10", "FedProto", float(np.mean(accs)))

    accs, _ = run_fedcgs_personalized(
        world.backbone, clients, tests, c,
        proto_lambda=1.0, epochs=local_epochs, lr=0.05, seed=seed,
    )
    reporter.add("table3", "synth10", "FedCGS", float(np.mean(accs)))
