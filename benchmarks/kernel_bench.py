"""Kernel microbenchmarks: Pallas (interpret) correctness-at-scale sweep
and jnp-oracle wall time, plus the kernels' arithmetic intensities for
the TPU roofline (compute-bound vs memory-bound classification)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Reporter
from repro.kernels import ref
from repro.launch.hlo_analysis import HBM_BW, PEAK_FLOPS


def _bench(fn, *args, iters=5):
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters


def run(reporter: Reporter, *, quick: bool = False, seed: int = 0) -> None:
    shapes = [(4096, 512, 100)] if quick else [(4096, 512, 100), (16384, 1024, 1000)]
    for n, d, c in shapes:
        k1, k2 = jax.random.split(jax.random.key(seed))
        f = jax.random.normal(k1, (n, d))
        y = jax.random.randint(k2, (n,), 0, c)
        tag = f"n{n}|d{d}|C{c}"

        # oracle wall time on CPU (the TPU kernel itself can't be timed here)
        jitted = jax.jit(lambda f, y: ref.client_stats_ref(f, y, c))
        us = _bench(jitted, f, y) * 1e6
        reporter.add("kernels", tag, "stats_oracle_us", us)

        # arithmetic intensity of the Gram kernel: 2nd²  /  (nd + d²) * 4B
        flops = 2.0 * n * d * d + 2.0 * n * c * d
        bytes_ = 4.0 * (n * d + d * d + c * d)
        ai = flops / bytes_
        reporter.add("kernels", tag, "stats_flops", flops)
        reporter.add("kernels", tag, "stats_arith_intensity", ai)
        # TPU v5e ridge point: compute-bound iff AI > peak/bw
        ridge = PEAK_FLOPS / HBM_BW
        reporter.add("kernels", tag, "stats_compute_bound", float(ai > ridge))

        # correctness at bench scale (interpret kernel vs oracle)
        from repro.kernels import client_stats

        A, B, N = client_stats(f, y, c)
        A0, B0, N0 = ref.client_stats_ref(f, y, c)
        err = max(
            float(jnp.max(jnp.abs(A - A0))),
            float(jnp.max(jnp.abs(B - B0))),
        )
        reporter.add("kernels", tag, "stats_kernel_max_err", err)
