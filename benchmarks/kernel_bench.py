"""Kernel microbenchmarks: fused single-pass statistics engine vs the
seed's two-kernel path — wall-clock AND modelled HBM traffic — plus the
jnp-oracle time and roofline classification.

The traffic model counts tile loads the pipeline actually issues
(HBM→VMEM), not optimistic reuse: the two-kernel path re-streams the
feature matrix for the Gram sweep, the class-sum sweep, and (in the
seed) materialized an (n, C) one-hot on the host for N.  The fused
engine visits only the upper Gram triangle and folds A, B, N into one
k-sweep.

A second comparison times the STREAMING data path — the
``core.stats_pipeline.StatsPipeline`` batch fold (carry/accumulate
kernel, one jit trace per batch shape) — against the materialized
one-shot sweep on the same data, with a peak-feature-memory model that
shows why streaming is the only option once a client's dataset
outgrows device memory: the materialized path must hold all n rows,
the streaming path holds one batch plus the fixed-size carry.

Besides the CSV rows, ``run`` writes both comparisons to ``json_path``
(default ``kernel_bench.json`` in the CWD — the acceptance artifact,
uploaded by CI; pass ``json_path=None`` to suppress).

Standalone:  PYTHONPATH=src python -m benchmarks.kernel_bench [--smoke]

``--smoke`` (what CI runs on every push) shrinks shapes/iters to keep
the module a regression tripwire rather than a measurement: it still
exercises both kernels, the streaming fold, and the JSON emission, so
a benchmark-path breakage fails CI loudly instead of rotting.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import math

import jax
import jax.numpy as jnp

from benchmarks.common import Reporter
from benchmarks.roofline import kernel_roofline
from repro import tune
from repro.core.stats_pipeline import StatsPipeline
from repro.kernels import client_stats, ref
from repro.launch.hlo_analysis import HBM_BW, PEAK_FLOPS
from repro.serve.metrics import timed


def _bench(fn, *args, iters=3):
    jax.block_until_ready(fn(*args))  # compile + warm

    def loop():
        for _ in range(iters):
            out = fn(*args)
        return jax.block_until_ready(out)

    _, dt = timed(loop)
    return dt / iters


def _interleaved_min(thunks, iters=3):
    """Per-thunk min-of-iters wall seconds, measured ROUND-ROBIN.

    Min, not mean: scheduling noise only ever ADDS time (see
    ``repro.tune._time_best_ms``).  Interleaved, not sequential: host
    load drifts over a long sweep, and timing variant A entirely before
    variant B would charge the drift to whichever ran later — round
    robin spreads it evenly, which is what makes the auto-vs-best ratio
    a dispatch-overhead measurement instead of a drift measurement.
    """

    def once(fn):
        return jax.block_until_ready(jax.tree_util.tree_leaves(fn()))

    for fn in thunks:
        once(fn)  # compile + warm
    best = [math.inf] * len(thunks)
    for _ in range(max(1, iters)):
        for i, fn in enumerate(thunks):
            _, dt = timed(once, fn)
            best[i] = min(best[i], dt)
    return best


def _ceil_div(a, b):
    return -(-a // b)


def stats_flops(n, d, c):
    """2nd² + 2nCd: the Gram sweep plus the class-sum sweep."""
    return 2.0 * n * d * d + 2.0 * n * c * d


def traffic_model_bytes(
    n, d, c, *, fused,
    block_d=tune.DEFAULT_STATS_BLOCK_D, block_n=tune.DEFAULT_STATS_BLOCK_N,
):
    """HBM→VMEM bytes the grid actually streams (f32 features)."""
    t = _ceil_div(d, block_d)          # feature tiles per dim
    ct = _ceil_div(max(c, block_d), block_d)  # class tiles
    n_chunks = _ceil_div(n, block_n)
    feat_tile = block_n * block_d * 4
    label_tile = block_n * 4
    if fused:
        steps = (t * (t + 1)) // 2 + ct * t    # upper gram + class tiles
        in_bytes = steps * n_chunks * (2 * feat_tile + label_tile)
        out_bytes = (d + ct * block_d) * d * 4 + ct * block_d * 4
        return in_bytes + out_bytes
    # seed path: dense gram grid + class-sum grid + host one-hot for N
    gram_in = t * t * n_chunks * 2 * feat_tile
    class_in = ct * t * n_chunks * (feat_tile + label_tile)
    onehot_host = 2 * n * c * 4 + n * 4  # write + reduce-read of (n, C)
    out_bytes = d * d * 4 + ct * block_d * d * 4 + c * 4
    return gram_in + class_in + onehot_host + out_bytes


def compare_fused(reporter: Reporter, n: int, d: int, c: int, *, seed: int = 0,
                  iters: int = 3) -> dict:
    k1, k2 = jax.random.split(jax.random.key(seed))
    f = jax.random.normal(k1, (n, d))
    y = jax.random.randint(k2, (n,), 0, c)
    tag = f"n{n}|d{d}|C{c}"

    t_unfused = _bench(
        lambda: client_stats(f, y, c, fused=False), iters=iters
    )
    t_fused = _bench(lambda: client_stats(f, y, c, fused=True), iters=iters)
    bytes_unfused = traffic_model_bytes(n, d, c, fused=False)
    bytes_fused = traffic_model_bytes(n, d, c, fused=True)
    flops = stats_flops(n, d, c)
    roof_fused = kernel_roofline(flops, bytes_fused)
    roof_unfused = kernel_roofline(flops, bytes_unfused)

    reporter.add("kernels", tag, "stats_unfused_ms", t_unfused * 1e3)
    reporter.add("kernels", tag, "stats_fused_ms", t_fused * 1e3)
    reporter.add("kernels", tag, "stats_speedup", t_unfused / t_fused)
    reporter.add("kernels", tag, "hbm_bytes_unfused", bytes_unfused)
    reporter.add("kernels", tag, "hbm_bytes_fused", bytes_fused)
    reporter.add(
        "kernels", tag, "hbm_traffic_ratio", bytes_unfused / bytes_fused
    )
    reporter.add(
        "kernels", tag, "roofline_fused_compute_bound",
        float(roof_fused["compute_bound"]),
    )
    return {
        "shape": {"n": n, "d": d, "C": c},
        "backend": jax.default_backend(),
        "unfused_ms": t_unfused * 1e3,
        "fused_ms": t_fused * 1e3,
        "speedup": t_unfused / t_fused,
        "hbm_bytes_unfused": bytes_unfused,
        "hbm_bytes_fused": bytes_fused,
        "hbm_traffic_ratio": bytes_unfused / bytes_fused,
        "roofline": {"fused": roof_fused, "unfused": roof_unfused},
    }


def peak_feature_bytes(
    n, d, c, *, batch=None,
    block_d=tune.DEFAULT_STATS_BLOCK_D, block_n=tune.DEFAULT_STATS_BLOCK_N,
):
    """Modelled peak device bytes the statistics sweep must hold at once.

    Materialized (batch=None): the full padded (n, d) feature matrix plus
    the padded outputs.  Streaming: ONE padded batch plus the running
    padded carry (M = [B-upper | A], N) — constant in n, which is the
    whole point for n ≫ device memory.  The carry layout comes from the
    kernel wrapper itself (``ops._padded_dims``), so the model can't
    drift from what ``stats_carry_init`` actually allocates.
    """
    from repro.kernels.ops import _padded_dims

    d_pad, c_pad = _padded_dims(c, d, block_d)
    carry = (d_pad + c_pad) * d_pad * 4 + c_pad * 4
    rows = n if batch is None else batch
    return _ceil_div(rows, block_n) * block_n * d_pad * 4 + carry


def compare_streaming(
    reporter: Reporter, n: int, d: int, c: int, batch: int, *, seed: int = 0,
    iters: int = 3, production_n: int = 1 << 22,
) -> dict:
    """Streaming pipeline fold vs materialized one-shot sweep.

    Wall-clock is measured at a host-feasible (n, d, C); the peak-memory
    model is additionally evaluated at ``production_n`` (default 4M
    rows) where the materialized path exceeds a TPU core's HBM while the
    streaming footprint stays flat.
    """
    k1, k2 = jax.random.split(jax.random.key(seed))
    f = jax.random.normal(k1, (n, d))
    y = jax.random.randint(k2, (n,), 0, c)
    tag = f"n{n}|d{d}|C{c}|b{batch}"
    pipeline = StatsPipeline(c, backend="fused")

    def streaming():
        return pipeline.from_batches(
            (f[i : i + batch], y[i : i + batch]) for i in range(0, n, batch)
        )

    t_mat = _bench(lambda: client_stats(f, y, c, fused=True), iters=iters)
    t_stream = _bench(lambda: jax.tree_util.tree_leaves(streaming()), iters=iters)

    mem_mat = peak_feature_bytes(n, d, c)
    mem_stream = peak_feature_bytes(n, d, c, batch=batch)
    mem_mat_prod = peak_feature_bytes(production_n, d, c)
    mem_stream_prod = peak_feature_bytes(production_n, d, c, batch=batch)

    # roofline positions: the materialized sweep streams the fused tile
    # traffic once; the streaming fold re-reads the carry every batch
    flops = stats_flops(n, d, c)
    bytes_mat = traffic_model_bytes(n, d, c, fused=True)
    bytes_stream = _ceil_div(n, batch) * traffic_model_bytes(
        batch, d, c, fused=True
    )
    roof_mat = kernel_roofline(flops, bytes_mat)
    roof_stream = kernel_roofline(flops, bytes_stream)

    reporter.add("kernels", tag, "stats_materialized_ms", t_mat * 1e3)
    reporter.add("kernels", tag, "stats_streaming_ms", t_stream * 1e3)
    reporter.add("kernels", tag, "stats_streaming_overhead", t_stream / t_mat)
    reporter.add("kernels", tag, "peak_bytes_materialized", mem_mat)
    reporter.add("kernels", tag, "peak_bytes_streaming", mem_stream)
    reporter.add(
        "kernels", tag, "peak_bytes_ratio_at_production_n",
        mem_mat_prod / mem_stream_prod,
    )
    return {
        "shape": {"n": n, "d": d, "C": c, "batch": batch},
        "backend": jax.default_backend(),
        "materialized_ms": t_mat * 1e3,
        "streaming_ms": t_stream * 1e3,
        "streaming_overhead": t_stream / t_mat,
        "peak_bytes_materialized": mem_mat,
        "peak_bytes_streaming": mem_stream,
        "production_n": production_n,
        "peak_bytes_materialized_at_production_n": mem_mat_prod,
        "peak_bytes_streaming_at_production_n": mem_stream_prod,
        "peak_bytes_ratio_at_production_n": mem_mat_prod / mem_stream_prod,
        "roofline": {"materialized": roof_mat, "streaming": roof_stream},
    }


def compare_crossover(
    reporter: Reporter, n: int, d: int, c: int, *, cache: tune.TuneCache,
    seed: int = 0, iters: int = 3, smoke: bool = False,
) -> dict:
    """jnp vs fused-default vs fused-tuned vs ``backend="auto"`` at (n,d,C).

    Every backend is timed at the PIPELINE level — what a caller of
    ``StatsPipeline.from_arrays`` actually pays, eager overheads
    included.  The tuner's verdict is re-recorded from those
    pipeline-level numbers before timing auto, so the auto measurement
    exercises exactly the dispatch a tuned deployment would see.  The
    acceptance check: auto tracks the better concrete backend within
    noise (``auto_within_5pct``).
    """
    k1, k2 = jax.random.split(jax.random.key(seed))
    f = jax.random.normal(k1, (n, d))
    y = jax.random.randint(k2, (n,), 0, c)
    tag = f"n{n}|d{d}|C{c}"

    empty = tune.TuneCache()  # default blocks, no env cache

    def pipeline_at(backend, use_cache):
        def thunk():
            with tune.using_cache(use_cache):
                return StatsPipeline(c, backend=backend).from_arrays(f, y)

        return thunk

    decision = tune.tune_stats(
        n, d, c, cache=cache, iters=iters, seed=seed,
        candidates=tune.stats_candidates(n, d, smoke=smoke),
    )
    t_jnp, t_default, t_tuned = _interleaved_min(
        [
            pipeline_at("jnp", empty),
            pipeline_at("fused", empty),
            pipeline_at("fused", cache),
        ],
        iters,
    )
    # winner from the pipeline-level truth, so auto dispatches on what
    # callers pay at this shape, not on kernel microtiming
    decision = dataclasses.replace(
        decision,
        winner="jnp" if t_jnp <= t_tuned else "fused",
        jnp_ms=t_jnp * 1e3, fused_ms=t_tuned * 1e3,
        default_ms=t_default * 1e3,
    )
    cache.record(decision)
    # auto vs the backend it should select, as a PAIRED fresh measurement
    winner_thunk = pipeline_at(
        decision.winner, cache if decision.winner == "fused" else empty
    )
    t_best, t_auto = _interleaved_min(
        [winner_thunk, pipeline_at("auto", cache)], iters
    )
    best = min(t_best, t_jnp, t_tuned)
    reporter.add("kernels", tag, "crossover_jnp_ms", t_jnp * 1e3)
    reporter.add("kernels", tag, "crossover_fused_tuned_ms", t_tuned * 1e3)
    reporter.add("kernels", tag, "crossover_auto_ms", t_auto * 1e3)
    reporter.add("kernels", tag, "tuned_vs_default_speedup", t_default / t_tuned)
    reporter.add("kernels", tag, "auto_vs_best", t_auto / best)
    return {
        "shape": {"n": n, "d": d, "C": c},
        "backend": jax.default_backend(),
        "device_kind": tune.device_kind(),
        "jnp_ms": t_jnp * 1e3,
        "fused_default_ms": t_default * 1e3,
        "fused_tuned_ms": t_tuned * 1e3,
        "auto_ms": t_auto * 1e3,
        "winner": decision.winner,
        "tuned_blocks": dict(decision.blocks),
        "tuned_vs_default": t_default / t_tuned,
        "auto_vs_best": t_auto / best,
        "auto_within_5pct": bool(t_auto <= best * 1.05),
    }


def run(
    reporter: Reporter,
    *,
    quick: bool = False,
    seed: int = 0,
    json_path: str | None = "kernel_bench.json",
    smoke: bool = False,
) -> None:
    if smoke:
        shapes = [(1024, 256, 16)]
        cross_shapes = [(256, 128, 16), (1024, 128, 16)]
    elif quick:
        shapes = [(4096, 512, 100)]
        cross_shapes = [(512, 512, 100), (4096, 512, 100)]
    else:
        shapes = [(4096, 512, 100), (8192, 768, 128)]
        cross_shapes = [
            (512, 512, 100), (4096, 512, 100),
            (16384, 512, 100), (65536, 512, 100),
        ]
    iters = 1 if smoke else 3
    results = []
    streaming_results = []
    crossover_results = []
    for n, d, c in shapes:
        k1, k2 = jax.random.split(jax.random.key(seed))
        f = jax.random.normal(k1, (n, d))
        y = jax.random.randint(k2, (n,), 0, c)
        tag = f"n{n}|d{d}|C{c}"

        # oracle wall time on CPU (the TPU kernel itself can't be timed here)
        jitted = jax.jit(lambda f, y: ref.client_stats_ref(f, y, c))
        us = _bench(jitted, f, y, iters=iters) * 1e6
        reporter.add("kernels", tag, "stats_oracle_us", us)

        # arithmetic intensity: 2nd² + 2nCd FLOPs over one feature stream
        flops = stats_flops(n, d, c)
        bytes_ = 4.0 * (n * d + d * d + c * d)
        ai = flops / bytes_
        reporter.add("kernels", tag, "stats_flops", flops)
        reporter.add("kernels", tag, "stats_arith_intensity", ai)
        # TPU v5e ridge point: compute-bound iff AI > peak/bw
        ridge = PEAK_FLOPS / HBM_BW
        reporter.add("kernels", tag, "stats_compute_bound", float(ai > ridge))

        # fused vs the seed two-kernel formulation: measured + modelled
        results.append(compare_fused(reporter, n, d, c, seed=seed, iters=iters))

        # streaming pipeline fold vs materialized one-shot at the same shape
        streaming_results.append(
            compare_streaming(reporter, n, d, c,
                              batch=max(n // 8, tune.DEFAULT_STATS_BLOCK_N),
                              seed=seed, iters=iters)
        )

        # correctness at bench scale (kernel vs oracle)
        A, B, N = client_stats(f, y, c)
        A0, B0, N0 = ref.client_stats_ref(f, y, c)
        err = max(
            float(jnp.max(jnp.abs(A - A0))),
            float(jnp.max(jnp.abs(B - B0))),
            float(jnp.max(jnp.abs(N - N0))),
        )
        reporter.add("kernels", tag, "stats_kernel_max_err", err)

    # jnp↔fused crossover: where does each backend win, does tuning move
    # the fused time, and does backend="auto" track the better of the two?
    cross_cache = tune.TuneCache()
    for n, d, c in cross_shapes:
        crossover_results.append(
            compare_crossover(reporter, n, d, c, cache=cross_cache,
                              seed=seed, iters=iters, smoke=smoke)
        )

    if json_path:
        with open(json_path, "w") as fh:
            json.dump(
                {
                    "fused_vs_unfused": results,
                    "streaming_vs_materialized": streaming_results,
                    "crossover": crossover_results,
                },
                fh,
                indent=2,
            )
        print(f"# wrote {json_path} ({len(results)} shapes)")


if __name__ == "__main__":
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument(
        "--smoke", action="store_true",
        help="tiny shapes / single iteration — CI's regression tripwire",
    )
    p.add_argument("--quick", action="store_true", help="reduced shape sweep")
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args()
    run(Reporter(), quick=args.quick, seed=args.seed, smoke=args.smoke)
