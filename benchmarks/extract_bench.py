"""Extractor benchmark: backbone-forward cost vs statistics-fold cost.

The Extractor protocol turns the FedCGS round into a two-stage
streaming pipeline — zoo-config forward pass, then (A, B, N) fold —
and this bench answers the capacity-planning question that split
raises: WHERE does the round's wall-clock go?  For each config the
same token stream is timed three ways:

- ``forward``  — extractor-forward alone (pooled features, jit warm);
- ``fold``     — the statistics fold alone over pre-materialized
  features (the pre-extractor pipeline's whole cost);
- ``streamed`` — the fused path (`StatsPipeline(extractor=)`), one
  extract→fold step per batch, what `fedcgs-extract` actually runs.

Rows land in ``extract_bench.json`` next to ``kernel_bench.json`` /
``serve_bench.json`` (CI uploads all three).  On every platform the
forward dominates at transformer scale — the fold's share is the
overhead the paper's "one extra statistics sweep" costs on top of
inference the clients were running anyway.

Standalone:  PYTHONPATH=src python -m benchmarks.extract_bench [--smoke]

``--smoke`` (the CI step) is whisper_tiny only, tiny batches — a
tripwire for the extractor stack plus the JSON emission.
"""

from __future__ import annotations

import argparse
import json
from typing import Dict, List

import jax
import numpy as np

from benchmarks.common import Reporter
from repro.core.stats_pipeline import StatsPipeline
from repro.fl.extractors import ModelExtractor, synthetic_token_clients
from repro.timing import timed

SMOKE_CONFIGS = ["whisper-tiny"]
QUICK_CONFIGS = ["whisper-tiny", "gemma-2b"]
FULL_CONFIGS = ["whisper-tiny", "gemma-2b", "mamba2-2.7b", "qwen2-moe-a2.7b"]


def bench_config(
    name: str,
    *,
    batches: int,
    batch: int,
    seq_len: int,
    seed: int,
    backend: str = "jnp",
) -> Dict[str, float]:
    ext = ModelExtractor(name, pooling="tokens", seed=seed)
    cfg = ext.cfg
    stream = synthetic_token_clients(
        cfg, clients=1, batches_per_client=batches,
        batch=batch, seq_len=seq_len, seed=seed,
    )[0]
    rows = batches * batch * seq_len

    # warm every trace first: the bench measures steady state, not jit
    np.asarray(ext.features(stream[0][0]))
    feats = [(ext.features(t), y.reshape(-1)) for t, y in stream]
    pipe = StatsPipeline(cfg.vocab_size, backend=backend)
    streamed = pipe.replace(extractor=ext)
    np.asarray(pipe.from_batches(iter(feats)).A)
    np.asarray(streamed.from_batches(iter(stream)).A)

    _, dt_fwd = timed(lambda: [
        jax.block_until_ready(ext.features(t)) for t, _ in stream
    ])
    _, dt_fold = timed(
        lambda: jax.block_until_ready(pipe.from_batches(iter(feats)).A)
    )
    _, dt_streamed = timed(
        lambda: jax.block_until_ready(streamed.from_batches(iter(stream)).A)
    )
    return {
        "config": name,
        "feature_dim": ext.feature_dim,
        "num_classes": cfg.vocab_size,
        "rows": rows,
        "forward_ms": dt_fwd * 1e3,
        "fold_ms": dt_fold * 1e3,
        "streamed_ms": dt_streamed * 1e3,
        "fold_share": dt_fold / max(dt_fwd + dt_fold, 1e-12),
        "rows_per_s_streamed": rows / max(dt_streamed, 1e-12),
    }


def run(
    reporter: Reporter,
    *,
    quick: bool = False,
    seed: int = 0,
    json_path: str | None = "extract_bench.json",
    smoke: bool = False,
) -> None:
    if smoke:
        configs, batches, batch, seq_len = SMOKE_CONFIGS, 2, 2, 8
    elif quick:
        configs, batches, batch, seq_len = QUICK_CONFIGS, 2, 4, 16
    else:
        configs, batches, batch, seq_len = FULL_CONFIGS, 4, 8, 32
    results: List[Dict[str, float]] = []
    for name in configs:
        row = bench_config(
            name, batches=batches, batch=batch, seq_len=seq_len, seed=seed,
        )
        results.append(row)
        for metric in (
            "forward_ms", "fold_ms", "streamed_ms",
            "fold_share", "rows_per_s_streamed",
        ):
            reporter.add("extract", name, metric, row[metric])
    if json_path:
        with open(json_path, "w") as fh:
            json.dump(
                {
                    "config": {
                        "batches": batches,
                        "batch": batch,
                        "seq_len": seq_len,
                        "pooling": "tokens",
                        "mode": "smoke" if smoke else ("quick" if quick else "full"),
                    },
                    "results": results,
                },
                fh,
                indent=2,
            )
        print(f"# wrote {json_path} ({len(results)} configs)")


if __name__ == "__main__":
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument(
        "--smoke", action="store_true",
        help="whisper_tiny only, tiny sizes — CI's regression tripwire",
    )
    p.add_argument("--quick", action="store_true", help="reduced config set")
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args()
    run(Reporter(), quick=args.quick, seed=args.seed, smoke=args.smoke)
