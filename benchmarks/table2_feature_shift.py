"""Paper Table 2 analogue: feature-shift / domain generalization.

4 synthetic domains (PACS-style); train on 3 (5 clients each = 15
clients), evaluate on the held-out target; rotate the target.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import Reporter
from repro.data import SyntheticSpec, domain_partition, make_domain_shift_data
from repro.fl.backbone import make_backbone
from repro.fl.baselines import run_fedpft, run_dense
from repro.fl.fedcgs import run_fedcgs

DOMAINS = ["P", "A", "C", "S"]


def run(reporter: Reporter, *, quick: bool = False, seed: int = 0) -> None:
    spec = SyntheticSpec(
        num_classes=7, input_dim=64, samples_per_class=80 if quick else 200,
        class_sep=2.0, modes_per_class=2, seed=77,
    )
    domains = make_domain_shift_data(spec, num_domains=4, domain_strength=0.8)
    domains = [(np.asarray(x), np.asarray(y)) for x, y in domains]
    backbone = make_backbone("resnet18-like", spec.input_dim)
    epochs = 10 if quick else 30

    fedcgs_accs, fedpft_accs = [], []
    for target in range(4):
        sources = [d for i, d in enumerate(domains) if i != target]
        parts = domain_partition([len(d[0]) for d in sources], 5, seed=seed)
        clients = [
            (sources[dom][0][idx], sources[dom][1][idx]) for dom, idx in parts
        ]
        test = domains[target]
        tag = f"target={DOMAINS[target]}"

        acc = run_fedcgs(
            backbone, clients, spec.num_classes, test_data=test
        ).accuracy
        reporter.add("table2", tag, "FedCGS", acc)
        fedcgs_accs.append(acc)

        acc = run_fedpft(
            backbone, clients, spec.num_classes, test,
            k_components=10, epochs=epochs, seed=seed,
        )
        reporter.add("table2", tag, "FedPFT", acc)
        fedpft_accs.append(acc)

        if not quick:
            acc = run_dense(
                backbone, clients, spec.num_classes, test,
                local_epochs=epochs, gen_epochs=15, distill_epochs=20, seed=seed,
            )
            reporter.add("table2", tag, "DENSE", acc)

    reporter.add("table2", "avg", "FedCGS", float(np.mean(fedcgs_accs)))
    reporter.add("table2", "avg", "FedPFT", float(np.mean(fedpft_accs)))
