"""Shared benchmark world: synthetic datasets standing in for the paper's
CIFAR10/CIFAR100/SVHN (label shift) and PACS/OfficeHome (feature shift).

Absolute accuracies are NOT comparable to the paper (no ImageNet
weights offline); orderings and invariances are (DESIGN.md §2).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Tuple

import numpy as np

from repro.data import SyntheticSpec, make_classification_data
from repro.fl.backbone import Backbone, make_backbone
from repro.timing import timed

Dataset = Tuple[np.ndarray, np.ndarray]


@dataclasses.dataclass
class World:
    name: str
    spec: SyntheticSpec
    train: Dataset
    test: Dataset
    backbone: Backbone


def make_world(name: str, *, backbone: str = "resnet18-like", quick: bool = False) -> World:
    presets = {
        # name:        (C,  samples/class, sep, modes)
        "synth10": (10, 150 if quick else 400, 1.6, 3),
        "synth100": (100, 30 if quick else 80, 2.2, 2),
        "synth-svhn": (10, 150 if quick else 400, 1.2, 4),
    }
    c, spc, sep, modes = presets[name]
    spec = SyntheticSpec(
        num_classes=c, input_dim=64, samples_per_class=spc,
        class_sep=sep, modes_per_class=modes, seed=hash(name) % 10000,
    )
    x, y = make_classification_data(spec, seed=spec.seed + 1)
    xt, yt = make_classification_data(spec, seed=spec.seed + 2)
    return World(
        name=name, spec=spec,
        train=(np.asarray(x), np.asarray(y)),
        test=(np.asarray(xt), np.asarray(yt)),
        backbone=make_backbone(backbone, spec.input_dim),
    )


class Reporter:
    """Collects (bench, config, metric, value) rows; prints CSV."""

    def __init__(self):
        self.rows: List[Tuple[str, str, str, float]] = []

    def add(self, bench: str, config: str, metric: str, value: float) -> None:
        self.rows.append((bench, config, metric, float(value)))
        print(f"{bench},{config},{metric},{value:.6g}", flush=True)

    def timeit(self, bench: str, config: str, fn: Callable, *args, **kwargs):
        out, dt = timed(fn, *args, **kwargs)
        self.add(bench, config, "wall_s", dt)
        return out
