"""Ablations beyond the paper's tables.

1. SecureAgg mask scale vs. statistics exactness: pairwise masks cancel
   only up to float32 associativity, so privacy (bigger masks) trades
   directly against the paper's Table-4 exactness. The paper never
   quantifies this; we sweep mask_scale over 6 decades.
2. GNB ridge sensitivity: the head's single numerical knob.
3. Backbone ladder (paper Table 5 analogue): stronger frozen features →
   better FedCGS accuracy, same statistics machinery.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import Reporter, make_world
from repro.core.classifier import gnb_head
from repro.core.secure_agg import secure_sum
from repro.core.statistics import (
    centralized_statistics,
    derive_global,
    statistics_deviation,
)
from repro.data import dirichlet_partition
from repro.fl.backbone import BACKBONES, make_backbone
from repro.fl.fedcgs import client_stats_pass, run_fedcgs


def run(reporter: Reporter, *, quick: bool = False, seed: int = 0) -> None:
    world = make_world("synth10", quick=True)
    x, y = world.train
    c = world.spec.num_classes
    parts = dirichlet_partition(y, 10, 0.1, seed=seed)
    clients = [(x[p], y[p]) for p in parts]

    feats = world.backbone.features(jnp.asarray(x))
    ref = centralized_statistics(feats, jnp.asarray(y), c)
    test_feats = world.backbone.features(jnp.asarray(world.test[0]))
    yt = jnp.asarray(world.test[1])

    # --- 1. mask scale sweep -------------------------------------------
    stats_list = [client_stats_pass(world.backbone, cx, cy, c) for cx, cy in clients]
    for scale in (0.0, 1e1, 1e3, 1e5, 1e7):
        if scale == 0.0:
            agg = stats_list[0]
            for s in stats_list[1:]:
                agg = agg + s
        else:
            agg = secure_sum(stats_list, mask_scale=scale)
        g = derive_global(agg)
        dmu, dsig = statistics_deviation(g, ref)
        acc = float(gnb_head(g).accuracy(test_feats, yt))
        tag = f"mask{scale:g}"
        reporter.add("ablate_secagg", tag, "delta_mu", float(dmu))
        reporter.add("ablate_secagg", tag, "delta_sigma", float(dsig))
        reporter.add("ablate_secagg", tag, "acc", acc)

    # --- 2. ridge sensitivity ------------------------------------------
    for ridge in (1e-8, 1e-6, 1e-4, 1e-2, 1.0):
        head = gnb_head(ref, ridge=ridge)
        acc = float(head.accuracy(test_feats, yt))
        reporter.add("ablate_ridge", f"r{ridge:g}", "acc", acc)

    # --- 3. backbone ladder (paper Table 5 analogue) -------------------
    for name in BACKBONES:
        bb = make_backbone(name, world.spec.input_dim)
        res = run_fedcgs(bb, clients, c, test_data=world.test)
        reporter.add("ablate_backbone", name, "acc", res.accuracy)
        reporter.add("ablate_backbone", name, "upload_floats", res.uploaded_floats_per_client)
