"""Ablations beyond the paper's tables.

1. SecureAgg mask scale vs. statistics exactness: pairwise masks cancel
   only up to float32 associativity, so privacy (bigger masks) trades
   directly against the paper's Table-4 exactness. The paper never
   quantifies this; we sweep mask_scale over 6 decades.
2. GNB ridge sensitivity: the head's single numerical knob.
3. Backbone ladder (paper Table 5 analogue): stronger frozen features →
   better FedCGS accuracy, same statistics machinery.
4. Dropout-recovery cost curve: K=16 / t=9 rounds with 0..K−t clients
   dropped — wall-clock of masking + Shamir recovery and the recovered
   sum's deviation from the plain survivor sum, per dropout rate.  The
   curve is emitted to ``secureagg_dropout.json`` (CSV rows too).
"""

from __future__ import annotations

import json
import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import Reporter, make_world
from repro.core.classifier import gnb_head
from repro.core.secure_agg import (
    masked_survivor_views,
    recover_round,
    secure_sum,
    setup_round,
)
from repro.core.statistics import (
    aggregate,
    centralized_statistics,
    derive_global,
    statistics_deviation,
)
from repro.data import dirichlet_partition
from repro.fl.backbone import BACKBONES, make_backbone
from repro.fl.fedcgs import client_stats_pass, run_fedcgs


def _dropout_recovery_curve(
    reporter: Reporter,
    client_stats,
    *,
    threshold: int,
    base_seed: int,
    mask_scale: float = 10.0,
    json_path: str | None = "secureagg_dropout.json",
) -> None:
    """Recovery cost + exactness vs. dropout rate for one K-client round."""
    k = len(client_stats)
    setup = setup_round(k, threshold, base_seed=base_seed)
    rng = np.random.default_rng(base_seed)
    curve = []
    for n_drop in range(0, k - threshold + 1):
        dropped = sorted(rng.choice(k, size=n_drop, replace=False).tolist())
        survivors = [i for i in range(k) if i not in set(dropped)]
        plain = aggregate([client_stats[i] for i in survivors])

        t0 = time.perf_counter()
        views = masked_survivor_views(
            client_stats, survivors, k,
            base_seed=base_seed, mask_scale=mask_scale,
        )
        jnp.asarray(views[-1].A).block_until_ready()
        mask_s = time.perf_counter() - t0

        t0 = time.perf_counter()
        recovered = recover_round(
            views, survivors, setup, mask_scale=mask_scale
        )
        jnp.asarray(recovered.A).block_until_ready()
        recover_s = time.perf_counter() - t0

        err = float(
            jnp.linalg.norm(recovered.A - plain.A)
            / (jnp.linalg.norm(plain.A) + 1e-12)
        )
        rate = n_drop / k
        tag = f"drop{n_drop}"
        reporter.add("ablate_dropout", tag, "dropout_rate", rate)
        reporter.add("ablate_dropout", tag, "mask_wall_s", mask_s)
        reporter.add("ablate_dropout", tag, "recover_wall_s", recover_s)
        reporter.add("ablate_dropout", tag, "rel_err_A", err)
        curve.append(
            {
                "num_dropped": n_drop,
                "dropout_rate": rate,
                "dropped": dropped,
                "mask_wall_s": mask_s,
                "recover_wall_s": recover_s,
                "rel_err_A": err,
            }
        )
    if json_path:
        with open(json_path, "w") as fh:
            json.dump(
                {
                    "num_clients": k,
                    "threshold": threshold,
                    "mask_scale": mask_scale,
                    "curve": curve,
                },
                fh,
                indent=2,
            )
        print(f"# wrote {json_path} ({len(curve)} dropout rates)")


def run(reporter: Reporter, *, quick: bool = False, seed: int = 0) -> None:
    world = make_world("synth10", quick=True)
    x, y = world.train
    c = world.spec.num_classes
    parts = dirichlet_partition(y, 10, 0.1, seed=seed)
    clients = [(x[p], y[p]) for p in parts]

    feats = world.backbone.features(jnp.asarray(x))
    ref = centralized_statistics(feats, jnp.asarray(y), c)
    test_feats = world.backbone.features(jnp.asarray(world.test[0]))
    yt = jnp.asarray(world.test[1])

    # --- 1. mask scale sweep -------------------------------------------
    stats_list = [client_stats_pass(world.backbone, cx, cy, c) for cx, cy in clients]
    for scale in (0.0, 1e1, 1e3, 1e5, 1e7):
        if scale == 0.0:
            agg = stats_list[0]
            for s in stats_list[1:]:
                agg = agg + s
        else:
            agg = secure_sum(stats_list, mask_scale=scale)
        g = derive_global(agg)
        dmu, dsig = statistics_deviation(g, ref)
        acc = float(gnb_head(g).accuracy(test_feats, yt))
        tag = f"mask{scale:g}"
        reporter.add("ablate_secagg", tag, "delta_mu", float(dmu))
        reporter.add("ablate_secagg", tag, "delta_sigma", float(dsig))
        reporter.add("ablate_secagg", tag, "acc", acc)

    # --- 1b. dropout-recovery cost curve (K=16, t=9) -------------------
    parts16 = dirichlet_partition(y, 16, 0.3, seed=seed + 1)
    stats16 = [
        client_stats_pass(world.backbone, x[p], y[p], c) for p in parts16
    ]
    _dropout_recovery_curve(
        reporter, stats16, threshold=9, base_seed=seed,
    )

    # --- 2. ridge sensitivity ------------------------------------------
    for ridge in (1e-8, 1e-6, 1e-4, 1e-2, 1.0):
        head = gnb_head(ref, ridge=ridge)
        acc = float(head.accuracy(test_feats, yt))
        reporter.add("ablate_ridge", f"r{ridge:g}", "acc", acc)

    # --- 3. backbone ladder (paper Table 5 analogue) -------------------
    for name in BACKBONES:
        bb = make_backbone(name, world.spec.input_dim)
        res = run_fedcgs(bb, clients, c, test_data=world.test)
        reporter.add("ablate_backbone", name, "acc", res.accuracy)
        reporter.add("ablate_backbone", name, "upload_floats", res.uploaded_floats_per_client)
